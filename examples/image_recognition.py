"""Paper §4.2 at CPU scale: residual net vs the SAME network as a
continuous-depth Neural ODE trained with MALI.

    PYTHONPATH=src python examples/image_recognition.py [--steps 400]

Synthetic 8x8 3-class "images" (license-free stand-in for Cifar; the paper's
mechanism — y = x + f(x) vs y = x + int_0^1 f(z)dt with SHARED f — is
architecture-faithful). Reports test accuracy for (a) the residual baseline,
(b) Neural-ODE+MALI, and (c) solver-invariance of (b) at inference.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import odeint

D = 64           # flattened 8x8 image
N_CLASS = 3
HIDDEN = 64


_PROTOS = np.random.default_rng(12345).standard_normal((N_CLASS, D)) * 0.6


def make_data(n, seed):
    """Three gaussian-blob classes (FIXED means shared by train/test) with
    pixel noise; hard enough that the head alone can't solve it linearly."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, N_CLASS, n)
    x = _PROTOS[y] + rng.standard_normal((n, D)) * 0.8
    return jnp.asarray(x, jnp.float32), jnp.asarray(y.astype(np.int32))


def init_params(key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = lambda *sh: 0.3 * jax.random.normal(k1, sh)
    return {
        "f": {"w1": 0.3 * jax.random.normal(k1, (D, HIDDEN)),
              "b1": jnp.zeros((HIDDEN,)),
              "w2": 0.3 * jax.random.normal(k2, (HIDDEN, D)),
              "b2": jnp.zeros((D,))},
        "norm": jnp.ones((D,)),
        "head": 0.3 * jax.random.normal(k3, (D, N_CLASS)),
        "bh": jnp.zeros((N_CLASS,)),
    }


def field(fp, z, t):
    """The shared residual function f(z) (t-independent, like a ResNet
    block)."""
    h = jnp.tanh(z @ fp["w1"] + fp["b1"])
    return h @ fp["w2"] + fp["b2"]


def forward(params, x, mode, solver="alf", n_steps=4):
    if mode == "resnet":                       # y = x + f(x)
        z = x + field(params["f"], x, 0.0)
    else:                                      # y = x + int_0^1 f dt
        method = "mali" if solver == "alf" else "naive"
        z = odeint(field, params["f"], x, 0.0, 1.0, method=method,
                   solver=solver, n_steps=n_steps)
    z = z * params["norm"]
    return z @ params["head"] + params["bh"]


def train(params, x, y, mode, steps, lr=3e-3):
    def loss_fn(p):
        logp = jax.nn.log_softmax(forward(p, x, mode))
        return -jnp.take_along_axis(logp, y[:, None], 1).mean()

    tm = jax.tree_util.tree_map
    m = tm(jnp.zeros_like, params)
    v = tm(jnp.zeros_like, params)

    @jax.jit
    def step(carry, i):
        p, m, v = carry
        l, g = jax.value_and_grad(loss_fn)(p)
        m = tm(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = tm(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        t = i + 1.0
        p = tm(lambda pp, mm, vv: pp - lr * (mm / (1 - 0.9 ** t)) /
               (jnp.sqrt(vv / (1 - 0.999 ** t)) + 1e-8), p, m, v)
        return (p, m, v), l

    (params, _, _), losses = jax.lax.scan(
        step, (params, m, v), jnp.arange(steps, dtype=jnp.float32))
    return params, float(losses[-1])


def accuracy(params, x, y, mode, **kw):
    return float((forward(params, x, mode, **kw).argmax(-1) == y).mean())


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()

    x, y = make_data(2048, seed=0)
    xt, yt = make_data(1024, seed=1)
    p0 = init_params(jax.random.PRNGKey(0))

    res, lr_loss = train(p0, x, y, "resnet", args.steps)
    print(f"resnet      train_loss={lr_loss:.4f} "
          f"test_acc={accuracy(res, xt, yt, 'resnet'):.3f}")

    node, node_loss = train(p0, x, y, "node", args.steps)
    print(f"node(MALI)  train_loss={node_loss:.4f} "
          f"test_acc={accuracy(node, xt, yt, 'node'):.3f}")

    # solver invariance (paper Table 2): same weights, different solvers
    for solver, n in (("alf", 4), ("alf", 8), ("euler", 8), ("rk4", 4),
                      ("dopri5", 4)):
        a = accuracy(node, xt, yt, "node", solver=solver, n_steps=n)
        print(f"  invariance: solver={solver:7s} n={n}  test_acc={a:.3f}")


if __name__ == "__main__":
    main()
