"""End-to-end driver: train a continuous-depth LM with MALI through the
repro.train subsystem (config -> Trainer -> checkpoint -> fault recovery),
then serve from the trained weights.

    PYTHONPATH=src python examples/lm_continuous_depth.py [--steps 120]

This is the paper's §4.2 protocol transplanted to the LM substrate: the
SAME per-block dynamics f is trained (a) discrete (y = x + f(x), the
"ResNet") and (b) continuous (y = x + int f dt, MALI) — losses should land
in the same regime at equal parameter count; (b) runs at O(1) activation
memory in ODE steps. The third phase kills the run mid-step and lets the
Trainer recover from its checkpoint: the resumed loss trace matches the
uninterrupted one step-for-step (resumable MALI state).
"""
import argparse
import tempfile

from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()
    every = max(args.steps // 3, 1)

    with tempfile.TemporaryDirectory() as d:
        print("=== continuous-depth (MALI, 2 ODE steps/block) ===")
        cfg = TrainerConfig(arch=args.arch, smoke=True, ode=True, ode_steps=2,
                            steps=args.steps, global_batch=8, seq_len=64,
                            ckpt_dir=d + "/node", ckpt_every=every)
        clean = Trainer(cfg)
        assert clean.train() == args.steps

        print("=== discrete baseline (same params, ode off) ===")
        Trainer(TrainerConfig(arch=args.arch, smoke=True, ode=False,
                              steps=args.steps, global_batch=8, seq_len=64,
                              ckpt_dir=d + "/discrete",
                              ckpt_every=every)).train()

        print("=== fault-injected recovery (kill mid-run, resume) ===")
        crash_at = {"step": args.steps // 2, "armed": True}

        def hook(step):
            if crash_at["armed"] and step == crash_at["step"]:
                crash_at["armed"] = False
                raise RuntimeError("injected node failure")

        faulted = Trainer(
            TrainerConfig(arch=args.arch, smoke=True, ode=True, ode_steps=2,
                          steps=args.steps, global_batch=8, seq_len=64,
                          ckpt_dir=d + "/faulted", ckpt_every=every),
            step_hook=hook)
        assert faulted.train() == args.steps
        assert faulted.loss_trace() == clean.loss_trace(), \
            "recovered run must reproduce the uninterrupted loss trace"
        print("loss-trace continuity after recovery: OK")

    print("=== serve from a continuous-depth model ===")
    from repro.launch.serve import serve
    serve(args.arch, smoke=True, ode=True, prompt_len=16, decode_tokens=8,
          batch=2)


if __name__ == "__main__":
    main()
