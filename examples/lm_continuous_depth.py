"""End-to-end driver: train a continuous-depth LM with MALI through the
full production path (config -> sharded step -> checkpoint -> resume), then
serve from the trained weights.

    PYTHONPATH=src python examples/lm_continuous_depth.py [--steps 120]

This is the paper's §4.2 protocol transplanted to the LM substrate: the
SAME per-block dynamics f is trained (a) discrete (y = x + f(x), the
"ResNet") and (b) continuous (y = x + int f dt, MALI) — losses should land
in the same regime at equal parameter count; (b) runs at O(1) activation
memory in ODE steps.
"""
import argparse
import tempfile

from repro.launch.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        print("=== continuous-depth (MALI, 2 ODE steps/block) ===")
        tc = TrainConfig(arch=args.arch, smoke=True, ode=True, ode_steps=2,
                         steps=args.steps, global_batch=8, seq_len=64,
                         ckpt_dir=d + "/node", ckpt_every=max(args.steps // 3, 1))
        final = train(tc)
        assert final == args.steps

        print("=== discrete baseline (same params, ode off) ===")
        tc2 = TrainConfig(arch=args.arch, smoke=True, ode=False,
                          steps=args.steps, global_batch=8, seq_len=64,
                          ckpt_dir=d + "/discrete",
                          ckpt_every=max(args.steps // 3, 1))
        train(tc2)

        print("=== resume-from-checkpoint path (fault-tolerance) ===")
        tc3 = TrainConfig(arch=args.arch, smoke=True, ode=True, ode_steps=2,
                          steps=args.steps + 20, global_batch=8, seq_len=64,
                          ckpt_dir=d + "/node",
                          ckpt_every=max(args.steps // 3, 1))
        # restore_latest finds the step-`steps` checkpoint and continues
        train(tc3)

    print("=== serve from a continuous-depth model ===")
    from repro.launch.serve import serve
    serve(args.arch, smoke=True, ode=True, prompt_len=16, decode_tokens=8,
          batch=2)


if __name__ == "__main__":
    main()
