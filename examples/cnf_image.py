"""Paper §4.4 at image scale: FFJORD-class CNF on MNIST-shaped data,
trained with MALI + ALF(backend='pallas') under Sharded batching.

    PYTHONPATH=src python examples/cnf_image.py [--steps 20] [--n-steps 8]
                                                [--batch 16] [--hidden 64]

The flow integrates the 784-dimensional augmented state with the
Hutchinson trace estimator (one JVP per state, fixed probe per solve) and
reports bits/dim. The Sharded batching axis shard_maps the solve over the
host mesh's 'data' axis — the same fleet semantics the serving path uses —
and MALI keeps the backward residual at O(T * N_z) regardless of the step
count (benchmarks/cnf_bits_dim.py turns that into an AOT-measured
memory-vs-depth proof).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.cnf import CNF, Hutchinson, bits_per_dim, cnf_loss
from repro.core import ALF, ConstantSteps, Lockstep, MALI, Sharded
from repro.data import DataConfig, make_image_batch
from repro.launch.mesh import make_host_mesh
from repro.models import init_mlp_vfield, mlp_vfield

DIM = 28 * 28
KINETIC_REG = 0.05  # the paper's §4.4 image-scale coefficient


def dequantized_batch(dcfg, step, rng):
    """256-level quantized images + uniform dequantization noise — the
    standard continuous-likelihood protocol behind bits/dim."""
    img = make_image_batch(dcfg, step)["image"]
    return jnp.asarray(img + rng.uniform(0, 1.0 / 256.0, img.shape),
                       jnp.float32)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--n-steps", type=int, default=8,
                    help="ODE steps per solve (h = 1/n)")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=64)
    args = ap.parse_args()

    mesh = make_host_mesh()
    n_data = mesh.shape["data"]
    batch = args.batch - args.batch % n_data or n_data
    dcfg = DataConfig(seed=0, global_batch=batch)
    rng = np.random.default_rng(0)

    flow = CNF(mlp_vfield, dim=DIM, estimator=Hutchinson())
    solver = ALF(backend="pallas")
    controller = ConstantSteps(args.n_steps)
    batching = Sharded(axis="data", inner=Lockstep())
    fp = init_mlp_vfield(jax.random.PRNGKey(0), DIM, hidden=args.hidden,
                         depth=2)

    def loss_fn(p, x, key):
        res = flow.log_prob(p, x, key, solver=solver, controller=controller,
                            gradient=MALI(), batching=batching)
        return cnf_loss(res, kinetic_reg=KINETIC_REG), res

    tm = jax.tree_util.tree_map
    opt = (tm(jnp.zeros_like, fp), tm(jnp.zeros_like, fp))

    @jax.jit
    def train_step(p, opt, x, key, i):
        (l, res), g = jax.value_and_grad(loss_fn, has_aux=True)(p, x, key)
        m, v = opt
        m = tm(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = tm(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        t = i + 1.0
        p = tm(lambda pp, mm, vv: pp - 1e-3 * (mm / (1 - 0.9 ** t)) /
               (jnp.sqrt(vv / (1 - 0.999 ** t)) + 1e-8), p, m, v)
        return p, (m, v), l, res

    bpds = []
    with mesh:
        for i in range(args.steps):
            x = dequantized_batch(dcfg, i, rng)
            key = jax.random.PRNGKey(i)
            fp, opt, l, res = train_step(fp, opt, x, key,
                                         jnp.asarray(i, jnp.float32))
            bpd = float(bits_per_dim(res, DIM))
            bpds.append(bpd)
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:3d}  loss={float(l):9.3f}  "
                      f"bits/dim={bpd:7.3f}")
        print(f"residual bytes (MALI, n_steps={args.n_steps}): "
              f"{int(res.solution.stats.residual_bytes)} "
              "(O(T * N_z): constant in the step count)")

    assert np.isfinite(bpds).all(), "training diverged"
    assert bpds[-1] < bpds[0], "bits/dim must improve over training"
    print(f"bits/dim first={bpds[0]:.3f} last={bpds[-1]:.3f}  OK")


if __name__ == "__main__":
    main()
