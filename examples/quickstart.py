"""Quickstart: the MALI integrator in ~70 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Integrate an ODE with the composable `solve()` API
   (solver x step-controller x gradient-method x saveat -> Solution).
2. Take gradients through it with each method (Table 1 of the paper) —
   a method swap is a one-argument change.
3. Show MALI's two properties: constant memory and reverse accuracy.
"""
import math

import jax
import jax.numpy as jnp

from repro.core import (ACA, ALF, AdaptiveController, Backsolve,
                        ConstantSteps, Dopri5, HeunEuler, Lockstep, MALI,
                        Naive, PerSample, SaveAt, odeint, solve)


# dz/dt = alpha * z  — the paper's Sec 4.1 toy with analytic solution.
def f(params, z, t):
    return params["alpha"] * z


params = {"alpha": jnp.float32(0.5)}
z0 = jnp.float32(1.3)
T = 1.0

# ---- 1. forward integration --------------------------------------------
sol = solve(f, params, z0, 0.0, T, solver=ALF(eta=1.0),
            controller=ConstantSteps(16), gradient=MALI())
print(f"z(T) numeric {float(sol.ys):.6f} vs analytic "
      f"{1.3 * math.exp(0.5 * T):.6f}")
print(f"stats: {int(sol.stats.n_accepted)} steps, "
      f"{int(sol.stats.n_fevals)} f-evals, "
      f"{sol.stats.residual_bytes} residual bytes")

# adaptive stepping + the whole trajectory is a SaveAt/controller swap:
traj = solve(f, params, z0, solver=ALF(),
             controller=AdaptiveController(rtol=1e-4, atol=1e-5),
             gradient=MALI(), saveat=SaveAt(ts=jnp.linspace(0, T, 5)))
print("trajectory", [f"{v:.4f}" for v in traj.ys])

# the legacy string facade builds exactly these objects:
assert float(odeint(f, params, z0, 0.0, T, method="mali",
                    n_steps=16)) == float(sol.ys)

# ---- 2. gradients through the integrator, all four methods --------------
exact_dalpha = 2 * T * 1.3 ** 2 * math.exp(2 * 0.5 * T)

CONFIGS = (("mali", MALI(), ALF()), ("naive", Naive(), ALF()),
           ("aca", ACA(), HeunEuler()), ("adjoint", Backsolve(), Dopri5()))


def loss(p, z, gradient, solver):
    return solve(f, p, z, 0.0, T, solver=solver,
                 controller=ConstantSteps(16), gradient=gradient).ys ** 2


for name, gradient, solver in CONFIGS:
    g = jax.grad(loss)(params, z0, gradient, solver)
    err = abs(float(g["alpha"]) - exact_dalpha)
    print(f"{name:8s} dL/dalpha = {float(g['alpha']):.5f} "
          f"(analytic {exact_dalpha:.5f}, err {err:.2e})")

# ---- 3a. constant memory: residual bytes flat in n_steps ----------------
big = {"w": jnp.ones((65536,), jnp.float32)}


def big_f(p, z, t):
    return jnp.tanh(p["w"] * z)


def big_loss(p, z, gradient, n):
    return jnp.sum(solve(big_f, p, z, 0.0, 1.0, solver=ALF(),
                         controller=ConstantSteps(n),
                         gradient=gradient).ys ** 2)


for name, gradient in (("mali", MALI()), ("naive", Naive())):
    sizes = []
    for n in (8, 64):
        c = jax.jit(jax.grad(big_loss, argnums=0),
                    static_argnums=(2, 3)).lower(
            big, jnp.ones((65536,)), gradient, n).compile()
        sizes.append(c.memory_analysis().temp_size_in_bytes)
    print(f"{name:8s} backward temp bytes: n=8 -> {sizes[0]:,}  "
          f"n=64 -> {sizes[1]:,}  (x{sizes[1] / sizes[0]:.1f})")

# ---- 4. batching is an explicit axis ------------------------------------
# A batch of initial states with per-sample stiffness: Lockstep() (one
# shared controller decision — the classic concatenated odeint) vs
# PerSample() (each row adapts independently; finished rows ride as no-ops).
zb = {"y": jnp.ones((8, 1)),
      "lam": jnp.logspace(-0.3, 1.5, 8)[:, None]}


def decay(p, z, t):
    return {"y": -z["lam"] * z["y"], "lam": jnp.zeros_like(z["lam"])}


for batching in (Lockstep(), PerSample()):
    sol = solve(decay, {}, zb, 0.0, 1.0, solver=ALF(eta=0.9),
                controller=AdaptiveController(1e-3, 1e-4, 256),
                gradient=MALI(), batching=batching)
    per = sol.stats.per_sample
    print(f"{batching.name:10s} total f-evals {int(sol.stats.n_fevals):5d}  "
          f"per-row accepted {[int(v) for v in per.n_accepted]}")

# ---- 3b. reverse accuracy: MALI == backprop through its own forward -----
g_mali = jax.grad(loss)(params, z0, MALI(), ALF())
g_naive = jax.grad(loss)(params, z0, Naive(), ALF())
rel = abs(float(g_mali["alpha"]) - float(g_naive["alpha"])) / abs(
    float(g_naive["alpha"]))
print(f"reverse-accuracy invariant |mali-naive|/|naive| = {rel:.2e} "
      "(float rounding)")

# ---- 5. time as a first-class axis --------------------------------------
from repro.core import Event  # noqa: E402  (demo-local import)

# reverse-time solve: run the flow backwards and recover z0
zT = solve(f, params, z0, 0.0, T, solver=ALF(),
           controller=ConstantSteps(16), gradient=MALI()).ys
z_back = solve(f, params, zT, T, 0.0, solver=ALF(),
               controller=ConstantSteps(16), gradient=MALI()).ys
print(f"reverse-time roundtrip: z0 {float(z0):.6f} -> recovered "
      f"{float(z_back):.6f}")

# dense output: one solve, query anywhere in the span
dense = solve(f, params, z0, 0.0, T, solver=ALF(),
              controller=AdaptiveController(1e-4, 1e-5, 256),
              saveat=SaveAt(dense=True))
queries = jnp.asarray([0.21, 0.5, 0.83])
vals = dense.evaluate(queries)
print("dense evaluate:", [f"{float(v):.5f}" for v in vals],
      "vs analytic", [f"{1.3 * math.exp(0.5 * float(t)):.5f}"
                      for t in queries])

# terminating event: stop when z grows through 2.0 (analytic t*)
ev = Event(lambda z, t: z - 2.0, direction=+1)
sol = solve(f, params, z0, 0.0, 4.0, solver=ALF(),
            controller=ConstantSteps(64), gradient=MALI(), event=ev)
t_star = math.log(2.0 / 1.3) / 0.5
print(f"event fired={bool(sol.stats.event_fired)} at "
      f"t={float(sol.stats.event_time):.5f} (analytic {t_star:.5f}); "
      f"z(t_event)={float(sol.ys):.5f}")
