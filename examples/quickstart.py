"""Quickstart: the MALI integrator in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Integrate an ODE with the public `odeint` facade.
2. Take gradients through it with each method (Table 1 of the paper).
3. Show MALI's two properties: constant memory and reverse accuracy.
"""
import math

import jax
import jax.numpy as jnp

from repro.core.api import odeint


# dz/dt = alpha * z  — the paper's Sec 4.1 toy with analytic solution.
def f(params, z, t):
    return params["alpha"] * z


params = {"alpha": jnp.float32(0.5)}
z0 = jnp.float32(1.3)
T = 1.0

# ---- 1. forward integration --------------------------------------------
zT = odeint(f, params, z0, 0.0, T, method="mali", n_steps=16)
print(f"z(T) numeric {float(zT):.6f} vs analytic "
      f"{1.3 * math.exp(0.5 * T):.6f}")

# ---- 2. gradients through the integrator, all four methods --------------
exact_dalpha = 2 * T * 1.3 ** 2 * math.exp(2 * 0.5 * T)


def loss(p, z, method):
    return odeint(f, p, z, 0.0, T, method=method, n_steps=16) ** 2


for method in ("mali", "naive", "aca", "adjoint"):
    g = jax.grad(loss)(params, z0, method)
    err = abs(float(g["alpha"]) - exact_dalpha)
    print(f"{method:8s} dL/dalpha = {float(g['alpha']):.5f} "
          f"(analytic {exact_dalpha:.5f}, err {err:.2e})")

# ---- 3a. constant memory: residual bytes flat in n_steps ----------------
big = {"w": jnp.ones((65536,), jnp.float32)}


def big_f(p, z, t):
    return jnp.tanh(p["w"] * z)


def big_loss(p, z, method, n):
    return jnp.sum(odeint(big_f, p, z, 0.0, 1.0, method=method,
                          solver="alf" if method == "naive" else None,
                          n_steps=n) ** 2)


for method in ("mali", "naive"):
    sizes = []
    for n in (8, 64):
        c = jax.jit(jax.grad(big_loss, argnums=0),
                    static_argnums=(2, 3)).lower(
            big, jnp.ones((65536,)), method, n).compile()
        sizes.append(c.memory_analysis().temp_size_in_bytes)
    print(f"{method:8s} backward temp bytes: n=8 -> {sizes[0]:,}  "
          f"n=64 -> {sizes[1]:,}  (x{sizes[1] / sizes[0]:.1f})")

# ---- 3b. reverse accuracy: MALI == backprop through its own forward -----
g_mali = jax.grad(loss)(params, z0, "mali")
g_naive = jax.grad(lambda p, z: odeint(f, p, z, 0.0, T, method="naive",
                                       solver="alf", n_steps=16) ** 2)(
    params, z0)
rel = abs(float(g_mali["alpha"]) - float(g_naive["alpha"])) / abs(
    float(g_naive["alpha"]))
print(f"reverse-accuracy invariant |mali-naive|/|naive| = {rel:.2e} "
      "(float rounding)")
