"""Paper §4.4 at CPU scale: continuous normalizing flow (FFJORD) trained
with MALI on a 2D density — expressed through the repro.cnf subsystem.

    PYTHONPATH=src python examples/cnf_toy.py [--steps 600]

The CNF integrates the augmented state (z, log|det|) with
d(logdet)/dt = -tr(df/dz) — exact trace in 2D (the Hutchinson estimator is
also checked against it). Reports NLL in nats (the 2D analogue of the
paper's bits/dim).
"""
import argparse
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.cnf import CNF, Exact, Hutchinson, cnf_loss, nll_nats
from repro.core import ALF, ConstantSteps, MALI, Naive, SaveAt, get_solver
from repro.models import init_mlp_vfield, mlp_vfield

HID = 48


def make_moons(n, seed):
    rng = np.random.default_rng(seed)
    half = n // 2
    th = rng.uniform(0, np.pi, half)
    a = np.stack([np.cos(th), np.sin(th)], -1)
    b = np.stack([1 - np.cos(th), 0.5 - np.sin(th)], -1)
    x = np.concatenate([a, b]) + rng.normal(0, 0.08, (n, 2))
    return jnp.asarray(x, jnp.float32)


FLOW = CNF(mlp_vfield, dim=2, estimator=Exact())

KINETIC_REG = 0.5    # Finlay-et-al-style coefficient (the paper uses 0.05
                     # at image scale; the 2D toy needs a stronger pull to
                     # keep the discretized logdet honest — see eval below)


def nll(fp, x, method="mali", n_steps=8, reg=0.0, solver_n=None):
    """-log p(x): integrate x -> base gaussian, exact trace (+ optional
    kinetic-energy regularizer used during training). ``solver_n`` swaps in
    a different (solver, n_steps) re-discretization at eval time — a
    one-argument change on the object API."""
    solver = ALF()
    if solver_n is not None:
        name, n_steps = solver_n
        solver = get_solver(name)
    gradient = MALI() if method == "mali" else Naive()
    res = FLOW.log_prob(fp, x, solver=solver,
                        controller=ConstantSteps(n_steps), gradient=gradient)
    return cnf_loss(res, kinetic_reg=reg)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--method", default="mali")
    args = ap.parse_args()

    x = make_moons(1024, seed=0)
    xt = make_moons(512, seed=1)
    fp = init_mlp_vfield(jax.random.PRNGKey(0), dim=2, hidden=HID, depth=2)

    # sanity: Hutchinson estimator is unbiased vs exact trace — straight off
    # the registered estimator objects, one state batch, 64 probe draws
    # (on perturbed params: the zero-init output layer has J = 0 exactly)
    hutch = Hutchinson()
    xs = x[:100]
    fq = jax.tree_util.tree_map(
        lambda a, k: a + 0.3 * jax.random.normal(k, a.shape), fp,
        jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(fp),
            list(jax.random.split(jax.random.PRNGKey(7),
                                  len(jax.tree_util.tree_leaves(fp))))))
    trace_at = lambda est, zi, ei: est.value_and_trace(
        lambda zz: mlp_vfield(fq, zz, 0.3), zi, ei)[1]
    ld_exact = jax.vmap(lambda zi: trace_at(Exact(), zi, None))(xs)
    ld_h = jnp.stack([
        jax.vmap(lambda zi, ei: trace_at(hutch, zi, ei))(
            xs, hutch.init_noise(k, xs))
        for k in jax.random.split(jax.random.PRNGKey(0), 64)])
    err = float(jnp.abs(ld_h.mean(0) - ld_exact).mean())
    print(f"hutchinson-vs-exact trace |bias| over 64 probes: {err:.4f}")

    tm = jax.tree_util.tree_map
    m = tm(jnp.zeros_like, fp)
    v = tm(jnp.zeros_like, fp)

    @jax.jit
    def step(carry, i):
        p, m, v = carry
        l, g = jax.value_and_grad(
            lambda pp, xx: nll(pp, xx, reg=KINETIC_REG))(p, x)
        m = tm(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = tm(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        t = i + 1.0
        p = tm(lambda pp, mm, vv: pp - 5e-3 * (mm / (1 - 0.9 ** t)) /
               (jnp.sqrt(vv / (1 - 0.999 ** t)) + 1e-8), p, m, v)
        return (p, m, v), l

    (fp, _, _), losses = jax.lax.scan(
        step, (fp, m, v), jnp.arange(args.steps, dtype=jnp.float32))
    test_nll = float(nll(fp, xt, method=args.method))
    # honest NLL: re-discretize finely with a higher-order solver — a CNF
    # trained on a fixed coarse grid can game the discretized logdet, and
    # the fine-solver eval (paper Table 2 spirit) exposes that
    test_nll_fine = float(nll(fp, xt, method="naive", solver_n=("rk4", 64)))
    base_nll = float(-(-0.5 * (xt ** 2).sum(-1)
                       - math.log(2 * math.pi)).mean())
    print(f"train NLL: first={float(losses[0]):.3f} "
          f"last={float(losses[-1]):.3f}")
    print(f"test NLL coarse(alf,8)={test_nll:.3f}  fine(rk4,64)="
          f"{test_nll_fine:.3f}  raw-gaussian baseline={base_nll:.3f}")
    assert test_nll_fine < base_nll, "flow must beat the identity baseline"

    # trainable integration bounds (the FFJORD end_time parameter): the
    # analytic boundary cotangent of the test NLL w.r.t. the flow end time
    g_t1 = jax.grad(lambda t1: nll_nats(FLOW.log_prob(
        fp, xt, controller=ConstantSteps(8), t1=t1,
        diff_bounds=True)))(jnp.asarray(1.0))
    print(f"d(test NLL)/d t1 = {float(g_t1):+.4f} (diff_bounds=True)")

    # sample back through the inverse flow (integrate base -> data time),
    # requesting the whole flow path on an observation grid in ONE call —
    # the continuous-generative-model visualization (paper Fig. 6 spirit)
    flow_ts = jnp.linspace(1.0, 0.0, 5)
    path = FLOW.sample(fp, jax.random.PRNGKey(2), 8,
                       controller=ConstantSteps(2),
                       saveat=SaveAt(ts=flow_ts))
    traj = path.ys[0]
    assert traj.shape == (5, 8, 2)
    for t, snap in zip(np.asarray(flow_ts), np.asarray(traj)):
        print(f"flow t={t:.2f} sample[0]={snap[0].round(2).tolist()}")
    print("samples (first 3):", np.asarray(traj[-1][:3]).round(2).tolist())


if __name__ == "__main__":
    main()
