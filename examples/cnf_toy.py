"""Paper §4.4 at CPU scale: continuous normalizing flow (FFJORD) trained
with MALI on a 2D density.

    PYTHONPATH=src python examples/cnf_toy.py [--steps 600]

The CNF integrates the augmented state (z, log|det|) with
d(logdet)/dt = -tr(df/dz) — exact trace in 2D (the Hutchinson estimator is
also implemented and checked against it). Reports NLL in nats (the 2D
analogue of the paper's bits/dim).
"""
import argparse
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ALF, ConstantSteps, MALI, Naive, SaveAt, get_solver,
                        solve)

HID = 48


def make_moons(n, seed):
    rng = np.random.default_rng(seed)
    half = n // 2
    th = rng.uniform(0, np.pi, half)
    a = np.stack([np.cos(th), np.sin(th)], -1)
    b = np.stack([1 - np.cos(th), 0.5 - np.sin(th)], -1)
    x = np.concatenate([a, b]) + rng.normal(0, 0.08, (n, 2))
    return jnp.asarray(x, jnp.float32)


def init_field(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w1": 0.5 * jax.random.normal(k1, (3, HID)),
            "b1": jnp.zeros((HID,)),
            "w2": 0.5 * jax.random.normal(k2, (HID, HID)),
            "b2": jnp.zeros((HID,)),
            "w3": 0.5 * jax.random.normal(k3, (HID, 2)),
            "b3": jnp.zeros((2,))}


def vfield(fp, z, t):
    """f(z, t) for a single point z: [2] -> [2]."""
    t_col = jnp.broadcast_to(jnp.asarray(t, z.dtype), z.shape[:-1] + (1,))
    h = jnp.tanh(jnp.concatenate([z, t_col], -1) @ fp["w1"] + fp["b1"])
    h = jnp.tanh(h @ fp["w2"] + fp["b2"])
    return h @ fp["w3"] + fp["b3"]


def aug_field_exact(fp, state, t):
    """Augmented dynamics with the EXACT 2D trace (vmapped over batch).
    State = (z, delta, kinetic) with d(delta)/dt = +tr(df/dz), so that
    log p(x) = log p_base(z_T) + delta_T (instantaneous change of variables:
    d log p(z(t))/dt = -tr(df/dz) along the flow). dk/dt = |f|^2 is the
    RNODE kinetic-energy
    regularizer of Finlay et al. 2020 — the setting the paper's §4.4 uses
    (reg coefficient 0.05)."""
    z, _, _ = state

    def one(zi):
        f = lambda zz: vfield(fp, zz, t)
        J = jax.jacfwd(f)(zi)
        fz = f(zi)
        return fz, jnp.trace(J), jnp.sum(fz ** 2)

    dz, dld, dk = jax.vmap(one)(z)
    return (dz, dld, dk)


def aug_field_hutch(fp, state, t, eps):
    """Hutchinson trace estimator (what image-scale FFJORD uses)."""
    z, _, _ = state

    def one(zi, ei):
        f = lambda zz: vfield(fp, zz, t)
        fz, jvp = jax.jvp(f, (zi,), (ei,))
        return fz, jnp.dot(ei, jvp), jnp.sum(fz ** 2)

    dz, dld, dk = jax.vmap(one)(z, eps)
    return (dz, dld, dk)


KINETIC_REG = 0.5    # Finlay-et-al-style coefficient (the paper uses 0.05
                     # at image scale; the 2D toy needs a stronger pull to
                     # keep the discretized logdet honest — see eval below)


def nll(fp, x, method="mali", n_steps=8, reg=0.0, solver_n=None):
    """-log p(x): integrate x -> base gaussian, exact trace (+ optional
    kinetic-energy regularizer used during training). ``solver_n`` swaps in
    a different (solver, n_steps) re-discretization at eval time — a
    one-argument change on the object API."""
    state0 = (x, jnp.zeros(x.shape[:-1]), jnp.zeros(x.shape[:-1]))
    solver = ALF()
    if solver_n is not None:
        name, n_steps = solver_n
        solver = get_solver(name)
    gradient = MALI() if method == "mali" else Naive()
    zT, logdet, kinetic = solve(aug_field_exact, fp, state0, 0.0, 1.0,
                                solver=solver,
                                controller=ConstantSteps(n_steps),
                                gradient=gradient).ys
    logp_base = -0.5 * jnp.sum(zT ** 2, -1) - math.log(2 * math.pi)
    return -(logp_base + logdet).mean() + reg * kinetic.mean()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--method", default="mali")
    args = ap.parse_args()

    x = make_moons(1024, seed=0)
    xt = make_moons(512, seed=1)
    fp = init_field(jax.random.PRNGKey(0))

    # sanity: Hutchinson estimator is unbiased vs exact trace
    eps = jnp.asarray(np.random.default_rng(0).choice(
        [-1.0, 1.0], (64, 100, 2)), jnp.float32)
    s0 = (x[:100], jnp.zeros((100,)), jnp.zeros((100,)))
    _, ld_exact, _ = aug_field_exact(fp, s0, 0.3)
    ld_h = jnp.stack([aug_field_hutch(fp, s0, 0.3, e)[1] for e in eps])
    err = float(jnp.abs(ld_h.mean(0) - ld_exact).mean())
    print(f"hutchinson-vs-exact trace |bias| over 64 probes: {err:.4f}")

    tm = jax.tree_util.tree_map
    m = tm(jnp.zeros_like, fp)
    v = tm(jnp.zeros_like, fp)

    @jax.jit
    def step(carry, i):
        p, m, v = carry
        l, g = jax.value_and_grad(
            lambda pp, xx: nll(pp, xx, reg=KINETIC_REG))(p, x)
        m = tm(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = tm(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        t = i + 1.0
        p = tm(lambda pp, mm, vv: pp - 5e-3 * (mm / (1 - 0.9 ** t)) /
               (jnp.sqrt(vv / (1 - 0.999 ** t)) + 1e-8), p, m, v)
        return (p, m, v), l

    (fp, _, _), losses = jax.lax.scan(
        step, (fp, m, v), jnp.arange(args.steps, dtype=jnp.float32))
    test_nll = float(nll(fp, xt, method=args.method))
    # honest NLL: re-discretize finely with a higher-order solver — a CNF
    # trained on a fixed coarse grid can game the discretized logdet, and
    # the fine-solver eval (paper Table 2 spirit) exposes that
    test_nll_fine = float(nll(fp, xt, method="naive", solver_n=("rk4", 64)))
    base_nll = float(-(-0.5 * (xt ** 2).sum(-1)
                       - math.log(2 * math.pi)).mean())
    print(f"train NLL: first={float(losses[0]):.3f} "
          f"last={float(losses[-1]):.3f}")
    print(f"test NLL coarse(alf,8)={test_nll:.3f}  fine(rk4,64)="
          f"{test_nll_fine:.3f}  raw-gaussian baseline={base_nll:.3f}")
    assert test_nll_fine < base_nll, "flow must beat the identity baseline"

    # sample back through the inverse flow (integrate base -> data time),
    # requesting the whole flow path on an observation grid in ONE call —
    # the continuous-generative-model visualization (paper Fig. 6 spirit)
    zs = jnp.asarray(np.random.default_rng(2).standard_normal((8, 2)),
                     jnp.float32)
    flow_ts = jnp.linspace(1.0, 0.0, 5)
    traj, _, _ = solve(aug_field_exact, fp,
                       (zs, jnp.zeros(8), jnp.zeros(8)),
                       solver=ALF(), controller=ConstantSteps(2),
                       gradient=MALI(),
                       saveat=SaveAt(ts=flow_ts)).ys
    assert traj.shape == (5, 8, 2)
    for t, snap in zip(np.asarray(flow_ts), np.asarray(traj)):
        print(f"flow t={t:.2f} sample[0]={snap[0].round(2).tolist()}")
    print("samples (first 3):", np.asarray(traj[-1][:3]).round(2).tolist())


if __name__ == "__main__":
    main()
