"""Paper §4.3 at CPU scale: latent ODE (Rubanova et al. 2019) for
irregularly-sampled time series, trained with MALI.

    PYTHONPATH=src python examples/time_series_latent_ode.py [--steps 500]

Encoder (GRU over observed points, reversed) -> latent z0 -> latent dynamics
integrated with MALI -> decoder -> MSE on held-out segment. Synthetic damped
2D oscillators with random frequencies/phases stand in for the Mujoco-Hopper
stream (same protocol: condition on the first half, extrapolate the rest).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ACA, ALF, Backsolve, ConstantSteps, Dopri5,
                        HeunEuler, MALI, Naive, SaveAt, solve)

METHODS = {"mali": (MALI(), ALF()), "naive": (Naive(), ALF()),
           "aca": (ACA(), HeunEuler()), "adjoint": (Backsolve(), Dopri5())}

LATENT = 8
OBS = 2
HID = 32
T_OBS = 25     # conditioning points
T_EXT = 25     # extrapolation points


def make_series(n, seed):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.8, 2.0, (n, 1))
    phi = rng.uniform(0, 2 * np.pi, (n, 1))
    amp = rng.uniform(0.5, 1.5, (n, 1))
    t = np.linspace(0, 5, T_OBS + T_EXT)[None, :]
    x = amp * np.exp(-0.1 * t) * np.cos(w * t + phi)
    y = amp * np.exp(-0.1 * t) * np.sin(w * t + phi)
    series = np.stack([x, y], -1)   # [n, T, 2]
    return jnp.asarray(series, jnp.float32), jnp.asarray(t[0], jnp.float32)


def init_params(key):
    ks = jax.random.split(key, 8)
    g = lambda k, *sh: 0.3 * jax.random.normal(k, sh)
    return {
        "enc_in": g(ks[0], OBS, HID),
        "enc_h": g(ks[1], HID, HID),
        "enc_out": g(ks[2], HID, LATENT),
        "f": {"w1": g(ks[3], LATENT + 1, HID), "b1": jnp.zeros((HID,)),
              "w2": g(ks[4], HID, LATENT), "b2": jnp.zeros((LATENT,))},
        "dec_w": g(ks[5], LATENT, HID),
        "dec_w2": g(ks[6], HID, OBS),
        "dec_b": jnp.zeros((OBS,)),
    }


def encode(params, obs):
    """Reverse-time RNN over the conditioning window -> z0."""
    def cell(h, x):
        h = jnp.tanh(x @ params["enc_in"] + h @ params["enc_h"])
        return h, None

    h0 = jnp.zeros(obs.shape[:-2] + (HID,))
    h, _ = jax.lax.scan(cell, h0, jnp.moveaxis(obs[..., ::-1, :], -2, 0))
    return h @ params["enc_out"]


def latent_field(fp, z, t):
    t_col = jnp.broadcast_to(jnp.asarray(t, z.dtype), z.shape[:-1] + (1,))
    h = jnp.tanh(jnp.concatenate([z, t_col], -1) @ fp["w1"] + fp["b1"])
    return h @ fp["w2"] + fp["b2"]


def decode(params, z):
    return jnp.tanh(z @ params["dec_w"]) @ params["dec_w2"] + params["dec_b"]


def rollout(params, z0, ts, method="mali"):
    """Integrate latent state to every observation time in ONE native-grid
    SaveAt(ts=...) solve: the observation grid is threaded through the
    integrator's single compiled scan (no Python-side interval chaining, and
    for MALI the backward residuals stay at the per-observation (z, v)
    pairs). Swapping the gradient method is a one-argument change."""
    gradient, solver = METHODS[method]
    return solve(latent_field, params["f"], z0, solver=solver,
                 controller=ConstantSteps(2), gradient=gradient,
                 saveat=SaveAt(ts=ts)).ys   # [T, ..., LATENT]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--method", default="mali",
                    choices=["mali", "naive", "aca", "adjoint"])
    args = ap.parse_args()

    series, ts = make_series(256, seed=0)
    test, _ = make_series(128, seed=1)
    params = init_params(jax.random.PRNGKey(0))

    def loss_fn(p, data):
        obs = data[:, :T_OBS]
        z0 = encode(p, obs)
        zs = rollout(p, z0, ts, method=args.method)     # [T, B, L]
        pred = decode(p, jnp.moveaxis(zs, 0, 1))        # [B, T, OBS]
        return jnp.mean((pred - data) ** 2)

    tm = jax.tree_util.tree_map
    m = tm(jnp.zeros_like, params)
    v = tm(jnp.zeros_like, params)

    @jax.jit
    def step(carry, i):
        p, m, v = carry
        l, g = jax.value_and_grad(loss_fn)(p, series)
        m = tm(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = tm(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        t = i + 1.0
        p = tm(lambda pp, mm, vv: pp - 5e-3 * (mm / (1 - 0.9 ** t)) /
               (jnp.sqrt(vv / (1 - 0.999 ** t)) + 1e-8), p, m, v)
        return (p, m, v), l

    (params, _, _), losses = jax.lax.scan(
        step, (params, m, v), jnp.arange(args.steps, dtype=jnp.float32))
    print(f"train MSE: first={float(losses[0]):.4f} "
          f"last={float(losses[-1]):.4f}")

    # held-out extrapolation MSE (the paper's Table 4 metric)
    obs = test[:, :T_OBS]
    zs = rollout(params, encode(params, obs), ts, method=args.method)
    pred = decode(params, jnp.moveaxis(zs, 0, 1))
    ext_mse = float(jnp.mean((pred[:, T_OBS:] - test[:, T_OBS:]) ** 2))
    print(f"test extrapolation MSE ({args.method}): {ext_mse:.4f}")
    assert np.isfinite(ext_mse)


if __name__ == "__main__":
    main()
