"""repro: production-grade JAX framework reproducing MALI (ICLR 2021)."""
__version__ = "0.1.0"
