"""Pure-jnp oracle for causal GQA flash attention (+softcap, window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jnp.ndarray:
    """q: [B, Sq, H, d]; k/v: [B, Sk, K, d] with H % K == 0. f32 math."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, sq, kh, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf) * (d ** -0.5)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)
    kpos = jnp.arange(k.shape[1])
    keep = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        keep &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        keep &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(keep[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return o.reshape(b, sq, h, d).astype(q.dtype)
