"""jit'd wrapper: [B,S,H,d]/[B,S,K,d] layout -> flash kernel (or jnp oracle)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_call


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "use_pallas", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, use_pallas: bool = False,
                    interpret: bool = True) -> jax.Array:
    """q: [B, Sq, H, d]; k/v: [B, Sk, K, d] -> [B, Sq, H, d]."""
    if not use_pallas:
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 softcap=softcap)
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = jnp.moveaxis(q.reshape(b, sq, kh, g, d), 1, 3)   # [B,KV,G,Sq,d]
    kg = jnp.moveaxis(k, 1, 2)                            # [B,KV,Sk,d]
    vg = jnp.moveaxis(v, 1, 2)
    o = flash_attention_call(qg, kg, vg, causal=causal, window=window,
                             softcap=softcap, interpret=interpret)
    return jnp.moveaxis(o, 3, 1).reshape(b, sq, h, d)
