"""Pure-jnp oracle for the fused selective scan.

y[b,t,i] = C[b,t,:] . h[b,t,i,:]
h[b,t]   = exp(delta[b,t,i] * A[i,:]) * h[b,t-1] + (delta*u)[b,t,i] * B[b,t,:]

(the discretized diagonal SSM of Mamba; A is the raw negative-real matrix,
i.e. already -exp(A_log)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(delta: jnp.ndarray, u: jnp.ndarray, A: jnp.ndarray,
                       B: jnp.ndarray, C: jnp.ndarray,
                       h0: jnp.ndarray | None = None):
    """delta/u: [Bt, S, DI]; A: [DI, ST]; B/C: [Bt, S, ST]; h0: [Bt, DI, ST].
    Returns (y [Bt, S, DI] f32, h_final [Bt, DI, ST] f32)."""
    bt, s, di = delta.shape
    st = A.shape[1]
    dA = jnp.exp(delta.astype(jnp.float32)[..., None]
                 * A.astype(jnp.float32))                      # [Bt,S,DI,ST]
    dBu = (delta.astype(jnp.float32) * u.astype(jnp.float32))[..., None] \
        * B.astype(jnp.float32)[..., None, :]                  # [Bt,S,DI,ST]
    h = jnp.zeros((bt, di, st), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)

    def step(h, xs):
        dA_t, dBu_t, c_t = xs
        h = dA_t * h + dBu_t
        y = jnp.einsum("bis,bs->bi", h, c_t)
        return h, y

    h, ys = jax.lax.scan(
        step, h, (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBu, 1, 0),
                  jnp.moveaxis(C.astype(jnp.float32), 1, 0)))
    return jnp.moveaxis(ys, 0, 1), h
