"""Pallas TPU kernel: fused selective scan (Mamba recurrence).

The core insight of the Mamba CUDA kernel, adapted to TPU: the discretized
state tensors dA, dBu of shape [B, S, DI, ST] must NEVER hit HBM. The
kernel reads only the factors (delta, u: [B, S, DI]; B, C: [B, S, ST];
A: [DI, ST]) and keeps the running state h [block_di, ST] in VMEM/VREGs
across the sequence loop, emitting y [B, S, DI] — HBM traffic drops from
O(S*DI*ST) to O(S*(DI+ST)), a ~ST/2 = 8x reduction at Jamba's ST=16 before
counting the elementwise-chain savings.

Tiling: grid (B, DI/block_di). Per program the VMEM working set is
delta/u/y tiles [S, block_di] f32 (3 x 4 MB at S=4096, block_di=256),
B/C [S, ST] (2 x 256 KB) and h [block_di, ST] (16 KB) — comfortably inside
the ~16 MB VMEM budget; longer sequences are handled by the caller chunking
S (models/ssm.py already scans over chunks).

GPU->TPU adaptation notes (DESIGN.md §8): the CUDA kernel's warp-parallel
prefix scan becomes a sequential fori_loop over S here — on TPU the VPU
processes the [block_di, ST] state as full vector registers per step, and
the win comes from VMEM residency, not intra-step parallelism. The
matmul-free recurrence never touches the MXU; y's contraction over ST is a
VPU reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

BLOCK_DI = 256


def _scan_kernel(delta_ref, u_ref, a_ref, b_ref, c_ref, h0_ref,
                 y_ref, hout_ref, *, seq_len: int):
    a = a_ref[0].astype(jnp.float32)                 # [bdi, ST]
    h = h0_ref[0].astype(jnp.float32)                # [bdi, ST]

    def step(t, h):
        dt = delta_ref[0, t].astype(jnp.float32)     # [bdi]
        ut = u_ref[0, t].astype(jnp.float32)         # [bdi]
        bt = b_ref[0, t].astype(jnp.float32)         # [ST]
        ct = c_ref[0, t].astype(jnp.float32)         # [ST]
        dA = jnp.exp(dt[:, None] * a)                # [bdi, ST]
        h = dA * h + (dt * ut)[:, None] * bt[None, :]
        y_ref[0, t] = (h * ct[None, :]).sum(-1).astype(y_ref.dtype)
        return h

    h = lax.fori_loop(0, seq_len, step, h)
    hout_ref[0] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_di", "interpret"))
def selective_scan_call(delta: jax.Array, u: jax.Array, A: jax.Array,
                        B: jax.Array, C: jax.Array, h0: jax.Array,
                        block_di: int = BLOCK_DI, interpret: bool = True):
    """delta/u: [Bt, S, DI]; A: [DI, ST]; B/C: [Bt, S, ST];
    h0: [Bt, DI, ST]. Returns (y [Bt, S, DI] f32, h_final [Bt, DI, ST] f32).
    DI % block_di == 0 (ops wrapper pads)."""
    bt, s, di = delta.shape
    st = A.shape[1]
    block_di = min(block_di, di)
    assert di % block_di == 0
    grid = (bt, di // block_di)

    kernel = functools.partial(_scan_kernel, seq_len=s)
    y, h_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s, block_di), lambda b, i: (b, 0, i)),   # delta
            pl.BlockSpec((1, s, block_di), lambda b, i: (b, 0, i)),   # u
            pl.BlockSpec((1, block_di, st), lambda b, i: (0, i, 0)),  # A
            pl.BlockSpec((1, s, st), lambda b, i: (b, 0, 0)),         # B
            pl.BlockSpec((1, s, st), lambda b, i: (b, 0, 0)),         # C
            pl.BlockSpec((1, block_di, st), lambda b, i: (b, i, 0)),  # h0
        ],
        out_specs=[
            pl.BlockSpec((1, s, block_di), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, block_di, st), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, s, di), jnp.float32),
            jax.ShapeDtypeStruct((bt, di, st), jnp.float32),
        ],
        interpret=interpret,
    )(delta, u, A[None], B, C, h0)
    return y, h_out
