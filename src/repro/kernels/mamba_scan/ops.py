"""jit'd wrapper for the fused selective-scan kernel (jnp oracle on CPU)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .mamba_scan import BLOCK_DI, selective_scan_call


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def selective_scan(delta: jax.Array, u: jax.Array, A: jax.Array,
                   B: jax.Array, C: jax.Array,
                   h0: Optional[jax.Array] = None, *,
                   use_pallas: bool = False, interpret: bool = True
                   ) -> Tuple[jax.Array, jax.Array]:
    """delta/u: [Bt, S, DI]; A: [DI, ST]; B/C: [Bt, S, ST].
    Returns (y [Bt, S, DI] f32, h_final [Bt, DI, ST] f32)."""
    bt, s, di = delta.shape
    st = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bt, di, st), jnp.float32)
    if not use_pallas:
        return ref.selective_scan_ref(delta, u, A, B, C, h0)
    # pad DI up to a block multiple (A rows padded with zeros -> dA=1,
    # dBu=0: padded state stays 0 and is sliced off)
    pad = (-di) % min(BLOCK_DI, max(di, 1))
    if pad:
        delta = jnp.pad(delta, ((0, 0), (0, 0), (0, pad)))
        u = jnp.pad(u, ((0, 0), (0, 0), (0, pad)))
        A = jnp.pad(A, ((0, pad), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad), (0, 0)))
    y, h = selective_scan_call(delta, u, A, B, C, h0, interpret=interpret)
    if pad:
        y = y[..., :di]
        h = h[:, :di]
    return y, h
