"""Pallas TPU kernels for the fused ALF state updates.

Tiling: the state is flattened to [rows, 128] (lane-aligned) and tiled in
(block_rows, 128) VMEM blocks — elementwise, so any tiling is valid; 128
lanes match the VPU, block_rows sized so in+out blocks fit comfortably in
VMEM (default 1024 rows -> 5 x 512KB f32 blocks per program).

The step size ``h`` is prefetched as a scalar (SMEM) so one compiled kernel
serves every step of an adaptive integration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 1024


def _midpoint_kernel(h_ref, z_ref, v_ref, k1_ref, *, sign: float):
    h = h_ref[0]
    z = z_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    k1_ref[...] = (z + sign * v * (h * 0.5)).astype(k1_ref.dtype)


def _update_kernel(h_ref, k1_ref, v_ref, u1_ref, z_out_ref, v_out_ref, *,
                   eta: float):
    h = h_ref[0]
    k1 = k1_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    u1 = u1_ref[...].astype(jnp.float32)
    v_out = v + 2.0 * eta * (u1 - v)
    v_out_ref[...] = v_out.astype(v_out_ref.dtype)
    z_out_ref[...] = (k1 + v_out * (h * 0.5)).astype(z_out_ref.dtype)


def _inverse_update_kernel(h_ref, k1_ref, vo_ref, u1_ref, z_in_ref, v_in_ref,
                           *, eta: float):
    h = h_ref[0]
    k1 = k1_ref[...].astype(jnp.float32)
    vo = vo_ref[...].astype(jnp.float32)
    u1 = u1_ref[...].astype(jnp.float32)
    if eta == 1.0:
        v_in = 2.0 * u1 - vo
    else:
        v_in = (vo - 2.0 * eta * u1) * (1.0 / (1.0 - 2.0 * eta))
    v_in_ref[...] = v_in.astype(v_in_ref.dtype)
    z_in_ref[...] = (k1 - v_in * (h * 0.5)).astype(z_in_ref.dtype)


def _tiled_call(kernel, args, n_out, block_rows=BLOCK_ROWS, interpret=True):
    """args: (h_scalar, *arrays) with arrays pre-shaped [rows, LANES]."""
    h, *arrays = args
    rows = arrays[0].shape[0]
    bs = min(block_rows, rows)
    # Pad rows to a block multiple: an unguarded `rows // bs` grid covers
    # only (rows // bs) * bs rows and the tail is silently never written
    # (odelint R003). The ops are elementwise, so zero-padding is exact.
    pad = (-rows) % bs
    if pad:
        arrays = [jnp.pad(a, ((0, pad), (0, 0))) for a in arrays]
    rows_p = rows + pad
    assert rows_p % bs == 0
    grid = (rows_p // bs,)
    spec = pl.BlockSpec((bs, LANES), lambda i: (i, 0))
    out_shape = tuple(
        jax.ShapeDtypeStruct((rows_p, LANES), a.dtype)
        for a in arrays[:n_out])
    fn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))] + [spec] * len(arrays),
        out_specs=(spec,) * n_out if n_out > 1 else spec,
        out_shape=out_shape if n_out > 1 else out_shape[0],
        interpret=interpret,
    )
    out = fn(jnp.asarray(h, jnp.float32).reshape(1), *arrays)
    if not pad:
        return out
    if n_out > 1:
        return tuple(o[:rows] for o in out)
    return out[:rows]


def midpoint_call(z, v, h, *, sign=1.0, interpret=True, block_rows=BLOCK_ROWS):
    return _tiled_call(functools.partial(_midpoint_kernel, sign=sign),
                       (h, z, v), 1, block_rows, interpret)


def update_call(k1, v, u1, h, *, eta=1.0, interpret=True,
                block_rows=BLOCK_ROWS):
    return _tiled_call(functools.partial(_update_kernel, eta=eta),
                       (h, k1, v, u1), 2, block_rows, interpret)


def inverse_update_call(k1, v_out, u1, h, *, eta=1.0, interpret=True,
                        block_rows=BLOCK_ROWS):
    return _tiled_call(functools.partial(_inverse_update_kernel, eta=eta),
                       (h, k1, v_out, u1), 2, block_rows, interpret)
