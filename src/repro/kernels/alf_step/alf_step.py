"""Pallas TPU kernels for the fused ALF state updates — forward AND backward.

Tiling: the state is flattened to [rows, 128] (lane-aligned) and tiled in
(block_rows, 128) VMEM blocks — elementwise, so any tiling is valid; 128
lanes match the VPU, block_rows sized so in+out blocks fit comfortably in
VMEM (default 1024 rows -> 5 x 512KB f32 blocks per program).

The step size ``h`` is prefetched as a scalar (SMEM) so one compiled kernel
serves every step of an adaptive integration.

Kernel inventory (the jnp oracle for each lives in ref.py):

  forward step        _midpoint_kernel, _update_kernel
  psi^-1              _inverse_update_kernel (tail, given k1),
                      _inverse_kernel (full, re-derives k1)
  direct backprop     _midpoint_vjp_kernel, _update_vjp_kernel — the
                      closed-form custom_vjp rules of the forward ops
  MALI backward       _bwd_pre_kernel (inverse midpoint + f-cotangent),
                      _bwd_post_kernel (inverse tail + adjoint propagation)
                      — ONE launch on each side of the step's f-eval VJP

Compute dtype: blocks arrive in the storage dtype; ``_acc`` promotes to at
least f32 for the arithmetic (f64 blocks stay f64 under x64) and every
write casts back via ``.astype(ref.dtype)`` (odelint R003d).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 1024


def _acc(x):
    """Storage dtype -> compute dtype (>= f32; f64 preserved)."""
    return x.astype(jnp.promote_types(x.dtype, jnp.float32))


def _midpoint_kernel(h_ref, z_ref, v_ref, k1_ref, *, sign: float):
    h = h_ref[0]
    z = _acc(z_ref[...])
    v = _acc(v_ref[...])
    k1_ref[...] = (z + sign * v * (h * 0.5)).astype(k1_ref.dtype)


def _update_kernel(h_ref, k1_ref, v_ref, u1_ref, z_out_ref, v_out_ref, *,
                   eta: float):
    h = h_ref[0]
    k1 = _acc(k1_ref[...])
    v = _acc(v_ref[...])
    u1 = _acc(u1_ref[...])
    v_out = v + 2.0 * eta * (u1 - v)
    v_out_ref[...] = v_out.astype(v_out_ref.dtype)
    z_out_ref[...] = (k1 + v_out * (h * 0.5)).astype(z_out_ref.dtype)


def _inverse_update_kernel(h_ref, k1_ref, vo_ref, u1_ref, z_in_ref, v_in_ref,
                           *, eta: float):
    h = h_ref[0]
    k1 = _acc(k1_ref[...])
    vo = _acc(vo_ref[...])
    u1 = _acc(u1_ref[...])
    if eta == 1.0:
        v_in = 2.0 * u1 - vo
    else:
        v_in = (vo - 2.0 * eta * u1) * (1.0 / (1.0 - 2.0 * eta))
    v_in_ref[...] = v_in.astype(v_in_ref.dtype)
    z_in_ref[...] = (k1 - v_in * (h * 0.5)).astype(z_in_ref.dtype)


def _inverse_kernel(h_ref, zo_ref, vo_ref, u1_ref, z_in_ref, v_in_ref, *,
                    eta: float):
    """Full psi^-1: midpoint recovery + inverse tail in one pass."""
    h = h_ref[0]
    zo = _acc(zo_ref[...])
    vo = _acc(vo_ref[...])
    u1 = _acc(u1_ref[...])
    k1 = zo - vo * (h * 0.5)
    if eta == 1.0:
        v_in = 2.0 * u1 - vo
    else:
        v_in = (vo - 2.0 * eta * u1) * (1.0 / (1.0 - 2.0 * eta))
    v_in_ref[...] = v_in.astype(v_in_ref.dtype)
    z_in_ref[...] = (k1 - v_in * (h * 0.5)).astype(z_in_ref.dtype)


def _midpoint_vjp_kernel(h_ref, g_ref, vbar_ref, *, sign: float):
    h = h_ref[0]
    g = _acc(g_ref[...])
    vbar_ref[...] = (sign * g * (h * 0.5)).astype(vbar_ref.dtype)


def _update_vjp_kernel(h_ref, gz_ref, gv_ref, vbar_ref, ubar_ref, *,
                       eta: float):
    h = h_ref[0]
    gz = _acc(gz_ref[...])
    gv = _acc(gv_ref[...])
    cot_vout = gv + gz * (h * 0.5)
    vbar_ref[...] = ((1.0 - 2.0 * eta) * cot_vout).astype(vbar_ref.dtype)
    ubar_ref[...] = (2.0 * eta * cot_vout).astype(ubar_ref.dtype)


def _bwd_pre_kernel(h_ref, z_ref, v_ref, az_ref, av_ref, k1_ref, cu_ref, *,
                    eta: float):
    h = h_ref[0]
    z = _acc(z_ref[...])
    v = _acc(v_ref[...])
    az = _acc(az_ref[...])
    av = _acc(av_ref[...])
    k1_ref[...] = (z - v * (h * 0.5)).astype(k1_ref.dtype)
    cu_ref[...] = (2.0 * eta * (av + az * (h * 0.5))).astype(cu_ref.dtype)


def _bwd_post_kernel(h_ref, k1_ref, vo_ref, u1_ref, az_ref, av_ref, dk1_ref,
                     zp_ref, vp_ref, dz_ref, dv_ref, *, eta: float):
    h = h_ref[0]
    k1 = _acc(k1_ref[...])
    vo = _acc(vo_ref[...])
    u1 = _acc(u1_ref[...])
    az = _acc(az_ref[...])
    av = _acc(av_ref[...])
    dk1 = _acc(dk1_ref[...])
    if eta == 1.0:
        v_prev = 2.0 * u1 - vo
    else:
        v_prev = (vo - 2.0 * eta * u1) * (1.0 / (1.0 - 2.0 * eta))
    vp_ref[...] = v_prev.astype(vp_ref.dtype)
    zp_ref[...] = (k1 - v_prev * (h * 0.5)).astype(zp_ref.dtype)
    cot_k1 = az + dk1
    dz_ref[...] = cot_k1.astype(dz_ref.dtype)
    cot_vout = av + az * (h * 0.5)
    dv_ref[...] = (cot_k1 * (h * 0.5)
                   + (1.0 - 2.0 * eta) * cot_vout).astype(dv_ref.dtype)


def _tiled_call(kernel, args, n_out, block_rows=BLOCK_ROWS, interpret=True):
    """args: (h_scalar, *arrays) with arrays pre-shaped [rows, LANES]."""
    h, *arrays = args
    rows = arrays[0].shape[0]
    bs = min(block_rows, rows)
    # Pad rows to a block multiple: an unguarded `rows // bs` grid covers
    # only (rows // bs) * bs rows and the tail is silently never written
    # (odelint R003). The ops are elementwise, so zero-padding is exact.
    pad = (-rows) % bs
    if pad:
        arrays = [jnp.pad(a, ((0, pad), (0, 0))) for a in arrays]
    rows_p = rows + pad
    assert rows_p % bs == 0
    grid = (rows_p // bs,)
    spec = pl.BlockSpec((bs, LANES), lambda i: (i, 0))
    out_shape = tuple(
        jax.ShapeDtypeStruct((rows_p, LANES), a.dtype)
        for a in arrays[:n_out])
    fn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))] + [spec] * len(arrays),
        out_specs=(spec,) * n_out if n_out > 1 else spec,
        out_shape=out_shape if n_out > 1 else out_shape[0],
        interpret=interpret,
    )
    # h rides at >= f32 whatever the block storage dtype (a bf16 h would
    # quantize small adaptive steps); f64 blocks get an f64 h under x64.
    h_dtype = jnp.promote_types(arrays[0].dtype, jnp.float32)
    out = fn(jnp.asarray(h, h_dtype).reshape(1), *arrays)
    if not pad:
        return out
    if n_out > 1:
        return tuple(o[:rows] for o in out)
    return out[:rows]


def midpoint_call(z, v, h, *, sign=1.0, interpret=True, block_rows=BLOCK_ROWS):
    return _tiled_call(functools.partial(_midpoint_kernel, sign=sign),
                       (h, z, v), 1, block_rows, interpret)


def update_call(k1, v, u1, h, *, eta=1.0, interpret=True,
                block_rows=BLOCK_ROWS):
    return _tiled_call(functools.partial(_update_kernel, eta=eta),
                       (h, k1, v, u1), 2, block_rows, interpret)


def inverse_update_call(k1, v_out, u1, h, *, eta=1.0, interpret=True,
                        block_rows=BLOCK_ROWS):
    return _tiled_call(functools.partial(_inverse_update_kernel, eta=eta),
                       (h, k1, v_out, u1), 2, block_rows, interpret)


def inverse_call(z_out, v_out, u1, h, *, eta=1.0, interpret=True,
                 block_rows=BLOCK_ROWS):
    return _tiled_call(functools.partial(_inverse_kernel, eta=eta),
                       (h, z_out, v_out, u1), 2, block_rows, interpret)


def midpoint_vjp_call(g, h, *, sign=1.0, interpret=True,
                      block_rows=BLOCK_ROWS):
    return _tiled_call(functools.partial(_midpoint_vjp_kernel, sign=sign),
                       (h, g), 1, block_rows, interpret)


def update_vjp_call(g_z, g_v, h, *, eta=1.0, interpret=True,
                    block_rows=BLOCK_ROWS):
    return _tiled_call(functools.partial(_update_vjp_kernel, eta=eta),
                       (h, g_z, g_v), 2, block_rows, interpret)


def bwd_pre_call(z, v, a_z, a_v, h, *, eta=1.0, interpret=True,
                 block_rows=BLOCK_ROWS):
    return _tiled_call(functools.partial(_bwd_pre_kernel, eta=eta),
                       (h, z, v, a_z, a_v), 2, block_rows, interpret)


def bwd_post_call(k1, v_out, u1, a_z, a_v, dk1, h, *, eta=1.0,
                  interpret=True, block_rows=BLOCK_ROWS):
    return _tiled_call(functools.partial(_bwd_post_kernel, eta=eta),
                       (h, k1, v_out, u1, a_z, a_v, dk1), 4, block_rows,
                       interpret)
