"""jit'd public wrappers for the fused ALF update kernels.

Pytree-generic: leaves are flattened/concatenated to a lane-aligned [rows,
128] buffer, processed by one kernel launch, and split back — so the whole
model state is one fused elementwise pass regardless of parameter structure.

``use_pallas=False`` (the CPU-container default) routes to the jnp oracle —
identical math, XLA-fused; the Pallas path (interpret=True on CPU, compiled
on TPU) is validated against it in tests.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .alf_step import LANES, inverse_update_call, midpoint_call, update_call

Pytree = Any


def _flatten(tree: Pytree) -> Tuple[jax.Array, Any, Any, int]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    n = flat.shape[0]
    pad = (-n) % LANES
    flat = jnp.pad(flat, (0, pad)).reshape(-1, LANES)
    shapes = [(l.shape, l.dtype) for l in leaves]
    return flat, treedef, shapes, n


def _unflatten(flat: jax.Array, treedef, shapes, n: int) -> Pytree:
    flat = flat.reshape(-1)[:n]
    leaves = []
    off = 0
    for shape, dtype in shapes:
        size = 1
        for s in shape:
            size *= s
        leaves.append(flat[off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, leaves)


@functools.partial(jax.jit, static_argnames=("sign", "use_pallas"))
def alf_midpoint(z: Pytree, v: Pytree, h, *, sign: float = 1.0,
                 use_pallas: bool = False) -> Pytree:
    """k1 = z + sign*v*h/2 over an arbitrary pytree state."""
    if not use_pallas:
        return jax.tree_util.tree_map(
            lambda zi, vi: ref.midpoint_ref(zi, vi, h, sign), z, v)
    zf, td, sh, n = _flatten(z)
    vf, _, _, _ = _flatten(v)
    k1 = midpoint_call(zf, vf, h, sign=sign)
    return _unflatten(k1, td, sh, n)


@functools.partial(jax.jit, static_argnames=("eta", "use_pallas"))
def alf_update(k1: Pytree, v: Pytree, u1: Pytree, h, *, eta: float = 1.0,
               use_pallas: bool = False) -> Tuple[Pytree, Pytree]:
    if not use_pallas:
        pairs = jax.tree_util.tree_map(
            lambda a, b, c: ref.update_ref(a, b, c, h, eta), k1, v, u1)
        z_out = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                       is_leaf=lambda p: isinstance(p, tuple))
        v_out = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                       is_leaf=lambda p: isinstance(p, tuple))
        return z_out, v_out
    kf, td, sh, n = _flatten(k1)
    vf, _, _, _ = _flatten(v)
    uf, _, _, _ = _flatten(u1)
    zo, vo = update_call(kf, vf, uf, h, eta=eta)
    return _unflatten(zo, td, sh, n), _unflatten(vo, td, sh, n)


@functools.partial(jax.jit, static_argnames=("eta", "use_pallas"))
def alf_inverse_update(k1: Pytree, v_out: Pytree, u1: Pytree, h, *,
                       eta: float = 1.0, use_pallas: bool = False
                       ) -> Tuple[Pytree, Pytree]:
    if not use_pallas:
        pairs = jax.tree_util.tree_map(
            lambda a, b, c: ref.inverse_update_ref(a, b, c, h, eta),
            k1, v_out, u1)
        z_in = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                      is_leaf=lambda p: isinstance(p, tuple))
        v_in = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                      is_leaf=lambda p: isinstance(p, tuple))
        return z_in, v_in
    kf, td, sh, n = _flatten(k1)
    vf, _, _, _ = _flatten(v_out)
    uf, _, _, _ = _flatten(u1)
    zi, vi = inverse_update_call(kf, vf, uf, h, eta=eta)
    return _unflatten(zi, td, sh, n), _unflatten(vi, td, sh, n)
