"""jit'd public wrappers for the fused ALF update kernels.

Pytree-generic: leaves are flattened/concatenated to a lane-aligned [rows,
128] buffer in a common storage dtype derived from the leaves
(``jnp.result_type`` — a bf16 tree stays bf16 in HBM, float64 states under
x64 stay f64), processed by one kernel launch, and split back with every
leaf's original dtype restored — so the whole model state is one fused
elementwise pass regardless of parameter structure.

``use_pallas=False`` (the CPU-container default) routes to the jnp oracle —
identical math, XLA-fused; the Pallas path (interpret=True on CPU, compiled
on TPU) is validated against it in tests.

Reverse rules: the ops a *forward* integration launches (``alf_midpoint``,
``alf_update``) carry closed-form ``jax.custom_vjp`` rules — the step is
elementwise in state, so each cotangent rule is just a second fused kernel
(``midpoint_vjp_call`` / ``update_vjp_call``) plus an identity and a scalar
h-cotangent reduction. Direct backprop (``Naive()``, ``SaveAt(steps=True)``,
dense output) therefore works through the launch. The backward-sweep ops
(``alf_inverse``, ``alf_inverse_update``, ``alf_bwd_pre``, ``alf_bwd_post``)
only ever run inside MALI's own custom_vjp backward and stay forward-only
by design — see ``repro.kernels.registry.NO_REVERSE_RULE``.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .alf_step import (LANES, bwd_post_call, bwd_pre_call, inverse_call,
                       inverse_update_call, midpoint_call, midpoint_vjp_call,
                       update_call, update_vjp_call)

Pytree = Any

_tm = jax.tree_util.tree_map


def _common_dtype(*trees):
    """The jnp.result_type of every leaf across the argument trees — the
    shared storage dtype of one fused launch (mixed trees promote once at
    the flatten, not silently to f32)."""
    leaves = [l for t in trees for l in jax.tree_util.tree_leaves(t)]
    return jnp.result_type(*leaves)


def _as_h(h, cdtype):
    """Normalize the step size to a strong scalar of at least f32 (f64 for
    f64 states) — the fixed aval the custom_vjp h-cotangent reproduces."""
    return jax.lax.convert_element_type(
        jnp.asarray(h), jnp.promote_types(cdtype, jnp.float32))


def _flatten(tree: Pytree, dtype) -> Tuple[jax.Array, Any, Any, int]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(dtype) for l in leaves])
    n = flat.shape[0]
    pad = (-n) % LANES
    flat = jnp.pad(flat, (0, pad)).reshape(-1, LANES)
    shapes = [(l.shape, l.dtype) for l in leaves]
    return flat, treedef, shapes, n


def _meta(tree: Pytree) -> Tuple[Any, Any, int]:
    """(treedef, shapes, n) of a tree without building its flat buffer —
    for unflattening a kernel output against a *different* tree's leaf
    dtypes (cotangents must reproduce the primal avals exactly)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [(l.shape, l.dtype) for l in leaves]
    n = 0
    for shape, _ in shapes:
        size = 1
        for s in shape:
            size *= s
        n += size
    return treedef, shapes, n


def _unflatten(flat: jax.Array, treedef, shapes, n: int) -> Pytree:
    flat = flat.reshape(-1)[:n]
    leaves = []
    off = 0
    for shape, dtype in shapes:
        size = 1
        for s in shape:
            size *= s
        leaves.append(flat[off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _pick(pairs: Pytree, i: int) -> Pytree:
    """Select component i from a tree whose leaves are tuples."""
    return _tm(lambda p: p[i], pairs, is_leaf=lambda p: isinstance(p, tuple))


def _dtype_tree(tree: Pytree) -> Pytree:
    """Scalar-zero carriers of a tree's leaf dtypes — a residual that
    records the primal avals' dtypes without keeping the arrays alive."""
    return _tm(lambda x: jnp.zeros((), x.dtype), tree)


def _cast_like(tree: Pytree, dt: Pytree) -> Pytree:
    return _tm(lambda x, d: x.astype(d.dtype), tree, dt)


def _meta_like(shaped: Pytree, dt: Pytree) -> Tuple[Any, Any, int]:
    """_meta with shapes from ``shaped`` and dtypes from ``dt``."""
    leaves, treedef = jax.tree_util.tree_flatten(shaped)
    dts = jax.tree_util.tree_leaves(dt)
    shapes = [(l.shape, d.dtype) for l, d in zip(leaves, dts)]
    n = 0
    for shape, _ in shapes:
        size = 1
        for s in shape:
            size *= s
        n += size
    return treedef, shapes, n


def _h_cotangent(h, coeff: float, a: Pytree, g: Pytree):
    """h_bar = coeff * sum over leaves of <a, g>, reduced at h's dtype."""
    tot = jnp.zeros((), h.dtype)
    for ai, gi in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(g)):
        tot = tot + jnp.sum(ai.astype(h.dtype) * gi.astype(h.dtype))
    return tot * coeff


# ---------------------------------------------------------------------------
# alf_midpoint: k1 = z + sign*v*h/2, with a closed-form VJP
#   z_bar = g;  v_bar = sign*(h/2)*g;  h_bar = sum <sign*v/2, g>
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _midpoint(sign, use_pallas, z, v, h):
    if not use_pallas:
        return _tm(lambda zi, vi: ref.midpoint_ref(zi, vi, h, sign), z, v)
    cd = _common_dtype(z, v)
    zf, td, sh, n = _flatten(z, cd)
    vf, _, _, _ = _flatten(v, cd)
    return _unflatten(midpoint_call(zf, vf, h, sign=sign), td, sh, n)


def _midpoint_fwd(sign, use_pallas, z, v, h):
    return _midpoint(sign, use_pallas, z, v, h), (v, h)


def _midpoint_bwd(sign, use_pallas, res, g):
    v, h = res
    if use_pallas:
        gf, _, _, _ = _flatten(g, _common_dtype(g))
        v_bar = _unflatten(midpoint_vjp_call(gf, h, sign=sign), *_meta(v))
    else:
        v_bar = _tm(lambda vi, gi:
                    ref.midpoint_vjp_ref(gi, h, sign).astype(vi.dtype), v, g)
    h_bar = _h_cotangent(h, 0.5 * sign, v, g)
    return (g, v_bar, h_bar)


_midpoint.defvjp(_midpoint_fwd, _midpoint_bwd)


@functools.partial(jax.jit, static_argnames=("sign", "use_pallas"))
def alf_midpoint(z: Pytree, v: Pytree, h, *, sign: float = 1.0,
                 use_pallas: bool = False) -> Pytree:
    """k1 = z + sign*v*h/2 over an arbitrary pytree state. Differentiable:
    the cotangent rule is closed-form (itself one fused kernel on the
    pallas path), so direct backprop works through the launch."""
    return _midpoint(float(sign), bool(use_pallas), z, v,
                     _as_h(h, _common_dtype(z, v)))


# ---------------------------------------------------------------------------
# alf_update: the forward tail, with a closed-form VJP
#   cot_vout = g_v + (h/2)*g_z
#   k1_bar = g_z;  v_bar = (1-2*eta)*cot_vout;  u1_bar = 2*eta*cot_vout
#   h_bar = sum <v_out/2, g_z>
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _update(eta, use_pallas, k1, v, u1, h):
    if not use_pallas:
        pairs = _tm(lambda a, b, c: ref.update_ref(a, b, c, h, eta),
                    k1, v, u1)
        return _pick(pairs, 0), _pick(pairs, 1)
    cd = _common_dtype(k1, v, u1)
    kf, td, sh, n = _flatten(k1, cd)
    vf, _, _, _ = _flatten(v, cd)
    uf, _, _, _ = _flatten(u1, cd)
    zo, vo = update_call(kf, vf, uf, h, eta=eta)
    return _unflatten(zo, td, sh, n), _unflatten(vo, *_meta(v))


def _update_fwd(eta, use_pallas, k1, v, u1, h):
    out = _update(eta, use_pallas, k1, v, u1, h)
    # v_out is the only array the bwd needs numerically (the h-cotangent);
    # the scalar dtype carriers pin the cotangent avals of v and u1.
    return out, (_dtype_tree(v), _dtype_tree(u1), out[1], h)


def _update_bwd(eta, use_pallas, res, g):
    v_dt, u1_dt, v_out, h = res
    g_z, g_v = g
    if use_pallas:
        cd = _common_dtype(g_z, g_v)
        gzf, _, _, _ = _flatten(g_z, cd)
        gvf, _, _, _ = _flatten(g_v, cd)
        vb, ub = update_vjp_call(gzf, gvf, h, eta=eta)
        v_bar = _unflatten(vb, *_meta_like(g_v, v_dt))
        u1_bar = _unflatten(ub, *_meta_like(g_v, u1_dt))
    else:
        pairs = _tm(lambda a, b: ref.update_vjp_ref(a, b, h, eta), g_z, g_v)
        v_bar = _cast_like(_pick(pairs, 0), v_dt)
        u1_bar = _cast_like(_pick(pairs, 1), u1_dt)
    h_bar = _h_cotangent(h, 0.5, v_out, g_z)
    return (g_z, v_bar, u1_bar, h_bar)


_update.defvjp(_update_fwd, _update_bwd)


@functools.partial(jax.jit, static_argnames=("eta", "use_pallas"))
def alf_update(k1: Pytree, v: Pytree, u1: Pytree, h, *, eta: float = 1.0,
               use_pallas: bool = False) -> Tuple[Pytree, Pytree]:
    """Forward tail (z_out, v_out). Differentiable: the step is linear in
    (k1, v, u1), so the VJP is closed-form — one fused kernel on the
    pallas path."""
    return _update(float(eta), bool(use_pallas), k1, v, u1,
                   _as_h(h, _common_dtype(k1, v, u1)))


# ---------------------------------------------------------------------------
# Forward-only backward-sweep ops (NO_REVERSE_RULE — only ever launched
# inside MALI's custom_vjp backward, which is itself never differentiated)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("eta", "use_pallas"))
def alf_inverse_update(k1: Pytree, v_out: Pytree, u1: Pytree, h, *,
                       eta: float = 1.0, use_pallas: bool = False
                       ) -> Tuple[Pytree, Pytree]:
    """psi^-1 tail given the (already recovered) midpoint k1."""
    if not use_pallas:
        pairs = _tm(lambda a, b, c: ref.inverse_update_ref(a, b, c, h, eta),
                    k1, v_out, u1)
        return _pick(pairs, 0), _pick(pairs, 1)
    cd = _common_dtype(k1, v_out, u1)
    kf, td, sh, n = _flatten(k1, cd)
    vf, _, _, _ = _flatten(v_out, cd)
    uf, _, _, _ = _flatten(u1, cd)
    zi, vi = inverse_update_call(kf, vf, uf, h, eta=eta)
    return _unflatten(zi, td, sh, n), _unflatten(vi, *_meta(v_out))


@functools.partial(jax.jit, static_argnames=("eta", "use_pallas"))
def alf_inverse(z_out: Pytree, v_out: Pytree, u1: Pytree, h, *,
                eta: float = 1.0, use_pallas: bool = False
                ) -> Tuple[Pytree, Pytree]:
    """Full psi^-1 state reconstruction in ONE elementwise pass: recover
    (z_in, v_in) from the step output (z_{i+1}, v_{i+1}), given
    u1 = f(k1, s1); the midpoint k1 = z_out - v_out*h/2 is re-derived
    inside the kernel instead of being read back from HBM."""
    if not use_pallas:
        pairs = _tm(lambda a, b, c: ref.inverse_ref(a, b, c, h, eta),
                    z_out, v_out, u1)
        return _pick(pairs, 0), _pick(pairs, 1)
    cd = _common_dtype(z_out, v_out, u1)
    zf, td, sh, n = _flatten(z_out, cd)
    vf, _, _, _ = _flatten(v_out, cd)
    uf, _, _, _ = _flatten(u1, cd)
    zi, vi = inverse_call(zf, vf, uf, h, eta=eta)
    return _unflatten(zi, td, sh, n), _unflatten(vi, *_meta(v_out))


@functools.partial(jax.jit, static_argnames=("eta", "use_pallas"))
def alf_bwd_pre(z_i: Pytree, v_i: Pytree, a_z: Pytree, a_v: Pytree, h, *,
                eta: float = 1.0, use_pallas: bool = False
                ) -> Tuple[Pytree, Pytree]:
    """Fused head of one MALI backward step: the inverse's midpoint
    k1 = z_i - v_i*h/2 plus the f-eval cotangent
    cot_u1 = 2*eta*(a_v + (h/2)*a_z) — which depends only on the adjoints,
    so the WHOLE elementwise algebra before the step's f linearization is
    this single launch."""
    if not use_pallas:
        pairs = _tm(lambda a, b, c, d: ref.bwd_pre_ref(a, b, c, d, h, eta),
                    z_i, v_i, a_z, a_v)
        return _pick(pairs, 0), _pick(pairs, 1)
    cd = _common_dtype(z_i, v_i, a_z, a_v)
    zf, td, sh, n = _flatten(z_i, cd)
    vf, _, _, _ = _flatten(v_i, cd)
    azf, _, _, _ = _flatten(a_z, cd)
    avf, _, _, _ = _flatten(a_v, cd)
    k1, cu = bwd_pre_call(zf, vf, azf, avf, h, eta=eta)
    return _unflatten(k1, td, sh, n), _unflatten(cu, *_meta(a_z))


@functools.partial(jax.jit, static_argnames=("eta", "use_pallas"))
def alf_bwd_post(k1: Pytree, v_out: Pytree, u1: Pytree, a_z: Pytree,
                 a_v: Pytree, dk1: Pytree, h, *, eta: float = 1.0,
                 use_pallas: bool = False
                 ) -> Tuple[Pytree, Pytree, Pytree, Pytree]:
    """Fused tail of one MALI backward step, given dk1 = vjp_f(cot_u1)
    from the shared f linearization: the psi^-1 reconstruction
    (z_prev, v_prev) plus the propagated adjoints (dz_prev, dv_prev) — all
    elementwise algebra after the f linearization, one launch."""
    if not use_pallas:
        pairs = _tm(lambda a, b, c, d, e, g:
                    ref.bwd_post_ref(a, b, c, d, e, g, h, eta),
                    k1, v_out, u1, a_z, a_v, dk1)
        return tuple(_pick(pairs, i) for i in range(4))
    cd = _common_dtype(k1, v_out, u1, a_z, a_v, dk1)
    kf, td, sh, n = _flatten(k1, cd)
    vf, _, _, _ = _flatten(v_out, cd)
    uf, _, _, _ = _flatten(u1, cd)
    azf, _, _, _ = _flatten(a_z, cd)
    avf, _, _, _ = _flatten(a_v, cd)
    df, _, _, _ = _flatten(dk1, cd)
    zp, vp, dz, dv = bwd_post_call(kf, vf, uf, azf, avf, df, h, eta=eta)
    return (_unflatten(zp, td, sh, n), _unflatten(vp, *_meta(v_out)),
            _unflatten(dz, *_meta(a_z)), _unflatten(dv, *_meta(a_v)))
