"""Pure-jnp oracle for the fused ALF state-update kernels.

These are the elementwise algebra of paper Algo 2/3 *between* the two f
evaluations — the part MALI executes once per step in forward and twice per
step (inverse + replay) in backward. Fusing them avoids ~6 HBM round-trips
of the full model state per solver step on TPU.
"""
from __future__ import annotations

import jax.numpy as jnp


def midpoint_ref(z: jnp.ndarray, v: jnp.ndarray, h, sign: float = 1.0):
    """k1 = z + sign * v * h/2 (sign=-1 gives the inverse's midpoint)."""
    return (z.astype(jnp.float32)
            + sign * v.astype(jnp.float32) * (h / 2)).astype(z.dtype)


def update_ref(k1: jnp.ndarray, v: jnp.ndarray, u1: jnp.ndarray, h,
               eta: float = 1.0):
    """Forward tail: v_out = v + 2*eta*(u1 - v); z_out = k1 + v_out*h/2."""
    k1f = k1.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    uf = u1.astype(jnp.float32)
    v_out = vf + 2.0 * eta * (uf - vf)
    z_out = k1f + v_out * (h / 2)
    return z_out.astype(k1.dtype), v_out.astype(v.dtype)


def inverse_update_ref(k1: jnp.ndarray, v_out: jnp.ndarray, u1: jnp.ndarray,
                       h, eta: float = 1.0):
    """Inverse tail: v_in from (u1, v_out); z_in = k1 - v_in*h/2."""
    k1f = k1.astype(jnp.float32)
    vf = v_out.astype(jnp.float32)
    uf = u1.astype(jnp.float32)
    if eta == 1.0:
        v_in = 2.0 * uf - vf
    else:
        v_in = (vf - 2.0 * eta * uf) / (1.0 - 2.0 * eta)
    z_in = k1f - v_in * (h / 2)
    return z_in.astype(k1.dtype), v_in.astype(v_out.dtype)
