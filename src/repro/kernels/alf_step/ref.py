"""Pure-jnp oracle for the fused ALF state-update kernels.

These are the elementwise algebra of paper Algo 2/3 *between* the two f
evaluations — the part MALI executes once per step in forward and twice per
step (inverse + replay) in backward. Fusing them avoids ~6 HBM round-trips
of the full model state per solver step on TPU.

Backward algebra (this file is the oracle for the fused backward kernels):
the ALF step is linear in state except for the single f evaluation, so its
cotangent rules are closed-form. With ``g_z``/``g_v`` the output cotangents
of one forward step and ``a_z``/``a_v`` MALI's adjoint state:

    cot_vout = g_v + (h/2) * g_z          # v_out feeds z_out with weight h/2
    k1_bar   = g_z                        # identity (handled by callers)
    v_bar    = (1 - 2*eta) * cot_vout
    u1_bar   = 2*eta * cot_vout           # the cotangent handed to vjp(f)

Compute dtype: ``_acc`` promotes the storage dtype to at least float32 —
bf16 leaves accumulate in f32 and are cast back at the write, while float64
states (x64 mode) stay in f64 end to end instead of rounding through f32.
"""
from __future__ import annotations

import jax.numpy as jnp


def _acc(x):
    """Storage dtype -> compute dtype: f32 accumulation for sub-f32
    storage; f64 is preserved (never rounded through f32)."""
    return x.astype(jnp.promote_types(x.dtype, jnp.float32))


def midpoint_ref(z: jnp.ndarray, v: jnp.ndarray, h, sign: float = 1.0):
    """k1 = z + sign * v * h/2 (sign=-1 gives the inverse's midpoint)."""
    return (_acc(z) + sign * _acc(v) * (h / 2)).astype(z.dtype)


def update_ref(k1: jnp.ndarray, v: jnp.ndarray, u1: jnp.ndarray, h,
               eta: float = 1.0):
    """Forward tail: v_out = v + 2*eta*(u1 - v); z_out = k1 + v_out*h/2."""
    k1f, vf, uf = _acc(k1), _acc(v), _acc(u1)
    v_out = vf + 2.0 * eta * (uf - vf)
    z_out = k1f + v_out * (h / 2)
    return z_out.astype(k1.dtype), v_out.astype(v.dtype)


def inverse_update_ref(k1: jnp.ndarray, v_out: jnp.ndarray, u1: jnp.ndarray,
                       h, eta: float = 1.0):
    """Inverse tail: v_in from (u1, v_out); z_in = k1 - v_in*h/2."""
    k1f, vf, uf = _acc(k1), _acc(v_out), _acc(u1)
    if eta == 1.0:
        v_in = 2.0 * uf - vf
    else:
        v_in = (vf - 2.0 * eta * uf) / (1.0 - 2.0 * eta)
    z_in = k1f - v_in * (h / 2)
    return z_in.astype(k1.dtype), v_in.astype(v_out.dtype)


def inverse_ref(z_out: jnp.ndarray, v_out: jnp.ndarray, u1: jnp.ndarray, h,
                eta: float = 1.0):
    """Full psi^-1 in one pass: recover (z_in, v_in) from the step output,
    re-deriving the midpoint k1 = z_out - v_out*h/2 internally (Algo 3)."""
    zf, vf, uf = _acc(z_out), _acc(v_out), _acc(u1)
    k1 = zf - vf * (h / 2)
    if eta == 1.0:
        v_in = 2.0 * uf - vf
    else:
        v_in = (vf - 2.0 * eta * uf) / (1.0 - 2.0 * eta)
    z_in = k1 - v_in * (h / 2)
    return z_in.astype(z_out.dtype), v_in.astype(v_out.dtype)


def midpoint_vjp_ref(g: jnp.ndarray, h, sign: float = 1.0):
    """v-cotangent of the midpoint: v_bar = sign * (h/2) * g (z_bar = g
    is the identity and stays with the caller)."""
    return (sign * _acc(g) * (h / 2)).astype(g.dtype)


def update_vjp_ref(g_z: jnp.ndarray, g_v: jnp.ndarray, h, eta: float = 1.0):
    """(v_bar, u1_bar) cotangents of the forward tail (k1_bar = g_z is the
    identity and stays with the caller)."""
    cot_vout = _acc(g_v) + _acc(g_z) * (h / 2)
    v_bar = (1.0 - 2.0 * eta) * cot_vout
    u1_bar = 2.0 * eta * cot_vout
    return v_bar.astype(g_v.dtype), u1_bar.astype(g_v.dtype)


def bwd_pre_ref(z: jnp.ndarray, v: jnp.ndarray, a_z: jnp.ndarray,
                a_v: jnp.ndarray, h, eta: float = 1.0):
    """Head of one MALI backward step, fused: the inverse's midpoint
    k1 = z - v*h/2 AND the f-eval cotangent u1_bar = 2*eta*(a_v + (h/2)*a_z)
    — the latter depends only on the adjoints, so it is ready *before* the
    f linearization runs."""
    k1 = _acc(z) - _acc(v) * (h / 2)
    cot_u1 = 2.0 * eta * (_acc(a_v) + _acc(a_z) * (h / 2))
    return k1.astype(z.dtype), cot_u1.astype(a_z.dtype)


def bwd_post_ref(k1: jnp.ndarray, v_out: jnp.ndarray, u1: jnp.ndarray,
                 a_z: jnp.ndarray, a_v: jnp.ndarray, dk1: jnp.ndarray,
                 h, eta: float = 1.0):
    """Tail of one MALI backward step, fused: the psi^-1 reconstruction
    (z_prev, v_prev) AND the propagated adjoints (dz_prev, dv_prev), given
    dk1 = vjp_f(u1_bar) from the shared f linearization."""
    k1f, vf, uf = _acc(k1), _acc(v_out), _acc(u1)
    azf, avf, dkf = _acc(a_z), _acc(a_v), _acc(dk1)
    if eta == 1.0:
        v_prev = 2.0 * uf - vf
    else:
        v_prev = (vf - 2.0 * eta * uf) / (1.0 - 2.0 * eta)
    z_prev = k1f - v_prev * (h / 2)
    cot_k1 = azf + dkf
    cot_vout = avf + azf * (h / 2)
    dv_prev = cot_k1 * (h / 2) + (1.0 - 2.0 * eta) * cot_vout
    return (z_prev.astype(k1.dtype), v_prev.astype(v_out.dtype),
            cot_k1.astype(a_z.dtype), dv_prev.astype(a_v.dtype))
