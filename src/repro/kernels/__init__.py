"""Pallas TPU kernels (interpret-mode validated on CPU) + jnp oracles."""
from .registry import NO_REVERSE_RULE, forward_only_ops, no_reverse_reason

__all__ = ["NO_REVERSE_RULE", "no_reverse_reason", "forward_only_ops"]
