"""Pallas TPU kernels (interpret-mode validated on CPU) + jnp oracles."""
