"""Reverse-rule registry for the Pallas kernel layer.

Every public op in ``kernels/*/ops.py`` must either define a
``jax.custom_vjp`` or appear here, in the explicit ``NO_REVERSE_RULE``
allowlist (odelint rule R003 enforces this mechanically). An entry means
"this op is forward-only BY DESIGN": differentiating through the kernel
launch is either impossible (interpret-mode ``pallas_call`` has no
transpose rule) or deliberately avoided because the surrounding gradient
method never needs it.

``GradientMethod`` validation reads this registry through
:func:`repro.core.naive.check_direct_backprop`: a method that
backpropagates directly through recorded solver steps looks up every op
the solver's trial step dispatches (``Solver.pallas_step_ops``) and
refuses any that is allowlisted here — with the recorded justification —
instead of silently tracing a launch that AD cannot transpose. Ops with a
``custom_vjp`` are absent from this dict and pass.

This module is import-light on purpose (no jax, no kernel imports) so
``repro.core`` can read it without a circular dependency.
"""
from __future__ import annotations

from typing import Optional

# Map "<kernel package>.<op name>" -> justification. Keep each entry's
# justification with the entry (R003 rejects empty/placeholder reasons):
# these strings are the reviewed record of WHY forward-only is sound.
NO_REVERSE_RULE = {
    # ALF fused state updates: the *forward* ops (alf_midpoint, alf_update)
    # now carry closed-form custom_vjp rules — fused VJP kernels — so they
    # are deliberately ABSENT here and direct backprop (Naive, dense
    # SaveAt) accepts backend='pallas'. Only the backward-sweep ops below
    # stay forward-only: they are MALI's backward.
    "alf_step.alf_inverse":
        "psi^-1 reconstruction op; only ever called inside custom_vjp "
        "backward sweeps, which are themselves never differentiated (no "
        "double-backward support)",
    "alf_step.alf_inverse_update":
        "only ever called inside custom_vjp backward sweeps, which are "
        "themselves never differentiated (no double-backward support)",
    "alf_step.alf_bwd_pre":
        "fused head of one MALI backward step (inverse midpoint + f-eval "
        "cotangent); lives inside _mali_grid_bwd and is never itself "
        "differentiated",
    "alf_step.alf_bwd_post":
        "fused tail of one MALI backward step (inverse tail + adjoint "
        "propagation); lives inside _mali_grid_bwd and is never itself "
        "differentiated",
    # Transformer/SSM serving kernels: inference-path only. Training uses
    # the jnp oracle implementations, which AD handles natively.
    "flash_attention.flash_attention":
        "serving/prefill path only; training falls back to the jnp oracle "
        "(ops wrapper), so no VJP for the Pallas launch is required",
    "mamba_scan.selective_scan":
        "forward serving scan; the training path scans chunks with the jnp "
        "oracle where XLA derives the gradient",
    "rmsnorm.rmsnorm":
        "elementwise-norm serving kernel; training uses the jnp oracle and "
        "XLA's native VJP",
}


def no_reverse_reason(qualname: str) -> Optional[str]:
    """Justification string if ``qualname`` ("package.op") is registered
    forward-only, else None (the op has — or must define — a VJP)."""
    return NO_REVERSE_RULE.get(qualname)


def forward_only_ops(package: str) -> list:
    """All allowlisted op names inside one kernel package."""
    prefix = package + "."
    return sorted(k[len(prefix):] for k in NO_REVERSE_RULE if
                  k.startswith(prefix))
