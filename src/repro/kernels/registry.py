"""Reverse-rule registry for the Pallas kernel layer.

Every public op in ``kernels/*/ops.py`` must either define a
``jax.custom_vjp`` or appear here, in the explicit ``NO_REVERSE_RULE``
allowlist (odelint rule R003 enforces this mechanically). An entry means
"this op is forward-only BY DESIGN": differentiating through the kernel
launch is either impossible (interpret-mode ``pallas_call`` has no
transpose rule) or deliberately avoided because the surrounding gradient
method never needs it.

``GradientMethod`` validation reads this registry
(:meth:`repro.core.naive.Naive.validate`,
:func:`repro.core.solve._check_direct_backprop`): a method that
backpropagates directly through recorded solver steps must refuse a solver
backend whose step ops are allowlisted here, instead of silently tracing a
launch that AD cannot transpose.

This module is import-light on purpose (no jax, no kernel imports) so
``repro.core`` can read it without a circular dependency.
"""
from __future__ import annotations

from typing import Optional

# Map "<kernel package>.<op name>" -> justification. Keep each entry's
# justification with the entry (R003 rejects empty/placeholder reasons):
# these strings are the reviewed record of WHY forward-only is sound.
NO_REVERSE_RULE = {
    # ALF fused state updates: MALI reconstructs states by running the
    # algebraically exact inverse update (Algo 3) instead of differentiating
    # the forward launch; Naive() must (and does) reject backend='pallas'.
    "alf_step.alf_midpoint":
        "MALI inverts the leapfrog algebraically (alf_inverse_update); the "
        "backward pass re-derives k1 and never transposes the launch",
    "alf_step.alf_update":
        "reverse-accurate gradient comes from state reconstruction, not AD "
        "through the kernel; Naive.validate rejects the pallas backend",
    "alf_step.alf_inverse_update":
        "only ever called inside custom_vjp backward sweeps, which are "
        "themselves never differentiated (no double-backward support)",
    # Transformer/SSM serving kernels: inference-path only. Training uses
    # the jnp oracle implementations, which AD handles natively.
    "flash_attention.flash_attention":
        "serving/prefill path only; training falls back to the jnp oracle "
        "(ops wrapper), so no VJP for the Pallas launch is required",
    "mamba_scan.selective_scan":
        "forward serving scan; the training path scans chunks with the jnp "
        "oracle where XLA derives the gradient",
    "rmsnorm.rmsnorm":
        "elementwise-norm serving kernel; training uses the jnp oracle and "
        "XLA's native VJP",
}


def no_reverse_reason(qualname: str) -> Optional[str]:
    """Justification string if ``qualname`` ("package.op") is registered
    forward-only, else None (the op has — or must define — a VJP)."""
    return NO_REVERSE_RULE.get(qualname)


def forward_only_ops(package: str) -> list:
    """All allowlisted op names inside one kernel package."""
    prefix = package + "."
    return sorted(k[len(prefix):] for k in NO_REVERSE_RULE if
                  k.startswith(prefix))
