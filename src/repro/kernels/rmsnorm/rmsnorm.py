"""Pallas TPU kernel: fused RMSNorm over [rows, d] with (block_rows, d)
VMEM tiles — one HBM read + one write per element, reduction in f32.

d must be lane-aligned (multiple of 128) for the VPU; the ops wrapper pads
otherwise (all assigned archs have d_model % 128 == 0 except gemma2's 2304
which is 18*128 — fine).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_call(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
                 block_rows: int = BLOCK_ROWS, interpret: bool = True):
    rows, d = x.shape
    bs = min(block_rows, rows)
    assert rows % bs == 0
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // bs,),
        in_specs=[pl.BlockSpec((bs, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((bs, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, scale)
