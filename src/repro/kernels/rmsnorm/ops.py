"""jit'd wrapper for the fused RMSNorm kernel (jnp fallback on CPU)."""
from __future__ import annotations

import functools

import jax

from . import ref
from .rmsnorm import rmsnorm_call


@functools.partial(jax.jit, static_argnames=("eps", "use_pallas"))
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
            use_pallas: bool = False) -> jax.Array:
    """x: [..., d]; scale: [d]."""
    if not use_pallas:
        return ref.rmsnorm_ref(x, scale, eps)
    shape = x.shape
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, shape[-1])
    # pick a block size dividing rows
    bs = 256
    while rows % bs:
        bs //= 2
    out = rmsnorm_call(x2, scale, eps, block_rows=max(bs, 1))
    return out.reshape(shape)
