"""xLSTM mixers: mLSTM (matrix memory) and sLSTM (scalar memory + hidden
mixing) blocks (Beck et al. 2024, arXiv:2405.04517).

Both are token-axis recurrences; the depth-axis Neural-ODE wrapping (MALI)
is orthogonal and composes cleanly (DESIGN.md §Arch-applicability).

Train path scans over sequence chunks with ``jax.checkpoint`` around the
chunk body (same memory strategy as ssm.py). Decode is an O(1) state update.

mLSTM per-head state: matrix memory C [dk, dv], normalizer n [dk], and the
log-domain gate stabilizer m (exp input gate + sigmoid/exp forget gate,
stabilized as in the paper App. A).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from .common import dense_init, silu

Pytree = Any

_CHUNK = 64


def _head_dims(cfg: ModelConfig) -> Tuple[int, int]:
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    return nh, dh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg: ModelConfig):
    """mLSTM operates in the up-projected space: up = proj_factor * d."""
    up = int(cfg.lstm_proj_factor * cfg.d_model)
    nh = cfg.n_heads
    assert up % nh == 0
    return up, nh, up // nh


def init_mlstm(key: jax.Array, cfg: ModelConfig) -> Pytree:
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    up, nh, dh = _mlstm_dims(cfg)
    keys = jax.random.split(key, 8)
    return {
        "w_up": dense_init(keys[0], (d, 2 * up), dt),      # value path + gate
        "w_q": dense_init(keys[1], (up, nh * dh), dt, fan_in=up),
        "w_k": dense_init(keys[2], (up, nh * dh), dt, fan_in=up),
        "w_v": dense_init(keys[3], (up, nh * dh), dt, fan_in=up),
        "w_i": dense_init(keys[4], (up, nh), dt, fan_in=up),
        "w_f": dense_init(keys[5], (up, nh), dt, fan_in=up),
        "f_bias": jnp.full((nh,), 3.0, jnp.float32),       # open forget gates
        "w_down": dense_init(keys[6], (up, d), dt, fan_in=up),
        "out_norm": jnp.ones((up,), dt),
    }


def _mlstm_step(carry, inp):
    """carry: (C [B,H,dk,dv], n [B,H,dk], m [B,H]); one token."""
    c_mem, n_mem, m = carry
    q, k, v, i_raw, f_raw = inp                     # q/k/v [B,H,dh]; gates [B,H]
    f_log = jax.nn.log_sigmoid(f_raw)               # log forget gate
    m_new = jnp.maximum(f_log + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(f_log + m - m_new)
    c_new = f_g[..., None, None] * c_mem + \
        i_g[..., None, None] * (k[..., :, None] * v[..., None, :])
    n_new = f_g[..., None] * n_mem + i_g[..., None] * k
    h_num = jnp.einsum("bhkv,bhk->bhv", c_new, q)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)), 1.0)
    h = h_num / h_den[..., None]
    return (c_new, n_new, m_new), h


def _mlstm_qkvif(params: Pytree, cfg: ModelConfig, u: jax.Array):
    _, nh, dh = _mlstm_dims(cfg)
    b, s, up = u.shape
    scale = dh ** -0.5
    q = (u @ params["w_q"]).reshape(b, s, nh, dh).astype(jnp.float32) * scale
    k = (u @ params["w_k"]).reshape(b, s, nh, dh).astype(jnp.float32) * scale
    v = (u @ params["w_v"]).reshape(b, s, nh, dh).astype(jnp.float32)
    i_raw = (u @ params["w_i"]).astype(jnp.float32)
    f_raw = (u @ params["w_f"]).astype(jnp.float32) + params["f_bias"]
    return q, k, v, i_raw, f_raw


def apply_mlstm_train(params: Pytree, cfg: ModelConfig, x: jax.Array,
                      chunk: int = _CHUNK, return_state: bool = False):
    b, s, d = x.shape
    _, nh, dh = _mlstm_dims(cfg)
    u, gate = jnp.split(x @ params["w_up"], 2, axis=-1)
    q, k, v, i_raw, f_raw = _mlstm_qkvif(params, cfg, u)

    c = min(chunk, s)
    n_chunks = -(-s // c)
    pad = n_chunks * c - s
    if return_state and pad:
        raise ValueError("prefill requires seq_len % chunk == 0")

    def pad_r(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))

    seqs = jax.tree_util.tree_map(pad_r, (q, k, v, i_raw, f_raw))
    # [B, n_chunks, c, ...] -> scan over chunk axis
    seqs = jax.tree_util.tree_map(
        lambda a: jnp.moveaxis(a.reshape((b, n_chunks, c) + a.shape[2:]), 1, 0),
        seqs)

    @jax.checkpoint
    def chunk_body(carry, ch):
        qc, kc, vc, ic, fc = ch  # [B, c, ...]
        def tok(cr, t):
            return _mlstm_step(cr, jax.tree_util.tree_map(lambda a: a[:, t],
                                                          (qc, kc, vc, ic, fc)))
        carry, hs = lax.scan(tok, carry, jnp.arange(c))
        return carry, jnp.moveaxis(hs, 0, 1)  # [B, c, H, dh]

    carry0 = (jnp.zeros((b, nh, dh, dh), jnp.float32),
              jnp.zeros((b, nh, dh), jnp.float32),
              jnp.full((b, nh), -1e30, jnp.float32))
    carry, h_chunks = lax.scan(chunk_body, carry0, seqs)
    h = jnp.moveaxis(h_chunks, 0, 1).reshape(b, n_chunks * c, nh * dh)[:, :s]
    h = h.astype(x.dtype) * params["out_norm"] * silu(gate)
    out = h @ params["w_down"]
    if return_state:
        return out, carry
    return out


class LstmCache(NamedTuple):
    c: jax.Array   # mLSTM: [n_slots,B,H,dk,dv]; sLSTM: [n_slots,B,H,dh]
    n: jax.Array
    m: jax.Array   # [n_slots, B, H]
    h: jax.Array   # sLSTM hidden (zeros-shaped for mLSTM)

    @staticmethod
    def init_mlstm(cfg: ModelConfig, n_slots: int, batch: int):
        _, nh, dh = _mlstm_dims(cfg)
        return LstmCache(
            jnp.zeros((n_slots, batch, nh, dh, dh), jnp.float32),
            jnp.zeros((n_slots, batch, nh, dh), jnp.float32),
            jnp.full((n_slots, batch, nh), -1e30, jnp.float32),
            jnp.zeros((n_slots, batch, 1), jnp.float32))

    @staticmethod
    def init_slstm(cfg: ModelConfig, n_slots: int, batch: int):
        nh, dh = _head_dims(cfg)
        return LstmCache(
            jnp.zeros((n_slots, batch, nh, dh), jnp.float32),
            jnp.zeros((n_slots, batch, nh, dh), jnp.float32),
            jnp.full((n_slots, batch, nh), -1e30, jnp.float32),
            jnp.zeros((n_slots, batch, nh, dh), jnp.float32))


def apply_mlstm_decode(params: Pytree, cfg: ModelConfig, x: jax.Array,
                       cache: LstmCache, slot) -> Tuple[jax.Array, LstmCache]:
    b = x.shape[0]
    u, gate = jnp.split(x[:, 0] @ params["w_up"], 2, axis=-1)
    q, k, v, i_raw, f_raw = _mlstm_qkvif(params, cfg, u[:, None])
    sel = lambda a: lax.dynamic_index_in_dim(a, slot, 0, keepdims=False)
    carry = (sel(cache.c), sel(cache.n), sel(cache.m))
    (c_new, n_new, m_new), h = _mlstm_step(
        carry, jax.tree_util.tree_map(lambda a: a[:, 0], (q, k, v, i_raw, f_raw)))
    h = h.reshape(b, -1).astype(x.dtype) * params["out_norm"] * silu(gate)
    out = (h @ params["w_down"])[:, None]
    upd = lambda buf, val: lax.dynamic_update_slice(
        buf, val[None].astype(buf.dtype), (slot,) + (0,) * val.ndim)
    cache = LstmCache(upd(cache.c, c_new), upd(cache.n, n_new),
                      upd(cache.m, m_new), cache.h)
    return out, cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key: jax.Array, cfg: ModelConfig) -> Pytree:
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    nh, dh = _head_dims(cfg)
    keys = jax.random.split(key, 7)
    return {
        # input projections for (z, i, f, o) gates
        "w_in": dense_init(keys[0], (d, 4 * d), dt),
        # block-diagonal recurrent mixing per head (z, i, f, o)
        "r_in": dense_init(keys[1], (4, nh, dh, dh), jnp.float32, fan_in=dh),
        "bias": jnp.concatenate([jnp.zeros((3 * d,), jnp.float32),
                                 jnp.full((d,), 0.0, jnp.float32)]),
        "w_down": dense_init(keys[2], (d, d), dt),
        "out_norm": jnp.ones((d,), dt),
    }


def _slstm_step(params, cfg, carry, x_t):
    """carry: (c, n, m, h) each [B,H,dh] (m is [B,H]); x_t [B, 4*d] pre-proj."""
    nh, dh = _head_dims(cfg)
    c_mem, n_mem, m, h_prev = carry
    b = x_t.shape[0]
    rec = jnp.einsum("ghij,bhj->bghi", params["r_in"],
                     h_prev.astype(jnp.float32))        # [B,4,H,dh]
    pre = x_t.astype(jnp.float32).reshape(b, 4, nh, dh) + rec + \
        params["bias"].reshape(4, nh, dh)
    z_t = jnp.tanh(pre[:, 0])
    i_raw = pre[:, 1].mean(-1)                          # per-head gates [B,H]
    f_raw = pre[:, 2].mean(-1)
    o_t = jax.nn.sigmoid(pre[:, 3])
    f_log = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(f_log + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)[..., None]
    f_g = jnp.exp(f_log + m - m_new)[..., None]
    c_new = f_g * c_mem + i_g * z_t
    n_new = f_g * n_mem + i_g
    h_new = o_t * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new), h_new


def apply_slstm_train(params: Pytree, cfg: ModelConfig, x: jax.Array,
                      chunk: int = _CHUNK, return_state: bool = False):
    b, s, d = x.shape
    nh, dh = _head_dims(cfg)
    pre = x @ params["w_in"]                            # [B,S,4d]

    c = min(chunk, s)
    n_chunks = -(-s // c)
    pad = n_chunks * c - s
    if return_state and pad:
        raise ValueError("prefill requires seq_len % chunk == 0")
    pre_p = jnp.pad(pre, ((0, 0), (0, pad), (0, 0)))
    pre_p = jnp.moveaxis(pre_p.reshape(b, n_chunks, c, 4 * d), 1, 0)

    @jax.checkpoint
    def chunk_body(carry, ch):
        def tok(cr, t):
            return _slstm_step(params, cfg, cr, ch[:, t])
        carry, hs = lax.scan(tok, carry, jnp.arange(c))
        return carry, jnp.moveaxis(hs, 0, 1)

    carry0 = (jnp.zeros((b, nh, dh), jnp.float32),
              jnp.zeros((b, nh, dh), jnp.float32),
              jnp.full((b, nh), -1e30, jnp.float32),
              jnp.zeros((b, nh, dh), jnp.float32))
    carry, h_chunks = lax.scan(chunk_body, carry0, pre_p)
    h = jnp.moveaxis(h_chunks, 0, 1).reshape(b, n_chunks * c, d)[:, :s]
    h = h.astype(x.dtype) * params["out_norm"]
    out = h @ params["w_down"]
    if return_state:
        return out, carry
    return out


def apply_slstm_decode(params: Pytree, cfg: ModelConfig, x: jax.Array,
                       cache: LstmCache, slot) -> Tuple[jax.Array, LstmCache]:
    b = x.shape[0]
    pre = x[:, 0] @ params["w_in"]
    sel = lambda a: lax.dynamic_index_in_dim(a, slot, 0, keepdims=False)
    carry = (sel(cache.c), sel(cache.n), sel(cache.m), sel(cache.h))
    (c_new, n_new, m_new, h_new), h = _slstm_step(params, cfg, carry, pre)
    out_h = h.reshape(b, -1).astype(x.dtype) * params["out_norm"]
    out = (out_h @ params["w_down"])[:, None]
    upd = lambda buf, val: lax.dynamic_update_slice(
        buf, val[None].astype(buf.dtype), (slot,) + (0,) * val.ndim)
    cache = LstmCache(upd(cache.c, c_new), upd(cache.n, n_new),
                      upd(cache.m, m_new), upd(cache.h, h_new))
    return out, cache
