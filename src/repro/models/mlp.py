"""Dense gated-MLP (SwiGLU) feed-forward."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import dense_init, silu

Pytree = Any


def init_mlp(key: jax.Array, cfg: ModelConfig, d_ff: int) -> Pytree:
    dt = jnp.dtype(cfg.param_dtype)
    kg, ku, kd = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "w_gate": dense_init(kg, (d, d_ff), dt),
        "w_up": dense_init(ku, (d, d_ff), dt),
        "w_down": dense_init(kd, (d_ff, d), dt, fan_in=d_ff),
    }


def apply_mlp(params: Pytree, x: jax.Array) -> jax.Array:
    return (silu(x @ params["w_gate"]) * (x @ params["w_up"])) @ params["w_down"]
