"""Mixture-of-Experts FFN: top-k softmax router, capacity-bounded dispatch,
optional shared experts (DeepSeekMoE-style fine-grained + shared).

Dispatch is the GShard dense-einsum formulation — one-hot dispatch/combine
tensors contracted against the token batch — which shards cleanly under
GSPMD with the expert axis on the 'model' mesh axis (expert parallelism);
XLA lowers the dispatch einsums to all-to-alls when profitable.

Routing is a deterministic function of (z, t) ⇒ the ALF inverse re-derives
identical routing decisions during MALI's backward reconstruction (DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import dense_init, silu
from .mlp import apply_mlp, init_mlp

Pytree = Any


def init_moe(key: jax.Array, cfg: ModelConfig) -> Pytree:
    dt = jnp.dtype(cfg.param_dtype)
    d, e, dff = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff or cfg.d_ff
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(kr, (d, e), jnp.float32),  # router kept f32
        "w_gate": dense_init(kg, (e, d, dff), dt),
        "w_up": dense_init(ku, (e, d, dff), dt),
        "w_down": dense_init(kd, (e, dff, d), dt, fan_in=dff),
    }
    if cfg.moe_shared_experts > 0:
        params["shared"] = init_mlp(ks, cfg, dff * cfg.moe_shared_experts)
    return params


def _capacity(n_tokens: int, cfg: ModelConfig, factor: float) -> int:
    cap = int(math.ceil(n_tokens * cfg.moe_top_k / cfg.moe_experts * factor))
    return max(min(cap, n_tokens), cfg.moe_top_k)


def apply_moe(params: Pytree, cfg: ModelConfig, x: jax.Array,
              eval_mode: bool = False) -> jax.Array:
    """x: [B, S, D] -> [B, S, D]. eval_mode uses the (laxer) serve-time
    capacity factor — inference should be (near-)dropless."""
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    xt = x.reshape(b * s, d)
    n = b * s
    factor = cfg.moe_eval_capacity_factor if eval_mode else cfg.moe_capacity_factor
    cap = _capacity(n, cfg, factor)

    logits = xt.astype(jnp.float32) @ params["router"]          # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # [N, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                 # renormalize

    # Position of each (token, choice) within its expert's capacity buffer —
    # scatter/gather dispatch (MegaBlocks-style), O(N*k) memory instead of
    # the GShard dense [N, E, cap] tensors (infeasible for fine-grained MoE).
    # Rank-within-expert via a stable int32 argsort instead of a cumsum over
    # a [N*k, E] f32 one-hot (100+ MB and a log-pass cumsum at DeepSeek's
    # E=64): sort the expert ids, rank = index - group start, scatter back.
    eidx = gate_idx.reshape(-1)                                 # [N*k]
    order = jnp.argsort(eidx, stable=True)
    sorted_e = eidx[order]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(e, dtype=eidx.dtype),
                                   side="left")                 # [E]
    pos_sorted = (jnp.arange(n * k, dtype=jnp.int32)
                  - group_start[sorted_e].astype(jnp.int32))
    pos = jnp.zeros((n * k,), jnp.int32).at[order].set(pos_sorted)
    keep = (pos < cap) & (gate_vals.reshape(-1) > 0)
    pos_safe = jnp.minimum(pos, cap - 1)

    cdt = jnp.dtype(cfg.compute_dtype)
    x_rep = jnp.repeat(xt, k, axis=0)                           # [N*k, D]
    contrib = jnp.where(keep[:, None], x_rep, 0).astype(cdt)
    expert_in = jnp.zeros((e, cap, d), cdt).at[eidx, pos_safe].add(contrib)
    h = jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", silu(h) * u, params["w_down"])
    gathered = expert_out[eidx, pos_safe]                       # [N*k, D]
    w = (gate_vals.reshape(-1) * keep).astype(cdt)
    out = (gathered * w[:, None]).reshape(n, k, d).sum(axis=1)

    if cfg.moe_shared_experts > 0:
        out = out + apply_mlp(params["shared"], xt)
    return out.reshape(b, s, d)


def aux_load_balance_loss(params: Pytree, cfg: ModelConfig,
                          x: jax.Array) -> jax.Array:
    """Switch-style auxiliary load-balancing loss (fraction * prob)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.moe_experts), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    return cfg.moe_experts * jnp.sum(frac * mean_prob)
