"""Stub modality frontends for the [audio]/[vlm] archs.

Per the assignment, the transformer BACKBONE is what's specified; the
modality frontend (EnCodec for musicgen, InternViT for internvl2) is a STUB:
``input_specs()`` (see launch/dryrun.py) provides precomputed frame/patch
embeddings. These helpers generate deterministic synthetic embeddings with
the right shapes/dtypes for smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def synthetic_frame_embeddings(key: jax.Array, cfg: ModelConfig, batch: int,
                               seq_len: int) -> jax.Array:
    """Stand-in for EnCodec frame / ViT patch embeddings: [B, S, D]."""
    x = jax.random.normal(key, (batch, seq_len, cfg.d_model), jnp.float32)
    return (x * 0.02).astype(jnp.dtype(cfg.compute_dtype))


def synthetic_labels(key: jax.Array, cfg: ModelConfig, batch: int,
                     seq_len: int) -> jax.Array:
    return jax.random.randint(key, (batch, seq_len), 0, cfg.vocab_size,
                              jnp.int32)
