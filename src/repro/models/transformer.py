"""Block assembly: (prelude, scanned periods) x (mixer, mlp) residual
branches, with the paper's continuous-depth mode as a first-class feature.

Train path: each residual branch is either the discrete ``x + f(norm(x))``
(ode.mode='off' — the "ResNet" baseline of paper Sec 4.2) or the Neural-ODE
``x <- z(T), dz/dt = f_branch(z)`` integrated by the configured gradient
method (MALI by default) — paper Sec 4.2's ResNet->Neural-ODE conversion
applied per residual branch, parameter count unchanged.

Serve path (prefill/decode): forward-only, so the ALF steps are unrolled
explicitly with the KV/SSM cache threaded through every f-eval; each eval
index is a cache "virtual layer" slot (DESIGN.md §3).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LayerSpec, ModelConfig
from repro.core import solve
from repro.core.interface import RunStats
from .attention import (KVCache, attention_decode, attention_prefill,
                        attention_train, init_attention)
from .common import rmsnorm, rmsnorm_init
from .mlp import apply_mlp, init_mlp
from .moe import apply_moe, init_moe
from .ssm import MambaCache, apply_mamba_decode, apply_mamba_train, init_mamba
from .xlstm import (LstmCache, apply_mlstm_decode, apply_mlstm_train,
                    apply_slstm_decode, apply_slstm_train, init_mlstm,
                    init_slstm)

Pytree = Any


def n_cache_slots(cfg: ModelConfig) -> int:
    """Virtual-layer count per block: v0-init + one per ALF step."""
    if cfg.ode.mode == "off":
        return 1
    return cfg.ode.n_steps + 1


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

_MIXER_INIT = {
    "attn": init_attention,
    "mamba": init_mamba,
    "mlstm": init_mlstm,
    "slstm": init_slstm,
}


def init_layer(key: jax.Array, cfg: ModelConfig, spec: LayerSpec,
               dense_d_ff: Optional[int] = None) -> Pytree:
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    params = {
        "mixer_norm": rmsnorm_init(cfg.d_model, dt),
        "mixer": _MIXER_INIT[spec.mixer](k1, cfg),
    }
    if spec.mlp == "dense":
        params["mlp_norm"] = rmsnorm_init(cfg.d_model, dt)
        params["mlp"] = init_mlp(k2, cfg, dense_d_ff or cfg.d_ff)
    elif spec.mlp == "moe":
        params["mlp_norm"] = rmsnorm_init(cfg.d_model, dt)
        params["mlp"] = init_moe(k2, cfg)
    return params


# ---------------------------------------------------------------------------
# Train path
# ---------------------------------------------------------------------------

def _mixer_train_fn(cfg: ModelConfig, spec: LayerSpec, positions=None):
    # NOTE: positions must be None under the ODE path (tracer capture would
    # break custom_vjp's static f); attention computes its own arange.
    if spec.mixer == "attn":
        return lambda p, z: attention_train(p, cfg, spec, z, positions)
    if spec.mixer == "mamba":
        return lambda p, z: apply_mamba_train(p, cfg, z)
    if spec.mixer == "mlstm":
        return lambda p, z: apply_mlstm_train(p, cfg, z)
    return lambda p, z: apply_slstm_train(p, cfg, z)


def _mlp_train_fn(cfg: ModelConfig, spec: LayerSpec, eval_mode: bool = False):
    if spec.mlp == "moe":
        return lambda p, z: apply_moe(p, cfg, z, eval_mode=eval_mode)
    return lambda p, z: apply_mlp(p, z)


def zero_run_stats() -> RunStats:
    z = jnp.zeros((), jnp.int32)
    return RunStats(z, z, z)


def _detach_counter(c: jax.Array) -> jax.Array:
    # lax.stop_gradient is a no-op on integer dtypes, so a custom_vjp's
    # instantiated float0 tangent (R002c) rides through it and crashes the
    # first arithmetic op under a jvp trace (grad-of-scan, vmap-of-grad).
    # The int -> f32 conversion has no tangent space, so its jvp emits a
    # real float32 zero; stop_gradient then binds for real.
    return lax.stop_gradient(c.astype(jnp.float32)).astype(jnp.int32)


def add_run_stats(a: RunStats, b: RunStats) -> RunStats:
    return RunStats(a.n_accepted + b.n_accepted,
                    a.n_rejected + b.n_rejected,
                    a.n_fevals + b.n_fevals)


def _residual_branch(cfg: ModelConfig, branch_params: Pytree, x: jax.Array,
                     inner) -> Tuple[jax.Array, RunStats]:
    """Apply one residual branch discretely or as a Neural ODE.

    The ODE state (z, v) is kept in f32 — ALF's exact reversibility is a
    float-rounding property, and bf16 state would visibly degrade the
    backward reconstruction; ``f`` itself still computes in the model dtype
    (cast at the norm boundary). The discrete path is untouched.

    Returns the branch output and the solve's :class:`RunStats`. The raw
    counters are custom_vjp primal outputs — instantiated float0 tangents
    under a jvp trace (R002c) — so they are laundered through
    ``lax.stop_gradient`` before any cross-branch arithmetic, and only
    float0-tolerant ops (add, scan carry) ever touch them: a ``jnp.sum``
    here would hit ``reduce_sum`` on the instantiated float0 tangent and
    crash under grad-of-scan. ``solve`` already returns scalar totals, so
    no reduction is needed.
    """
    cdt = jnp.dtype(cfg.compute_dtype)

    def dynamics(p, z, t):
        out = inner(p["inner"], rmsnorm(p["norm"], z.astype(cdt)))
        return out.astype(jnp.float32)

    p = {"norm": branch_params["norm"], "inner": branch_params["inner"]}
    ode = cfg.ode
    if ode.mode == "off":
        return x + inner(p["inner"], rmsnorm(p["norm"], x)), zero_run_stats()
    solver, controller, gradient, saveat = ode.as_objects()
    sol = solve(dynamics, p, x.astype(jnp.float32), 0.0, ode.t1,
                solver=solver, controller=controller, gradient=gradient,
                saveat=saveat, batching=ode.batching())
    stats = RunStats(*(_detach_counter(c)
                       for c in (sol.stats.n_accepted, sol.stats.n_rejected,
                                 sol.stats.n_fevals)))
    return sol.ys.astype(x.dtype), stats


def layer_train(params: Pytree, cfg: ModelConfig, spec: LayerSpec,
                x: jax.Array, positions: jax.Array = None
                ) -> Tuple[jax.Array, RunStats]:
    mixer = _mixer_train_fn(cfg, spec, None)
    x, stats = _residual_branch(
        cfg, {"norm": params["mixer_norm"], "inner": params["mixer"]}, x,
        mixer)
    if spec.mlp != "none":
        mlp = _mlp_train_fn(cfg, spec)
        x, s2 = _residual_branch(
            cfg, {"norm": params["mlp_norm"], "inner": params["mlp"]}, x, mlp)
        stats = add_run_stats(stats, s2)
    return x, stats


def init_blocks(key: jax.Array, cfg: ModelConfig) -> Pytree:
    params: Pytree = {}
    keys = jax.random.split(key, max(len(cfg.prelude), 1) + 1)
    if cfg.prelude:
        params["prelude"] = [
            init_layer(keys[i], cfg, spec, dense_d_ff=cfg.prelude_d_ff or None)
            for i, spec in enumerate(cfg.prelude)]
    if cfg.period:
        def init_period(k):
            sub = {}
            ks = jax.random.split(k, len(cfg.period))
            for j, spec in enumerate(cfg.period):
                sub[f"sub{j}"] = init_layer(ks[j], cfg, spec)
            return sub

        pkeys = jax.random.split(keys[-1], cfg.n_periods)
        params["period"] = jax.vmap(init_period)(pkeys)
    return params


def blocks_train(params: Pytree, cfg: ModelConfig, x: jax.Array,
                 positions: jax.Array) -> Tuple[jax.Array, RunStats]:
    """Returns (activations, summed ODE RunStats over every residual branch).

    Stats counters are detached int32 scalars (see ``_residual_branch``), so
    carrying their sum through the period scan is float0-safe.
    """
    stats = zero_run_stats()
    for i, spec in enumerate(cfg.prelude):
        x, s = layer_train(params["prelude"][i], cfg, spec, x, positions)
        stats = add_run_stats(stats, s)

    if cfg.period:
        def period_fn(carry, pp):
            xc, sc = carry
            for j, spec in enumerate(cfg.period):
                xc, s = layer_train(pp[f"sub{j}"], cfg, spec, xc, positions)
                sc = add_run_stats(sc, s)
            return (xc, sc), None

        (x, stats), _ = lax.scan(period_fn, (x, stats), params["period"])
    return x, stats


# ---------------------------------------------------------------------------
# Serve path (prefill / decode) — explicit ALF unroll with cache threading
# ---------------------------------------------------------------------------

def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     s_max: int) -> Pytree:
    slots = n_cache_slots(cfg)
    if spec.mixer == "attn":
        return KVCache.init(cfg, slots, batch, s_max)
    if spec.mixer == "mamba":
        return MambaCache.init(cfg, slots, batch)
    if spec.mixer == "mlstm":
        return LstmCache.init_mlstm(cfg, slots, batch)
    return LstmCache.init_slstm(cfg, slots, batch)


def init_cache(cfg: ModelConfig, batch: int, s_max: int) -> Pytree:
    cache: Pytree = {}
    if cfg.prelude:
        cache["prelude"] = [init_layer_cache(cfg, spec, batch, s_max)
                            for spec in cfg.prelude]
    if cfg.period:
        def one_period():
            return {f"sub{j}": init_layer_cache(cfg, spec, batch, s_max)
                    for j, spec in enumerate(cfg.period)}

        proto = one_period()
        # tile (not zeros) to preserve non-zero inits (the -inf mLSTM stabilizer)
        cache["period"] = jax.tree_util.tree_map(
            lambda small: jnp.tile(small[None],
                                   (cfg.n_periods,) + (1,) * small.ndim),
            proto)
    return cache


def _write_slot(buf: jax.Array, val: jax.Array, slot) -> jax.Array:
    return lax.dynamic_update_slice(
        buf, val[None].astype(buf.dtype), (slot,) + (0,) * val.ndim)


def _mixer_serve(params, cfg, spec, z, cache, slot, pos_info, kind):
    """Dispatch one mixer f-eval with cache read/write at `slot`."""
    if spec.mixer == "attn":
        if kind == "prefill":
            return attention_prefill(params, cfg, spec, z, pos_info, cache, slot)
        return attention_decode(params, cfg, spec, z, pos_info, cache, slot)
    if spec.mixer == "mamba":
        if kind == "prefill":
            y, (conv_state, ssm_state) = apply_mamba_train(
                params, cfg, z, return_state=True)
            cache = MambaCache(_write_slot(cache.conv, conv_state, slot),
                               _write_slot(cache.ssm, ssm_state, slot))
            return y, cache
        return apply_mamba_decode(params, cfg, z, cache, slot)
    if spec.mixer == "mlstm":
        if kind == "prefill":
            y, (c_m, n_m, m_m) = apply_mlstm_train(params, cfg, z,
                                                   return_state=True)
            cache = LstmCache(_write_slot(cache.c, c_m, slot),
                              _write_slot(cache.n, n_m, slot),
                              _write_slot(cache.m, m_m, slot), cache.h)
            return y, cache
        return apply_mlstm_decode(params, cfg, z, cache, slot)
    if kind == "prefill":
        y, (c_m, n_m, m_m, h_m) = apply_slstm_train(params, cfg, z,
                                                    return_state=True)
        cache = LstmCache(_write_slot(cache.c, c_m, slot),
                          _write_slot(cache.n, n_m, slot),
                          _write_slot(cache.m, m_m, slot),
                          _write_slot(cache.h, h_m, slot))
        return y, cache
    return apply_slstm_decode(params, cfg, z, cache, slot)


def layer_serve(params: Pytree, cfg: ModelConfig, spec: LayerSpec,
                x: jax.Array, cache: Pytree, pos_info, kind: str
                ) -> Tuple[jax.Array, Pytree]:
    """One layer, serve mode. pos_info: positions [B,S] (prefill) or scalar
    pos (decode)."""
    ode = cfg.ode
    cdt = jnp.dtype(cfg.compute_dtype)

    def mixer_eval(z, slot, c):
        zn = rmsnorm(params["mixer_norm"], z.astype(cdt))
        y, c = _mixer_serve(params["mixer"], cfg, spec, zn, c, slot,
                            pos_info, kind)
        return y.astype(jnp.float32), c

    if ode.mode == "off":
        y, cache = mixer_eval(x, 0, cache)
        x = x + y.astype(x.dtype)
    else:
        n, eta = ode.n_steps, ode.eta
        h = ode.t1 / n
        v, cache = mixer_eval(x, 0, cache)          # v0 (slot 0)
        z = x.astype(jnp.float32)
        for i in range(n):
            k1 = z + v * (h / 2)
            u1, cache = mixer_eval(k1, i + 1, cache)  # slot i+1
            v = v + 2.0 * eta * (u1 - v)
            z = k1 + v * (h / 2)
        x = z.astype(x.dtype)

    if spec.mlp != "none":
        mlp = _mlp_train_fn(cfg, spec, eval_mode=True)

        def mlp_f(z):
            return mlp(params["mlp"],
                       rmsnorm(params["mlp_norm"], z.astype(cdt))
                       ).astype(jnp.float32)

        if ode.mode == "off":
            x = x + mlp_f(x).astype(x.dtype)
        else:
            n, eta = ode.n_steps, ode.eta
            h = ode.t1 / n
            v = mlp_f(x)
            z = x.astype(jnp.float32)
            for _ in range(n):
                k1 = z + v * (h / 2)
                u1 = mlp_f(k1)
                v = v + 2.0 * eta * (u1 - v)
                z = k1 + v * (h / 2)
            x = z.astype(x.dtype)
    return x, cache


def blocks_serve(params: Pytree, cfg: ModelConfig, x: jax.Array,
                 cache: Pytree, pos_info, kind: str
                 ) -> Tuple[jax.Array, Pytree]:
    new_cache: Pytree = {}
    if cfg.prelude:
        entries = []
        for i, spec in enumerate(cfg.prelude):
            x, ce = layer_serve(params["prelude"][i], cfg, spec, x,
                                cache["prelude"][i], pos_info, kind)
            entries.append(ce)
        new_cache["prelude"] = entries

    if cfg.period:
        def period_fn(xc, inp):
            pp, cc = inp
            outs = {}
            for j, spec in enumerate(cfg.period):
                xc, ce = layer_serve(pp[f"sub{j}"], cfg, spec, xc,
                                     cc[f"sub{j}"], pos_info, kind)
                outs[f"sub{j}"] = ce
            return xc, outs

        x, period_cache = lax.scan(period_fn, x,
                                   (params["period"], cache["period"]))
        new_cache["period"] = period_cache
    return x, new_cache
