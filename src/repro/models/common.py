"""Shared model components: norms, rotary embeddings, init, dtype policy."""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype,
               fan_in: Optional[int] = None) -> jax.Array:
    """Truncated-normal with 1/sqrt(fan_in) scale (standard LM init)."""
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key: jax.Array, shape: Tuple[int, ...], dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms (RMSNorm is the backbone default; Pallas kernel available in
# repro.kernels.rmsnorm — models call through `rmsnorm` so the kernel can be
# swapped in by the ops layer)
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Pytree:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Pytree, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, n_heads, d_head]; positions: [..., seq] (int)."""
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)          # [d_head/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, d/2]
    cos = jnp.cos(angles)[..., :, None, :]            # [..., S, 1, d/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS = {"silu": silu, "gelu": gelu, "relu": jax.nn.relu}
