"""Model substrate: attention/MoE/SSM/xLSTM blocks + continuous-depth LM
+ flow vector fields."""
from .lm import (ServeState, decode_step, init_lm, init_serve_state, lm_loss,
                 lm_loss_and_stats, prefill)
from .transformer import init_blocks, init_cache, n_cache_slots
from .vfield import init_mlp_vfield, mlp_vfield

__all__ = ["init_lm", "lm_loss", "lm_loss_and_stats", "prefill",
           "decode_step", "init_serve_state", "ServeState", "init_blocks",
           "init_cache", "n_cache_slots", "init_mlp_vfield", "mlp_vfield"]
