"""Model substrate: attention/MoE/SSM/xLSTM blocks + continuous-depth LM."""
from .lm import (ServeState, decode_step, init_lm, init_serve_state, lm_loss,
                 lm_loss_and_stats, prefill)
from .transformer import init_blocks, init_cache, n_cache_slots

__all__ = ["init_lm", "lm_loss", "lm_loss_and_stats", "prefill",
           "decode_step", "init_serve_state", "ServeState", "init_blocks",
           "init_cache", "n_cache_slots"]
