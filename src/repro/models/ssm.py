"""Mamba (selective SSM) mixer — the Jamba hybrid's dominant layer type.

Train/prefill path: chunked selective scan — ``lax.scan`` over sequence
chunks carrying the SSM state, with a parallel ``associative_scan`` inside
each chunk and ``jax.checkpoint`` around the chunk body so the backward pass
recomputes chunk internals instead of storing O(S * d_inner * d_state)
activations (which at Jamba scale would be tens of GB per chip).

Decode path: O(1) recurrent update against a (conv_state, ssm_state) cache.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import hint
from .common import dense_init, silu

Pytree = Any

_CHUNK = 4096


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.mamba_expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return d_inner, dt_rank, cfg.mamba_d_state, cfg.mamba_d_conv


def init_mamba(key: jax.Array, cfg: ModelConfig) -> Pytree:
    dt = jnp.dtype(cfg.param_dtype)
    d_inner, dt_rank, d_state, d_conv = _dims(cfg)
    d = cfg.d_model
    keys = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    return {
        "in_proj": dense_init(keys[0], (d, 2 * d_inner), dt),
        "conv_w": dense_init(keys[1], (d_conv, d_inner), dt, fan_in=d_conv),
        "conv_b": jnp.zeros((d_inner,), dt),
        "x_proj": dense_init(keys[2], (d_inner, dt_rank + 2 * d_state), dt,
                             fan_in=d_inner),
        "dt_proj": dense_init(keys[3], (dt_rank, d_inner), dt, fan_in=dt_rank),
        "dt_bias": jnp.full((d_inner,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(a),                                  # f32
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(keys[4], (d_inner, d), dt, fan_in=d_inner),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over S. x: [B, S, C]; w: [K, C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def _ssm_inputs(params: Pytree, cfg: ModelConfig, u: jax.Array):
    """u: [B, S, d_inner] -> (dA, dBu, C) discretized per-token terms."""
    d_inner, dt_rank, d_state, _ = _dims(cfg)
    proj = u @ params["x_proj"]
    dt_raw, b_mat, c_mat = jnp.split(
        proj, [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(
        (dt_raw @ params["dt_proj"]).astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])                              # [d_inner, S_st]
    dA = jnp.exp(delta[..., None] * a)                         # [B,S,di,st]
    dBu = (delta * u.astype(jnp.float32))[..., None] * \
        b_mat.astype(jnp.float32)[..., None, :]                # [B,S,di,st]
    return dA, dBu, c_mat.astype(jnp.float32)


def _scan_chunk(carry, chunk):
    """carry: h [B,di,st]; chunk: (dA, dBu) of [B,c,di,st]."""
    dA, dBu = chunk

    def combine(left, right):
        aL, bL = left
        aR, bR = right
        return aL * aR, bL * aR + bR

    a_cum, b_cum = lax.associative_scan(combine, (dA, dBu), axis=1)
    h_all = a_cum * carry[:, None] + b_cum                     # [B,c,di,st]
    return h_all[:, -1], h_all


def apply_mamba_train(params: Pytree, cfg: ModelConfig, x: jax.Array,
                      chunk: int = _CHUNK, return_state: bool = False):
    """x: [B, S, D] -> [B, S, D] (+ final (conv_state, ssm_state) if asked —
    requires S % chunk == 0 so the final scan carry is exact)."""
    b, s, d = x.shape
    d_inner, _, d_state, d_conv = _dims(cfg)
    ui, res = jnp.split(x @ params["in_proj"], 2, axis=-1)
    u = silu(_causal_conv(ui, params["conv_w"], params["conv_b"]))

    c = min(chunk, s)
    n_chunks = -(-s // c)
    pad = n_chunks * c - s
    if return_state and pad:
        raise ValueError("prefill requires seq_len % chunk == 0")
    u_p = jnp.pad(u, ((0, 0), (0, pad), (0, 0))) if pad else u

    dA, dBu, c_mat = _ssm_inputs(params, cfg, u_p)
    # pin batch->dp, d_inner->model: without these GSPMD replicates the
    # batch dim of the scan carry across 'data' (16x blowup; §Perf)
    dA = hint(dA, "batch", None, "model", None)
    dBu = hint(dBu, "batch", None, "model", None)
    dA = dA.reshape(b, n_chunks, c, d_inner, d_state)
    dBu = dBu.reshape(b, n_chunks, c, d_inner, d_state)

    @jax.checkpoint
    def chunk_body(h, ch):
        return _scan_chunk(h, ch)

    h0 = jnp.zeros((b, d_inner, d_state), jnp.float32)
    h_last, h_seq = lax.scan(chunk_body, h0,
                             (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBu, 1, 0)))
    h_seq = jnp.moveaxis(h_seq, 0, 1).reshape(b, n_chunks * c, d_inner, d_state)
    h_seq = hint(h_seq, "batch", None, "model", None)
    h_seq = h_seq[:, :s]
    # y[b,t,i] = sum_s h[b,t,i,s] * C[b,t,s]
    y = jnp.einsum("btis,bts->bti", h_seq, c_mat[:, :s])
    y = y + params["D"] * u.astype(jnp.float32)
    y = (y.astype(x.dtype)) * silu(res)
    out = y @ params["out_proj"]
    if not return_state:
        return out
    conv_state = ui[:, s - (d_conv - 1):].astype(jnp.float32)
    return out, (conv_state, h_last)


class MambaCache(NamedTuple):
    conv: jax.Array   # [n_slots, B, d_conv-1, d_inner]
    ssm: jax.Array    # [n_slots, B, d_inner, d_state]

    @staticmethod
    def init(cfg: ModelConfig, n_slots: int, batch: int):
        d_inner, _, d_state, d_conv = _dims(cfg)
        return MambaCache(
            jnp.zeros((n_slots, batch, d_conv - 1, d_inner), jnp.float32),
            jnp.zeros((n_slots, batch, d_inner, d_state), jnp.float32))


def apply_mamba_decode(params: Pytree, cfg: ModelConfig, x: jax.Array,
                       cache: MambaCache, slot) -> Tuple[jax.Array, MambaCache]:
    """x: [B, 1, D] single-token recurrent update."""
    b = x.shape[0]
    d_inner, _, d_state, d_conv = _dims(cfg)
    ui, res = jnp.split(x[:, 0] @ params["in_proj"], 2, axis=-1)  # [B, di]

    conv_state = lax.dynamic_index_in_dim(cache.conv, slot, 0, keepdims=False)
    window = jnp.concatenate(
        [conv_state, ui.astype(jnp.float32)[:, None]], axis=1)   # [B,d_conv,di]
    u = silu(jnp.einsum("bkc,kc->bc", window,
                        params["conv_w"].astype(jnp.float32))
             + params["conv_b"].astype(jnp.float32))
    new_conv = window[:, 1:]

    dA, dBu, c_mat = _ssm_inputs(params, cfg, u[:, None])         # S=1
    h_prev = lax.dynamic_index_in_dim(cache.ssm, slot, 0, keepdims=False)
    h = dA[:, 0] * h_prev + dBu[:, 0]                            # [B,di,st]
    y = jnp.einsum("bis,bs->bi", h, c_mat[:, 0])
    y = y + params["D"] * u
    y = (y.astype(x.dtype)) * silu(res)
    out = (y @ params["out_proj"])[:, None]

    cache = MambaCache(
        conv=lax.dynamic_update_slice(
            cache.conv, new_conv[None].astype(cache.conv.dtype),
            (slot, 0, 0, 0)),
        ssm=lax.dynamic_update_slice(
            cache.ssm, h[None].astype(cache.ssm.dtype), (slot, 0, 0, 0)))
    return out, cache
