"""GQA attention: RoPE, qk-norm, logit softcap, sliding window, KV cache.

Three execution paths:
  * ``attention_train`` — full-sequence causal attention. Short sequences use
    the direct einsum; long sequences use a flash-style chunked online-softmax
    (pure-jnp ``lax.scan`` over query/KV blocks: O(S * block) memory, lowers
    on any backend). The Pallas TPU kernel (repro.kernels.flash_attention)
    implements the same contraction for the hot path.
  * ``attention_prefill`` — train path + writes K/V into the cache slot.
  * ``attention_decode`` — single-token query against the cache.

The ``slot`` axis of the cache is the *virtual layer* index of continuous-
depth mode: every ALF f-eval inside a block gets its own KV slot (see
DESIGN.md §3); slot 0 is used when ode.mode == 'off'.

Shapes: activations [B, S, D]; q/k/v [B, S, H|K, d_head]; caches
k/v: [n_slots, B, S_max, K, d_head].
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LayerSpec, ModelConfig
from repro.distributed.sharding import hint, model_axis_size
from .common import apply_rope, dense_init, rmsnorm, rmsnorm_init, softcap

Pytree = Any

NEG_INF = -2.0 ** 30  # large-but-finite: keeps softmax NaN-free on fully-masked rows

# Direct-einsum threshold; above this the flash-style chunked path is used
# (keeps attention scores VMEM/loop-local instead of materializing
# [B, H, S, S] f32 in HBM — on TPU this is the Pallas kernel's contraction).
_DIRECT_SEQ_LIMIT = 2048
_BLOCK_Q = 512
_BLOCK_KV = 1024


def init_attention(key: jax.Array, cfg: ModelConfig) -> Pytree:
    dt = jnp.dtype(cfg.param_dtype)
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, k_, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    params = {
        "wq": dense_init(kq, (d, h * dh), dt),
        "wk": dense_init(kk, (d, k_ * dh), dt),
        "wv": dense_init(kv, (d, k_ * dh), dt),
        "wo": dense_init(ko, (h * dh, d), dt, fan_in=h * dh),
    }
    if cfg.qk_norm:
        params["q_norm"] = rmsnorm_init(dh, dt)
        params["k_norm"] = rmsnorm_init(dh, dt)
    return params


def _project_qkv(params: Pytree, cfg: ModelConfig, x: jax.Array,
                 positions: jax.Array):
    b, s, _ = x.shape
    h, k_, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ params["wq"]).reshape(b, s, h, dh)
    k = (x @ params["wk"]).reshape(b, s, k_, dh)
    v = (x @ params["wv"]).reshape(b, s, k_, dh)
    # pin: q sharded on whole heads, K/V replicated over 'model' when the
    # kv-head count doesn't divide it — otherwise GSPMD splits d_head and
    # every attention tile (and the qk-norm variance) needs a psum
    # (measured: 172k ARs / 21.6 TB wire on qwen3 prefill_32k; §Perf)
    q = hint(q, "batch", None, "model", None)
    k = hint(k, "batch", None, "model", None)
    v = hint(v, "batch", None, "model", None)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, window: int) -> jax.Array:
    """[Sq, Sk] additive bias: causal (+ sliding window if window > 0)."""
    keep = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        keep &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(keep, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa_direct(cfg: ModelConfig, q, k, v, bias) -> jax.Array:
    """[B,Sq,H,dh] x [B,Sk,K,dh] grouped attention, f32 accumulation."""
    b, sq, h, dh = q.shape
    k_heads = k.shape[2]
    g = h // k_heads
    qg = q.reshape(b, sq, k_heads, g, dh)
    scale = dh ** -0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = softcap(scores, cfg.attn_softcap)
    scores = scores + bias[None, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def _sdpa_chunked(cfg: ModelConfig, q, k, v, q_pos, k_pos, window,
                  block_q: int = _BLOCK_Q, block_kv: int = _BLOCK_KV):
    """Flash-style online-softmax over (Q-block x KV-block) tiles in pure jnp.

    Memory is O(block_q * block_kv) per tile instead of O(Sq * Sk); this is
    the backend-portable twin of the Pallas kernel.
    """
    b, sq, h, dh = q.shape
    (qp, kp_x, vp_x, qpos, kpos, nq, nkv, pad_q, pad_kv, g) = _chunk_arrays(
        cfg, q, k, v, q_pos, k_pos, block_q, block_kv, ctx_parallel=True)
    k_heads = h  # _chunk_arrays repeats KV to full head count (g == 1)
    scale = dh ** -0.5

    # Both loops consume their tiles as scan xs (dynamic-sliced per
    # iteration) rather than closures, so the loop state never carries the
    # full K/V arrays — keeps the while-carry (and real HBM traffic) at
    # O(tile) like the Pallas kernel.

    def q_block(carry, xs):
        qb, qpb = xs                              # [B, bq, K, G, dh]
        qb = qb.astype(jnp.float32)

        def kv_step(c, kxs):
            m, l, acc = c
            kb, vb, kposb = kxs
            kb = kb.astype(jnp.float32)           # [B, bk, K, dh]
            vb = vb.astype(jnp.float32)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb) * scale
            s = softcap(s, cfg.attn_softcap)
            s = s + _mask_bias(qpb, kposb, window)[None, None, None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vb)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, k_heads, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, k_heads, g, block_q), jnp.float32)
        a0 = jnp.zeros((b, k_heads, g, block_q, dh), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (kp_x, vp_x, kpos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry, out  # [B, K, G, bq, dh]

    _, outs = lax.scan(q_block, 0, (qp, qpos))    # [nq, B, K, G, bq, dh]
    out = jnp.moveaxis(outs, 0, 3)                # [B, K, G, nq, bq, dh]
    out = out.reshape(b, k_heads, g, nq * block_q, dh)[:, :, :, :sq]
    out = jnp.moveaxis(out.reshape(b, h, sq, dh), 1, 2)
    return out.astype(q.dtype)


def _chunk_arrays(cfg, q, k, v, q_pos, k_pos, block_q, block_kv,
                  ctx_parallel: bool = False):
    """Pad + tile q/k/v for the blocked paths. Returns grouped layouts.

    K/V are pre-repeated to the full head count (GQA -> MHA layout): the
    tiled (K, G) head split is not expressible as a single-axis GSPMD
    sharding, so GSPMD shards the KV tile stack along the kv-block axis and
    all-gathers one tile per loop iteration (measured 172k AGs / 1.35 TB on
    qwen3 prefill_32k; §Perf). With H fused the head dim shards cleanly and
    attention runs collective-free.
    """
    b, sq, h, dh = q.shape
    if k.shape[2] != h:
        rep = h // k.shape[2]
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    k_heads = h
    g = 1
    sk = k.shape[1]
    nq = -(-sq // block_q)
    nkv = -(-sk // block_kv)
    pad_q = nq * block_q - sq
    pad_kv = nkv * block_kv - sk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, (0, pad_q), constant_values=-1)
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    kpos = jnp.pad(k_pos, (0, pad_kv), constant_values=2 ** 30)
    qp = jnp.moveaxis(qp.reshape(b, nq, block_q, k_heads, g, dh), 1, 0)
    kp = jnp.moveaxis(kp.reshape(b, nkv, block_kv, k_heads, dh), 1, 0)
    vp = jnp.moveaxis(vp.reshape(b, nkv, block_kv, k_heads, dh), 1, 0)
    # tile stacks: batch on dp, scan axes replicated, heads on model when
    # they divide it; otherwise shard the per-tile q ROWS over 'model'
    # (context-parallel fallback for few-head archs like gemma2's 8 heads
    # on a 16-way axis — replicated-q attention costs 16x redundant
    # compute+memory; §Perf)
    if h % max(model_axis_size(), 1) == 0:
        qp = hint(qp, None, "batch", None, "model", None, None)
    elif ctx_parallel:
        # serve path only: the train path measures better with q left to
        # GSPMD when heads don't divide (gemma2 train 9.4 s vs 33.4 s; §Perf)
        qp = hint(qp, None, "batch", "model", None, None, None)
    kp = hint(kp, None, "batch", None, "model", None)
    vp = hint(vp, None, "batch", None, "model", None)
    return (qp, kp, vp, qpos.reshape(nq, block_q),
            kpos.reshape(nkv, block_kv), nq, nkv, pad_q, pad_kv, g)


@functools.lru_cache(maxsize=None)
def _make_flash_sdpa(softcap_val: float, window: int, scale: float,
                     block_q: int, block_kv: int):
    """FlashAttention-2-style custom_vjp over pre-tiled inputs.

    Forward: online softmax, residuals = (tiles, out, lse) — O(S*d), no
    O(S^2) tiles survive to the backward (the vanilla AD-of-scan backward
    stacks the per-tile f32 probabilities: measured 2.1 GB/layer residual at
    stablelm train_4k; EXPERIMENTS.md §Perf iteration 2).
    Backward: recompute each (q-block, kv-block) tile from (q,k,v,lse),
    accumulate dq/dk/dv — standard FA2, incl. the softcap chain rule.

    Tiled layouts: q [nq, B, bq, K, G, dh]; k/v [nkv, B, bk, K, dh];
    qpos [nq, bq]; kpos [nkv, bk]. Returns out [nq, B, K, G, bq, dh].
    """

    def _bias(qpb, kposb):
        return _mask_bias(qpb, kposb, window)[None, None, None]

    def _scores(qb, kb, qpb, kposb):
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb) * scale
        s = softcap(s, softcap_val)
        return s + _bias(qpb, kposb)

    def forward(qp, kp, vp, qpos, kpos):
        def q_block(carry, xs):
            qb, qpb = xs
            qb = qb.astype(jnp.float32)

            def kv_step(c, kxs):
                m, l, acc = c
                kb, vb, kposb = kxs
                s = _scores(qb, kb.astype(jnp.float32), qpb, kposb)
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                alpha = jnp.exp(m - m_new)
                l_new = l * alpha + p.sum(axis=-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32))
                return (m_new, l_new, acc_new), None

            b, bq, kh, g, dh = qb.shape
            m0 = jnp.full((b, kh, g, bq), NEG_INF, jnp.float32)
            l0 = jnp.zeros((b, kh, g, bq), jnp.float32)
            a0 = jnp.zeros((b, kh, g, bq, dh), jnp.float32)
            (m, l, acc), _ys = lax.scan(kv_step, (m0, l0, a0),
                                        (kp, vp, kpos))
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            # +inf lse for fully-masked (padding) rows => p == 0 in bwd
            lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)),
                            jnp.inf)
            return carry, (out, lse)

        _, (outs, lses) = lax.scan(q_block, 0, (qp, qpos))
        return outs, lses  # [nq,B,K,G,bq,dh], [nq,B,K,G,bq]

    @jax.custom_vjp
    def flash(qp, kp, vp, qpos, kpos):
        return forward(qp, kp, vp, qpos, kpos)[0]

    def flash_fwd(qp, kp, vp, qpos, kpos):
        outs, lses = forward(qp, kp, vp, qpos, kpos)
        return outs, (qp, kp, vp, qpos, kpos, outs, lses)

    def flash_bwd(res, g_out):
        qp, kp, vp, qpos, kpos, outs, lses = res
        nkv = kp.shape[0]
        b, bk, kh, dh = kp.shape[1:]
        # delta_i = sum_d dO_i * O_i  (FA2)
        delta = jnp.sum(g_out.astype(jnp.float32)
                        * outs.astype(jnp.float32), axis=-1)  # [nq,B,K,G,bq]

        def q_block(carry, xs):
            dk_all, dv_all = carry
            qb, dob, lseb, deltab, qpb = xs
            qb = qb.astype(jnp.float32)
            dob = dob.astype(jnp.float32)

            def kv_step(c, kxs):
                dq_b, = c
                kb, vb, kposb, j = kxs
                kb = kb.astype(jnp.float32)
                vb = vb.astype(jnp.float32)
                s_raw = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb) * scale
                s_c = softcap(s_raw, softcap_val)
                s_b = s_c + _bias(qpb, kposb)
                p = jnp.exp(s_b - lseb[..., None])           # [b,k,g,bq,bk]
                dv_t = jnp.einsum("bkgqs,bkgqd->bskd", p, dob)
                dp = jnp.einsum("bkgqd,bskd->bkgqs", dob, vb)
                ds_c = p * (dp - deltab[..., None])
                if softcap_val > 0:
                    ds = ds_c * (1.0 - (s_c / softcap_val) ** 2)
                else:
                    ds = ds_c
                ds = ds * scale
                dq_b = dq_b + jnp.einsum("bkgqs,bskd->bqkgd", ds, kb)
                dk_t = jnp.einsum("bkgqs,bqkgd->bskd", ds, qb)
                return (dq_b,), (dk_t, dv_t)

            dq0 = jnp.zeros(qb.shape, jnp.float32)
            (dq_b,), (dk_ts, dv_ts) = lax.scan(
                kv_step, (dq0,),
                (kp, vp, kpos, jnp.arange(nkv, dtype=jnp.int32)))
            return (dk_all + dk_ts, dv_all + dv_ts), dq_b

        dk0 = jnp.zeros(kp.shape, jnp.float32)
        dv0 = jnp.zeros(vp.shape, jnp.float32)
        (dk, dv), dqs = lax.scan(q_block, (dk0, dv0),
                                 (qp, g_out, lses, delta, qpos))
        return (dqs.astype(qp.dtype), dk.astype(kp.dtype),
                dv.astype(vp.dtype), None, None)

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


def _sdpa_chunked_flash(cfg: ModelConfig, q, k, v, q_pos, k_pos, window,
                        block_q: int = _BLOCK_Q, block_kv: int = _BLOCK_KV):
    """Differentiable chunked attention with the FA2-style backward."""
    b, sq, h, dh = q.shape
    (qp, kp, vp, qpos, kpos, nq, nkv, pad_q, pad_kv, g) = _chunk_arrays(
        cfg, q, k, v, q_pos, k_pos, block_q, block_kv)
    k_heads = h  # _chunk_arrays repeats KV to full head count (g == 1)
    flash = _make_flash_sdpa(float(cfg.attn_softcap), int(window),
                             float(dh ** -0.5), block_q, block_kv)
    outs = flash(qp, kp, vp, qpos, kpos)      # [nq, B, K, G, bq, dh]
    out = jnp.moveaxis(outs, 0, 3)            # [B, K, G, nq, bq, dh]
    out = out.reshape(b, k_heads, g, nq * block_q, dh)[:, :, :, :sq]
    out = jnp.moveaxis(out.reshape(b, h, sq, dh), 1, 2)
    return out.astype(q.dtype)


def _finish(params, b, s, out):
    return out.reshape(b, s, -1) @ params["wo"]


def attention_train(params: Pytree, cfg: ModelConfig, spec: LayerSpec,
                    x: jax.Array, positions: jax.Array = None) -> jax.Array:
    b, s, _ = x.shape
    if positions is None:
        # computed here (not closed over) so the ODE dynamics closure stays
        # tracer-free for custom_vjp's nondiff f argument
        positions = jnp.tile(jnp.arange(s, dtype=jnp.int32)[None], (b, 1))
    window = cfg.sliding_window if spec.attn_kind == "local" else 0
    q, k, v = _project_qkv(params, cfg, x, positions)
    if s <= _DIRECT_SEQ_LIMIT:
        bias = _mask_bias(positions[0], positions[0], window)
        out = _sdpa_direct(cfg, q, k, v, bias)
    elif getattr(cfg, "attn_bwd", "flash") == "flash":
        out = _sdpa_chunked_flash(cfg, q, k, v, positions[0], positions[0],
                                  window)
    else:
        out = _sdpa_chunked(cfg, q, k, v, positions[0], positions[0], window)
    return _finish(params, b, s, out)


class KVCache(NamedTuple):
    k: jax.Array  # [n_slots, B, S_max, K, dh]
    v: jax.Array

    @staticmethod
    def init(cfg: ModelConfig, n_slots: int, batch: int, s_max: int):
        dt = jnp.dtype(cfg.compute_dtype)
        shape = (n_slots, batch, s_max, cfg.n_kv_heads, cfg.d_head)
        return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def attention_prefill(params: Pytree, cfg: ModelConfig, spec: LayerSpec,
                      x: jax.Array, positions: jax.Array, cache: KVCache,
                      slot) -> Tuple[jax.Array, KVCache]:
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    cache = KVCache(
        k=lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype)[None],
                                   (slot, 0, 0, 0, 0)),
        v=lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype)[None],
                                   (slot, 0, 0, 0, 0)))
    window = cfg.sliding_window if spec.attn_kind == "local" else 0
    if s <= _DIRECT_SEQ_LIMIT:
        bias = _mask_bias(positions[0], positions[0], window)
        out = _sdpa_direct(cfg, q, k, v, bias)
    else:
        out = _sdpa_chunked(cfg, q, k, v, positions[0], positions[0], window)
    return _finish(params, b, s, out), cache


def attention_decode(params: Pytree, cfg: ModelConfig, spec: LayerSpec,
                     x: jax.Array, pos: jax.Array, cache: KVCache,
                     slot) -> Tuple[jax.Array, KVCache]:
    """One-token decode: x [B, 1, D]; pos scalar int32 (current position)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(params, cfg, x, positions)
    cache = KVCache(
        k=lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype)[None],
                                   (slot, 0, pos, 0, 0)),
        v=lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype)[None],
                                   (slot, 0, pos, 0, 0)))
    k_all = lax.dynamic_index_in_dim(cache.k, slot, 0, keepdims=False)
    v_all = lax.dynamic_index_in_dim(cache.v, slot, 0, keepdims=False)
    s_max = k_all.shape[1]
    k_pos = jnp.arange(s_max, dtype=jnp.int32)
    window = cfg.sliding_window if spec.attn_kind == "local" else 0
    keep = k_pos <= pos
    if window > 0:
        keep &= k_pos > pos - window
    bias = jnp.where(keep, 0.0, NEG_INF).astype(jnp.float32)[None, :]  # [1,S]
    out = _sdpa_direct(cfg, q, k_all, v_all, bias)
    return _finish(params, b, 1, out), cache
