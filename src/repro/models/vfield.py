"""Concat-time MLP vector fields for continuous flows (repro.cnf).

The canonical FFJORD field shape: ``f([z, t]) -> dz/dt`` through a tanh
MLP. Operates on a SINGLE state of shape (..., dim) — batch axes broadcast
through the matmuls, and the CNF wrapper vmaps per-sample where the trace
estimator needs a per-state linearization.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Pytree = Any


def init_mlp_vfield(key: jax.Array, dim: int, hidden: int = 64,
                    depth: int = 2, scale: float = 0.5) -> Dict[str, Any]:
    """Parameters of a concat-time tanh MLP field: (dim+1) -> hidden^depth
    -> dim. The output layer is zero-initialized so the flow starts at the
    identity map (logdet 0 — the stable CNF init)."""
    widths = [dim + 1] + [hidden] * depth + [dim]
    keys = jax.random.split(key, len(widths) - 1)
    layers = []
    for i, k in enumerate(keys):
        fan_in, fan_out = widths[i], widths[i + 1]
        last = i == len(keys) - 1
        w = (jnp.zeros((fan_in, fan_out)) if last
             else scale * jax.random.normal(k, (fan_in, fan_out))
             / jnp.sqrt(fan_in))
        layers.append({"w": w, "b": jnp.zeros((fan_out,))})
    return {"layers": layers}


def mlp_vfield(params: Pytree, z: jax.Array, t: jax.Array) -> jax.Array:
    """f(params, z, t) -> dz/dt for z of shape (..., dim); time enters as
    an extra input column (broadcast over batch axes)."""
    t_col = jnp.broadcast_to(jnp.asarray(t, z.dtype), z.shape[:-1] + (1,))
    h = jnp.concatenate([z, t_col], -1)
    layers = params["layers"]
    for layer in layers[:-1]:
        h = jnp.tanh(h @ layer["w"] + layer["b"])
    return h @ layers[-1]["w"] + layers[-1]["b"]
