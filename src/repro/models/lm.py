"""Full language model: embeddings -> blocks -> head, plus the train / prefill
/ decode entry points the launcher and dry-run lower.

Loss is next-token cross-entropy computed in sequence chunks under
``jax.checkpoint`` so the full [B, S, vocab] logits tensor is never alive
(vocab up to 256k makes the dense tensor tens of GB at the assigned shapes).

``input_mode='embeds'`` is the stub modality frontend of the [audio]/[vlm]
archs: the model consumes precomputed frame/patch embeddings from
``input_specs()`` instead of token ids (the backbone — the part under test —
is identical).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core.interface import RunStats
from .common import embed_init, rmsnorm, rmsnorm_init, softcap
from .transformer import blocks_serve, blocks_train, init_blocks, init_cache

Pytree = Any

_LOSS_CHUNK = 512


def init_lm(key: jax.Array, cfg: ModelConfig) -> Pytree:
    ke, kb, kh = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    params = {
        "embed": embed_init(ke, (cfg.vocab_size, cfg.d_model), dt),
        "blocks": init_blocks(kb, cfg),
        "final_norm": rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(kh, (cfg.d_model, cfg.vocab_size), dt)
    return params


def _head_matrix(params: Pytree, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def _embed(params: Pytree, cfg: ModelConfig, batch: Pytree) -> jax.Array:
    if cfg.input_mode == "embeds":
        return batch["embeds"].astype(jnp.dtype(cfg.compute_dtype))
    x = params["embed"][batch["tokens"]]
    return x.astype(jnp.dtype(cfg.compute_dtype))


def backbone_train(params: Pytree, cfg: ModelConfig, batch: Pytree
                   ) -> Tuple[jax.Array, RunStats]:
    """Returns (final hidden states, summed ODE RunStats — detached int32
    counters from every residual-branch solve; zeros with ode.mode='off')."""
    x = _embed(params, cfg, batch)
    x, stats = blocks_train(params["blocks"], cfg, x, None)
    return rmsnorm(params["final_norm"], x), stats


def chunked_ce_loss(h: jax.Array, head: jax.Array, labels: jax.Array,
                    cfg: ModelConfig, chunk: int = _LOSS_CHUNK) -> jax.Array:
    """Mean next-token CE without materializing [B, S, vocab]."""
    b, s, d = h.shape
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    h_p = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    l_p = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    h_p = jnp.moveaxis(h_p.reshape(b, n_chunks, chunk, d), 1, 0)
    l_p = jnp.moveaxis(l_p.reshape(b, n_chunks, chunk), 1, 0)

    @jax.checkpoint
    def chunk_loss(carry, inp):
        hc, lc = inp
        logits = (hc @ head).astype(jnp.float32)
        logits = softcap(logits, cfg.final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        valid = lc >= 0
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        nll = jnp.where(valid, lse - tgt, 0.0)
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    (total, count), _ = lax.scan(
        chunk_loss, (jnp.float32(0.0), jnp.int32(0)), (h_p, l_p))
    return total / jnp.maximum(count, 1)


def lm_loss_and_stats(params: Pytree, cfg: ModelConfig, batch: Pytree
                      ) -> Tuple[jax.Array, RunStats]:
    """Like :func:`lm_loss` but also returns the integration accounting.

    The stats are the ``has_aux`` side of the train step's value_and_grad:
    already stop_gradient-detached inside the backbone, so they thread out
    of a jitted (and microbatch-scanned) step without touching the float0
    tangent machinery (R002c).
    """
    h, stats = backbone_train(params, cfg, batch)
    loss = chunked_ce_loss(h, _head_matrix(params, cfg), batch["labels"], cfg)
    return loss, stats


def lm_loss(params: Pytree, cfg: ModelConfig, batch: Pytree) -> jax.Array:
    """batch: {'tokens' | 'embeds', 'labels'} with labels already shifted."""
    return lm_loss_and_stats(params, cfg, batch)[0]


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

class ServeState(NamedTuple):
    cache: Pytree
    pos: jax.Array   # next write position, int32


def init_serve_state(cfg: ModelConfig, batch: int, s_max: int) -> ServeState:
    return ServeState(init_cache(cfg, batch, s_max), jnp.int32(0))


def prefill(params: Pytree, cfg: ModelConfig, batch: Pytree,
            state: ServeState) -> Tuple[jax.Array, ServeState]:
    """Process the prompt; returns last-position logits + filled cache."""
    x = _embed(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.tile(jnp.arange(s, dtype=jnp.int32)[None], (b, 1))
    x, cache = blocks_serve(params["blocks"], cfg, x, state.cache,
                            positions, "prefill")
    h_last = rmsnorm(params["final_norm"], x[:, -1:])
    logits = (h_last @ _head_matrix(params, cfg)).astype(jnp.float32)
    logits = softcap(logits, cfg.final_softcap)
    return logits, ServeState(cache, jnp.int32(s))


def decode_step(params: Pytree, cfg: ModelConfig, tokens_or_embeds: jax.Array,
                state: ServeState) -> Tuple[jax.Array, ServeState]:
    """One decode step. tokens [B, 1] int32 (or [B, 1, D] embeds)."""
    if cfg.input_mode == "embeds" and tokens_or_embeds.ndim == 3:
        x = tokens_or_embeds.astype(jnp.dtype(cfg.compute_dtype))
    else:
        x = params["embed"][tokens_or_embeds].astype(
            jnp.dtype(cfg.compute_dtype))
    x, cache = blocks_serve(params["blocks"], cfg, x, state.cache,
                            state.pos, "decode")
    h = rmsnorm(params["final_norm"], x)
    logits = (h @ _head_matrix(params, cfg)).astype(jnp.float32)
    logits = softcap(logits, cfg.final_softcap)
    return logits, ServeState(cache, state.pos + 1)
