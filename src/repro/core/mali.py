"""MALI: Memory-efficient ALF Integrator (paper Algo 4) as a jax.custom_vjp.

The integrator is built around an *observation grid* ``ts`` of T timepoints
(the torchdiffeq ``odeint(func, y0, t)`` shape): the forward pass is a single
scan whose carry (z, v) crosses segment boundaries, emitting the augmented
state at every requested ``ts[k]``. The VJP residual set is exactly the
per-observation ``(z_k, v_k)`` pairs — O(T * N_z), *constant in the number of
solver steps*. The scalar ``t0 -> t1`` path is the length-1 grid
``ts = [t0, t1]``.

Backward: per segment (in reverse), reconstruct the trajectory step-by-step
with the exact ALF inverse (psi^-1) starting from the stored segment-end
state, and run one local VJP of psi per accepted step, accumulating the
adjoint state a(t) and dL/dtheta — the discretized Eq. (2)/(3) of the paper.
The trajectory cotangent g[k] is injected into a(t) as the sweep crosses
observation k. The stepsize *search* (rejected trials) is excluded, so the
effective computation-graph depth is N_f x N_t (Table 1, MALI column).

Gradients w.r.t. the observation times are not propagated (zeros); the
framework never differentiates them.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .alf import (alf_inverse, alf_step, alf_step_with_error, check_eta,
                  init_velocity, tree_add, tree_zeros_like)
from .integrate import (as_time_grid, fixed_grid_times,
                        integrate_adaptive_grid, integrate_fixed_grid,
                        reverse_masked_scan, reverse_segment_sweep,
                        scalar_time_grid)
from .stepsize import error_ratio

_tm = jax.tree_util.tree_map

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]


class MaliConfig(NamedTuple):
    """Static (hashable) integrator configuration."""
    f: Dynamics
    n_steps: int            # >0: fixed grid; 0: adaptive
    eta: float
    rtol: float
    atol: float
    max_steps: int
    fused_bwd: bool = True  # share the inverse's f-eval with the local VJP


def _traj_row(traj: Pytree, k: int) -> Pytree:
    return _tm(lambda b: b[k], traj)


def _step_backward(cfg: MaliConfig, params, z_i, v_i, t_start, h, a_z, a_v):
    """One reverse step: reconstruct the step input via psi^-1 and backprop
    psi, either fused (3 f-eval-equivalents) or via the reference two-pass."""
    if cfg.fused_bwd:
        return _fused_inverse_and_vjp(cfg.f, cfg.eta, params, z_i, v_i,
                                      t_start + h, h, a_z, a_v)
    z_prev, v_prev = alf_inverse(cfg.f, params, z_i, v_i, t_start + h, h,
                                 cfg.eta)
    dp, dz, dv = _local_step_vjp(cfg.f, cfg.eta, params, z_prev, v_prev,
                                 t_start, h, a_z, a_v)
    return z_prev, v_prev, dz, dv, dp


def _local_step_vjp(f, eta, params, z_prev, v_prev, t_prev, h, a_z, a_v):
    """VJP of one ALF step at the reconstructed input state (reference
    path: re-plays psi under jax.vjp; kept as the oracle for the fused
    implementation below)."""
    def step_fn(p, z, v):
        return alf_step(f, p, z, v, t_prev, h, eta)

    _, vjp_fn = jax.vjp(step_fn, params, z_prev, v_prev)
    return vjp_fn((a_z, a_v))  # (dL/dparams, dL/dz_prev, dL/dv_prev)


def _fused_inverse_and_vjp(f, eta, params, z_i, v_i, t_i, h, a_z, a_v):
    """One backward step of Algo 4 with the inverse's f-eval SHARED with the
    local VJP (beyond-paper optimization; EXPERIMENTS.md §Perf).

    The ALF inverse evaluates u1 = f(k1, s1) at k1 = z_i - v_i*h/2; the
    local VJP of psi needs the linearization of f at exactly the same point
    (k1 = z_prev + v_prev*h/2 by construction). One ``jax.vjp`` call
    provides both, cutting the backward from 4 to 3 f-eval-equivalents per
    step. The rest of psi is linear, so its VJP is written out by hand:

        v_out = (1-2*eta)*v_prev + 2*eta*u1 ;  z_out = k1 + v_out*h/2
        cot_vout = a_v + (h/2)*a_z
        cot_u1   = 2*eta*cot_vout
        (dparams, dk1) = vjp_f(cot_u1)
        cot_k1   = a_z + dk1
        dz_prev  = cot_k1
        dv_prev  = (h/2)*cot_k1 + (1-2*eta)*cot_vout

    Returns (z_prev, v_prev, dz_prev, dv_prev, dparams).
    """
    s1 = t_i - h / 2
    k1 = _tm(lambda zi, vi: zi - vi * (h / 2), z_i, v_i)
    u1, vjp_f = jax.vjp(lambda p, kk: f(p, kk, s1), params, k1)
    # inverse tail (Algo 3 / damped Appendix Algo 3)
    if eta == 1.0:
        v_prev = _tm(lambda ui, vo: 2.0 * ui - vo, u1, v_i)
    else:
        inv = 1.0 / (1.0 - 2.0 * eta)
        v_prev = _tm(lambda vo, ui: (vo - 2.0 * eta * ui) * inv, v_i, u1)
    z_prev = _tm(lambda ki, vp: ki - vp * (h / 2), k1, v_prev)
    # manual VJP of the (linear-except-f) forward step
    cot_vout = _tm(lambda av, az: av + (h / 2) * az, a_v, a_z)
    cot_u1 = _tm(lambda c: 2.0 * eta * c, cot_vout)
    dparams, dk1 = vjp_f(cot_u1)
    cot_k1 = _tm(jnp.add, a_z, dk1)
    dz_prev = cot_k1
    dv_prev = _tm(lambda ck, cv: (h / 2) * ck + (1.0 - 2.0 * eta) * cv,
                  cot_k1, cot_vout)
    return z_prev, v_prev, dz_prev, dv_prev, dparams


def _close_v0_vjp(f, params, z0, t0, a_z, a_v, g_params):
    """Close the v0 = f(z0, t0) initialization: route a_v into z0/params."""
    _, vjp_f = jax.vjp(lambda p, z: f(p, z, t0), params, z0)
    dp, dz = vjp_f(a_v)
    return tree_add(g_params, dp), tree_add(a_z, dz)


# ---------------------------------------------------------------------------
# Fixed-step MALI over an observation grid
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _mali_grid_fixed(cfg: MaliConfig, params: Pytree, z0: Pytree,
                     ts: jax.Array) -> Pytree:
    z_traj, _ = _mali_grid_fixed_forward(cfg, params, z0, ts)
    return z_traj


def _mali_grid_fixed_forward(cfg, params, z0, ts):
    v0 = init_velocity(cfg.f, params, z0, ts[0])

    def step(state, t, h):
        z, v = state
        return alf_step(cfg.f, params, z, v, t, h, cfg.eta)

    _, traj = integrate_fixed_grid(step, (z0, v0), ts, cfg.n_steps)
    return traj  # (z_traj, v_traj), each with leading axis T


def _mali_grid_fixed_fwd(cfg, params, z0, ts):
    z_traj, v_traj = _mali_grid_fixed_forward(cfg, params, z0, ts)
    # Residuals: the per-observation (z_k, v_k) pairs — O(T * N_z),
    # constant in n_steps.
    return z_traj, (params, z_traj, v_traj, ts)


def _mali_grid_fixed_bwd(cfg, res, g):
    params, z_traj, v_traj, ts = res

    def seg(carry, g_k1, xs_k):
        a_z, a_v, g_p = carry
        z_k1, v_k1, t0k, t1k = xs_k
        # The stored segment-end state is the exact forward value: resetting
        # to it (rather than chaining psi^-1 across segments) stops float
        # drift from accumulating across observations.
        a_z = tree_add(a_z, g_k1)
        step_ts, h = fixed_grid_times(t0k, t1k, cfg.n_steps)

        def body(c, t_start):
            z_i, v_i, az, av, gp = c
            z_prev, v_prev, dz, dv, dp = _step_backward(
                cfg, params, z_i, v_i, t_start, h, az, av)
            return (z_prev, v_prev, dz, dv, tree_add(gp, dp)), None

        (_, _, a_z, a_v, g_p), _ = lax.scan(
            body, (z_k1, v_k1, a_z, a_v, g_p), step_ts, reverse=True)
        return (a_z, a_v, g_p)

    z0 = _traj_row(z_traj, 0)
    carry0 = (tree_zeros_like(z0), tree_zeros_like(_traj_row(v_traj, 0)),
              tree_zeros_like(params))
    extras = (_tm(lambda b: b[1:], z_traj), _tm(lambda b: b[1:], v_traj),
              ts[:-1], ts[1:])
    a_z, a_v, g_params = reverse_segment_sweep(seg, carry0, g, extras)

    g_params, a_z = _close_v0_vjp(cfg.f, params, z0, ts[0], a_z, a_v, g_params)
    return g_params, a_z, jnp.zeros_like(ts)


_mali_grid_fixed.defvjp(_mali_grid_fixed_fwd, _mali_grid_fixed_bwd)


# ---------------------------------------------------------------------------
# Adaptive-step MALI over an observation grid
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _mali_grid_adaptive(cfg: MaliConfig, params: Pytree, z0: Pytree,
                        ts: jax.Array) -> Pytree:
    out = _mali_grid_adaptive_forward(cfg, params, z0, ts)
    return out.traj[0]


def _mali_grid_adaptive_forward(cfg, params, z0, ts):
    v0 = init_velocity(cfg.f, params, z0, ts[0])

    def trial(state, t, h):
        z, v = state
        z1, v1, err = alf_step_with_error(cfg.f, params, z, v, t, h, cfg.eta)
        ratio = error_ratio(err, z, z1, cfg.rtol, cfg.atol)
        return (z1, v1), ratio

    return integrate_adaptive_grid(trial, (z0, v0), ts, order=2,
                                   rtol=cfg.rtol, atol=cfg.atol,
                                   max_steps=cfg.max_steps)


def _mali_grid_adaptive_fwd(cfg, params, z0, ts):
    out = _mali_grid_adaptive_forward(cfg, params, z0, ts)
    z_traj, v_traj = out.traj
    # Residuals: per-observation (z_k, v_k) + O(T * max_steps) scalars (the
    # accepted h_i / t_i per segment) — still constant in solver-step count.
    res = (params, z_traj, v_traj, out.ts, out.hs, out.n_accepted, ts)
    return z_traj, res


def _mali_grid_adaptive_bwd(cfg, res, g):
    params, z_traj, v_traj, seg_ts, seg_hs, seg_acc, ts = res

    def step_body(c, t_start, h):
        z_i, v_i, az, av, gp = c
        z_prev, v_prev, dz, dv, dp = _step_backward(
            cfg, params, z_i, v_i, t_start, h, az, av)
        return (z_prev, v_prev, dz, dv, tree_add(gp, dp))

    def seg(carry, g_k1, xs_k):
        a_z, a_v, g_p = carry
        z_k1, v_k1, ts_k, hs_k, n_k = xs_k
        a_z = tree_add(a_z, g_k1)
        carry_k = (z_k1, v_k1, a_z, a_v, g_p)
        _, _, a_z, a_v, g_p = reverse_masked_scan(
            step_body, carry_k, ts_k, hs_k, n_k, cfg.max_steps)
        return (a_z, a_v, g_p)

    z0 = _traj_row(z_traj, 0)
    carry0 = (tree_zeros_like(z0), tree_zeros_like(_traj_row(v_traj, 0)),
              tree_zeros_like(params))
    extras = (_tm(lambda b: b[1:], z_traj), _tm(lambda b: b[1:], v_traj),
              seg_ts, seg_hs, seg_acc)
    a_z, a_v, g_params = reverse_segment_sweep(seg, carry0, g, extras)

    g_params, a_z = _close_v0_vjp(cfg.f, params, z0, ts[0], a_z, a_v, g_params)
    return g_params, a_z, jnp.zeros_like(ts)


_mali_grid_adaptive.defvjp(_mali_grid_adaptive_fwd, _mali_grid_adaptive_bwd)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def odeint_mali(f: Dynamics, params: Pytree, z0: Pytree,
                t0=0.0, t1=1.0, *, ts=None, n_steps: int = 0,
                eta: float = 1.0, rtol: float = 1e-2, atol: float = 1e-3,
                max_steps: int = 64, fused_bwd: bool = True) -> Pytree:
    """Integrate dz/dt = f(params, z, t) with MALI gradients.

    Without ``ts``: integrate t0 -> t1 and return z(t1) (internally the
    length-1 observation grid ``[t0, t1]``). With ``ts`` (shape (T,), T >= 2):
    return the trajectory pytree with leading axis T, ``traj[0] == z0``.

    ``n_steps > 0`` selects the fixed uniform grid *per segment* (the paper's
    large-scale setting, e.g. h=0.25 -> n_steps=4 on [0,1]); ``n_steps == 0``
    selects the adaptive controller with ``rtol/atol`` and a per-segment
    ``max_steps`` trial budget.
    """
    check_eta(eta)
    cfg = MaliConfig(f, int(n_steps), float(eta), float(rtol), float(atol),
                     int(max_steps), bool(fused_bwd))
    scalar = ts is None
    grid = scalar_time_grid(t0, t1) if scalar else as_time_grid(ts)
    if n_steps > 0:
        traj = _mali_grid_fixed(cfg, params, z0, grid)
    else:
        traj = _mali_grid_adaptive(cfg, params, z0, grid)
    return _traj_row(traj, -1) if scalar else traj


def mali_forward_stats(f: Dynamics, params: Pytree, z0: Pytree, t0=0.0,
                       t1=1.0, *, eta: float = 1.0, rtol: float = 1e-2,
                       atol: float = 1e-3, max_steps: int = 64):
    """Adaptive forward only, returning (zT, n_accepted, n_evals) for
    benchmarking the paper's m / N_t accounting."""
    check_eta(eta)
    cfg = MaliConfig(f, 0, float(eta), float(rtol), float(atol), int(max_steps))
    out = _mali_grid_adaptive_forward(cfg, params, z0, scalar_time_grid(t0, t1))
    return out.state[0], jnp.sum(out.n_accepted), out.n_evals
