"""MALI: Memory-efficient ALF Integrator (paper Algo 4) as a jax.custom_vjp.

Forward: integrate with ALF (fixed grid or adaptive), keep ONLY the end-time
augmented state (z_T, v_T) and — in the adaptive case — the accepted step
sizes / start times. No per-step activations are saved: the VJP residual set
is O(N_z), constant in the number of solver steps.

Backward: reconstruct the trajectory step-by-step with the exact ALF inverse
(psi^-1) and run one local VJP of psi per accepted step, accumulating the
adjoint state a(t) and dL/dtheta — the discretized Eq. (2)/(3) of the paper.
The stepsize *search* (rejected trials) is excluded, so the effective
computation-graph depth is N_f x N_t (Table 1, MALI column).

Gradients w.r.t. the integration bounds t0/t1 are not propagated (zeros); the
framework never differentiates them.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .alf import (alf_inverse, alf_step, alf_step_with_error, check_eta,
                  init_velocity, tree_add, tree_zeros_like)
from .integrate import (fixed_grid_times, integrate_adaptive, integrate_fixed,
                        reverse_masked_scan)
from .stepsize import error_ratio

_tm = jax.tree_util.tree_map

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]


class MaliConfig(NamedTuple):
    """Static (hashable) integrator configuration."""
    f: Dynamics
    n_steps: int            # >0: fixed grid; 0: adaptive
    eta: float
    rtol: float
    atol: float
    max_steps: int
    fused_bwd: bool = True  # share the inverse's f-eval with the local VJP


# ---------------------------------------------------------------------------
# Fixed-step MALI
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _mali_fixed(cfg: MaliConfig, params: Pytree, z0: Pytree,
                t0: jax.Array, t1: jax.Array) -> Pytree:
    zT, _vT = _mali_fixed_forward(cfg, params, z0, t0, t1)
    return zT


def _mali_fixed_forward(cfg, params, z0, t0, t1):
    v0 = init_velocity(cfg.f, params, z0, t0)

    def step(state, t, h):
        z, v = state
        return alf_step(cfg.f, params, z, v, t, h, cfg.eta)

    return integrate_fixed(step, (z0, v0), t0, t1, cfg.n_steps)


def _mali_fixed_fwd(cfg, params, z0, t0, t1):
    zT, vT = _mali_fixed_forward(cfg, params, z0, t0, t1)
    # Residuals: end state only — O(N_z), constant in n_steps.
    return zT, (params, zT, vT, t0, t1)


def _local_step_vjp(f, eta, params, z_prev, v_prev, t_prev, h, a_z, a_v):
    """VJP of one ALF step at the reconstructed input state (reference
    path: re-plays psi under jax.vjp; kept as the oracle for the fused
    implementation below)."""
    def step_fn(p, z, v):
        return alf_step(f, p, z, v, t_prev, h, eta)

    _, vjp_fn = jax.vjp(step_fn, params, z_prev, v_prev)
    return vjp_fn((a_z, a_v))  # (dL/dparams, dL/dz_prev, dL/dv_prev)


def _fused_inverse_and_vjp(f, eta, params, z_i, v_i, t_i, h, a_z, a_v):
    """One backward step of Algo 4 with the inverse's f-eval SHARED with the
    local VJP (beyond-paper optimization; EXPERIMENTS.md §Perf).

    The ALF inverse evaluates u1 = f(k1, s1) at k1 = z_i - v_i*h/2; the
    local VJP of psi needs the linearization of f at exactly the same point
    (k1 = z_prev + v_prev*h/2 by construction). One ``jax.vjp`` call
    provides both, cutting the backward from 4 to 3 f-eval-equivalents per
    step. The rest of psi is linear, so its VJP is written out by hand:

        v_out = (1-2*eta)*v_prev + 2*eta*u1 ;  z_out = k1 + v_out*h/2
        cot_vout = a_v + (h/2)*a_z
        cot_u1   = 2*eta*cot_vout
        (dparams, dk1) = vjp_f(cot_u1)
        cot_k1   = a_z + dk1
        dz_prev  = cot_k1
        dv_prev  = (h/2)*cot_k1 + (1-2*eta)*cot_vout

    Returns (z_prev, v_prev, dz_prev, dv_prev, dparams).
    """
    s1 = t_i - h / 2
    k1 = _tm(lambda zi, vi: zi - vi * (h / 2), z_i, v_i)
    u1, vjp_f = jax.vjp(lambda p, kk: f(p, kk, s1), params, k1)
    # inverse tail (Algo 3 / damped Appendix Algo 3)
    if eta == 1.0:
        v_prev = _tm(lambda ui, vo: 2.0 * ui - vo, u1, v_i)
    else:
        inv = 1.0 / (1.0 - 2.0 * eta)
        v_prev = _tm(lambda vo, ui: (vo - 2.0 * eta * ui) * inv, v_i, u1)
    z_prev = _tm(lambda ki, vp: ki - vp * (h / 2), k1, v_prev)
    # manual VJP of the (linear-except-f) forward step
    cot_vout = _tm(lambda av, az: av + (h / 2) * az, a_v, a_z)
    cot_u1 = _tm(lambda c: 2.0 * eta * c, cot_vout)
    dparams, dk1 = vjp_f(cot_u1)
    cot_k1 = _tm(jnp.add, a_z, dk1)
    dz_prev = cot_k1
    dv_prev = _tm(lambda ck, cv: (h / 2) * ck + (1.0 - 2.0 * eta) * cv,
                  cot_k1, cot_vout)
    return z_prev, v_prev, dz_prev, dv_prev, dparams


def _close_v0_vjp(f, params, z0, t0, a_z, a_v, g_params):
    """Close the v0 = f(z0, t0) initialization: route a_v into z0/params."""
    _, vjp_f = jax.vjp(lambda p, z: f(p, z, t0), params, z0)
    dp, dz = vjp_f(a_v)
    return tree_add(g_params, dp), tree_add(a_z, dz)


def _mali_fixed_bwd(cfg, res, g_zT):
    params, zT, vT, t0, t1 = res
    ts, h = fixed_grid_times(t0, t1, cfg.n_steps)

    a_z = g_zT
    a_v = tree_zeros_like(vT)
    g_params = tree_zeros_like(params)

    def body(carry, t_start):
        z_i, v_i, a_z, a_v, g_p = carry
        if cfg.fused_bwd:
            z_prev, v_prev, dz, dv, dp = _fused_inverse_and_vjp(
                cfg.f, cfg.eta, params, z_i, v_i, t_start + h, h, a_z, a_v)
        else:
            # Reconstruct the step input exactly via the ALF inverse ...
            z_prev, v_prev = alf_inverse(cfg.f, params, z_i, v_i,
                                         t_start + h, h, cfg.eta)
            # ... then backprop through the (re-played) accepted step only.
            dp, dz, dv = _local_step_vjp(cfg.f, cfg.eta, params, z_prev,
                                         v_prev, t_start, h, a_z, a_v)
        return (z_prev, v_prev, dz, dv, tree_add(g_p, dp)), None

    carry0 = (zT, vT, a_z, a_v, g_params)
    (z0_rec, v0_rec, a_z, a_v, g_params), _ = lax.scan(
        body, carry0, ts, reverse=True)

    g_params, a_z = _close_v0_vjp(cfg.f, params, z0_rec, t0, a_z, a_v, g_params)
    zero_t = jnp.zeros_like(jnp.asarray(t0))
    return g_params, a_z, zero_t, jnp.zeros_like(jnp.asarray(t1))


_mali_fixed.defvjp(_mali_fixed_fwd, _mali_fixed_bwd)


# ---------------------------------------------------------------------------
# Adaptive-step MALI
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _mali_adaptive(cfg: MaliConfig, params: Pytree, z0: Pytree,
                   t0: jax.Array, t1: jax.Array) -> Pytree:
    out = _mali_adaptive_forward(cfg, params, z0, t0, t1)
    return out.state[0]


def _mali_adaptive_forward(cfg, params, z0, t0, t1):
    v0 = init_velocity(cfg.f, params, z0, t0)

    def trial(state, t, h):
        z, v = state
        z1, v1, err = alf_step_with_error(cfg.f, params, z, v, t, h, cfg.eta)
        ratio = error_ratio(err, z, z1, cfg.rtol, cfg.atol)
        return (z1, v1), ratio

    return integrate_adaptive(trial, (z0, v0), t0, t1, order=2,
                              rtol=cfg.rtol, atol=cfg.atol,
                              max_steps=cfg.max_steps)


def _mali_adaptive_fwd(cfg, params, z0, t0, t1):
    out = _mali_adaptive_forward(cfg, params, z0, t0, t1)
    zT, vT = out.state
    # Residuals: end state + O(max_steps) scalars (the accepted h_i / t_i) —
    # still O(N_z) in the state dimension, constant in step count.
    res = (params, zT, vT, out.ts, out.hs, out.n_accepted, t0, t1)
    return zT, res


def _mali_adaptive_bwd(cfg, res, g_zT):
    params, zT, vT, ts, hs, n_acc, t0, t1 = res

    def body(carry, t_start, h, _extra):
        z_i, v_i, a_z, a_v, g_p = carry
        if cfg.fused_bwd:
            z_prev, v_prev, dz, dv, dp = _fused_inverse_and_vjp(
                cfg.f, cfg.eta, params, z_i, v_i, t_start + h, h, a_z, a_v)
        else:
            z_prev, v_prev = alf_inverse(cfg.f, params, z_i, v_i,
                                         t_start + h, h, cfg.eta)
            dp, dz, dv = _local_step_vjp(cfg.f, cfg.eta, params, z_prev,
                                         v_prev, t_start, h, a_z, a_v)
        return (z_prev, v_prev, dz, dv, tree_add(g_p, dp))

    carry0 = (zT, vT, g_zT, tree_zeros_like(vT), tree_zeros_like(params))
    z0_rec, v0_rec, a_z, a_v, g_params = reverse_masked_scan(
        body, carry0, ts, hs, n_acc, cfg.max_steps)

    g_params, a_z = _close_v0_vjp(cfg.f, params, z0_rec, t0, a_z, a_v, g_params)
    zero_t = jnp.zeros_like(jnp.asarray(t0))
    return g_params, a_z, zero_t, jnp.zeros_like(jnp.asarray(t1))


_mali_adaptive.defvjp(_mali_adaptive_fwd, _mali_adaptive_bwd)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def odeint_mali(f: Dynamics, params: Pytree, z0: Pytree,
                t0=0.0, t1=1.0, *, n_steps: int = 0, eta: float = 1.0,
                rtol: float = 1e-2, atol: float = 1e-3,
                max_steps: int = 64, fused_bwd: bool = True) -> Pytree:
    """Integrate dz/dt = f(params, z, t) from t0 to t1 with MALI gradients.

    ``n_steps > 0`` selects the fixed uniform grid (the paper's large-scale
    setting, e.g. h=0.25 -> n_steps=4 on [0,1]); ``n_steps == 0`` selects the
    adaptive controller with ``rtol/atol`` and a ``max_steps`` trial budget.
    """
    check_eta(eta)
    t0 = jnp.asarray(t0, jnp.float32)
    t1 = jnp.asarray(t1, jnp.float32)
    cfg = MaliConfig(f, int(n_steps), float(eta), float(rtol), float(atol),
                     int(max_steps), bool(fused_bwd))
    if n_steps > 0:
        return _mali_fixed(cfg, params, z0, t0, t1)
    return _mali_adaptive(cfg, params, z0, t0, t1)


def mali_forward_stats(f: Dynamics, params: Pytree, z0: Pytree, t0=0.0,
                       t1=1.0, *, eta: float = 1.0, rtol: float = 1e-2,
                       atol: float = 1e-3, max_steps: int = 64):
    """Adaptive forward only, returning (zT, n_accepted, n_evals) for
    benchmarking the paper's m / N_t accounting."""
    check_eta(eta)
    cfg = MaliConfig(f, 0, float(eta), float(rtol), float(atol), int(max_steps))
    out = _mali_adaptive_forward(cfg, params, z0, jnp.asarray(t0, jnp.float32),
                                 jnp.asarray(t1, jnp.float32))
    return out.state[0], out.n_accepted, out.n_evals
