"""MALI: Memory-efficient ALF Integrator (paper Algo 4) as a jax.custom_vjp.

The integrator is built around an *observation grid* ``ts`` of T timepoints
(the torchdiffeq ``odeint(func, y0, t)`` shape): the forward pass is a single
scan whose carry (z, v) crosses segment boundaries, emitting the augmented
state at every requested ``ts[k]``. The VJP residual set is exactly the
per-observation ``(z_k, v_k)`` pairs — O(T * N_z), *constant in the number of
solver steps*. The scalar ``t0 -> t1`` path is the length-1 grid
``ts = [t0, t1]``.

Both step-size policies go through ONE custom_vjp: the static
:class:`~repro.core.stepsize.StepController` in the config decides whether
the forward replays a uniform per-segment sub-grid (``ConstantSteps``) or
runs the bounded accept/reject loop of Algo 1 (``AdaptiveController``); the
backward sweep is controller-agnostic, masking over the recorded accepted
(t_i, h_i) of each segment.

Backward: per segment (in reverse), reconstruct the trajectory step-by-step
with the exact ALF inverse (psi^-1) starting from the stored segment-end
state, and run one local VJP of psi per accepted step, accumulating the
adjoint state a(t) and dL/dtheta — the discretized Eq. (2)/(3) of the paper.
The trajectory cotangent g[k] is injected into a(t) as the sweep crosses
observation k. The stepsize *search* (rejected trials) is excluded, so the
effective computation-graph depth is N_f x N_t (Table 1, MALI column).

Gradients w.r.t. the observation times are zeros by default; with
``MaliConfig(diff_bounds=True)`` (the ``solve(..., diff_bounds=True)``
surface) the backward emits the analytic boundary cotangents
``dL/dt_k = <g_k, f(z_k, t_k)>`` / ``dL/dt_0 = -<a(t0), f(z0, t0)>``
from state already in the replay buffer — the FFJORD trainable-end-time
hook. The forward also emits
:class:`~repro.core.interface.RunStats` integer counters (the
``Solution.stats`` feed); their cotangents are ignored.

:class:`MALI` is this module's :class:`~repro.core.interface.GradientMethod`
— the Table 1 row the paper contributes; it validates solver compatibility
(MALI is defined for the ALF solver only) and carries the ``fused_bwd``
backward-sharing switch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .alf import (alf_inverse, alf_step, alf_step_with_error, check_eta,
                  init_velocity, tree_add, tree_sub, tree_zeros_like)
from .integrate import (as_time_grid, integrate_grid, reverse_masked_scan,
                        reverse_segment_sweep, scalar_time_grid)
from .interface import (GradientMethod, RunStats, bounds_cotangents,
                        make_run_stats, state_nbytes)
from .solvers import ALF
from .stepsize import (AdaptiveController, StepController,
                       controller_from_kwargs)

_tm = jax.tree_util.tree_map

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]


class MaliConfig(NamedTuple):
    """Static (hashable) integrator configuration."""
    f: Dynamics
    eta: float
    controller: StepController
    fused_bwd: bool = True  # share the inverse's f-eval with the local VJP
    backend: str = "reference"  # forward step algebra: jnp or fused Pallas
    diff_bounds: bool = False  # emit analytic dL/dts boundary cotangents


def _traj_row(traj: Pytree, k: int) -> Pytree:
    return _tm(lambda b: b[k], traj)


def _step_backward(cfg: MaliConfig, params, z_i, v_i, t_start, h, a_z, a_v):
    """One reverse step: reconstruct the step input via psi^-1 and backprop
    psi, either fused (3 f-eval-equivalents) or via the reference two-pass.
    ``backend='pallas'`` dispatches the fused backward kernels: the whole
    elementwise algebra collapses to one launch on each side of the step's
    f-eval linearization (alf_bwd_pre / alf_bwd_post)."""
    if cfg.fused_bwd:
        if cfg.backend == "pallas":
            return _pallas_fused_inverse_and_vjp(cfg.f, cfg.eta, params,
                                                 z_i, v_i, t_start + h, h,
                                                 a_z, a_v)
        return _fused_inverse_and_vjp(cfg.f, cfg.eta, params, z_i, v_i,
                                      t_start + h, h, a_z, a_v)
    z_prev, v_prev = alf_inverse(cfg.f, params, z_i, v_i, t_start + h, h,
                                 cfg.eta, cfg.backend)
    dp, dz, dv = _local_step_vjp(cfg.f, cfg.eta, params, z_prev, v_prev,
                                 t_start, h, a_z, a_v, cfg.backend)
    return z_prev, v_prev, dz, dv, dp


def _local_step_vjp(f, eta, params, z_prev, v_prev, t_prev, h, a_z, a_v,
                    backend="reference"):
    """VJP of one ALF step at the reconstructed input state (reference
    path: re-plays psi under jax.vjp; kept as the oracle for the fused
    implementation below). With ``backend='pallas'`` the replayed step
    launches the fused kernels and jax.vjp differentiates through their
    closed-form custom_vjp rules — the same machinery Naive() uses."""
    def step_fn(p, z, v):
        return alf_step(f, p, z, v, t_prev, h, eta, backend)

    _, vjp_fn = jax.vjp(step_fn, params, z_prev, v_prev)
    return vjp_fn((a_z, a_v))  # (dL/dparams, dL/dz_prev, dL/dv_prev)


def _pallas_fused_inverse_and_vjp(f, eta, params, z_i, v_i, t_i, h, a_z,
                                  a_v):
    """The fused backward step of :func:`_fused_inverse_and_vjp` with its
    elementwise algebra as TWO Pallas launches instead of ~10 per-leaf jnp
    ops: ``alf_bwd_pre`` emits the inverse midpoint k1 AND the f-eval
    cotangent cot_u1 = 2*eta*(a_v + (h/2)*a_z) — which depends only on the
    adjoints, so it is available BEFORE the linearization — then one shared
    ``jax.vjp`` of f provides (u1, dparams, dk1), and ``alf_bwd_post``
    finishes both the psi^-1 reconstruction and the adjoint propagation.
    The f-evaluation VJP itself stays in JAX (it is the model's business,
    not the integrator's)."""
    from repro.kernels.alf_step.ops import alf_bwd_post, alf_bwd_pre
    s1 = t_i - h / 2
    k1, cot_u1 = alf_bwd_pre(z_i, v_i, a_z, a_v, h, eta=eta,
                             use_pallas=True)
    u1, vjp_f = jax.vjp(lambda p, kk: f(p, kk, s1), params, k1)
    dparams, dk1 = vjp_f(cot_u1)
    z_prev, v_prev, dz_prev, dv_prev = alf_bwd_post(
        k1, v_i, u1, a_z, a_v, dk1, h, eta=eta, use_pallas=True)
    return z_prev, v_prev, dz_prev, dv_prev, dparams


def _fused_inverse_and_vjp(f, eta, params, z_i, v_i, t_i, h, a_z, a_v):
    """One backward step of Algo 4 with the inverse's f-eval SHARED with the
    local VJP (beyond-paper optimization; EXPERIMENTS.md §Perf).

    The ALF inverse evaluates u1 = f(k1, s1) at k1 = z_i - v_i*h/2; the
    local VJP of psi needs the linearization of f at exactly the same point
    (k1 = z_prev + v_prev*h/2 by construction). One ``jax.vjp`` call
    provides both, cutting the backward from 4 to 3 f-eval-equivalents per
    step. The rest of psi is linear, so its VJP is written out by hand:

        v_out = (1-2*eta)*v_prev + 2*eta*u1 ;  z_out = k1 + v_out*h/2
        cot_vout = a_v + (h/2)*a_z
        cot_u1   = 2*eta*cot_vout
        (dparams, dk1) = vjp_f(cot_u1)
        cot_k1   = a_z + dk1
        dz_prev  = cot_k1
        dv_prev  = (h/2)*cot_k1 + (1-2*eta)*cot_vout

    Returns (z_prev, v_prev, dz_prev, dv_prev, dparams).
    """
    s1 = t_i - h / 2
    k1 = _tm(lambda zi, vi: zi - vi * (h / 2), z_i, v_i)
    u1, vjp_f = jax.vjp(lambda p, kk: f(p, kk, s1), params, k1)
    # inverse tail (Algo 3 / damped Appendix Algo 3)
    if eta == 1.0:
        v_prev = _tm(lambda ui, vo: 2.0 * ui - vo, u1, v_i)
    else:
        inv = 1.0 / (1.0 - 2.0 * eta)
        v_prev = _tm(lambda vo, ui: (vo - 2.0 * eta * ui) * inv, v_i, u1)
    z_prev = _tm(lambda ki, vp: ki - vp * (h / 2), k1, v_prev)
    # manual VJP of the (linear-except-f) forward step
    cot_vout = _tm(lambda av, az: av + (h / 2) * az, a_v, a_z)
    cot_u1 = _tm(lambda c: 2.0 * eta * c, cot_vout)
    dparams, dk1 = vjp_f(cot_u1)
    cot_k1 = _tm(jnp.add, a_z, dk1)
    dz_prev = cot_k1
    dv_prev = _tm(lambda ck, cv: (h / 2) * ck + (1.0 - 2.0 * eta) * cv,
                  cot_k1, cot_vout)
    return z_prev, v_prev, dz_prev, dv_prev, dparams


def _close_v0_vjp(f, params, z0, t0, a_z, a_v, g_params):
    """Close the v0 = f(z0, t0) initialization: route a_v into z0/params."""
    _, vjp_f = jax.vjp(lambda p, z: f(p, z, t0), params, z0)
    dp, dz = vjp_f(a_v)
    return tree_add(g_params, dp), tree_add(a_z, dz)


# ---------------------------------------------------------------------------
# The (single, controller-parameterized) MALI custom_vjp
# ---------------------------------------------------------------------------

def _mali_forward(cfg: MaliConfig, params, z0, ts):
    """Shared forward: one grid integration of the augmented (z, v) state
    under cfg's controller. Returns the full GridResult bookkeeping.

    The forward runs inside the custom_vjp primal — never differentiated
    through — so cfg.backend may route the step algebra through the fused
    Pallas kernels; the backward sweep honors the same backend, dispatching
    the fused inverse+VJP kernels (_pallas_fused_inverse_and_vjp) or the
    hand-fused jnp reference (_fused_inverse_and_vjp).
    """
    v0 = init_velocity(cfg.f, params, z0, ts[0])

    def trial(state, t, h):
        z, v = state
        z1, v1, err = alf_step_with_error(cfg.f, params, z, v, t, h,
                                          cfg.eta, cfg.backend)
        return (z1, v1), cfg.controller.error_ratio(err, z, z1)

    return integrate_grid(trial, (z0, v0), ts, controller=cfg.controller,
                          order=2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _mali_grid(cfg: MaliConfig, params: Pytree, z0: Pytree,
               ts: jax.Array) -> Tuple[Pytree, RunStats]:
    res = _mali_forward(cfg, params, z0, ts)
    z_traj, _ = res.traj
    return z_traj, make_run_stats(res.n_accepted, res.n_trials, 1, 1)


def _mali_grid_fwd(cfg, params, z0, ts):
    res = _mali_forward(cfg, params, z0, ts)
    z_traj, v_traj = res.traj
    # Residuals: the per-observation (z_k, v_k) pairs — O(T * N_z), constant
    # in the solver-step count — plus the O(T * step_bound) recorded (t, h)
    # scalars the backward sweep replays.
    out = (z_traj, make_run_stats(res.n_accepted, res.n_trials, 1, 1))
    return out, (params, z_traj, v_traj, res.ts, res.hs, res.n_accepted, ts)


def _mali_grid_bwd(cfg, res, g):
    g_traj = g[0]  # RunStats cotangents (g[1]) are zero/float0 — ignored.
    params, z_traj, v_traj, seg_ts, seg_hs, seg_acc, ts = res

    def step_body(c, t_start, h):
        z_i, v_i, az, av, gp = c
        z_prev, v_prev, dz, dv, dp = _step_backward(
            cfg, params, z_i, v_i, t_start, h, az, av)
        return (z_prev, v_prev, dz, dv, tree_add(gp, dp))

    def seg(carry, g_k1, xs_k):
        a_z, a_v, g_p = carry
        z_k1, v_k1, ts_k, hs_k, n_k = xs_k
        # The stored segment-end state is the exact forward value: resetting
        # to it (rather than chaining psi^-1 across segments) stops float
        # drift from accumulating across observations.
        a_z = tree_add(a_z, g_k1)
        carry_k = (z_k1, v_k1, a_z, a_v, g_p)
        _, _, a_z, a_v, g_p = reverse_masked_scan(
            step_body, carry_k, ts_k, hs_k, n_k, cfg.controller.step_bound)
        return (a_z, a_v, g_p)

    z0 = _traj_row(z_traj, 0)
    carry0 = (tree_zeros_like(z0), tree_zeros_like(_traj_row(v_traj, 0)),
              tree_zeros_like(params))
    extras = (_tm(lambda b: b[1:], z_traj), _tm(lambda b: b[1:], v_traj),
              seg_ts, seg_hs, seg_acc)
    a_z, a_v, g_params = reverse_segment_sweep(seg, carry0, g_traj, extras)

    g_params, a_z = _close_v0_vjp(cfg.f, params, z0, ts[0], a_z, a_v, g_params)
    if cfg.diff_bounds:
        # a(t0) is the flow-swept adjoint: total dL/dz0 minus the
        # traj[0] == z0 identity-row cotangent.
        a_t0 = tree_sub(a_z, _traj_row(g_traj, 0))
        g_ts = bounds_cotangents(cfg.f, params, z_traj, ts, g_traj, a_t0)
        return g_params, a_z, g_ts
    return g_params, a_z, jnp.zeros_like(ts)


_mali_grid.defvjp(_mali_grid_fwd, _mali_grid_bwd)


# ---------------------------------------------------------------------------
# The GradientMethod object + legacy function API
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MALI(GradientMethod):
    """The paper's method (Algo 4): reconstruct-the-trajectory gradients at
    O(T * N_z) residual memory, reverse-accurate w.r.t. its own forward
    discretization. ``fused_bwd`` shares psi^-1's f-eval with the local VJP
    (3 instead of 4 f-eval-equivalents per backward step)."""

    fused_bwd: bool = True

    name = "mali"

    # Time direction: the recorded (t_i, h_i) replay buffers are *signed* —
    # a reverse-time solve (t1 < t0, h_i < 0) records negative steps and
    # the backward sweep's psi^-1 reconstruction runs with the same signed
    # h, so ALF's inverse is exercised in both directions and gradients of
    # a reverse solve match the time-reflected forward solve.

    def default_solver(self) -> ALF:
        return ALF()

    def validate(self, solver, controller) -> None:
        if not isinstance(solver, ALF):
            raise ValueError(
                "MALI is defined for the ALF solver only (paper Sec 3); got "
                f"solver {getattr(solver, 'name', solver)!r}. Pass "
                "solver=ALF(eta=...) or use gradient=Naive()/ACA() for "
                "Runge-Kutta solvers.")

    def integrate(self, f, params, z0, ts, solver, controller,
                  diff_bounds: bool = False):
        cfg = MaliConfig(f, solver.eta, controller, self.fused_bwd,
                         solver.backend, diff_bounds)
        traj, stats = _mali_grid(cfg, params, z0, ts)
        return traj, stats

    def residual_bytes(self, z0, n_obs, solver, controller) -> int:
        # The per-observation (z_k, v_k) pairs — constant in step count.
        return 2 * n_obs * state_nbytes(z0)


def odeint_mali(f: Dynamics, params: Pytree, z0: Pytree,
                t0=0.0, t1=1.0, *, ts=None, n_steps: int = 0,
                eta: float = 1.0, rtol: float = 1e-2, atol: float = 1e-3,
                max_steps: int = 64, fused_bwd: bool = True) -> Pytree:
    """Integrate dz/dt = f(params, z, t) with MALI gradients (legacy kwargs
    facade over the object API).

    Without ``ts``: integrate t0 -> t1 and return z(t1) (internally the
    length-1 observation grid ``[t0, t1]``). With ``ts`` (shape (T,), T >= 2):
    return the trajectory pytree with leading axis T, ``traj[0] == z0``.

    ``n_steps > 0`` selects ``ConstantSteps`` (the paper's large-scale
    setting, e.g. h=0.25 -> n_steps=4 on [0,1]); ``n_steps == 0`` selects
    ``AdaptiveController(rtol, atol, max_steps)``.
    """
    check_eta(eta)
    cfg = MaliConfig(f, float(eta),
                     controller_from_kwargs(n_steps, rtol, atol, max_steps),
                     bool(fused_bwd))
    scalar = ts is None
    grid = scalar_time_grid(t0, t1) if scalar else as_time_grid(ts)
    traj, _ = _mali_grid(cfg, params, z0, grid)
    return _traj_row(traj, -1) if scalar else traj


def mali_forward_stats(f: Dynamics, params: Pytree, z0: Pytree, t0=0.0,
                       t1=1.0, *, eta: float = 1.0, rtol: float = 1e-2,
                       atol: float = 1e-3, max_steps: int = 64):
    """Adaptive forward only, returning (zT, n_accepted, n_evals) for the
    paper's m / N_t accounting. Superseded by ``Solution.stats`` (where
    n_evals = n_accepted + n_rejected); kept as a compatibility shim."""
    check_eta(eta)
    cfg = MaliConfig(f, float(eta),
                     AdaptiveController(float(rtol), float(atol),
                                        int(max_steps)), True)
    res = _mali_forward(cfg, params, z0, scalar_time_grid(t0, t1))
    return res.state[0], jnp.sum(res.n_accepted), res.n_trials

