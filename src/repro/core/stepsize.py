"""Step-size policy objects (paper Algo 1) — the step-controller axis.

The accept/reject policy of Algo 1 is an object, not a pair of free
functions + an ``n_steps`` kwarg:

* :class:`ConstantSteps` — ``n`` uniform sub-steps per observation segment
  (the paper's large-scale fixed-h setting; every trial is accepted).
* :class:`AdaptiveController` — the PI-free error-ratio controller of
  Algo 1 with ``rtol``/``atol`` and a bounded ``max_steps`` trial budget per
  segment (rejected trials still cost f-evals), warm-starting each segment
  at the previous segment's converged step size.

Both are frozen (hashable) dataclasses so they can ride in the static
config of a ``jax.custom_vjp``; the numeric policy itself stays expressed
as pure jit-friendly functions over scalars/pytrees, and the driving loop
lives in :mod:`repro.core.integrate` (one controller-parameterized driver —
a bounded masked ``lax.scan``, usable under reverse-mode AD).
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar

import jax
import jax.numpy as jnp

_tm = jax.tree_util.tree_map

# Classic Hairer-Norsett-Wanner defaults.
SAFETY = 0.9
MIN_FACTOR = 0.2     # paper's DecayFactor floor
MAX_FACTOR = 10.0    # paper's IncreaseFactor ceiling


def error_ratio(err: Any, z0: Any, z1: Any, rtol: float, atol: float) -> jax.Array:
    """RMS of err scaled by atol + rtol*max(|z0|,|z1|). Accept iff <= 1.

    The reduction runs over EVERY element of the state pytree — this single
    scalar is what makes a batch-shaped state integrate in lockstep (one
    shared accept/reject for all samples, ``Batching=Lockstep``). The
    per-sample batching driver gets row-wise decisions by vmapping the
    whole trial loop, which confines this reduction to one sample's slice.
    """
    leaves_err = jax.tree_util.tree_leaves(err)
    leaves_0 = jax.tree_util.tree_leaves(z0)
    leaves_1 = jax.tree_util.tree_leaves(z1)
    total = 0.0
    count = 0
    for e, a, b in zip(leaves_err, leaves_0, leaves_1):
        scale = atol + rtol * jnp.maximum(jnp.abs(a), jnp.abs(b))
        r = (e / scale).astype(jnp.float32)
        total = total + jnp.sum(r * r)
        count += r.size
    # safe sqrt: d(sqrt)/dx at exactly 0 is inf, which poisons reverse-mode
    # AD through the adaptive loop (0-cotangent * inf = NaN) — the naive
    # method differentiates through this code path.
    ms = total / max(count, 1)
    return jnp.sqrt(jnp.where(ms > 0, ms, 1.0)) * jnp.where(ms > 0, 1.0, 0.0)


def next_step_size(h: jax.Array, ratio: jax.Array, order: int) -> jax.Array:
    """PI-free single-exponent controller: h * clip(safety * ratio^(-1/(p+1))).

    The growth/shrink factor is strictly positive, so the *sign* of ``h``
    (the integration direction) is invariant under step-size control —
    reverse-time solves keep proposing negative steps."""
    ratio = jnp.maximum(ratio, 1e-10)
    factor = SAFETY * ratio ** (-1.0 / (order + 1))
    factor = jnp.clip(factor, MIN_FACTOR, MAX_FACTOR)
    return h * factor


def initial_step_size(rtol: float, atol: float, span: jax.Array) -> jax.Array:
    """Cheap initial h heuristic: a small fraction of the span, tol-scaled.
    Signed like the span — a negative span (reverse time) proposes a
    negative initial step."""
    base = jnp.abs(span) * 0.05
    tol_scale = jnp.clip(jnp.sqrt(rtol + atol), 1e-4, 1.0)
    return jnp.sign(span) * jnp.maximum(base * tol_scale, jnp.abs(span) * 1e-4)


@dataclasses.dataclass(frozen=True)
class StepController:
    """Base step-size policy. Subclasses own the accept/reject decision
    (``error_ratio``: <= 1 accepts) and the per-segment recorded-step bound
    (``step_bound``: the static buffer size the backward sweeps mask over).
    """

    adaptive: ClassVar[bool] = False

    def error_ratio(self, err: Any, z0: Any, z1: Any) -> jax.Array:
        raise NotImplementedError

    @property
    def step_bound(self) -> int:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ConstantSteps(StepController):
    """Fixed uniform grid: ``n`` sub-steps per observation segment."""

    n: int = 8

    adaptive: ClassVar[bool] = False

    def __post_init__(self):
        try:
            n = int(self.n)
        except (TypeError, ValueError):
            n = -1
        if n < 1 or n != self.n:
            raise ValueError(
                f"ConstantSteps needs a positive integer step count, got "
                f"n={self.n!r}")
        object.__setattr__(self, "n", n)

    def error_ratio(self, err, z0, z1) -> jax.Array:
        # Every trial is accepted; the (free) embedded error estimate is
        # dead code the compiler drops.
        return jnp.zeros(())

    @property
    def step_bound(self) -> int:
        return self.n


@dataclasses.dataclass(frozen=True)
class AdaptiveController(StepController):
    """Paper Algo 1: accept iff the atol/rtol-scaled error RMS is <= 1,
    shrink on reject / grow on accept with the clipped single-exponent
    factor, under a ``max_steps`` trial budget per segment."""

    rtol: float = 1e-2
    atol: float = 1e-3
    max_steps: int = 64

    adaptive: ClassVar[bool] = True

    def __post_init__(self):
        if self.rtol < 0.0 or self.atol < 0.0:
            raise ValueError(
                f"tolerances must be non-negative, got rtol={self.rtol}, "
                f"atol={self.atol}")
        if self.rtol == 0.0 and self.atol == 0.0:
            raise ValueError("rtol and atol cannot both be zero")
        try:
            m = int(self.max_steps)
        except (TypeError, ValueError):
            m = -1
        if m < 1 or m != self.max_steps:
            raise ValueError(
                f"max_steps must be a positive integer, got {self.max_steps!r}")
        object.__setattr__(self, "max_steps", m)
        object.__setattr__(self, "rtol", float(self.rtol))
        object.__setattr__(self, "atol", float(self.atol))

    def error_ratio(self, err, z0, z1) -> jax.Array:
        if err is None:
            raise ValueError(
                "adaptive step control needs a solver with an embedded "
                "error estimate; use ConstantSteps with this solver")
        return error_ratio(err, z0, z1, self.rtol, self.atol)

    @property
    def step_bound(self) -> int:
        return self.max_steps

    def initial_step(self, span: jax.Array) -> jax.Array:
        return initial_step_size(self.rtol, self.atol, span)

    def next_step(self, h: jax.Array, ratio: jax.Array, order: int) -> jax.Array:
        return next_step_size(h, ratio, order)


def controller_from_kwargs(n_steps: int, rtol: float, atol: float,
                           max_steps: int) -> StepController:
    """Map the legacy kwargs convention (n_steps > 0 fixed, == 0 adaptive)
    to a StepController — shared by every legacy odeint facade."""
    if n_steps < 0:
        raise ValueError(f"n_steps must be >= 0 (0 selects adaptive control),"
                         f" got {n_steps}")
    if n_steps > 0:
        return ConstantSteps(int(n_steps))
    return AdaptiveController(float(rtol), float(atol), int(max_steps))
