"""Adaptive step-size control (paper Algo 1) — PI controller + error norms.

jit-friendly: everything is expressed as pure functions over scalars/pytrees;
the accept/reject loop lives in the integrators (bounded ``lax.scan`` with
masking so the same code path works under reverse-mode AD where needed).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

_tm = jax.tree_util.tree_map

# Classic Hairer-Norsett-Wanner defaults.
SAFETY = 0.9
MIN_FACTOR = 0.2     # paper's DecayFactor floor
MAX_FACTOR = 10.0    # paper's IncreaseFactor ceiling


def error_ratio(err: Any, z0: Any, z1: Any, rtol: float, atol: float) -> jax.Array:
    """RMS of err scaled by atol + rtol*max(|z0|,|z1|). Accept iff <= 1."""
    leaves_err = jax.tree_util.tree_leaves(err)
    leaves_0 = jax.tree_util.tree_leaves(z0)
    leaves_1 = jax.tree_util.tree_leaves(z1)
    total = 0.0
    count = 0
    for e, a, b in zip(leaves_err, leaves_0, leaves_1):
        scale = atol + rtol * jnp.maximum(jnp.abs(a), jnp.abs(b))
        r = (e / scale).astype(jnp.float32)
        total = total + jnp.sum(r * r)
        count += r.size
    # safe sqrt: d(sqrt)/dx at exactly 0 is inf, which poisons reverse-mode
    # AD through the adaptive loop (0-cotangent * inf = NaN) — the naive
    # method differentiates through this code path.
    ms = total / max(count, 1)
    return jnp.sqrt(jnp.where(ms > 0, ms, 1.0)) * jnp.where(ms > 0, 1.0, 0.0)


def next_step_size(h: jax.Array, ratio: jax.Array, order: int) -> jax.Array:
    """PI-free single-exponent controller: h * clip(safety * ratio^(-1/(p+1)))."""
    ratio = jnp.maximum(ratio, 1e-10)
    factor = SAFETY * ratio ** (-1.0 / (order + 1))
    factor = jnp.clip(factor, MIN_FACTOR, MAX_FACTOR)
    return h * factor


class AdaptState(NamedTuple):
    """Carry for the bounded adaptive loop."""
    t: jax.Array          # current time
    h: jax.Array          # current proposed step
    done: jax.Array       # bool: reached end time
    n_accepted: jax.Array  # int32 accepted-step count
    n_evals: jax.Array     # int32 f-eval count (incl. rejected)


def clip_step_to_end(t: jax.Array, h: jax.Array, t1: jax.Array) -> jax.Array:
    """Never step past the end time (sign-aware)."""
    remaining = t1 - t
    return jnp.where(jnp.abs(h) > jnp.abs(remaining), remaining, h)


def initial_step_size(rtol: float, atol: float, span: jax.Array) -> jax.Array:
    """Cheap initial h heuristic: a small fraction of the span, tol-scaled."""
    base = jnp.abs(span) * 0.05
    tol_scale = jnp.clip(jnp.sqrt(rtol + atol), 1e-4, 1.0)
    return jnp.sign(span) * jnp.maximum(base * tol_scale, jnp.abs(span) * 1e-4)
