"""Core MALI / Neural-ODE integrator library (the paper's contribution).

Two API layers:

* the composable object API — :func:`solve` with
  Solver (:class:`ALF`, ``Dopri5()``, ...) x StepController
  (:class:`ConstantSteps`, :class:`AdaptiveController`) x GradientMethod
  (:class:`MALI`, :class:`Naive`, :class:`ACA`, :class:`Backsolve`) x
  :class:`SaveAt`, returning a :class:`Solution` with populated
  :class:`Stats`;
* the legacy string-keyed :func:`odeint` facade (a thin shim over the
  object API, kept behavior-preserving).
"""
from .alf import (alf_inverse, alf_step, alf_step_with_error, init_velocity,
                  tree_add, tree_scale, tree_sub, tree_zeros_like)
from .api import (METHODS, mali_forward_stats, odeint, odeint_aca,
                  odeint_adjoint, odeint_mali, odeint_naive)
from .dense import DenseInterpolation
from .integrate import (as_time_grid, integrate_adaptive_grid,
                        integrate_fixed_grid, integrate_grid, integrate_span,
                        validate_span)
from .interface import (Batching, Event, GradientMethod, Lockstep, PerSample,
                        RunStats, SaveAt, Sharded, Solution, Stats,
                        batch_size)
from .ode_block import OdeSettings, ode_block
from .solve import solve
from .aca import ACA
from .adjoint import Adjoint, Backsolve
from .mali import MALI
from .naive import Naive
from .solvers import (ALF, SOLVERS, Bosh3, ButcherTableau, Dopri5, Euler,
                      HeunEuler, Midpoint, Rk4, RungeKutta, Solver,
                      get_solver)
from .stepsize import AdaptiveController, ConstantSteps, StepController

__all__ = [
    # ALF primitives
    "alf_step", "alf_inverse", "alf_step_with_error", "init_velocity",
    # composable API
    "solve", "Solution", "SaveAt", "Stats", "RunStats", "Event",
    "DenseInterpolation",
    "Batching", "Lockstep", "PerSample", "Sharded", "batch_size",
    "GradientMethod", "MALI", "Naive", "ACA", "Backsolve", "Adjoint",
    "Solver", "RungeKutta", "ALF", "ButcherTableau",
    "Euler", "HeunEuler", "Midpoint", "Bosh3", "Rk4", "Dopri5",
    "StepController", "ConstantSteps", "AdaptiveController",
    # legacy facade
    "odeint", "odeint_mali", "odeint_naive", "odeint_aca", "odeint_adjoint",
    "mali_forward_stats", "METHODS", "SOLVERS", "get_solver",
    "OdeSettings", "ode_block",
    # drivers / tree utils
    "as_time_grid", "validate_span", "integrate_grid", "integrate_span",
    "integrate_fixed_grid", "integrate_adaptive_grid",
    "tree_add", "tree_sub", "tree_scale", "tree_zeros_like",
]
