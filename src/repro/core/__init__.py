"""Core MALI / Neural-ODE integrator library (the paper's contribution)."""
from .alf import (alf_inverse, alf_step, alf_step_with_error, init_velocity,
                  tree_add, tree_scale, tree_sub, tree_zeros_like)
from .api import (METHODS, mali_forward_stats, odeint, odeint_aca,
                  odeint_adjoint, odeint_mali, odeint_naive)
from .integrate import (as_time_grid, integrate_adaptive_grid,
                        integrate_fixed_grid)
from .ode_block import OdeSettings, ode_block
from .solvers import SOLVERS, get_solver

__all__ = [
    "alf_step", "alf_inverse", "alf_step_with_error", "init_velocity",
    "odeint", "odeint_mali", "odeint_naive", "odeint_aca", "odeint_adjoint",
    "mali_forward_stats", "METHODS", "SOLVERS", "get_solver",
    "OdeSettings", "ode_block",
    "as_time_grid", "integrate_fixed_grid", "integrate_adaptive_grid",
    "tree_add", "tree_sub", "tree_scale", "tree_zeros_like",
]
