"""Shared interface types of the composable solve() API.

This module is the hub the gradient-method modules (mali/naive/aca/adjoint)
implement against, so it deliberately depends on nothing but the solver and
controller axes:

* :class:`GradientMethod` — the gradient-estimation axis of paper Table 1.
  Each method validates its solver/controller compatibility (MALI => ALF),
  owns its ``jax.custom_vjp`` wiring, and integrates over an observation
  grid through one uniform entry point.
* :class:`Batching` — the batching axis of a solve over a leading batch
  dimension: :class:`Lockstep` (the whole batch is one ODE system — one
  shared controller decision per trial), :class:`PerSample` (each sample
  carries its own ``(t, h, done)`` adaptive state), :class:`Sharded`
  (shard the batch over a mesh axis, data-parallel).
* :class:`RunStats` — the raw accepted/trial counters a method's forward
  pass emits (threaded through the custom_vjp primal as integer outputs
  whose cotangents are ignored).
* :class:`Stats` / :class:`Solution` / :class:`SaveAt` — the user-facing
  result types of :func:`repro.core.solve.solve`; :class:`Solution` is a
  callable-in-time record when dense output was requested
  (``Solution.evaluate(t)``).
* :class:`Event` — a terminating event (stop at a sign change of
  ``cond_fn(z, t)``, bisection-refined on the dense interpolant).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dense import DenseInterpolation

Pytree = Any


class RunStats(NamedTuple):
    """Step accounting from one forward integration (paper Algo 1's
    accept/reject loop; for fixed-step control rejected == 0).

    Derived counters are computed *inside* each gradient method's
    custom_vjp primal (see :func:`make_run_stats`): the integer outputs of
    a custom_vjp carry instantiated float0 tangents under vmap-of-grad, so
    arithmetic on them outside the primal would crash jvp tracing.
    """
    n_accepted: jax.Array   # int32: accepted solver steps, all segments
    n_rejected: jax.Array   # int32: rejected trial steps
    n_fevals: jax.Array     # int32: forward dynamics evaluations


def make_run_stats(n_accepted: jax.Array, n_trials: jax.Array, stages: int,
                   init_evals: int = 0) -> RunStats:
    """Fold raw driver counters into :class:`RunStats`.

    ``n_accepted`` may be per-segment (summed here); ``stages`` is the
    solver's f-evals per trial step; ``init_evals`` covers state-init
    evaluations (ALF's ``v0 = f(z0, t0)``).
    """
    n_acc = jnp.sum(n_accepted).astype(jnp.int32)
    n_tr = jnp.asarray(n_trials, jnp.int32)
    return RunStats(n_acc, n_tr - n_acc, n_tr * stages + init_evals)


class Stats(NamedTuple):
    """``Solution.stats``: the paper's Table 1 accounting for one solve.

    ``n_fevals`` counts *forward-pass* dynamics evaluations (trials x the
    solver's stage count, + 1 for ALF's ``v0 = f(z0, t0)`` init); the
    backward pass of each method adds its own Table-1 cost on top.
    ``residual_bytes`` is the analytic backward-residual footprint of the
    chosen gradient method (MALI: the per-observation (z, v) pairs —
    O(T * N_z), constant in step count; ACA/naive grow with the step
    budget), computed from static shapes — not a measurement.

    Batched solves (``solve(..., batching=...)``) additionally populate
    ``per_sample`` with shape-(B,) counters, one row per batch sample. The
    scalar counters then hold per-row *totals* (the sum over rows), so a
    lockstep batch reports B x the shared trial count — directly comparable
    with a per-sample batch, where rows accept/reject independently. For
    unbatched solves ``per_sample`` is ``None`` and the scalars keep their
    single-trajectory meaning.
    """
    n_accepted: jax.Array   # int32
    n_rejected: jax.Array   # int32
    n_fevals: jax.Array     # int32
    n_segments: int         # static: observation segments (T - 1)
    residual_bytes: int     # static: analytic residual-memory estimate
    per_sample: Optional["RunStats"] = None  # (B,) rows for batched solves
    # Event solves (solve(..., event=Event(...))) populate these two: did
    # the event terminate the span, and at what (bisection-refined) time.
    # None on non-event solves.
    event_fired: Optional[jax.Array] = None  # bool
    event_time: Optional[jax.Array] = None   # refined t_event (== t1 if not
                                             # fired)
    # Span-recording solves (SaveAt(steps=True)/dense=True and the event
    # detection pass) populate this: False when the AdaptiveController's
    # max_steps trial budget ran out before reaching t1, i.e. the recorded
    # span (and any dense interpolant over it) covers only a prefix of
    # [t0, t1]. None where not tracked (plain grid/end-state solves).
    span_complete: Optional[jax.Array] = None  # bool


class Solution(NamedTuple):
    """Result of :func:`repro.core.solve.solve` (a pytree — jit/vmap-safe).

    ``ys``/``ts`` shape depends on the ``SaveAt`` mode: the end state and
    scalar ``t1`` (default), the (T, ...) trajectory over ``SaveAt.ts``, or
    the padded per-step record for ``SaveAt(steps=True)``. For padded
    records, :attr:`num_steps`/:attr:`step_mask` say which rows are live —
    use them instead of arithmetic on ``stats.n_accepted`` (a zero-padded
    ``ts`` row is otherwise indistinguishable from a legitimate ``t = 0.0``
    point).

    With ``SaveAt(dense=True)`` the solution is additionally *callable in
    time*: :meth:`evaluate` interpolates the state anywhere in the
    integration span off the recorded per-step cubic-Hermite coefficients.

    Example::

        sol = solve(f, params, z0, 0.0, 1.0,
                    controller=AdaptiveController(1e-4, 1e-5),
                    saveat=SaveAt(dense=True))
        z_mid = sol.evaluate(0.5)                 # one state
        zs = sol.evaluate(jnp.linspace(0., 1., 100))  # (100, ...) states
    """
    ys: Pytree
    ts: jax.Array
    stats: Stats
    # Dense-output record (SaveAt(dense=True) / event solves); None otherwise.
    interpolation: Optional[DenseInterpolation] = None
    # Live-row count of a padded ys/ts buffer (SaveAt(steps=True)); None for
    # exact-shape modes (end state / observation grid).
    n_live: Optional[jax.Array] = None

    @property
    def num_steps(self) -> jax.Array:
        """Accepted solver steps of the recorded trajectory; for
        ``SaveAt(steps=True)`` the live rows are ``0 .. num_steps``
        inclusive — the step-start states plus the final state. Derived
        from the padded buffer's live-row count when one exists (batched
        solves redefine ``stats.n_accepted`` as the per-row *total*, which
        is B x the shared step count under Lockstep); equals
        ``stats.n_accepted`` otherwise."""
        if self.n_live is not None:
            return self.n_live - 1
        return self.stats.n_accepted

    @property
    def step_mask(self) -> jax.Array:
        """Boolean mask over the rows of ``ts``/``ys``: True where the row
        holds real data. All-True for exact-shape modes (end state,
        ``SaveAt(ts=grid)``); for the padded ``SaveAt(steps=True)`` buffer
        only rows ``< n_live`` are live and later rows are padding."""
        ts = jnp.asarray(self.ts)
        if ts.ndim == 0:
            return jnp.ones((), bool)
        if self.n_live is None:
            return jnp.ones((ts.shape[0],), bool)
        return jnp.arange(ts.shape[0]) < self.n_live

    def evaluate(self, t) -> Pytree:
        """Dense-output interpolation at query time(s) ``t`` (vectorized:
        scalar in -> one state out, (Q,) in -> leading-Q states out).
        Requires a solve with ``SaveAt(dense=True)``; queries are clamped
        into the integration span. Differentiable w.r.t. params/z0 by
        direct backprop through the recorded step sequence."""
        if self.interpolation is None:
            raise ValueError(
                "Solution.evaluate(t) needs dense output: pass "
                "saveat=SaveAt(dense=True) to solve() to record the "
                "per-step interpolation coefficients")
        return self.interpolation.evaluate(t)

    def __call__(self, t) -> Pytree:
        """A dense Solution is callable in time: ``sol(t) == sol.evaluate(t)``."""
        return self.evaluate(t)


@dataclasses.dataclass(frozen=True, eq=False)
class SaveAt:
    """What to save (diffrax-style). One mode applies per solve:

    * ``ts=<1-D grid>`` — the trajectory at every requested timepoint
      (the observation-grid path; ``ys[0] == z0``); the grid may ascend or
      descend (descending = a reverse-time solve);
    * ``steps=True`` — raw per-step output: every accepted solver step's
      start state plus the final state, with the actual step times in
      ``Solution.ts`` as a padded buffer (``Solution.num_steps`` /
      ``Solution.step_mask`` say which rows are live);
    * ``dense=True`` — continuous dense output: record per-accepted-step
      cubic-Hermite coefficients so ``Solution.evaluate(t)`` interpolates
      the state anywhere in ``[t0, t1]``; ``ys``/``ts`` still hold the end
      state/time;
    * otherwise ``t1`` — only the final state ``z(t1)`` (the default;
      ``t1`` is the fallback mode, so passing ``ts=grid`` overrides it and
      ``SaveAt(ts=grid)`` needs no ``t1=False``).

    ``ts``, ``steps`` and ``dense`` are mutually exclusive. ``steps`` and
    ``dense`` pin every intermediate state by definition, so both are
    integrated with direct backpropagation through the recorded step
    sequence (the memory advantage of MALI/ACA/Backsolve does not exist in
    these modes).

    Equality and hashing are by VALUE (``ts`` compared by content), so a
    freshly constructed, identical ``SaveAt`` reuses a jit cache entry
    when passed as a static argument — the default dataclass identity
    hash retraced on every fresh instance (caught by the trace audit's
    retrace counter). A traced ``ts`` falls back to identity.
    """
    t1: bool = True
    ts: Optional[Any] = None
    steps: bool = False
    dense: bool = False

    def __post_init__(self):
        picked = [m for m, on in (("ts=<grid>", self.ts is not None),
                                  ("steps=True", self.steps),
                                  ("dense=True", self.dense)) if on]
        if len(picked) > 1:
            raise ValueError("SaveAt: pass only one of ts=<grid>, "
                             f"steps=True or dense=True, not {picked}")

    def _key(self):
        if self.ts is None:
            ts_key = None
        else:
            try:
                arr = np.asarray(self.ts)
                ts_key = (arr.dtype.str, arr.shape, arr.tobytes())
            except Exception:       # tracer/abstract grid: identity only
                ts_key = id(self.ts)
        return (self.t1, ts_key, self.steps, self.dense)

    def __eq__(self, other):
        if not isinstance(other, SaveAt):
            return NotImplemented
        return self._key() == other._key()

    def __hash__(self):
        return hash(self._key())


@dataclasses.dataclass(frozen=True)
class Event:
    """Terminating event: stop the solve at a sign change of
    ``cond_fn(z, t)`` (a scalar event function).

    The integrator runs a dense-recording forward over the full span,
    scans the accepted-step nodes for the first sign change (filtered by
    ``direction``), refines the crossing time by ``max_bisections``
    bisection iterations on the dense cubic-Hermite interpolant (zero
    extra dynamics evaluations), then re-solves ``[t0, t_event]`` with the
    chosen gradient method. Gradients flow through this frozen-``t_event``
    path for all four methods (``t_event`` is treated as a constant — the
    standard torchdiffeq-style event gradient convention).

    * ``direction = 0`` — trigger on any sign change;
    * ``direction = +1`` — rising crossings only (cond goes negative ->
      non-negative);
    * ``direction = -1`` — falling crossings only.

    ``Solution.stats.event_fired`` / ``event_time`` record the outcome;
    when no crossing exists the solve runs to ``t1`` and
    ``event_time == t1``.

    The detection pass integrates the *whole* ``[t0, t1]`` span as one
    segment, so with :class:`~repro.core.stepsize.AdaptiveController` its
    ``max_steps`` trial budget must cover the full span (size it for the
    span length, not for one observation segment) — an exhausted budget
    truncates the detection sweep before the crossing and the event
    silently does not fire.

    Example::

        # stop when the first state coordinate hits 0.5
        ev = Event(lambda z, t: z[0] - 0.5, direction=+1)
        sol = solve(f, params, z0, 0.0, 10.0, event=ev)
        sol.ys                      # z(t_event)
        sol.stats.event_time        # the crossing time

    Equality/hashing are field-based (``cond_fn`` by function identity):
    two Events wrapping the SAME condition function compare equal, so a
    fresh wrapper does not retrace a jit cache keyed on it statically.
    """
    cond_fn: Callable[[Pytree, jax.Array], jax.Array]
    direction: int = 0
    max_bisections: int = 32

    def __post_init__(self):
        if not callable(self.cond_fn):
            raise TypeError(f"Event.cond_fn must be callable (z, t) -> "
                            f"scalar, got {self.cond_fn!r}")
        if self.direction not in (-1, 0, 1):
            raise ValueError(f"Event.direction must be -1, 0 or +1, got "
                             f"{self.direction!r}")
        if not isinstance(self.max_bisections, int) or self.max_bisections < 1:
            raise ValueError(f"Event.max_bisections must be a positive "
                             f"integer, got {self.max_bisections!r}")


class Batching:
    """Base of the batching axis: how one ``solve`` treats the leading
    batch dimension of ``z0``.

    Batched solves return ``ys`` with the batch axis FIRST — ``(B, ...)``
    for the end state, ``(B, T, ...)`` for a ``SaveAt(ts=grid)`` trajectory
    — regardless of mode, so swapping Lockstep <-> PerSample <-> Sharded
    never changes output shapes. Subclasses are frozen dataclasses
    (hashable, jit-static-safe).
    """

    name: str = "?"

    def validate(self, controller, saveat) -> None:
        """Reject/flag incompatible axes before tracing (overridden)."""


@dataclasses.dataclass(frozen=True)
class Lockstep(Batching):
    """The whole batch is one ODE system (Chen et al. 2018's concatenated
    ``odeint`` semantics, made explicit): the adaptive controller's error
    norm reduces over every sample, so there is ONE shared accept/reject
    decision per trial and one sample's rejected step re-trials the whole
    batch. Cheapest per trial (no per-row bookkeeping); right for
    stiffness-homogeneous batches. This is exactly what an unbatched
    ``solve`` over a batch-shaped ``z0`` has always done implicitly."""

    name = "lockstep"


@dataclasses.dataclass(frozen=True)
class PerSample(Batching):
    """Per-sample adaptive control: each sample carries its own
    ``(t, h, done)`` state through the masked scan
    (:mod:`repro.core.integrate`), accept/reject is decided row-by-row by
    the batched controller norm, and finished samples ride along as no-ops
    (their padding iterations update nothing and cost no counted f-evals).
    The gradient methods' custom_vjps replay per-sample ``(t_i, h_i)``
    buffers, so reverse trajectories stay bit-accurate per row. Fewer total
    f-evals than :class:`Lockstep` on stiffness-heterogeneous batches."""

    name = "per_sample"

    def validate(self, controller, saveat) -> None:
        if saveat is not None and (saveat.steps or saveat.dense):
            mode = "steps=True" if saveat.steps else "dense=True"
            raise ValueError(
                f"SaveAt({mode}) under PerSample() batching is ragged "
                "(each sample accepts a different number of steps); use "
                "SaveAt(ts=grid) for a shared observation grid, or "
                "Lockstep() for a shared step sequence")
        if controller is not None and not controller.adaptive:
            warnings.warn(
                "PerSample() with a fixed-step controller degenerates to "
                "Lockstep(): every sample takes the identical step "
                "sequence, so there is no per-row accept/reject to "
                "exploit. Use AdaptiveController(...) or Lockstep().",
                UserWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True)
class Sharded(Batching):
    """Shard the batch over a mesh axis (``jax.shard_map`` data
    parallelism): a fleet of solves runs one shard per device group along
    ``axis``, each shard applying ``inner`` batching (:class:`Lockstep` or
    :class:`PerSample`) to its local rows. Requires an active mesh context
    (``with mesh: ...`` — see :func:`repro.launch.mesh.make_host_mesh` /
    ``make_production_mesh``) whose axis names include ``axis``, and a
    batch size divisible by that axis size."""

    axis: str = "data"
    inner: Batching = dataclasses.field(default_factory=Lockstep)

    name = "sharded"

    def __post_init__(self):
        if isinstance(self.inner, Sharded):
            raise ValueError("Sharded(inner=Sharded(...)) does not nest; "
                             "pick Lockstep() or PerSample() for inner")

    def validate(self, controller, saveat) -> None:
        if saveat is not None and (saveat.steps or saveat.dense):
            mode = "steps=True" if saveat.steps else "dense=True"
            raise ValueError(
                f"SaveAt({mode}) under Sharded() batching is ragged "
                "across shards (each shard's controller accepts its own "
                "step count); use SaveAt(ts=grid) or an unsharded "
                "Lockstep() solve")
        self.inner.validate(controller, saveat)


def batch_size(z0: Pytree) -> int:
    """Static leading-axis batch size of a batched state pytree.

    Every leaf must carry the batch axis in front; raises an actionable
    error when a leaf is scalar or leaves disagree (the classic bug of
    batching only part of the state).
    """
    leaves = jax.tree_util.tree_leaves(z0)
    if not leaves:
        raise ValueError("batched solve needs a non-empty z0 pytree")
    sizes = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(z0)[0]:
        shape = getattr(leaf, "shape", ())
        key = jax.tree_util.keystr(path) or "<root>"
        if len(shape) == 0:
            raise ValueError(
                f"batched solve: z0 leaf {key} is a scalar — every leaf "
                "must have the batch axis as its leading dimension (add "
                "one with z[:, None]... or drop batching=)")
        sizes[key] = shape[0]
    if len(set(sizes.values())) != 1:
        detail = ", ".join(f"{k}: {v}" for k, v in sizes.items())
        raise ValueError(
            "batched solve: inconsistent leading (batch) axis across z0 "
            f"leaves — {detail}. All leaves must share the same batch "
            "size; non-batched per-sample constants belong in params.")
    return next(iter(sizes.values()))


_tm = jax.tree_util.tree_map


def tree_vdot(a: Pytree, b: Pytree) -> jax.Array:
    """Scalar inner product over matching pytrees (the adjoint-state dot
    products the boundary cotangents are built from)."""
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    acc = jnp.vdot(leaves_a[0], leaves_b[0])
    for x, y in zip(leaves_a[1:], leaves_b[1:]):
        acc = acc + jnp.vdot(x, y)
    return acc


def bounds_cotangents(f, params: Pytree, z_traj: Pytree, ts: jax.Array,
                      g_traj: Pytree, a_t0: Pytree) -> jax.Array:
    """The analytic observation-time cotangents of an ODE solve
    (``solve(..., diff_bounds=True)``; torchdiffeq/diffrax convention).

    The continuous solution ``z(t_k)`` depends on an *interior or end*
    observation time only through where it is sampled, and on the span
    start ``t0`` only through the initial condition ``z(t0) = z0``::

        dL/dt_k = +<g_k, f(z_k, t_k)>          k = 1 .. T-1
        dL/dt_0 = -<a(t0), f(z0, t0)>

    where ``a(t0)`` is the swept adjoint state at ``t0`` — the method's
    total ``dL/dz0`` minus the ``traj[0] == z0`` identity-row cotangent
    ``g_0`` (``traj[0]`` is the raw input, not a function of ``t0``).
    Every gradient method's backward already holds ``z_traj``/``a(t0)``,
    so the boundary terms cost one batched ``f`` sweep over the T-1
    observation states plus one ``f(z0, t0)`` evaluation.
    """
    z0 = _tm(lambda b: b[0], z_traj)
    tail_z = _tm(lambda b: b[1:], z_traj)
    tail_g = _tm(lambda b: b[1:], g_traj)
    f_rows = jax.vmap(lambda z, t: f(params, z, t))(tail_z, ts[1:])
    g_tail = jax.vmap(tree_vdot)(tail_g, f_rows)
    g_t0 = -tree_vdot(a_t0, f(params, z0, ts[0]))
    return jnp.concatenate([jnp.reshape(g_t0, (1,)),
                            g_tail]).astype(ts.dtype)


class GradientMethod:
    """Base of the gradient-estimation axis (paper Table 1 rows).

    Subclasses are frozen dataclasses (hashable, so they can sit in static
    jit arguments) implementing:

    * ``default_solver()`` — the paper's pairing (MALI/Naive -> ALF,
      ACA -> Heun-Euler, Backsolve -> Dopri5);
    * ``validate(solver, controller)`` — reject incompatible axes with an
      actionable error *before* tracing;
    * ``integrate(f, params, z0, ts, solver, controller, diff_bounds)`` —
      run the observation-grid forward and return ``(traj, RunStats)``
      where ``traj`` has leading axis T = len(ts). custom_vjp methods own
      their VJP wiring here. With ``diff_bounds=True`` the backward emits
      the analytic :func:`bounds_cotangents` for ``ts`` (zeros otherwise —
      the pre-FFJORD static-bounds behavior);
    * ``residual_bytes(z0, n_obs, solver, controller)`` — the analytic
      backward-residual footprint for ``Stats``.
    """

    name: str = "?"

    def default_solver(self):
        raise NotImplementedError

    def validate(self, solver, controller) -> None:
        if controller.adaptive and not solver.has_error_estimate:
            raise ValueError(
                f"solver {solver.name!r} has no embedded error estimate; "
                "use ConstantSteps(n) with it or pick an embedded pair")

    def integrate(self, f, params, z0: Pytree, ts: jax.Array, solver,
                  controller,
                  diff_bounds: bool = False) -> Tuple[Pytree, RunStats]:
        raise NotImplementedError

    def integrate_batched(self, f, params, z0: Pytree, ts: jax.Array,
                          solver, controller,
                          diff_bounds: bool = False) -> Tuple[Pytree,
                                                              RunStats]:
        """PerSample driver: vmap the per-trajectory masked-scan driver
        over the leading batch axis of ``z0``. Under vmap the scan carry
        — ``(state, t, h, done)`` and the recorded ``(t_i, h_i)`` replay
        buffers — is per-row, so each sample accepts/rejects independently,
        finished samples ride along as no-ops, and this method's
        custom_vjp backward replays each row's own step script. Returns
        ``(traj, RunStats)`` with leading axis B (traj: ``(B, T, ...)``,
        counters: ``(B,)``). ``ts`` rides as a closed-over constant, so
        with ``diff_bounds=True`` its cotangent sums over the batch rows."""
        return jax.vmap(
            lambda z: self.integrate(f, params, z, ts, solver, controller,
                                     diff_bounds)
        )(z0)

    def residual_bytes(self, z0: Pytree, n_obs: int, solver,
                       controller) -> int:
        return 0


def state_nbytes(z0: Pytree) -> int:
    """Static byte size of one state pytree (shape/dtype only — works on
    tracers)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(z0):
        shape = getattr(leaf, "shape", ())
        dtype = getattr(leaf, "dtype", None)
        itemsize = np.dtype(dtype).itemsize if dtype is not None else 4
        total += int(np.prod(shape, dtype=np.int64)) * itemsize
    return total
