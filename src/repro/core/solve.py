"""solve(): the composable front door of the integrator library.

The paper's Table 1 is a matrix of gradient methods x solvers x step-size
policies; ``solve`` exposes exactly those axes as independent objects, so a
method-swap experiment is a one-argument change::

    from repro.core import (solve, SaveAt, Solution, ALF, Dopri5,
                            ConstantSteps, AdaptiveController,
                            MALI, Naive, ACA, Backsolve)

    sol = solve(f, params, z0, 0.0, 1.0,
                solver=ALF(eta=1.0),              # paper Algo 2/3
                controller=ConstantSteps(8),      # or AdaptiveController(...)
                gradient=MALI(fused_bwd=True),    # or Naive()/ACA()/Backsolve()
                saveat=SaveAt(ts=jnp.linspace(0., 1., 16)))
    sol.ys      # (16, ...) trajectory
    sol.stats   # accepted/rejected steps, f-evals, residual footprint

Each axis maps back to a paper concept:

* ``solver`` (:mod:`repro.core.solvers`) — the step map ``psi`` of Algo 1;
  :class:`ALF` is the invertible augmented-state solver of Algo 2/3 and
  carries the damping ``eta`` (Appendix A.5).
* ``controller`` (:mod:`repro.core.stepsize`) — Algo 1's accept/reject
  policy: :class:`ConstantSteps` (the large-scale fixed-h setting) or
  :class:`AdaptiveController` (rtol/atol with a bounded trial budget).
* ``gradient`` — the Table 1 row: :class:`MALI` (Algo 4),
  :class:`Naive` (direct backprop), :class:`ACA` (checkpoint adjoint),
  :class:`Backsolve` (reverse-time adjoint, Thm 2.1's drifting baseline).
* ``saveat`` — what to return: ``z(t1)``, the observation-grid trajectory
  (the shape MALI's O(T * N_z) residual claim is stated over), or dense
  per-step output.
* ``batching`` (:mod:`repro.core.interface`) — how a leading batch axis of
  ``z0`` is integrated: :class:`Lockstep` (one shared accept/reject per
  trial, the Chen et al. 2018 concatenated-system semantics),
  :class:`PerSample` (each row carries its own ``(t, h, done)`` through
  the masked scan), or :class:`Sharded` (shard_map data parallelism over
  a mesh axis — the serving path).

``Solution.stats`` replaces the old ``mali_forward_stats`` side channel:
accepted/rejected step counts and forward f-evals come from the actual run
(Algo 1's accounting, rejected trials included), the residual footprint is
the gradient method's analytic Table-1 memory column.

The legacy string-keyed :func:`repro.core.api.odeint` facade is a thin shim
that builds these objects and returns ``Solution.ys``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from .aca import ACA
from .adjoint import Adjoint, Backsolve
from .dense import build_interpolation, locate_event
from .integrate import (as_time_grid, integrate_grid, scalar_time_grid,
                        validate_span)
from .interface import (Batching, Event, GradientMethod, Lockstep, PerSample,
                        RunStats, SaveAt, Sharded, Solution, Stats,
                        batch_size, make_run_stats, state_nbytes, tree_vdot)
from .mali import MALI
from .naive import Naive, check_direct_backprop as _check_direct_backprop
from .solvers import ALF, Solver, get_solver
from .stepsize import AdaptiveController, StepController

_tm = jax.tree_util.tree_map

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]


def _build_stats(rstats: RunStats, gradient: GradientMethod, z0: Pytree,
                 grid: jax.Array, solver: Solver,
                 controller: StepController) -> Stats:
    # NOTE: all counter arithmetic happened inside the gradient method's
    # primal (make_run_stats) — the integer outputs of a custom_vjp carry
    # instantiated float0 tangents under vmap-of-grad, so operating on them
    # here would crash jvp tracing. This only repackages.
    n_obs = int(grid.shape[0])
    return Stats(
        n_accepted=rstats.n_accepted,
        n_rejected=rstats.n_rejected,
        n_fevals=rstats.n_fevals,
        n_segments=n_obs - 1,
        residual_bytes=gradient.residual_bytes(z0, n_obs, solver, controller),
    )


def _record_span(f, params, z0, t0, t1, solver, controller):
    """One state-recording integration over the single [t0, t1] segment
    (the shared forward of SaveAt(steps=True), SaveAt(dense=True) and the
    event-detection pass). Works in both time directions."""
    grid = scalar_time_grid(t0, t1)
    state0 = solver.init_state(f, params, z0, grid[0])
    trial = solver.trial_fn(f, params, controller)
    res = integrate_grid(trial, state0, grid, controller=controller,
                         order=solver.order, record_states=True)
    return grid, res


def _span_interpolation(f, params, solver, grid, res):
    """Fit the dense cubic-Hermite record of one recorded span."""
    states = _tm(lambda b: b[0], res.state_traj)
    return build_interpolation(solver, f, params, states, res.state,
                               res.ts[0], res.hs[0], res.n_accepted[0],
                               grid[0], grid[-1])


def _solve_dense(f, params, z0, t0, t1, solver, controller,
                 gradient) -> Solution:
    """SaveAt(steps=True): record every accepted step of the single
    [t0, t1] segment. Per-step output pins each intermediate state by
    definition, so gradients flow by direct backprop through the recorded
    sequence (there is nothing for a memory-efficient method to save)."""
    _check_direct_backprop(solver, "SaveAt(steps=True)")
    grid, res = _record_span(f, params, z0, t0, t1, solver, controller)

    n_acc = res.n_accepted[0]
    starts = solver.output(_tm(lambda b: b[0], res.state_traj))  # (bound, ...)
    final = solver.output(res.state)
    # One padded buffer: rows 0..n_acc-1 are step-start states, row n_acc is
    # the final state, later rows stay zero. stats.n_accepted tells the
    # caller how many rows are live (n_accepted + 1 including the endpoint).
    ys = _tm(
        lambda b, fin: jnp.concatenate([b, jnp.zeros_like(b[:1])], 0)
        .at[n_acc].set(fin),
        starts, final)
    ts_out = jnp.concatenate([res.ts[0], jnp.zeros((1,), grid.dtype)])
    ts_out = ts_out.at[n_acc].set(grid[-1])

    init_evals = 1 if isinstance(solver, ALF) else 0
    rstats = make_run_stats(res.n_accepted, res.n_trials, solver.stages,
                            init_evals)
    # Dense residuals = the recorded buffer itself.
    stats = _build_stats(rstats, Naive(), z0, grid, solver, controller)
    stats = stats._replace(span_complete=res.completed)
    # Live rows: the n_acc step-start states plus the endpoint row.
    return Solution(ys=ys, ts=ts_out, stats=stats, n_live=n_acc + 1)


def _solve_dense_interp(f, params, z0, t0, t1, solver, controller,
                        gradient) -> Solution:
    """SaveAt(dense=True): record the span and fit the per-accepted-step
    cubic-Hermite interpolant, making ``Solution.evaluate(t)`` live.
    Like steps=True, continuous output pins every intermediate state, so
    gradients (through ``ys`` *and* through ``evaluate``'s interpolated
    values) flow by direct backprop through the recorded sequence."""
    _check_direct_backprop(solver, "SaveAt(dense=True)")
    grid, res = _record_span(f, params, z0, t0, t1, solver, controller)
    interp = _span_interpolation(f, params, solver, grid, res)

    init_evals = ((1 if isinstance(solver, ALF) else 0)
                  + solver.interpolant_fevals(controller.step_bound))
    rstats = make_run_stats(res.n_accepted, res.n_trials, solver.stages,
                            init_evals)
    stats = _build_stats(rstats, Naive(), z0, grid, solver, controller)
    stats = stats._replace(span_complete=res.completed)
    return Solution(ys=solver.output(res.state), ts=grid[-1], stats=stats,
                    interpolation=interp)


def _ift_event_time(f, params, event: Event, z_ev, t_event, fired):
    """Differentiable event time via the implicit function theorem.

    ``locate_event`` runs on a stop-gradient detection pass, so the raw
    ``t_event`` carries no cotangents. The crossing is defined implicitly
    by ``c(z(t*; theta), t*) = 0``, giving

        dt*/dtheta = -<c_z, dz(t*)/dtheta> / (<c_z, f(z*, t*)> + c_t).

    Re-expressed as a value-preserving correction (the torchdiffeq/diffrax
    trick): ``t* - (c(z_ev, t*) - sg(c)) / sg(cdot)`` — the subtraction is
    identically zero in the primal, and its pullback routes the re-solve's
    differentiable ``z_ev`` into exactly the IFT quotient. ``fired`` gates
    the correction so an event-free span keeps a plain (zero-gradient)
    span endpoint."""
    t_arr = jnp.asarray(t_event)
    cval = jnp.asarray(event.cond_fn(z_ev, t_arr))
    z_sg = lax.stop_gradient(z_ev)
    _, vjp_c = jax.vjp(lambda z, t: jnp.asarray(event.cond_fn(z, t)),
                       z_sg, t_arr)
    c_z, c_t = vjp_c(jnp.ones_like(cval))
    cdot = tree_vdot(c_z, f(lax.stop_gradient(params), z_sg, t_arr)) + c_t
    safe = jnp.where(jnp.abs(cdot) > 1e-12, cdot, jnp.ones_like(cdot))
    corr = (cval - lax.stop_gradient(cval)) / lax.stop_gradient(safe)
    return t_arr - jnp.where(fired, corr, jnp.zeros_like(corr))


def _solve_event(f, params, z0, t0, t1, solver, controller, gradient,
                 saveat, event: Event, diff_bounds: bool) -> Solution:
    """Terminating-event solve: dense-record the full span on frozen
    (stop-gradient) inputs, locate/refine the first crossing of
    ``event.cond_fn`` on the interpolant, then re-solve ``[t0, t_event]``
    with the chosen gradient method — the frozen-``t_event`` gradient path
    every method supports (``t_event`` is a constant of the re-solve, so
    MALI replays/reconstructs, ACA checkpoints and Backsolve re-integrates
    exactly as in a plain solve). ``Stats.event_time`` is made
    differentiable afterwards via :func:`_ift_event_time`."""
    if saveat.steps or saveat.dense:
        raise ValueError(
            "SaveAt(steps=True)/SaveAt(dense=True) with event= is not "
            "supported: the per-step record would mix pre- and post-event "
            "steps of the detection pass; use SaveAt(ts=grid) (post-event "
            "rows hold the terminal state) or the default end state")
    trajectory = saveat.ts is not None
    if trajectory:
        user_grid = as_time_grid(saveat.ts)
        t0, t1 = user_grid[0], user_grid[-1]

    # Detection pass — never differentiated (inputs are stop-gradient'd),
    # so it composes with any forward backend, and its bisection costs no
    # dynamics evaluations (polynomial arithmetic on the interpolant).
    p_det = lax.stop_gradient(params)
    z_det = lax.stop_gradient(z0)
    grid, res = _record_span(f, p_det, z_det, t0, t1, solver, controller)
    interp = _span_interpolation(f, p_det, solver, grid, res)
    t_event, fired = locate_event(interp, event.cond_fn, event.direction,
                                  event.max_bisections, grid[-1])
    t_event = lax.stop_gradient(t_event)

    # Differentiable re-solve over the event-terminated span. In grid mode
    # the observation times are clamped at t_event (sign-aware), which
    # turns every post-event segment into a zero-length no-op — those rows
    # of ys/ts hold the frozen terminal state/time by construction.
    if trajectory:
        forward = user_grid[-1] >= user_grid[0]
        clamped = jnp.where(forward, jnp.minimum(user_grid, t_event),
                            jnp.maximum(user_grid, t_event))
        traj, rstats = gradient.integrate(f, params, z0, clamped, solver,
                                          controller, diff_bounds)
        ys, ts_out, grid_out = traj, clamped, clamped
        z_ev = _tm(lambda b: b[-1], traj)
    else:
        grid_out = jnp.stack([grid[0], jnp.asarray(t_event, grid.dtype)])
        traj, rstats = gradient.integrate(f, params, z0, grid_out, solver,
                                          controller, diff_bounds)
        ys, ts_out = _tm(lambda b: b[-1], traj), grid_out[-1]
        z_ev = ys
    t_event = _ift_event_time(f, params, event, z_ev, t_event, fired)

    # Total accounting = re-solve + detection pass. The re-solve counters
    # come out of a custom_vjp primal — detach before arithmetic (their
    # instantiated float0 tangents would crash jvp tracing under
    # vmap-of-grad otherwise).
    det = make_run_stats(res.n_accepted, res.n_trials, solver.stages,
                         (1 if isinstance(solver, ALF) else 0)
                         + solver.interpolant_fevals(controller.step_bound))
    rstats = _detached(rstats)
    stats = Stats(
        n_accepted=rstats.n_accepted + det.n_accepted,
        n_rejected=rstats.n_rejected + det.n_rejected,
        n_fevals=rstats.n_fevals + det.n_fevals,
        n_segments=int(grid_out.shape[0]) - 1,
        residual_bytes=gradient.residual_bytes(z0, int(grid_out.shape[0]),
                                               solver, controller),
        event_fired=fired,
        event_time=t_event,
        span_complete=res.completed,
    )
    return Solution(ys=ys, ts=ts_out, stats=stats)


# ---------------------------------------------------------------------------
# Batched drivers (the Batching axis)
# ---------------------------------------------------------------------------

def _detached(rstats: RunStats) -> RunStats:
    # Counters are integer outputs of a custom_vjp primal; detach before any
    # arithmetic so their instantiated float0 tangents never reach a jvp rule.
    return RunStats(*(jax.lax.stop_gradient(c) for c in rstats))


def _batched_stats(per: RunStats, gradient: GradientMethod, z0: Pytree,
                   grid: jax.Array, solver: Solver,
                   controller: StepController) -> Stats:
    """Stats for a batched solve: ``per_sample`` keeps the (B,) rows, the
    scalar counters hold the per-row totals (sum over rows — so lockstep
    reports B x its shared trial count, comparable with per-sample)."""
    per = _detached(per)
    return Stats(
        n_accepted=jnp.sum(per.n_accepted).astype(jnp.int32),
        n_rejected=jnp.sum(per.n_rejected).astype(jnp.int32),
        n_fevals=jnp.sum(per.n_fevals).astype(jnp.int32),
        n_segments=int(grid.shape[0]) - 1,
        residual_bytes=gradient.residual_bytes(z0, int(grid.shape[0]),
                                               solver, controller),
        per_sample=per,
    )


def _broadcast_rows(rstats: RunStats, nb: int) -> RunStats:
    """Lockstep per-row counters: every row takes the shared step sequence
    and is evaluated on every shared trial, so each row's counters equal
    the batch-system's shared counters."""
    det = _detached(rstats)
    return RunStats(*(jnp.broadcast_to(c, (nb,)) for c in det))


def _batch_first(traj: Pytree) -> Pytree:
    """(T, B, ...) observation trajectory -> the batch-first (B, T, ...)
    convention every batched mode returns."""
    return _tm(lambda b: jnp.moveaxis(b, 0, 1), traj)


def _solve_lockstep(f, params, z0, grid, nb, solver, controller, gradient,
                    trajectory, diff_bounds=False):
    """One shared controller decision per trial: integrate the batch as a
    single concatenated system (the unbatched machinery on the batched
    state — exactly the implicit pre-Batching semantics, made explicit)."""
    traj, rstats = gradient.integrate(f, params, z0, grid, solver,
                                      controller, diff_bounds)
    per = _broadcast_rows(rstats, nb)
    ys = _batch_first(traj) if trajectory else _tm(lambda b: b[-1], traj)
    return ys, per


def _solve_per_sample(f, params, z0, grid, solver, controller, gradient,
                      trajectory, diff_bounds=False):
    """Row-independent adaptive control via the vmapped masked-scan driver
    (each sample carries its own (t, h, done); see integrate.py)."""
    traj, per = gradient.integrate_batched(f, params, z0, grid, solver,
                                           controller, diff_bounds)
    ys = traj if trajectory else _tm(lambda b: b[:, -1], traj)
    return ys, _detached(per)


def _solve_sharded(f, params, z0, grid, nb, solver, controller, gradient,
                   trajectory, batching: Sharded):
    """Data-parallel fleet: shard_map the inner batched driver over one
    mesh axis, one shard of the batch per device group (the serving path —
    reuses the ambient production/host mesh, see repro.launch.mesh)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import ambient_mesh

    mesh = ambient_mesh()
    if mesh is None:
        raise ValueError(
            "Sharded() batching needs an active mesh context: wrap the "
            "solve in `with mesh:` (repro.launch.mesh.make_host_mesh() or "
            "make_production_mesh()), or use Lockstep()/PerSample() on a "
            "single device")
    if batching.axis not in mesh.axis_names:
        raise ValueError(
            f"Sharded(axis={batching.axis!r}): the active mesh has axes "
            f"{mesh.axis_names}; pass one of those (the production mesh "
            "uses 'data' for batch parallelism)")
    n_shards = mesh.shape[batching.axis]
    if nb % n_shards != 0:
        raise ValueError(
            f"Sharded(axis={batching.axis!r}): batch size {nb} is not "
            f"divisible by the axis size {n_shards}; pad the batch or "
            "pick a divisible size")

    inner_per_sample = isinstance(batching.inner, PerSample)

    def shard_body(p, z_local):
        if inner_per_sample:
            return _solve_per_sample(f, p, z_local, grid, solver,
                                     controller, gradient, trajectory)
        return _solve_lockstep(f, p, z_local, grid, nb // n_shards, solver,
                               controller, gradient, trajectory)

    spec = P(batching.axis)
    ys, per = shard_map(shard_body, mesh=mesh, in_specs=(P(), spec),
                        out_specs=(spec, spec), check_rep=False)(params, z0)
    return ys, per


def _solve_batched(f, params, z0, t0, t1, solver, controller, gradient,
                   saveat, batching: Batching,
                   diff_bounds: bool = False) -> Solution:
    nb = batch_size(z0)

    if saveat.steps or saveat.dense:
        # Lockstep's shared step sequence keeps per-step output rectangular;
        # PerSample/Sharded raggedness is rejected in Batching.validate.
        if saveat.steps:
            sol = _solve_dense(f, params, z0, t0, t1, solver, controller,
                               gradient)
            ys = _batch_first(sol.ys)
        else:
            # dense=True: the end state is already batch-first; the fitted
            # interpolant carries the batch axis inside each coefficient
            # leaf, so evaluate(t) returns (B, ...) per scalar query.
            sol = _solve_dense_interp(f, params, z0, t0, t1, solver,
                                      controller, gradient)
            ys = sol.ys
        per = _broadcast_rows(
            RunStats(sol.stats.n_accepted, sol.stats.n_rejected,
                     sol.stats.n_fevals), nb)
        # Same contract as _batched_stats: scalars are the per-row totals.
        stats = Stats(
            n_accepted=jnp.sum(per.n_accepted).astype(jnp.int32),
            n_rejected=jnp.sum(per.n_rejected).astype(jnp.int32),
            n_fevals=jnp.sum(per.n_fevals).astype(jnp.int32),
            n_segments=sol.stats.n_segments,
            residual_bytes=sol.stats.residual_bytes,
            per_sample=per,
            span_complete=sol.stats.span_complete)
        return Solution(ys=ys, ts=sol.ts, stats=stats,
                        interpolation=sol.interpolation, n_live=sol.n_live)

    trajectory = saveat.ts is not None
    grid = as_time_grid(saveat.ts) if trajectory else scalar_time_grid(t0, t1)

    if isinstance(batching, Sharded):
        ys, per = _solve_sharded(f, params, z0, grid, nb, solver, controller,
                                 gradient, trajectory, batching)
    elif isinstance(batching, PerSample):
        ys, per = _solve_per_sample(f, params, z0, grid, solver, controller,
                                    gradient, trajectory, diff_bounds)
    else:
        ys, per = _solve_lockstep(f, params, z0, grid, nb, solver,
                                  controller, gradient, trajectory,
                                  diff_bounds)

    stats = _batched_stats(per, gradient, z0, grid, solver, controller)
    ts_out = grid if trajectory else grid[-1]
    return Solution(ys=ys, ts=ts_out, stats=stats)


def solve(f: Dynamics, params: Pytree, z0: Pytree, t0=0.0, t1=1.0, *,
          solver: Optional[Solver] = None,
          controller: Optional[StepController] = None,
          gradient: Optional[GradientMethod] = None,
          saveat: Optional[SaveAt] = None,
          batching: Optional[Batching] = None,
          event: Optional[Event] = None,
          diff_bounds: bool = False) -> Solution:
    """Integrate ``dz/dt = f(params, z, t)`` and return a :class:`Solution`.

    Time is a first-class axis: ``t1 < t0`` (or a descending ``SaveAt.ts``
    grid) integrates in *reverse time* — the drivers carry the span's sign
    through step clipping and error control, and every gradient method
    replays its signed ``(t_i, h_i)`` step record, so values and gradients
    match the time-reflected forward solve. Only ``t0 == t1`` is rejected.

    Arguments (all axes default to the paper's MALI configuration):

    * ``solver`` — a :class:`~repro.core.solvers.Solver` (or legacy string
      name); defaults to the gradient method's paper pairing.
    * ``controller`` — a :class:`~repro.core.stepsize.StepController`;
      defaults to ``AdaptiveController(rtol=1e-2, atol=1e-3, max_steps=64)``.
    * ``gradient`` — a :class:`~repro.core.interface.GradientMethod`;
      defaults to ``MALI()``.
    * ``saveat`` — a :class:`~repro.core.interface.SaveAt`; defaults to the
      end state ``z(t1)``. With ``SaveAt(ts=grid)``, ``t0``/``t1`` are
      ignored and ``ys`` is the (T, ...) trajectory with ``ys[0] == z0``.
      With ``SaveAt(dense=True)`` the returned solution is callable in
      time: ``Solution.evaluate(t)`` interpolates anywhere in the span off
      per-step cubic-Hermite coefficients.
    * ``event`` — a terminating :class:`~repro.core.interface.Event`:
      integration stops at the first sign change of ``cond_fn(z, t)``
      (bisection-refined on the dense interpolant), ``stats.event_time`` /
      ``stats.event_fired`` record the outcome, and in grid mode the
      post-event rows of ``ys``/``ts`` hold the frozen terminal state.
      Gradients flow through the frozen-``t_event`` path for all four
      methods.
    * ``batching`` — a :class:`~repro.core.interface.Batching`, making the
      leading axis of ``z0`` an explicit batch axis: :class:`Lockstep`
      (one shared controller decision per trial — the implicit semantics
      an unbatched solve applies to a batch-shaped state, made explicit),
      :class:`PerSample` (row-independent adaptive control; fewer total
      f-evals on stiffness-heterogeneous batches), or :class:`Sharded`
      (data-parallel over a mesh axis). Batched ``ys`` is batch-first:
      ``(B, ...)`` end state or ``(B, T, ...)`` trajectory, identical
      across modes, and ``stats`` gains per-sample rows (see
      :class:`Stats`). ``None`` (default) keeps the single-trajectory
      semantics untouched.
    * ``diff_bounds`` — make the integration bounds differentiable: the
      chosen gradient method emits the analytic boundary cotangents
      ``dL/dt_k = <g_k, f(z_k, t_k)>`` (k >= 1) and
      ``dL/dt_0 = -<a(t0), f(z0, t0)>`` for ``t0``/``t1`` (and every
      ``SaveAt.ts`` entry) instead of zeros — the hook FFJORD-style
      trainable end-times (``repro.cnf``) need. Costs one extra batched
      f-sweep over the observation states in the backward. Not available
      with ``SaveAt(steps=True)``/``SaveAt(dense=True)`` (per-step output
      has no fixed observation grid) or ``Sharded`` batching (the grid is
      a closed-over constant inside shard_map).

    The returned :class:`Solution` is a pytree (jit/vmap/grad-safe);
    differentiate any loss of ``sol.ys`` and the chosen gradient method's
    custom VJP applies. Cross-axis compatibility (MALI => ALF, adaptive
    control => embedded error estimate, ACA => Runge-Kutta, per-sample
    batching => rectangular output) is validated eagerly with actionable
    errors.
    """
    gradient = MALI() if gradient is None else gradient
    if not isinstance(gradient, GradientMethod):
        raise TypeError(f"gradient must be a GradientMethod, got {gradient!r}")
    solver = gradient.default_solver() if solver is None else get_solver(solver)
    controller = AdaptiveController() if controller is None else controller
    if not isinstance(controller, StepController):
        raise TypeError(
            f"controller must be a StepController (ConstantSteps or "
            f"AdaptiveController), got {controller!r}")
    saveat = SaveAt() if saveat is None else saveat

    gradient.validate(solver, controller)
    if saveat.ts is None:
        validate_span(t0, t1)

    if diff_bounds:
        if saveat.steps or saveat.dense:
            raise ValueError(
                "diff_bounds=True needs a fixed observation grid; "
                "SaveAt(steps=True)/SaveAt(dense=True) output is indexed by "
                "accepted steps, which carry no boundary cotangents — use "
                "the default end state or SaveAt(ts=grid)")
        if isinstance(batching, Sharded):
            raise ValueError(
                "diff_bounds=True with Sharded() batching is not supported: "
                "the observation grid is a closed-over constant inside "
                "shard_map, so its cotangents cannot cross the mesh axis — "
                "use Lockstep()/PerSample(), or vmap sharded solves with "
                "static bounds")

    if event is not None:
        if not isinstance(event, Event):
            raise TypeError(f"event must be an Event, got {event!r}")
        if batching is not None:
            raise ValueError(
                "event= with batching= is not supported: per-sample event "
                "times are ragged; vmap single event solves, or solve the "
                "batch without an event and post-process")
        return _solve_event(f, params, z0, t0, t1, solver, controller,
                            gradient, saveat, event, diff_bounds)

    if batching is not None:
        if not isinstance(batching, Batching):
            raise TypeError(
                f"batching must be a Batching (Lockstep, PerSample or "
                f"Sharded), got {batching!r}")
        batching.validate(controller, saveat)
        return _solve_batched(f, params, z0, t0, t1, solver, controller,
                              gradient, saveat, batching, diff_bounds)

    if saveat.steps:
        return _solve_dense(f, params, z0, t0, t1, solver, controller,
                            gradient)
    if saveat.dense:
        return _solve_dense_interp(f, params, z0, t0, t1, solver,
                                   controller, gradient)

    trajectory = saveat.ts is not None
    grid = as_time_grid(saveat.ts) if trajectory else scalar_time_grid(t0, t1)
    traj, rstats = gradient.integrate(f, params, z0, grid, solver, controller,
                                      diff_bounds)
    stats = _build_stats(rstats, gradient, z0, grid, solver, controller)
    if trajectory:
        return Solution(ys=traj, ts=grid, stats=stats)
    return Solution(ys=_tm(lambda b: b[-1], traj), ts=grid[-1], stats=stats)


__all__ = ["solve", "Solution", "SaveAt", "Stats", "Event", "GradientMethod",
           "Batching", "Lockstep", "PerSample", "Sharded",
           "MALI", "Naive", "ACA", "Backsolve", "Adjoint", "ALF",
           "AdaptiveController", "state_nbytes"]
