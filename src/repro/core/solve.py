"""solve(): the composable front door of the integrator library.

The paper's Table 1 is a matrix of gradient methods x solvers x step-size
policies; ``solve`` exposes exactly those axes as independent objects, so a
method-swap experiment is a one-argument change::

    from repro.core import (solve, SaveAt, Solution, ALF, Dopri5,
                            ConstantSteps, AdaptiveController,
                            MALI, Naive, ACA, Backsolve)

    sol = solve(f, params, z0, 0.0, 1.0,
                solver=ALF(eta=1.0),              # paper Algo 2/3
                controller=ConstantSteps(8),      # or AdaptiveController(...)
                gradient=MALI(fused_bwd=True),    # or Naive()/ACA()/Backsolve()
                saveat=SaveAt(ts=jnp.linspace(0., 1., 16)))
    sol.ys      # (16, ...) trajectory
    sol.stats   # accepted/rejected steps, f-evals, residual footprint

Each axis maps back to a paper concept:

* ``solver`` (:mod:`repro.core.solvers`) — the step map ``psi`` of Algo 1;
  :class:`ALF` is the invertible augmented-state solver of Algo 2/3 and
  carries the damping ``eta`` (Appendix A.5).
* ``controller`` (:mod:`repro.core.stepsize`) — Algo 1's accept/reject
  policy: :class:`ConstantSteps` (the large-scale fixed-h setting) or
  :class:`AdaptiveController` (rtol/atol with a bounded trial budget).
* ``gradient`` — the Table 1 row: :class:`MALI` (Algo 4),
  :class:`Naive` (direct backprop), :class:`ACA` (checkpoint adjoint),
  :class:`Backsolve` (reverse-time adjoint, Thm 2.1's drifting baseline).
* ``saveat`` — what to return: ``z(t1)``, the observation-grid trajectory
  (the shape MALI's O(T * N_z) residual claim is stated over), or dense
  per-step output.

``Solution.stats`` replaces the old ``mali_forward_stats`` side channel:
accepted/rejected step counts and forward f-evals come from the actual run
(Algo 1's accounting, rejected trials included), the residual footprint is
the gradient method's analytic Table-1 memory column.

The legacy string-keyed :func:`repro.core.api.odeint` facade is a thin shim
that builds these objects and returns ``Solution.ys``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .aca import ACA
from .adjoint import Adjoint, Backsolve
from .integrate import as_time_grid, integrate_grid, scalar_time_grid
from .interface import (GradientMethod, RunStats, SaveAt, Solution, Stats,
                        make_run_stats, state_nbytes)
from .mali import MALI
from .naive import Naive
from .solvers import ALF, Solver, get_solver
from .stepsize import AdaptiveController, StepController

_tm = jax.tree_util.tree_map

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]


def _build_stats(rstats: RunStats, gradient: GradientMethod, z0: Pytree,
                 grid: jax.Array, solver: Solver,
                 controller: StepController) -> Stats:
    # NOTE: all counter arithmetic happened inside the gradient method's
    # primal (make_run_stats) — the integer outputs of a custom_vjp carry
    # instantiated float0 tangents under vmap-of-grad, so operating on them
    # here would crash jvp tracing. This only repackages.
    n_obs = int(grid.shape[0])
    return Stats(
        n_accepted=rstats.n_accepted,
        n_rejected=rstats.n_rejected,
        n_fevals=rstats.n_fevals,
        n_segments=n_obs - 1,
        residual_bytes=gradient.residual_bytes(z0, n_obs, solver, controller),
    )


def _solve_dense(f, params, z0, t0, t1, solver, controller,
                 gradient) -> Solution:
    """SaveAt(steps=True): record every accepted step of the single
    [t0, t1] segment. Dense output pins each intermediate state by
    definition, so gradients flow by direct backprop through the recorded
    sequence (there is nothing for a memory-efficient method to save)."""
    grid = scalar_time_grid(t0, t1)
    state0 = solver.init_state(f, params, z0, grid[0])
    trial = solver.trial_fn(f, params, controller)
    res = integrate_grid(trial, state0, grid, controller=controller,
                         order=solver.order, record_states=True)

    n_acc = res.n_accepted[0]
    starts = solver.output(_tm(lambda b: b[0], res.state_traj))  # (bound, ...)
    final = solver.output(res.state)
    # One padded buffer: rows 0..n_acc-1 are step-start states, row n_acc is
    # the final state, later rows stay zero. stats.n_accepted tells the
    # caller how many rows are live (n_accepted + 1 including the endpoint).
    ys = _tm(
        lambda b, fin: jnp.concatenate([b, jnp.zeros_like(b[:1])], 0)
        .at[n_acc].set(fin),
        starts, final)
    ts_out = jnp.concatenate([res.ts[0], jnp.zeros((1,), grid.dtype)])
    ts_out = ts_out.at[n_acc].set(grid[-1])

    init_evals = 1 if isinstance(solver, ALF) else 0
    rstats = make_run_stats(res.n_accepted, res.n_trials, solver.stages,
                            init_evals)
    # Dense residuals = the recorded buffer itself.
    stats = _build_stats(rstats, Naive(), z0, grid, solver, controller)
    return Solution(ys=ys, ts=ts_out, stats=stats)


def solve(f: Dynamics, params: Pytree, z0: Pytree, t0=0.0, t1=1.0, *,
          solver: Optional[Solver] = None,
          controller: Optional[StepController] = None,
          gradient: Optional[GradientMethod] = None,
          saveat: Optional[SaveAt] = None) -> Solution:
    """Integrate ``dz/dt = f(params, z, t)`` and return a :class:`Solution`.

    Arguments (all axes default to the paper's MALI configuration):

    * ``solver`` — a :class:`~repro.core.solvers.Solver` (or legacy string
      name); defaults to the gradient method's paper pairing.
    * ``controller`` — a :class:`~repro.core.stepsize.StepController`;
      defaults to ``AdaptiveController(rtol=1e-2, atol=1e-3, max_steps=64)``.
    * ``gradient`` — a :class:`~repro.core.interface.GradientMethod`;
      defaults to ``MALI()``.
    * ``saveat`` — a :class:`~repro.core.interface.SaveAt`; defaults to the
      end state ``z(t1)``. With ``SaveAt(ts=grid)``, ``t0``/``t1`` are
      ignored and ``ys`` is the (T, ...) trajectory with ``ys[0] == z0``.

    The returned :class:`Solution` is a pytree (jit/vmap/grad-safe);
    differentiate any loss of ``sol.ys`` and the chosen gradient method's
    custom VJP applies. Cross-axis compatibility (MALI => ALF, adaptive
    control => embedded error estimate, ACA => Runge-Kutta) is validated
    eagerly with actionable errors.
    """
    gradient = MALI() if gradient is None else gradient
    if not isinstance(gradient, GradientMethod):
        raise TypeError(f"gradient must be a GradientMethod, got {gradient!r}")
    solver = gradient.default_solver() if solver is None else get_solver(solver)
    controller = AdaptiveController() if controller is None else controller
    if not isinstance(controller, StepController):
        raise TypeError(
            f"controller must be a StepController (ConstantSteps or "
            f"AdaptiveController), got {controller!r}")
    saveat = SaveAt() if saveat is None else saveat

    gradient.validate(solver, controller)

    if saveat.steps:
        return _solve_dense(f, params, z0, t0, t1, solver, controller,
                            gradient)

    trajectory = saveat.ts is not None
    grid = as_time_grid(saveat.ts) if trajectory else scalar_time_grid(t0, t1)
    traj, rstats = gradient.integrate(f, params, z0, grid, solver, controller)
    stats = _build_stats(rstats, gradient, z0, grid, solver, controller)
    if trajectory:
        return Solution(ys=traj, ts=grid, stats=stats)
    return Solution(ys=_tm(lambda b: b[-1], traj), ts=grid[-1], stats=stats)


__all__ = ["solve", "Solution", "SaveAt", "Stats", "GradientMethod",
           "MALI", "Naive", "ACA", "Backsolve", "Adjoint", "ALF",
           "AdaptiveController", "state_nbytes"]
