"""Backsolve adjoint method (Chen et al. 2018, torchdiffeq-style) as
jax.custom_vjp.

Forward: integrate and keep only the per-observation states — O(T) memory.
Backward: solve the *reverse-time* augmented IVP

    d/dt [ z, a, g ] = [ f,  -(df/dz)^T a,  -(df/dtheta)^T a ]

from T down to t0, re-deriving the trajectory numerically. Because the
reverse-time trajectory is itself a numerical solution, it drifts from the
forward one (paper Thm 2.1) — this is the inaccuracy MALI removes. We keep
this implementation as the paper's main baseline.

:class:`Backsolve` is this module's
:class:`~repro.core.interface.GradientMethod` (alias :data:`Adjoint`); it
works with any registered solver — including ALF, whose damping rides on the
:class:`~repro.core.solvers.ALF` solver object — and both step controllers
(each observation segment restarts the adaptive controller fresh, matching
torchdiffeq's per-interval behavior).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from jax import lax

from .alf import tree_add, tree_sub, tree_zeros_like
from .integrate import (as_time_grid, integrate_span, prepend_row,
                        reverse_segment_sweep, scalar_time_grid,
                        segment_pairs)
from .interface import (GradientMethod, RunStats, bounds_cotangents,
                        make_run_stats, state_nbytes)
from .solvers import ALF, Dopri5, Solver, get_solver
from .stepsize import StepController, controller_from_kwargs

_tm = jax.tree_util.tree_map

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]


class AdjointConfig(NamedTuple):
    """Static (hashable) configuration of the Backsolve custom_vjp."""
    f: Dynamics
    solver: Solver
    controller: StepController
    diff_bounds: bool = False  # emit analytic dL/dts boundary cotangents


def _integrate(cfg: AdjointConfig, dyn: Dynamics, params: Pytree,
               state0: Pytree, t0, t1):
    """Integrate ``dyn`` over one span with cfg's solver/controller; not
    differentiated. Returns (z_out, n_accepted, n_trials)."""
    state = cfg.solver.init_state(dyn, params, state0, t0)
    trial = cfg.solver.trial_fn(dyn, params, cfg.controller)
    out = integrate_span(trial, state, t0, t1, controller=cfg.controller,
                         order=cfg.solver.order)
    return cfg.solver.output(out.state), out.n_accepted, out.n_trials


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _adjoint_grid(cfg: AdjointConfig, params: Pytree, z0: Pytree,
                  ts: jax.Array) -> Tuple[Pytree, RunStats]:
    (z_traj, stats), _ = _adjoint_grid_fwd(cfg, params, z0, ts)
    return z_traj, stats


def _adjoint_grid_fwd(cfg, params, z0, ts):
    def seg(carry, pair):
        z, n_acc, n_tr = carry
        z1, a, t = _integrate(cfg, cfg.f, params, z, pair[0], pair[1])
        return (z1, n_acc + a, n_tr + t), z1

    zero = jnp.asarray(0, jnp.int32)
    (_, n_acc, n_tr), tail = lax.scan(seg, (z0, zero, zero),
                                      segment_pairs(ts))
    z_traj = prepend_row(z0, tail)
    # ALF re-inits v0 = f(z, t) at every observation segment here.
    init_evals = (ts.shape[0] - 1) if isinstance(cfg.solver, ALF) else 0
    out = (z_traj, make_run_stats(n_acc, n_tr, cfg.solver.stages, init_evals))
    return out, (params, z_traj, ts)  # O(T) residuals


def _adjoint_grid_bwd(cfg, res, g):
    g_traj = g[0]  # RunStats cotangents (g[1]) are zero/float0 — ignored.
    params, z_traj, ts = res

    def aug_dyn(p, aug, t):
        z, a, _g = aug
        f_val, vjp_fn = jax.vjp(lambda pp, zz: cfg.f(pp, zz, t), p, z)
        dp, dz = vjp_fn(a)
        neg = _tm(jnp.negative, (dz, dp))
        return (f_val, neg[0], neg[1])

    def seg(carry, g_k1, xs_k):
        a_z, g_p = carry
        z_k1, t0k, t1k = xs_k
        # Reverse-time IVP over [t1k -> t0k]; z restarts from the stored
        # observation (torchdiffeq-style) so reverse drift does not compound
        # across segments, and the cotangent g[k+1] is injected into a(t).
        aug0 = (z_k1, tree_add(a_z, g_k1), g_p)
        (_zrec, a_z, g_p), _, _ = _integrate(cfg, aug_dyn, params, aug0,
                                             t1k, t0k)
        return (a_z, g_p)

    carry0 = (tree_zeros_like(_tm(lambda b: b[0], g_traj)),
              tree_zeros_like(params))
    a_z, g_params = reverse_segment_sweep(
        seg, carry0, g_traj, (_tm(lambda b: b[1:], z_traj), ts[:-1], ts[1:]))
    if cfg.diff_bounds:
        a_t0 = tree_sub(a_z, _tm(lambda b: b[0], g_traj))
        g_ts = bounds_cotangents(cfg.f, params, z_traj, ts, g_traj, a_t0)
        return g_params, a_z, g_ts
    return g_params, a_z, jnp.zeros_like(ts)


_adjoint_grid.defvjp(_adjoint_grid_fwd, _adjoint_grid_bwd)


@dataclasses.dataclass(frozen=True)
class Backsolve(GradientMethod):
    """Reverse-time adjoint (Table 1 'adjoint' row): O(T) forward memory,
    gradients subject to reverse-integration drift (paper Thm 2.1).

    Under ``solve(batching=PerSample())`` the backward's reverse-time
    augmented IVP is itself integrated with per-row adaptive control (the
    vmapped masked scan), so each sample's reverse solve converges on its
    own schedule — including the backward pass's f-eval budget.

    Direction: each backward segment integrates ts[k+1] -> ts[k], whatever
    their order — for a reverse-time *forward* solve (descending ts) the
    adjoint IVP therefore runs in ascending time; the span driver is
    sign-agnostic so both cases share one code path. Thm 2.1's drift
    argument applies symmetrically: the re-derived trajectory is a fresh
    numerical solution either way."""

    name = "adjoint"

    def default_solver(self) -> Solver:
        return Dopri5()

    def integrate(self, f, params, z0, ts, solver, controller,
                  diff_bounds: bool = False):
        cfg = AdjointConfig(f, solver, controller, diff_bounds)
        traj, stats = _adjoint_grid(cfg, params, z0, ts)
        return traj, stats

    def residual_bytes(self, z0, n_obs, solver, controller) -> int:
        # Only the per-observation states survive to the backward pass.
        return n_obs * state_nbytes(z0)


Adjoint = Backsolve


def odeint_adjoint(f: Dynamics, params: Pytree, z0: Pytree, t0=0.0, t1=1.0, *,
                   ts=None, solver="dopri5", n_steps: int = 0,
                   eta: float = 1.0, rtol: float = 1e-2, atol: float = 1e-3,
                   max_steps: int = 64) -> Pytree:
    """Backsolve-adjoint integration (legacy kwargs facade)."""
    sol = get_solver(solver)
    if isinstance(sol, ALF) and eta != sol.eta:
        sol = ALF(eta=float(eta))
    controller = controller_from_kwargs(n_steps, rtol, atol, max_steps)
    method = Backsolve()
    method.validate(sol, controller)
    scalar = ts is None
    grid = scalar_time_grid(t0, t1) if scalar else as_time_grid(ts)
    traj, _ = method.integrate(f, params, z0, grid, sol, controller)
    return _tm(lambda b: b[-1], traj) if scalar else traj
