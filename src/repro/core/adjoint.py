"""Adjoint method (Chen et al. 2018, torchdiffeq-style) as jax.custom_vjp.

Forward: integrate and keep only z(T) — O(1) memory. Backward: solve the
*reverse-time* augmented IVP

    d/dt [ z, a, g ] = [ f,  -(df/dz)^T a,  -(df/dtheta)^T a ]

from T down to t0, re-deriving the trajectory numerically. Because the
reverse-time trajectory is itself a numerical solution, it drifts from the
forward one (paper Thm 2.1) — this is the inaccuracy MALI removes. We keep
this implementation as the paper's main baseline.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from jax import lax

from .alf import (alf_step, alf_step_with_error, check_eta, init_velocity,
                  tree_add, tree_zeros_like)
from .integrate import (as_time_grid, integrate_adaptive, integrate_fixed,
                        prepend_row, reverse_segment_sweep, scalar_time_grid,
                        segment_pairs)
from .solvers import ButcherTableau, get_solver
from .stepsize import error_ratio

_tm = jax.tree_util.tree_map

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]


class AdjointConfig(NamedTuple):
    f: Dynamics
    solver: Any             # ButcherTableau or AlfSolverMeta
    solver_name: str
    n_steps: int
    eta: float
    rtol: float
    atol: float
    max_steps: int


def _integrate(cfg: AdjointConfig, dyn: Dynamics, params: Pytree,
               state0: Pytree, t0, t1) -> Pytree:
    """Forward-integrate ``dyn`` with cfg's solver; not differentiated."""
    if cfg.solver_name == "alf":
        v0 = init_velocity(dyn, params, state0, t0)

        if cfg.n_steps > 0:
            def step(s, t, h):
                z, v = s
                return alf_step(dyn, params, z, v, t, h, cfg.eta)

            zT, _ = integrate_fixed(step, (state0, v0), t0, t1, cfg.n_steps)
            return zT

        def trial(s, t, h):
            z, v = s
            z1, v1, err = alf_step_with_error(dyn, params, z, v, t, h, cfg.eta)
            return (z1, v1), error_ratio(err, z, z1, cfg.rtol, cfg.atol)

        out = integrate_adaptive(trial, (state0, v0), t0, t1, order=2,
                                 rtol=cfg.rtol, atol=cfg.atol,
                                 max_steps=cfg.max_steps)
        return out.state[0]

    sol = cfg.solver
    assert isinstance(sol, ButcherTableau)
    if cfg.n_steps > 0:
        def step(z, t, h):
            z1, _ = sol.step(dyn, params, z, t, h)
            return z1

        return integrate_fixed(step, state0, t0, t1, cfg.n_steps)

    def trial(z, t, h):
        z1, err = sol.step(dyn, params, z, t, h)
        return z1, error_ratio(err, z, z1, cfg.rtol, cfg.atol)

    out = integrate_adaptive(trial, state0, t0, t1, order=sol.order,
                             rtol=cfg.rtol, atol=cfg.atol,
                             max_steps=cfg.max_steps)
    return out.state


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _adjoint_grid(cfg: AdjointConfig, params: Pytree, z0: Pytree,
                  ts: jax.Array) -> Pytree:
    z_traj, _ = _adjoint_grid_fwd(cfg, params, z0, ts)
    return z_traj


def _adjoint_grid_fwd(cfg, params, z0, ts):
    def seg(z, pair):
        z1 = _integrate(cfg, cfg.f, params, z, pair[0], pair[1])
        return z1, z1

    _, tail = lax.scan(seg, z0, segment_pairs(ts))
    z_traj = prepend_row(z0, tail)
    return z_traj, (params, z_traj, ts)  # O(T) residuals


def _adjoint_grid_bwd(cfg, res, g):
    params, z_traj, ts = res

    def aug_dyn(p, aug, t):
        z, a, _g = aug
        f_val, vjp_fn = jax.vjp(lambda pp, zz: cfg.f(pp, zz, t), p, z)
        dp, dz = vjp_fn(a)
        neg = _tm(jnp.negative, (dz, dp))
        return (f_val, neg[0], neg[1])

    def seg(carry, g_k1, xs_k):
        a_z, g_p = carry
        z_k1, t0k, t1k = xs_k
        # Reverse-time IVP over [t1k -> t0k]; z restarts from the stored
        # observation (torchdiffeq-style) so reverse drift does not compound
        # across segments, and the cotangent g[k+1] is injected into a(t).
        aug0 = (z_k1, tree_add(a_z, g_k1), g_p)
        _zrec, a_z, g_p = _integrate(cfg, aug_dyn, params, aug0, t1k, t0k)
        return (a_z, g_p)

    carry0 = (tree_zeros_like(_tm(lambda b: b[0], g)),
              tree_zeros_like(params))
    a_z, g_params = reverse_segment_sweep(
        seg, carry0, g, (_tm(lambda b: b[1:], z_traj), ts[:-1], ts[1:]))
    return g_params, a_z, jnp.zeros_like(ts)


_adjoint_grid.defvjp(_adjoint_grid_fwd, _adjoint_grid_bwd)


def odeint_adjoint(f: Dynamics, params: Pytree, z0: Pytree, t0=0.0, t1=1.0, *,
                   ts=None, solver: str = "dopri5", n_steps: int = 0,
                   eta: float = 1.0, rtol: float = 1e-2, atol: float = 1e-3,
                   max_steps: int = 64) -> Pytree:
    sol = get_solver(solver)
    if solver == "alf":
        check_eta(eta)
    elif n_steps == 0 and sol.b_err is None:
        raise ValueError(f"solver {solver!r} has no embedded error estimate")
    cfg = AdjointConfig(f, sol, solver, int(n_steps), float(eta), float(rtol),
                        float(atol), int(max_steps))
    scalar = ts is None
    grid = scalar_time_grid(t0, t1) if scalar else as_time_grid(ts)
    traj = _adjoint_grid(cfg, params, z0, grid)
    return _tm(lambda b: b[-1], traj) if scalar else traj
