"""ODEBlock: the paper's technique as a composable neural-network module.

A residual block ``y = x + g(x)`` is the one-step Euler discretization of
``dz/dt = g(z, t)``; an ODEBlock replaces the discrete residual with a
continuous integration ``y = z(T), z(0) = x`` (paper Sec 4.2), sharing the
same parameterization g. :class:`OdeSettings` is the flat/hashable config
record model configs carry; ``as_objects()`` lowers it to the composable
Solver / StepController / GradientMethod / SaveAt objects the
:func:`repro.core.solve.solve` entry point takes.

With ``obs_times`` set, the block exposes the full observation-grid
trajectory (one native ``SaveAt(ts=...)`` integration — latent-ODE decoders,
CNF visualization, deep supervision) instead of only the end state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Tuple

import jax.numpy as jnp

from .aca import ACA
from .adjoint import Backsolve
from .alf import check_eta
from .interface import Batching, SaveAt, Sharded
from .mali import MALI
from .naive import Naive
from .solve import solve
from .solvers import ALF, SOLVERS, get_solver
from .stepsize import AdaptiveController, ConstantSteps

Pytree = Any

_METHODS = ("mali", "naive", "aca", "adjoint")


@dataclasses.dataclass(frozen=True)
class OdeSettings:
    """Integrator settings carried by model configs (hashable/static).

    ``t0``/``t1`` bound the integration span; ``t0 > t1`` expresses a
    *reverse-time* block (the invertible-flow direction MALI's backward
    pass exercises) straight from a model config.
    """
    mode: str = "off"          # 'off' | 'per_block'
    method: str = "mali"       # gradient method
    solver: str = "alf"
    n_steps: int = 2           # 0 = adaptive
    t0: float = 0.0            # span start (t0 > t1 = reverse-time block)
    t1: float = 1.0
    eta: float = 1.0           # ALF damping
    rtol: float = 1e-2
    atol: float = 1e-3
    max_steps: int = 32
    fused_bwd: bool = True     # share psi^-1's f-eval with the local VJP
    obs_times: Optional[Tuple[float, ...]] = None  # observation grid ts
                               # (>= 2 points); None -> end state only
    backend: str = "reference"  # ALF step backend: 'reference' | 'pallas'
    batch_axis: Optional[str] = None  # mesh axis for Sharded() batching of
                               # the block's solves; None -> lockstep

    def validate(self) -> "OdeSettings":
        if self.mode not in ("off", "per_block"):
            raise ValueError(f"bad ode.mode {self.mode!r}")
        if self.method not in _METHODS:
            raise ValueError(f"bad ode.method {self.method!r}; "
                             f"choose from {_METHODS}")
        if self.solver not in SOLVERS:
            raise ValueError(f"bad ode.solver {self.solver!r}; "
                             f"choose from {sorted(SOLVERS)}")
        if self.method == "mali" and self.solver != "alf":
            raise ValueError("MALI requires the ALF solver")
        if self.n_steps < 0:
            raise ValueError(f"ode.n_steps must be >= 0 (0 = adaptive), "
                             f"got {self.n_steps}")
        if self.max_steps < 1:
            raise ValueError(f"ode.max_steps must be >= 1, "
                             f"got {self.max_steps}")
        if self.rtol < 0.0 or self.atol < 0.0:
            raise ValueError(f"ode tolerances must be non-negative, got "
                             f"rtol={self.rtol}, atol={self.atol}")
        if not math.isfinite(self.t0):
            raise ValueError(f"ode.t0 must be finite, got {self.t0}")
        if not math.isfinite(self.t1):
            raise ValueError(f"ode.t1 must be finite, got {self.t1}")
        if self.t0 == self.t1:
            raise ValueError(
                f"ode.t0 == ode.t1 == {self.t1} is an empty integration "
                "span; use t1 > t0 for a forward block or t0 > t1 for a "
                "reverse-time block")
        if self.solver == "alf":
            check_eta(self.eta)
        if self.obs_times is not None and len(self.obs_times) < 2:
            raise ValueError("obs_times needs at least 2 timepoints")
        if self.backend not in ("reference", "pallas"):
            raise ValueError(f"bad ode.backend {self.backend!r}; "
                             "choose 'reference' or 'pallas'")
        if self.backend == "pallas" and self.solver != "alf":
            raise ValueError("ode.backend='pallas' requires the ALF solver "
                             "(the fused step kernels are ALF-specific)")
        if self.batch_axis is not None and self.obs_times is not None:
            raise ValueError("ode.batch_axis with obs_times is unsupported: "
                             "batched trajectories are (B, T, ...) while the "
                             "block contract is time-leading (T, ...)")
        return self

    def as_objects(self):
        """Lower to (solver, controller, gradient, saveat) for solve()."""
        self.validate()
        solver = (ALF(eta=self.eta, backend=self.backend)
                  if self.solver == "alf" else get_solver(self.solver))
        controller = (ConstantSteps(self.n_steps) if self.n_steps > 0 else
                      AdaptiveController(self.rtol, self.atol,
                                         self.max_steps))
        gradient = {"mali": MALI(fused_bwd=self.fused_bwd),
                    "naive": Naive(), "aca": ACA(),
                    "adjoint": Backsolve()}[self.method]
        saveat = (SaveAt() if self.obs_times is None else
                  SaveAt(ts=jnp.asarray(self.obs_times, jnp.float32)))
        return solver, controller, gradient, saveat

    def batching(self) -> Optional[Batching]:
        """The Batching object for this block's solves (None = lockstep).

        ``batch_axis`` names a mesh axis: the block's solve runs as a
        ``Sharded(axis)`` fleet over the ambient ``with mesh:`` context
        (data-parallel shard_map; see distributed/sharding.ambient_mesh).
        """
        if self.batch_axis is None:
            return None
        return Sharded(axis=self.batch_axis)


def ode_block(dynamics: Callable[[Pytree, Pytree, Any], Pytree],
              settings: OdeSettings) -> Callable[[Pytree, Pytree], Pytree]:
    """Wrap ``dynamics(params, z, t)`` into ``apply(params, x)``.

    Returns ``z(t1)`` integrated from ``settings.t0`` (same structure as
    ``x``; ``t0 > t1`` runs the block in reverse time), or — when
    ``settings.obs_times`` is set — the trajectory pytree with leading axis
    ``len(obs_times)`` from a single native observation-grid integration.
    """
    solver, controller, gradient, saveat = settings.as_objects()

    def apply(params: Pytree, x: Pytree) -> Pytree:
        return solve(dynamics, params, x, settings.t0, settings.t1,
                     solver=solver, controller=controller, gradient=gradient,
                     saveat=saveat).ys

    return apply
