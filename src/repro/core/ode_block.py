"""ODEBlock: the paper's technique as a composable neural-network module.

A residual block ``y = x + g(x)`` is the one-step Euler discretization of
``dz/dt = g(z, t)``; an ODEBlock replaces the discrete residual with a
continuous integration ``y = z(T), z(0) = x`` (paper Sec 4.2), sharing the
same parameterization g. The gradient method (MALI / adjoint / ACA / naive),
solver, step count/tolerances and damping are all config knobs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from .api import odeint

Pytree = Any


@dataclasses.dataclass(frozen=True)
class OdeSettings:
    """Integrator settings carried by model configs (hashable/static)."""
    mode: str = "off"          # 'off' | 'per_block'
    method: str = "mali"       # gradient method
    solver: str = "alf"
    n_steps: int = 2           # 0 = adaptive
    t1: float = 1.0
    eta: float = 1.0           # ALF damping
    rtol: float = 1e-2
    atol: float = 1e-3
    max_steps: int = 32
    fused_bwd: bool = True     # share psi^-1's f-eval with the local VJP

    def validate(self) -> "OdeSettings":
        if self.mode not in ("off", "per_block"):
            raise ValueError(f"bad ode.mode {self.mode!r}")
        if self.method == "mali" and self.solver != "alf":
            raise ValueError("MALI requires the ALF solver")
        return self


def ode_block(dynamics: Callable[[Pytree, Pytree, Any], Pytree],
              settings: OdeSettings) -> Callable[[Pytree, Pytree], Pytree]:
    """Wrap ``dynamics(params, z, t)`` into ``apply(params, x) -> z(T)``."""
    s = settings.validate()

    def apply(params: Pytree, x: Pytree) -> Pytree:
        return odeint(dynamics, params, x, 0.0, s.t1, method=s.method,
                      solver=s.solver, n_steps=s.n_steps, eta=s.eta,
                      rtol=s.rtol, atol=s.atol, max_steps=s.max_steps)

    return apply
