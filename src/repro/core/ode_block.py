"""ODEBlock: the paper's technique as a composable neural-network module.

A residual block ``y = x + g(x)`` is the one-step Euler discretization of
``dz/dt = g(z, t)``; an ODEBlock replaces the discrete residual with a
continuous integration ``y = z(T), z(0) = x`` (paper Sec 4.2), sharing the
same parameterization g. The gradient method (MALI / adjoint / ACA / naive),
solver, step count/tolerances and damping are all config knobs.

With ``obs_times`` set, the block exposes the full observation-grid
trajectory (one native ``odeint(..., ts=...)`` call — latent-ODE decoders,
CNF visualization, deep supervision) instead of only the end state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax.numpy as jnp

from .api import odeint

Pytree = Any


@dataclasses.dataclass(frozen=True)
class OdeSettings:
    """Integrator settings carried by model configs (hashable/static)."""
    mode: str = "off"          # 'off' | 'per_block'
    method: str = "mali"       # gradient method
    solver: str = "alf"
    n_steps: int = 2           # 0 = adaptive
    t1: float = 1.0
    eta: float = 1.0           # ALF damping
    rtol: float = 1e-2
    atol: float = 1e-3
    max_steps: int = 32
    fused_bwd: bool = True     # share psi^-1's f-eval with the local VJP
    obs_times: Optional[Tuple[float, ...]] = None  # observation grid ts
                               # (>= 2 points); None -> end state only

    def validate(self) -> "OdeSettings":
        if self.mode not in ("off", "per_block"):
            raise ValueError(f"bad ode.mode {self.mode!r}")
        if self.method == "mali" and self.solver != "alf":
            raise ValueError("MALI requires the ALF solver")
        if self.obs_times is not None and len(self.obs_times) < 2:
            raise ValueError("obs_times needs at least 2 timepoints")
        return self


def ode_block(dynamics: Callable[[Pytree, Pytree, Any], Pytree],
              settings: OdeSettings) -> Callable[[Pytree, Pytree], Pytree]:
    """Wrap ``dynamics(params, z, t)`` into ``apply(params, x)``.

    Returns ``z(t1)`` (same structure as ``x``), or — when
    ``settings.obs_times`` is set — the trajectory pytree with leading axis
    ``len(obs_times)`` from a single native observation-grid integration.
    """
    s = settings.validate()
    ts = None if s.obs_times is None else jnp.asarray(s.obs_times, jnp.float32)

    def apply(params: Pytree, x: Pytree) -> Pytree:
        return odeint(dynamics, params, x, 0.0, s.t1, ts=ts, method=s.method,
                      solver=s.solver, n_steps=s.n_steps, eta=s.eta,
                      rtol=s.rtol, atol=s.atol, max_steps=s.max_steps,
                      fused_bwd=s.fused_bwd)

    return apply
