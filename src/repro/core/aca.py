"""Adaptive Checkpoint Adjoint (ACA; Zhuang et al. 2020) as jax.custom_vjp.

Forward stores the *accepted* trajectory {z_i} (O(N_t) memory — the paper's
N_z(N_f + N_t)) plus the accepted (t_i, h_i); backward re-plays each accepted
step under a local VJP, excluding the stepsize search from the graph
(depth N_f * N_t). This is the paper's strongest accuracy baseline and the
method MALI matches in gradient quality while dropping the O(N_t) term.

Like MALI, ACA is built around an observation grid ``ts``: a single scan
whose carry crosses segment boundaries, checkpointing per-segment step start
states and emitting z at every requested ``ts[k]``. Fixed and adaptive step
control share one custom_vjp — the static
:class:`~repro.core.stepsize.StepController` in the config picks the driver
path, and the backward sweep masks over the recorded steps either way. The
scalar path is the length-1 grid [t0, t1].

:class:`ACA` is this module's :class:`~repro.core.interface.GradientMethod`;
it accepts any Runge-Kutta solver (the augmented-state ALF solver belongs to
MALI).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .alf import tree_add, tree_sub, tree_zeros_like
from .integrate import (as_time_grid, integrate_grid, reverse_masked_scan,
                        reverse_segment_sweep, scalar_time_grid)
from .interface import (GradientMethod, RunStats, bounds_cotangents,
                        make_run_stats, state_nbytes)
from .solvers import HeunEuler, RungeKutta, get_solver
from .stepsize import StepController, controller_from_kwargs

_tm = jax.tree_util.tree_map

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]


class AcaConfig(NamedTuple):
    """Static (hashable) configuration of the ACA custom_vjp."""
    f: Dynamics
    solver: RungeKutta
    controller: StepController
    diff_bounds: bool = False  # emit analytic dL/dts boundary cotangents


def _aca_forward(cfg: AcaConfig, params, z0, ts):
    trial = cfg.solver.trial_fn(cfg.f, params, cfg.controller)
    return integrate_grid(trial, z0, ts, controller=cfg.controller,
                          order=cfg.solver.order, record_states=True)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _aca_grid(cfg: AcaConfig, params: Pytree, z0: Pytree,
              ts: jax.Array) -> Tuple[Pytree, RunStats]:
    res = _aca_forward(cfg, params, z0, ts)
    return res.traj, make_run_stats(res.n_accepted, res.n_trials,
                                    cfg.solver.stages)


def _aca_grid_fwd(cfg, params, z0, ts):
    res = _aca_forward(cfg, params, z0, ts)
    out = (res.traj, make_run_stats(res.n_accepted, res.n_trials,
                                    cfg.solver.stages))
    # Residuals: the checkpointed per-step start states (the paper's O(N_t)
    # term) + the recorded (t_i, h_i) replay script + the observation
    # trajectory (re-used by the diff_bounds boundary cotangents).
    return out, (params, res.ts, res.hs, res.n_accepted, res.state_traj,
                 res.traj, ts)


def _aca_grid_bwd(cfg, res, g):
    g_traj = g[0]  # RunStats cotangents (g[1]) are zero/float0 — ignored.
    params, seg_ts, seg_hs, seg_acc, seg_ckpts, z_traj, ts = res
    tableau = cfg.solver.tableau

    def step_body(carry, t, h, z_i):
        a_z, g_p = carry

        def step_fn(p, z):
            z1, _ = tableau.step(cfg.f, p, z, t, h)
            return z1

        _, vjp_fn = jax.vjp(step_fn, params, z_i)
        dp, dz = vjp_fn(a_z)
        return (dz, tree_add(g_p, dp))

    def seg(carry, g_k1, xs_k):
        a_z, g_p = carry
        ts_k, hs_k, n_k, ckpts_k = xs_k
        a_z = tree_add(a_z, g_k1)
        a_z, g_p = reverse_masked_scan(step_body, (a_z, g_p), ts_k, hs_k,
                                       n_k, cfg.controller.step_bound,
                                       extras=ckpts_k)
        return (a_z, g_p)

    carry0 = (tree_zeros_like(_tm(lambda b: b[0], g_traj)),
              tree_zeros_like(params))
    a_z, g_params = reverse_segment_sweep(
        seg, carry0, g_traj, (seg_ts, seg_hs, seg_acc, seg_ckpts))
    if cfg.diff_bounds:
        a_t0 = tree_sub(a_z, _tm(lambda b: b[0], g_traj))
        g_ts = bounds_cotangents(cfg.f, params, z_traj, ts, g_traj, a_t0)
        return g_params, a_z, g_ts
    return g_params, a_z, jnp.zeros_like(ts)


_aca_grid.defvjp(_aca_grid_fwd, _aca_grid_bwd)


@dataclasses.dataclass(frozen=True)
class ACA(GradientMethod):
    """Adaptive Checkpoint Adjoint (Table 1 'ACA' row): checkpoint every
    accepted step, re-play each under a local VJP in the backward sweep.

    Under ``solve(batching=PerSample())`` the checkpoint buffer and the
    recorded (t_i, h_i) replay script gain a leading batch row, so the
    backward sweep re-plays each sample's own accepted steps — per-row
    step counts differ, the masked scan pads the shorter rows.

    The replay script is *signed*: a reverse-time solve checkpoints steps
    with negative h_i and the backward sweep re-plays each checkpointed
    step with exactly that h_i, so gradients are direction-agnostic."""

    name = "aca"

    def default_solver(self) -> RungeKutta:
        return HeunEuler()

    def validate(self, solver, controller) -> None:
        if not isinstance(solver, RungeKutta):
            raise ValueError(
                "ACA supports Runge-Kutta solvers; use gradient=MALI() for "
                f"the ALF solver (got {getattr(solver, 'name', solver)!r})")
        super().validate(solver, controller)

    def integrate(self, f, params, z0, ts, solver, controller,
                  diff_bounds: bool = False):
        cfg = AcaConfig(f, solver, controller, diff_bounds)
        traj, stats = _aca_grid(cfg, params, z0, ts)
        return traj, stats

    def residual_bytes(self, z0, n_obs, solver, controller) -> int:
        # Checkpointed step-start states per segment + the observation traj.
        return ((n_obs - 1) * controller.step_bound + n_obs) * state_nbytes(z0)


def odeint_aca(f: Dynamics, params: Pytree, z0: Pytree, t0=0.0, t1=1.0, *,
               ts=None, solver="heun_euler", n_steps: int = 0,
               rtol: float = 1e-2, atol: float = 1e-3,
               max_steps: int = 64) -> Pytree:
    """ACA integration (legacy kwargs facade over the object API)."""
    sol = get_solver(solver)
    controller = controller_from_kwargs(n_steps, rtol, atol, max_steps)
    method = ACA()
    method.validate(sol, controller)
    scalar = ts is None
    grid = scalar_time_grid(t0, t1) if scalar else as_time_grid(ts)
    traj, _ = method.integrate(f, params, z0, grid, sol, controller)
    return _tm(lambda b: b[-1], traj) if scalar else traj
