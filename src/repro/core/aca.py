"""Adaptive Checkpoint Adjoint (ACA; Zhuang et al. 2020) as jax.custom_vjp.

Forward stores the *accepted* trajectory {z_i} (O(N_t) memory — the paper's
N_z(N_f + N_t)) plus the accepted (t_i, h_i); backward re-plays each accepted
step under a local VJP, excluding the stepsize search from the graph
(depth N_f * N_t). This is the paper's strongest accuracy baseline and the
method MALI matches in gradient quality while dropping the O(N_t) term.

Like MALI, ACA is built around an observation grid ``ts``: a single scan
whose carry crosses segment boundaries, checkpointing per-segment step start
states and emitting z at every requested ``ts[k]``. The backward sweep walks
the segments in reverse, injecting the trajectory cotangent g[k] at each
observation. The scalar path is the length-1 grid [t0, t1].
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .alf import tree_add, tree_zeros_like
from .integrate import (as_time_grid, fixed_grid_times,
                        integrate_adaptive_grid, prepend_row,
                        reverse_masked_scan, reverse_segment_sweep,
                        scalar_time_grid, segment_pairs)
from .solvers import ButcherTableau, get_solver
from .stepsize import error_ratio

_tm = jax.tree_util.tree_map

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]


class AcaConfig(NamedTuple):
    f: Dynamics
    solver: ButcherTableau
    n_steps: int
    rtol: float
    atol: float
    max_steps: int


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _aca_grid(cfg: AcaConfig, params: Pytree, z0: Pytree,
              ts: jax.Array) -> Pytree:
    z_traj, _ = _aca_grid_fwd(cfg, params, z0, ts)
    return z_traj


def _aca_grid_fwd(cfg, params, z0, ts):
    sol = cfg.solver

    if cfg.n_steps > 0:
        def seg(z, pair):
            step_ts, h = fixed_grid_times(pair[0], pair[1], cfg.n_steps)

            def body(zz, t):
                z1, _ = sol.step(cfg.f, params, zz, t, h)
                return z1, zz  # checkpoint the step's start state

            z_end, ckpts = lax.scan(body, z, step_ts)
            hs = jnp.full((cfg.n_steps,), h, step_ts.dtype)
            return z_end, (z_end, step_ts, hs,
                           jnp.asarray(cfg.n_steps, jnp.int32), ckpts)

        zT, (tail, seg_ts, seg_hs, seg_acc, seg_ckpts) = lax.scan(
            seg, z0, segment_pairs(ts))
        return prepend_row(z0, tail), (params, seg_ts, seg_hs, seg_acc,
                                       seg_ckpts, ts)

    def trial(z, t, h):
        z1, err = sol.step(cfg.f, params, z, t, h)
        return z1, error_ratio(err, z, z1, cfg.rtol, cfg.atol)

    out = integrate_adaptive_grid(trial, z0, ts, order=sol.order,
                                  rtol=cfg.rtol, atol=cfg.atol,
                                  max_steps=cfg.max_steps, record_states=True)
    return out.traj, (params, out.ts, out.hs, out.n_accepted,
                      out.state_traj, ts)


def _aca_grid_bwd(cfg, res, g):
    params, seg_ts, seg_hs, seg_acc, seg_ckpts, ts = res
    sol = cfg.solver
    max_steps = cfg.n_steps if cfg.n_steps > 0 else cfg.max_steps

    def step_body(carry, t, h, z_i):
        a_z, g_p = carry

        def step_fn(p, z):
            z1, _ = sol.step(cfg.f, p, z, t, h)
            return z1

        _, vjp_fn = jax.vjp(step_fn, params, z_i)
        dp, dz = vjp_fn(a_z)
        return (dz, tree_add(g_p, dp))

    def seg(carry, g_k1, xs_k):
        a_z, g_p = carry
        ts_k, hs_k, n_k, ckpts_k = xs_k
        a_z = tree_add(a_z, g_k1)
        a_z, g_p = reverse_masked_scan(step_body, (a_z, g_p), ts_k, hs_k,
                                       n_k, max_steps, extras=ckpts_k)
        return (a_z, g_p)

    carry0 = (tree_zeros_like(_tm(lambda b: b[0], g)),
              tree_zeros_like(params))
    a_z, g_params = reverse_segment_sweep(
        seg, carry0, g, (seg_ts, seg_hs, seg_acc, seg_ckpts))
    return g_params, a_z, jnp.zeros_like(ts)


_aca_grid.defvjp(_aca_grid_fwd, _aca_grid_bwd)


def odeint_aca(f: Dynamics, params: Pytree, z0: Pytree, t0=0.0, t1=1.0, *,
               ts=None, solver: str = "heun_euler", n_steps: int = 0,
               rtol: float = 1e-2, atol: float = 1e-3,
               max_steps: int = 64) -> Pytree:
    sol = get_solver(solver)
    if not isinstance(sol, ButcherTableau):
        raise ValueError("ACA supports Runge-Kutta tableaus; use MALI for ALF")
    if n_steps == 0 and sol.b_err is None:
        raise ValueError(f"solver {solver!r} has no embedded error estimate")
    cfg = AcaConfig(f, sol, int(n_steps), float(rtol), float(atol),
                    int(max_steps))
    scalar = ts is None
    grid = scalar_time_grid(t0, t1) if scalar else as_time_grid(ts)
    traj = _aca_grid(cfg, params, z0, grid)
    return _tm(lambda b: b[-1], traj) if scalar else traj
