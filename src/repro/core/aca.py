"""Adaptive Checkpoint Adjoint (ACA; Zhuang et al. 2020) as jax.custom_vjp.

Forward stores the *accepted* trajectory {z_i} (O(N_t) memory — the paper's
N_z(N_f + N_t)) plus the accepted (t_i, h_i); backward re-plays each accepted
step under a local VJP, excluding the stepsize search from the graph
(depth N_f * N_t). This is the paper's strongest accuracy baseline and the
method MALI matches in gradient quality while dropping the O(N_t) term.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .alf import tree_add, tree_zeros_like
from .integrate import (fixed_grid_times, integrate_adaptive,
                        reverse_masked_scan)
from .solvers import ButcherTableau, get_solver
from .stepsize import error_ratio

_tm = jax.tree_util.tree_map

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]


class AcaConfig(NamedTuple):
    f: Dynamics
    solver: ButcherTableau
    n_steps: int
    rtol: float
    atol: float
    max_steps: int


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _aca(cfg: AcaConfig, params: Pytree, z0: Pytree,
         t0: jax.Array, t1: jax.Array) -> Pytree:
    zT, _ = _aca_fwd(cfg, params, z0, t0, t1)
    return zT


def _aca_fwd(cfg, params, z0, t0, t1):
    sol = cfg.solver
    if cfg.n_steps > 0:
        ts, h = fixed_grid_times(t0, t1, cfg.n_steps)

        def body(z, t):
            z1, _ = sol.step(cfg.f, params, z, t, h)
            return z1, z  # checkpoint the step's start state

        zT, traj = lax.scan(body, z0, ts)
        hs = jnp.full((cfg.n_steps,), h)
        n_acc = jnp.asarray(cfg.n_steps, jnp.int32)
        return zT, (params, traj, ts, hs, n_acc, t0, t1)

    def trial(z, t, h):
        z1, err = sol.step(cfg.f, params, z, t, h)
        return z1, error_ratio(err, z, z1, cfg.rtol, cfg.atol)

    out = integrate_adaptive(trial, z0, t0, t1, order=sol.order,
                             rtol=cfg.rtol, atol=cfg.atol,
                             max_steps=cfg.max_steps, record_states=True)
    return out.state, (params, out.state_traj, out.ts, out.hs,
                       out.n_accepted, t0, t1)


def _aca_bwd(cfg, res, g_zT):
    params, traj, ts, hs, n_acc, t0, t1 = res
    sol = cfg.solver
    max_steps = cfg.n_steps if cfg.n_steps > 0 else cfg.max_steps

    def body(carry, t, h, z_i):
        a_z, g_p = carry

        def step_fn(p, z):
            z1, _ = sol.step(cfg.f, p, z, t, h)
            return z1

        _, vjp_fn = jax.vjp(step_fn, params, z_i)
        dp, dz = vjp_fn(a_z)
        return (dz, tree_add(g_p, dp))

    carry0 = (g_zT, tree_zeros_like(params))
    a_z, g_params = reverse_masked_scan(body, carry0, ts, hs, n_acc,
                                        max_steps, extras=traj)
    zero_t = jnp.zeros_like(jnp.asarray(t0))
    return g_params, a_z, zero_t, jnp.zeros_like(jnp.asarray(t1))


_aca.defvjp(_aca_fwd, _aca_bwd)


def odeint_aca(f: Dynamics, params: Pytree, z0: Pytree, t0=0.0, t1=1.0, *,
               solver: str = "heun_euler", n_steps: int = 0,
               rtol: float = 1e-2, atol: float = 1e-3,
               max_steps: int = 64) -> Pytree:
    sol = get_solver(solver)
    if not isinstance(sol, ButcherTableau):
        raise ValueError("ACA supports Runge-Kutta tableaus; use MALI for ALF")
    if n_steps == 0 and sol.b_err is None:
        raise ValueError(f"solver {solver!r} has no embedded error estimate")
    cfg = AcaConfig(f, sol, int(n_steps), float(rtol), float(atol),
                    int(max_steps))
    return _aca(cfg, params, z0, jnp.asarray(t0, jnp.float32),
                jnp.asarray(t1, jnp.float32))
