"""Legacy string-keyed odeint facade over the composable solve() API.

``odeint(method=..., solver=..., n_steps=...)`` predates the object API and
is kept behavior-preserving: it builds the corresponding
Solver / StepController / GradientMethod / SaveAt objects and returns
``Solution.ys`` (see :mod:`repro.core.solve` for the object API and
``Solution.stats``). New code should call :func:`repro.core.solve.solve` —
calling this facade emits a ``DeprecationWarning`` (silent by default
outside test runners; filter or migrate).

Unlike the historical facade, inapplicable kwargs are no longer silently
dropped: passing ``eta`` to a non-ALF configuration or ``fused_bwd`` to a
non-MALI method raises, and ``rtol``/``atol``/``max_steps`` alongside a
fixed ``n_steps > 0`` warns.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable

from .aca import ACA, odeint_aca
from .adjoint import Backsolve, odeint_adjoint
from .interface import SaveAt
from .mali import MALI, mali_forward_stats, odeint_mali
from .naive import Naive, odeint_naive
from .solve import solve
from .solvers import ALF, get_solver
from .stepsize import AdaptiveController, ConstantSteps

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, Any], Pytree]

_DEFAULT_SOLVER = {
    "mali": "alf",
    "naive": "alf",
    "aca": "heun_euler",
    "adjoint": "dopri5",
}

METHODS = tuple(_DEFAULT_SOLVER)


def _gradient_for(method: str, fused_bwd: bool):
    if method == "mali":
        return MALI(fused_bwd=fused_bwd)
    if method == "naive":
        return Naive()
    if method == "aca":
        return ACA()
    return Backsolve()


def odeint(f: Dynamics, params: Pytree, z0: Pytree, t0=0.0, t1=1.0, *,
           ts=None, method: str = "mali", solver: str | None = None,
           n_steps: int | None = None, eta: float | None = None,
           rtol: float | None = None, atol: float | None = None,
           max_steps: int | None = None,
           fused_bwd: bool | None = None) -> Pytree:
    """Integrate dz/dt = f(params, z, t).

    Two output shapes (torchdiffeq-compatible):

    * ``ts=None`` (default): integrate over [t0, t1] and return ``z(t1)``
      with the same pytree structure as ``z0``. Internally this is the
      length-1 observation grid ``[t0, t1]``.
    * ``ts`` an increasing-or-decreasing 1-D grid of T >= 2 timepoints
      (array or sequence): return the trajectory pytree whose leaves gain a
      leading axis T, with ``traj[k] = z(ts[k])`` and ``traj[0] == z0``.
      ``t0``/``t1`` are ignored. One compiled scan carries the state across
      segment boundaries — no Python-side interval chaining — and for MALI
      the backward-pass residual set is the per-observation ``(z_k, v_k)``
      pairs: O(T * N_z), constant in the number of solver steps.

    method: gradient-estimation strategy — 'mali' (paper), 'naive',
            'aca', 'adjoint' (baselines; Table 1).
    solver: 'alf' | 'euler' | 'heun_euler' | 'midpoint' | 'rk23' | 'rk4' |
            'dopri5'. MALI requires 'alf'.
    n_steps > 0 -> fixed uniform grid (per observation segment);
            n_steps == 0 (default) -> adaptive (rtol/atol, bounded by
            max_steps trials per segment); n_steps < 0 -> error.

    Kwargs that do not apply to the selected method/solver raise instead of
    being silently ignored: ``eta`` is the ALF damping coefficient (any
    method, ALF solver only) and ``fused_bwd`` is MALI's backward-sharing
    switch.

    Example::

        traj = odeint(f, params, z0, ts=jnp.linspace(0.0, 1.0, 8),
                      method="mali", n_steps=4)      # traj: (8, *z0.shape)
    """
    warnings.warn(
        "odeint() is a legacy string-keyed facade; use repro.core.solve() "
        "with Solver/StepController/GradientMethod/SaveAt objects (see the "
        "README migration table) — it additionally exposes Solution.stats, "
        "reverse-time spans, dense output and terminating events",
        DeprecationWarning, stacklevel=2)
    if method not in _DEFAULT_SOLVER:
        raise ValueError(f"unknown method {method!r}; choose from {METHODS}")
    solver_name = solver or _DEFAULT_SOLVER[method]

    # Reject silently-inapplicable kwargs (only defaults are filled in).
    if eta is not None and solver_name != "alf":
        raise ValueError(
            f"eta={eta} was passed, but method={method!r} with "
            f"solver={solver_name!r} ignores it — eta is the ALF damping "
            "coefficient. Drop it, or pick solver='alf'.")
    if fused_bwd is not None and method != "mali":
        raise ValueError(
            f"fused_bwd={fused_bwd} was passed, but it is MALI's "
            f"backward-sharing switch; method={method!r} ignores it.")
    if n_steps is not None and n_steps < 0:
        raise ValueError(f"n_steps must be >= 0 (0 selects adaptive "
                         f"control), got {n_steps}")
    fixed = n_steps is not None and n_steps > 0
    if fixed:
        dropped = [kw for kw, v in (("rtol", rtol), ("atol", atol),
                                    ("max_steps", max_steps))
                   if v is not None]
        if dropped:
            warnings.warn(
                f"{'/'.join(dropped)} ignored: n_steps={n_steps} selects "
                "the fixed-step controller", stacklevel=2)

    solver_obj = (ALF(eta=1.0 if eta is None else float(eta))
                  if solver_name == "alf" else get_solver(solver_name))
    # Only pass what the caller set — AdaptiveController's dataclass
    # defaults stay the single source of truth.
    adaptive_kw = {k: v for k, v in
                   (("rtol", rtol), ("atol", atol), ("max_steps", max_steps))
                   if v is not None}
    controller = (ConstantSteps(int(n_steps)) if fixed else
                  AdaptiveController(**adaptive_kw))
    gradient = _gradient_for(method, True if fused_bwd is None else
                             bool(fused_bwd))
    saveat = SaveAt() if ts is None else SaveAt(ts=ts)
    return solve(f, params, z0, t0, t1, solver=solver_obj,
                 controller=controller, gradient=gradient, saveat=saveat).ys


__all__ = ["odeint", "odeint_mali", "odeint_naive", "odeint_aca",
           "odeint_adjoint", "mali_forward_stats", "METHODS"]
