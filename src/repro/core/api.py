"""Unified odeint facade: method x solver dispatch (paper Table 1 columns)."""
from __future__ import annotations

from typing import Any, Callable

from .aca import odeint_aca
from .adjoint import odeint_adjoint
from .mali import mali_forward_stats, odeint_mali
from .naive import odeint_naive

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, Any], Pytree]

_DEFAULT_SOLVER = {
    "mali": "alf",
    "naive": "alf",
    "aca": "heun_euler",
    "adjoint": "dopri5",
}

METHODS = tuple(_DEFAULT_SOLVER)


def odeint(f: Dynamics, params: Pytree, z0: Pytree, t0=0.0, t1=1.0, *,
           ts=None, method: str = "mali", solver: str | None = None,
           n_steps: int = 0, eta: float = 1.0, rtol: float = 1e-2,
           atol: float = 1e-3, max_steps: int = 64,
           fused_bwd: bool = True) -> Pytree:
    """Integrate dz/dt = f(params, z, t).

    Two output shapes (torchdiffeq-compatible):

    * ``ts=None`` (default): integrate over [t0, t1] and return ``z(t1)``
      with the same pytree structure as ``z0``. Internally this is the
      length-1 observation grid ``[t0, t1]``.
    * ``ts`` an increasing-or-decreasing 1-D grid of T >= 2 timepoints
      (array or sequence): return the trajectory pytree whose leaves gain a
      leading axis T, with ``traj[k] = z(ts[k])`` and ``traj[0] == z0``.
      ``t0``/``t1`` are ignored. One compiled scan carries the state across
      segment boundaries — no Python-side interval chaining — and for MALI
      the backward-pass residual set is the per-observation ``(z_k, v_k)``
      pairs: O(T * N_z), constant in the number of solver steps.

    method: gradient-estimation strategy — 'mali' (paper), 'naive',
            'aca', 'adjoint' (baselines; Table 1).
    solver: 'alf' | 'euler' | 'heun_euler' | 'midpoint' | 'rk23' | 'rk4' |
            'dopri5'. MALI requires 'alf'.
    n_steps > 0 -> fixed uniform grid (per observation segment);
            n_steps == 0 -> adaptive (rtol/atol, bounded by max_steps trials
            per segment).

    Example::

        traj = odeint(f, params, z0, ts=jnp.linspace(0.0, 1.0, 8),
                      method="mali", n_steps=4)      # traj: (8, *z0.shape)
    """
    if method not in _DEFAULT_SOLVER:
        raise ValueError(f"unknown method {method!r}; choose from {METHODS}")
    solver = solver or _DEFAULT_SOLVER[method]

    if method == "mali":
        if solver != "alf":
            raise ValueError("MALI is defined for the ALF solver only")
        return odeint_mali(f, params, z0, t0, t1, ts=ts, n_steps=n_steps,
                           eta=eta, rtol=rtol, atol=atol, max_steps=max_steps,
                           fused_bwd=fused_bwd)
    if method == "naive":
        return odeint_naive(f, params, z0, t0, t1, ts=ts, solver=solver,
                            n_steps=n_steps, eta=eta, rtol=rtol, atol=atol,
                            max_steps=max_steps)
    if method == "aca":
        return odeint_aca(f, params, z0, t0, t1, ts=ts, solver=solver,
                          n_steps=n_steps, rtol=rtol, atol=atol,
                          max_steps=max_steps)
    return odeint_adjoint(f, params, z0, t0, t1, ts=ts, solver=solver,
                          n_steps=n_steps, eta=eta, rtol=rtol, atol=atol,
                          max_steps=max_steps)


__all__ = ["odeint", "odeint_mali", "odeint_naive", "odeint_aca",
           "odeint_adjoint", "mali_forward_stats", "METHODS"]
