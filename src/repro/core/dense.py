"""Dense output: per-step cubic-Hermite interpolation + event location.

``solve(..., saveat=SaveAt(dense=True))`` records, for every accepted
solver step, enough endpoint data to build a cubic Hermite interpolant over
that step; :class:`DenseInterpolation` packages the fitted polynomial
coefficients as a pytree (jit/vmap/grad-safe) and evaluates them at
arbitrary query times — ``Solution.evaluate(t)`` delegates here. The same
machinery backs terminating events: :func:`locate_event` scans the recorded
step sequence for a sign change of the event function at the step nodes and
refines the crossing time by bisection *on the interpolant* (no extra
``f`` evaluations per bisection iteration).

Direction-awareness: all searches are done in ``sign(t1 - t0)``-reflected
coordinates, so a reverse-time solve (``t1 < t0``, negative step sizes)
interpolates and locates events exactly like a forward one.

Where the endpoint data comes from is the solver's business
(:meth:`repro.core.solvers.Solver.interpolant`): Runge-Kutta solvers
re-evaluate ``f`` at the recorded step endpoints (numerically identical to
the FSAL stage pair, one batched ``vmap`` per buffer rather than per step),
while ALF reads the slope off the tracked velocity ``v`` of its augmented
state — zero extra evaluations.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

_tm = jax.tree_util.tree_map

Pytree = Any


def hermite_coefficients(y0: Pytree, d0: Pytree, y1: Pytree, d1: Pytree,
                         hs: jax.Array) -> Tuple[Pytree, ...]:
    """Fit the cubic Hermite polynomial per recorded step.

    Inputs carry a leading step axis (``bound``). On the normalized step
    coordinate ``s = (t - t_i) / h_i`` in [0, 1] the cubic through
    ``(y0, d0)`` and ``(y1, d1)`` is ``c0 + s*(c1 + s*(c2 + s*c3))`` with::

        c0 = y0
        c1 = h * d0
        c2 = 3*(y1 - y0) - h*(2*d0 + d1)
        c3 = -2*(y1 - y0) + h*(d0 + d1)

    Returns the ``(c0, c1, c2, c3)`` pytrees. ``h`` is the *signed* step
    size — the signs cancel between ``h*d`` and the normalization, so the
    identical formula serves both integration directions.
    """
    def per_leaf(a0, b0, a1, b1):
        h = hs.reshape(hs.shape + (1,) * (a0.ndim - 1)).astype(a0.dtype)
        dy = a1 - a0
        return (a0,
                h * b0,
                3.0 * dy - h * (2.0 * b0 + b1),
                -2.0 * dy + h * (b0 + b1))

    fitted = _tm(lambda *xs: per_leaf(*xs), y0, d0, y1, d1)
    # transpose: pytree-of-4-tuples -> 4 pytrees
    outer = jax.tree_util.tree_structure(y0)
    inner = jax.tree_util.tree_structure((0, 0, 0, 0))
    return jax.tree_util.tree_transpose(outer, inner, fitted)


class DenseInterpolation(NamedTuple):
    """Piecewise-cubic dense output over one integration span (a pytree).

    ``t0s``/``hs`` are the recorded accepted-step start times and *signed*
    step sizes (rows ``>= num_steps`` are dead padding); ``c0..c3`` hold the
    per-step Hermite coefficients with the same leading ``bound`` axis.
    Evaluation clamps queries into ``[t_start, t_end]`` (sign-aware), so
    the interpolant never extrapolates.
    """
    t0s: jax.Array          # (bound,) accepted step start times
    hs: jax.Array           # (bound,) signed accepted step sizes
    c0: Pytree              # (bound, ...) Hermite coefficients
    c1: Pytree
    c2: Pytree
    c3: Pytree
    num_steps: jax.Array    # int32: live rows
    t_start: jax.Array      # span start (== solve's t0)
    t_end: jax.Array        # span end   (== solve's t1)

    @property
    def direction(self) -> jax.Array:
        """+1 for a forward-time span, -1 for reverse-time."""
        return jnp.where(self.t_end >= self.t_start, 1.0, -1.0).astype(
            self.t0s.dtype)

    def evaluate(self, t) -> Pytree:
        """Interpolate the state at query time(s) ``t``.

        Vectorized over ``t``: a scalar query returns one state pytree, a
        (Q,)-shaped query returns states with a leading Q axis. Queries are
        clamped into the integration span.
        """
        t = jnp.asarray(t, self.t0s.dtype)
        scalar = (t.ndim == 0)
        tq = jnp.atleast_1d(t)
        lo = jnp.minimum(self.t_start, self.t_end)
        hi = jnp.maximum(self.t_start, self.t_end)
        tq = jnp.clip(tq, lo, hi)

        # Locate the covering step in direction-reflected (ascending)
        # coordinates; dead padding rows sort to +inf so they are never hit.
        sgn = self.direction
        bound = self.t0s.shape[0]
        live = jnp.arange(bound) < self.num_steps
        keys = jnp.where(live, self.t0s * sgn, jnp.inf)
        j = jnp.searchsorted(keys, tq * sgn, side="right") - 1
        j = jnp.clip(j, 0, jnp.maximum(self.num_steps - 1, 0))

        h = self.hs[j]
        s = (tq - self.t0s[j]) / jnp.where(h == 0, 1.0, h)

        def horner(a0, a1, a2, a3):
            sb = s.reshape(s.shape + (1,) * (a0.ndim - 1)).astype(a0.dtype)
            return a0[j] + sb * (a1[j] + sb * (a2[j] + sb * a3[j]))

        out = _tm(horner, self.c0, self.c1, self.c2, self.c3)
        return _tm(lambda b: b[0], out) if scalar else out

    def __call__(self, t) -> Pytree:
        return self.evaluate(t)


def build_interpolation(solver, f, params, states: Pytree, state_end: Pytree,
                        ts: jax.Array, hs: jax.Array, n_live: jax.Array,
                        t_start, t_end) -> DenseInterpolation:
    """Fit the per-step Hermite record from one ``record_states=True`` run.

    ``states`` is the (bound, ...) buffer of accepted-step start *solver*
    states, ``state_end`` the final solver state; the solver supplies the
    endpoint values/slopes (:meth:`Solver.interpolant`).
    """
    y0, d0, y1, d1 = solver.interpolant(f, params, states, state_end,
                                        ts, hs, n_live)
    c0, c1, c2, c3 = hermite_coefficients(y0, d0, y1, d1, hs)
    dtype = ts.dtype
    return DenseInterpolation(
        t0s=ts, hs=hs, c0=c0, c1=c1, c2=c2, c3=c3,
        num_steps=jnp.asarray(n_live, jnp.int32),
        t_start=jnp.asarray(t_start, dtype), t_end=jnp.asarray(t_end, dtype))


def shift_to_step_ends(states: Pytree, state_end: Pytree,
                       n_live: jax.Array) -> Pytree:
    """Per-step *end* states from the start-state buffer: row i of the
    result is the start of step i+1, with the final state placed at the
    last live row (rows past ``n_live`` are dead padding)."""
    last = jnp.maximum(n_live - 1, 0)
    return _tm(
        lambda b, e: jnp.concatenate([b[1:], b[:1]], 0).at[last].set(e),
        states, state_end)


def pad_dead_rows(buf: Pytree, fill: Pytree, n_live: jax.Array) -> Pytree:
    """Replace dead padding rows (index >= n_live) with ``fill`` so that
    downstream ``f`` evaluations and event functions never see the zero
    padding (which may be outside f's domain)."""
    def per_leaf(b, e):
        live = (jnp.arange(b.shape[0]) < n_live).reshape(
            (b.shape[0],) + (1,) * e.ndim)
        return jnp.where(live, b, e[None])

    return _tm(per_leaf, buf, fill)


# ---------------------------------------------------------------------------
# Event location
# ---------------------------------------------------------------------------

def locate_event(interp: DenseInterpolation, cond_fn: Callable,
                 direction: int, max_bisections: int,
                 t_fallback) -> Tuple[jax.Array, jax.Array]:
    """Find the first root of ``cond_fn(z(t), t)`` along the interpolant.

    Scans the recorded step nodes for a sign change (filtered by
    ``direction``: +1 rising only, -1 falling only, 0 either), then bisects
    on the dense interpolant inside the bracketing step — each iteration
    costs one polynomial evaluation, zero dynamics evaluations. Returns
    ``(t_event, fired)``; when no crossing exists ``t_event == t_fallback``
    (the span end) and ``fired`` is False. Everything here runs on
    non-differentiated values — the caller freezes ``t_event``.
    """
    bound = interp.t0s.shape[0]
    live = jnp.arange(bound) < interp.num_steps
    node_t0 = interp.t0s
    node_t1 = interp.t0s + interp.hs

    def cond_at(tq):
        return jnp.asarray(cond_fn(interp.evaluate(tq), tq))

    g0 = jax.vmap(cond_at)(node_t0)
    # Step i's end node IS step i+1's start node (the interpolant is C0
    # there by construction), so reuse g0 shifted by one instead of a
    # second full vmapped evaluation pass; only the last live step's end
    # (the span end) needs a fresh evaluation.
    g_end = cond_at(interp.t_end)
    last = jnp.maximum(interp.num_steps - 1, 0)
    g1 = jnp.concatenate([g0[1:], g0[:1]]).at[last].set(g_end)

    rising = (g0 < 0) & (g1 >= 0)
    falling = (g0 > 0) & (g1 <= 0)
    if direction > 0:
        crossed = rising
    elif direction < 0:
        crossed = falling
    else:
        crossed = rising | falling
    crossed = crossed & live

    fired = jnp.any(crossed)
    j = jnp.argmax(crossed)  # first live crossing (argmax of bool = first True)

    t_lo0, t_hi0 = node_t0[j], node_t1[j]
    g_lo0 = g0[j]

    def body(_, carry):
        t_lo, t_hi, g_lo = carry
        mid = 0.5 * (t_lo + t_hi)
        g_mid = cond_at(mid)
        same = jnp.sign(g_mid) == jnp.sign(g_lo)
        return (jnp.where(same, mid, t_lo),
                jnp.where(same, t_hi, mid),
                jnp.where(same, g_mid, g_lo))

    t_lo, t_hi, _ = lax.fori_loop(0, max_bisections, body,
                                  (t_lo0, t_hi0, g_lo0))
    t_event = 0.5 * (t_lo + t_hi)
    t_event = jnp.where(fired, t_event,
                        jnp.asarray(t_fallback, t_event.dtype))
    return t_event, fired
