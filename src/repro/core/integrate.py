"""Shared fixed-step and adaptive-step integration drivers (paper Algo 1).

Both drivers are pure jittable functions built on ``lax.scan`` so that they
are usable (a) inside ``jax.custom_vjp`` forwards (MALI/ACA/adjoint) and
(b) directly under reverse-mode AD (the naive method) — ``lax.while_loop``
is not reverse-differentiable, a bounded masked scan is.

The adaptive driver performs exactly one trial step per scan iteration
(accepted or rejected), mirroring the eval accounting of Algo 1: rejected
trials still cost f-evals, and the step size shrinks on reject / grows on
accept via the controller in core/stepsize.py.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .stepsize import (MAX_FACTOR, MIN_FACTOR, SAFETY, initial_step_size,
                       next_step_size)

_tm = jax.tree_util.tree_map

Pytree = Any
# trial(state, t, h) -> (state_next, err_ratio)    err_ratio <= 1 accepts
TrialFn = Callable[[Pytree, jax.Array, jax.Array], Tuple[Pytree, jax.Array]]
# step(state, t, h) -> state_next
StepFn = Callable[[Pytree, jax.Array, jax.Array], Pytree]


def tree_where(pred: jax.Array, a: Pytree, b: Pytree) -> Pytree:
    return _tm(lambda x, y: jnp.where(pred, x, y), a, b)


def fixed_grid_times(t0: jax.Array, t1: jax.Array, n_steps: int):
    """(t_i, h) for a uniform grid; forward and backward passes must use the
    *identical* arithmetic (t_i = t0 + i*h) for MALI's exact reconstruction."""
    h = (t1 - t0) / n_steps
    ts = t0 + h * jnp.arange(n_steps, dtype=jnp.result_type(t0, t1, float))
    return ts, h


def integrate_fixed(step: StepFn, state0: Pytree, t0: jax.Array,
                    t1: jax.Array, n_steps: int) -> Pytree:
    ts, h = fixed_grid_times(t0, t1, n_steps)

    def body(state, t):
        return step(state, t, h), None

    state, _ = lax.scan(body, state0, ts)
    return state


class AdaptiveResult(NamedTuple):
    state: Pytree            # final state at t1
    ts: jax.Array            # (max_steps,) accepted step *start* times
    hs: jax.Array            # (max_steps,) accepted step sizes
    n_accepted: jax.Array    # int32
    n_evals: jax.Array       # int32 trial count (= f-eval multiplier)
    state_traj: Optional[Pytree]  # per-accepted-step start states (if recorded)


def integrate_adaptive(
    trial: TrialFn,
    state0: Pytree,
    t0: jax.Array,
    t1: jax.Array,
    *,
    order: int,
    rtol: float,
    atol: float,
    max_steps: int,
    h0: Optional[jax.Array] = None,
    record_states: bool = False,
) -> AdaptiveResult:
    dtype = jnp.result_type(t0, t1, float)
    t0 = jnp.asarray(t0, dtype)
    t1 = jnp.asarray(t1, dtype)
    span = t1 - t0
    h_init = initial_step_size(rtol, atol, span) if h0 is None else jnp.asarray(h0, dtype)

    ts_buf = jnp.zeros((max_steps,), dtype)
    hs_buf = jnp.zeros((max_steps,), dtype)
    traj_buf = None
    if record_states:
        traj_buf = _tm(lambda x: jnp.zeros((max_steps,) + x.shape, x.dtype), state0)

    def body(carry, _):
        state, t, h, done, n_acc, n_ev, ts, hs, traj = carry
        remaining = t1 - t
        is_last = jnp.abs(h) >= jnp.abs(remaining)
        h_eff = jnp.where(is_last, remaining, h)

        state_next, ratio = trial(state, t, h_eff)
        accept = (ratio <= 1.0) & (~done)
        n_ev = n_ev + jnp.where(done, 0, 1).astype(jnp.int32)

        # Record the accepted step's (start-time, stepsize, start-state).
        ts = ts.at[n_acc].set(jnp.where(accept, t, ts[n_acc]))
        hs = hs.at[n_acc].set(jnp.where(accept, h_eff, hs[n_acc]))
        if traj is not None:
            traj = _tm(
                lambda buf, s: buf.at[n_acc].set(jnp.where(accept, s, buf[n_acc])),
                traj, state)

        new_t = jnp.where(accept, jnp.where(is_last, t1, t + h_eff), t)
        new_state = tree_where(accept, state_next, state)
        new_done = done | (accept & is_last)
        h_next = next_step_size(h_eff, ratio, order)
        # Keep the controller's proposal frozen once done (cosmetic).
        h_next = jnp.where(done, h, h_next)
        n_acc = n_acc + accept.astype(jnp.int32)
        return (new_state, new_t, h_next, new_done, n_acc, n_ev, ts, hs, traj), None

    init = (state0, t0, h_init, jnp.asarray(False), jnp.asarray(0, jnp.int32),
            jnp.asarray(0, jnp.int32), ts_buf, hs_buf, traj_buf)
    (state, t, h, done, n_acc, n_ev, ts, hs, traj), _ = lax.scan(
        body, init, None, length=max_steps)
    return AdaptiveResult(state, ts, hs, n_acc, n_ev, traj)


def reverse_masked_scan(body: Callable, carry0: Pytree, ts: jax.Array,
                        hs: jax.Array, n_accepted: jax.Array,
                        max_steps: int, extras: Optional[Pytree] = None):
    """Scan i = n_accepted-1 .. 0 over recorded (t_i, h_i[, extras_i]) with
    identity pass-through for the padding slots i >= n_accepted.

    ``body(carry, t, h, extra) -> carry`` is only applied to live slots.
    """
    idxs = jnp.arange(max_steps - 1, -1, -1)

    def wrapped(carry, i):
        live = i < n_accepted
        extra_i = None if extras is None else _tm(lambda b: b[i], extras)
        new_carry = body(carry, ts[i], hs[i], extra_i)
        return tree_where(live, new_carry, carry), None

    carry, _ = lax.scan(wrapped, carry0, idxs)
    return carry
