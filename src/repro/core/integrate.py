"""One controller-parameterized integration driver (paper Algo 1).

The driver is a pure jittable function built on ``lax.scan`` so that it is
usable (a) inside ``jax.custom_vjp`` forwards (MALI/ACA/Backsolve) and
(b) directly under reverse-mode AD (the naive method) — ``lax.while_loop``
is not reverse-differentiable, a bounded masked scan is.

Entry points:

* :func:`integrate_grid` — integrate across an observation grid ``ts`` of
  T timepoints with ONE ``lax.scan`` over the T-1 segments whose carry
  crosses segment boundaries (state + the adaptive controller's warm-started
  step proposal). The :class:`~repro.core.stepsize.StepController` object
  decides everything fixed-vs-adaptive: :class:`ConstantSteps` replays the
  uniform per-segment sub-grid, :class:`AdaptiveController` runs exactly one
  trial step per scan iteration (accepted or rejected), mirroring the eval
  accounting of Algo 1 — rejected trials still cost f-evals.
* :func:`integrate_span` — single-interval ``t0 -> t1`` variant (used by
  the Backsolve method's reverse-time re-integration).

Both return uniform bookkeeping (:class:`GridResult` / :class:`SpanResult`):
the recorded per-segment ``(t_i, h_i)`` of every accepted step — the replay
script the MALI/ACA backward sweeps mask over — plus accepted/trial counters
that surface as ``Solution.stats``.

The trial signature is uniform across solvers and controllers::

    trial(state, t, h) -> (state_next, err_ratio)   # err_ratio <= 1 accepts

(solvers close their embedded error estimate over the controller's norm via
``Solver.trial_fn``; for ``ConstantSteps`` the ratio is constant 0 and the
estimate is dead code).

Batching: the adaptive loop is written as a *masked* bounded scan — the
carry holds ``(state, t, h, done)`` and a finished trajectory rides along
as a no-op (``done`` freezes state/time and stops the eval counter) — so
it IS the per-sample batching driver: under ``jax.vmap`` every carry slot
gains a batch row, the accept/reject predicate and the recorded
``(t_i, h_i)`` replay buffers become per-row, and each sample converges on
its own schedule inside one compiled scan. ``solve(batching=PerSample())``
enters here through :meth:`GradientMethod.integrate_batched`; an unbatched
call over a batch-shaped state instead reduces the controller norm across
the whole batch — lockstep (``Batching=Lockstep``).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .stepsize import (AdaptiveController, ConstantSteps, StepController,
                       initial_step_size, next_step_size)

_tm = jax.tree_util.tree_map

Pytree = Any
# trial(state, t, h) -> (state_next, err_ratio)    err_ratio <= 1 accepts
TrialFn = Callable[[Pytree, jax.Array, jax.Array], Tuple[Pytree, jax.Array]]
# step(state, t, h) -> state_next
StepFn = Callable[[Pytree, jax.Array, jax.Array], Pytree]


def tree_where(pred: jax.Array, a: Pytree, b: Pytree) -> Pytree:
    return _tm(lambda x, y: jnp.where(pred, x, y), a, b)


def as_time_grid(ts) -> jax.Array:
    """Validate/convert an observation grid: 1-D, at least two timepoints,
    strictly monotonic — *either direction*: an increasing grid is a
    forward-time solve, a decreasing one a reverse-time solve (checked when
    the values are concrete — inside a trace the structural checks still
    apply)."""
    grid = jnp.asarray(ts, jnp.float32)
    if grid.ndim != 1 or grid.shape[0] < 2:
        raise ValueError("ts must be a 1-D grid of at least 2 timepoints "
                         f"(got shape {grid.shape})")
    if not isinstance(grid, jax.core.Tracer):
        diffs = np.diff(np.asarray(grid))
        if not (np.all(diffs > 0) or np.all(diffs < 0)):
            raise ValueError(
                "ts must be strictly monotonic (all increasing or all "
                f"decreasing); got ts={np.asarray(grid).tolist()}")
    return grid


def validate_span(t0, t1) -> None:
    """Reject an empty integration span when both endpoints are concrete
    (``t1 < t0`` is legal — it selects reverse-time integration; only
    ``t0 == t1`` is degenerate). Traced endpoints pass through — the
    drivers themselves are span-sign-agnostic."""
    if isinstance(t0, jax.core.Tracer) or isinstance(t1, jax.core.Tracer):
        return
    if float(t0) == float(t1):
        raise ValueError(
            f"empty integration span: t0 == t1 == {float(t0)}; pass t1 > t0 "
            "for a forward solve or t1 < t0 for a reverse-time solve")


def scalar_time_grid(t0, t1) -> jax.Array:
    """The length-1 observation grid [t0, t1] backing the scalar odeint
    path (either direction: t1 < t0 integrates in reverse time)."""
    return jnp.stack([jnp.asarray(t0, jnp.float32),
                      jnp.asarray(t1, jnp.float32)])


def fixed_grid_times(t0: jax.Array, t1: jax.Array, n_steps: int):
    """(t_i, h) for a uniform grid; forward and backward passes must use the
    *identical* arithmetic (t_i = t0 + i*h) for MALI's exact reconstruction.
    ``h`` is signed — ``t1 < t0`` yields negative steps and the same
    formula drives reverse-time integration."""
    h = (t1 - t0) / n_steps
    ts = t0 + h * jnp.arange(n_steps, dtype=jnp.result_type(t0, t1, float))
    return ts, h


def integrate_fixed(step: StepFn, state0: Pytree, t0: jax.Array,
                    t1: jax.Array, n_steps: int) -> Pytree:
    ts, h = fixed_grid_times(t0, t1, n_steps)

    def body(state, t):
        return step(state, t, h), None

    state, _ = lax.scan(body, state0, ts)
    return state


def segment_pairs(ts: jax.Array) -> jax.Array:
    """(T-1, 2) array of consecutive (ts[k], ts[k+1]) segment bounds."""
    return jnp.stack([ts[:-1], ts[1:]], -1)


def prepend_row(state0: Pytree, tail: Pytree) -> Pytree:
    """Stack ``state0`` in front of a scanned segment-end trajectory, giving
    the (T, ...) observation trajectory with ``traj[0] == state0``."""
    return _tm(lambda s0, tl: jnp.concatenate([s0[None], tl], 0), state0, tail)


def reverse_segment_sweep(seg_fn: Callable, carry0: Pytree, g: Pytree,
                          extras: Tuple = ()) -> Tuple:
    """Shared backward scaffold for the observation-grid custom_vjps.

    Scans ``seg_fn(carry, g_k1, extras_k) -> carry`` over segments
    k = T-2 .. 0 in reverse, feeding each segment its end-observation
    cotangent ``g_k1 = g[k+1]`` and the k-th slice of every ``extras`` entry,
    then adds the ``traj[0] = z0`` identity-row cotangent ``g[0]`` into
    ``carry[0]`` (by convention the state adjoint a_z). Returns the final
    carry tuple.
    """
    xs = (_tm(lambda b: b[1:], g),) + tuple(extras)

    def wrapped(carry, x):
        return seg_fn(carry, x[0], x[1:]), None

    carry, _ = lax.scan(wrapped, carry0, xs, reverse=True)
    a_z = _tm(jnp.add, carry[0], _tm(lambda b: b[0], g))
    return (a_z,) + tuple(carry[1:])


class GridResult(NamedTuple):
    """Uniform bookkeeping of one observation-grid integration."""
    state: Pytree            # final state at ts[-1]
    traj: Pytree             # (T, ...) state at each ts[k]; traj[0] == state0
    ts: jax.Array            # (T-1, bound) accepted step start times
    hs: jax.Array            # (T-1, bound) accepted step sizes
    n_accepted: jax.Array    # (T-1,) int32 accepted steps per segment
    n_trials: jax.Array      # int32 total trial count (= accepted + rejected)
    state_traj: Optional[Pytree]  # (T-1, bound, ...) per-step start states
    # bool: every segment reached its end time within the controller's
    # trial budget (an exhausted AdaptiveController max_steps budget
    # truncates the integration silently — this flag is how callers tell).
    completed: jax.Array = jnp.asarray(True)


class SpanResult(NamedTuple):
    """Uniform bookkeeping of one t0 -> t1 integration."""
    state: Pytree
    n_accepted: jax.Array    # int32
    n_trials: jax.Array      # int32
    h_final: jax.Array       # controller's step proposal at exit (warm start)


class AdaptiveResult(NamedTuple):
    state: Pytree            # final state at t1
    ts: jax.Array            # (max_steps,) accepted step *start* times
    hs: jax.Array            # (max_steps,) accepted step sizes
    n_accepted: jax.Array    # int32
    n_evals: jax.Array       # int32 trial count (= f-eval multiplier)
    state_traj: Optional[Pytree]  # per-accepted-step start states (if recorded)
    h_final: jax.Array       # controller's step proposal at exit (warm start)
    done: jax.Array = jnp.asarray(True)  # bool: reached t1 within budget


def integrate_adaptive(
    trial: TrialFn,
    state0: Pytree,
    t0: jax.Array,
    t1: jax.Array,
    *,
    order: int,
    rtol: float,
    atol: float,
    max_steps: int,
    h0: Optional[jax.Array] = None,
    record_states: bool = False,
) -> AdaptiveResult:
    dtype = jnp.result_type(t0, t1, float)
    t0 = jnp.asarray(t0, dtype)
    t1 = jnp.asarray(t1, dtype)
    span = t1 - t0
    h_init = initial_step_size(rtol, atol, span) if h0 is None else jnp.asarray(h0, dtype)

    ts_buf = jnp.zeros((max_steps,), dtype)
    hs_buf = jnp.zeros((max_steps,), dtype)
    traj_buf = None
    if record_states:
        traj_buf = _tm(lambda x: jnp.zeros((max_steps,) + x.shape, x.dtype), state0)

    def body(carry, _):
        state, t, h, done, n_acc, n_ev, ts, hs, traj = carry
        # Direction-sign-agnostic throughout: h and remaining carry the
        # span's sign (negative for reverse time), every magnitude
        # comparison goes through abs, and end-clipping assigns the signed
        # remainder — so one loop serves both integration directions.
        remaining = t1 - t
        is_last = jnp.abs(h) >= jnp.abs(remaining)
        h_eff = jnp.where(is_last, remaining, h)

        state_next, ratio = trial(state, t, h_eff)
        accept = (ratio <= 1.0) & (~done)
        n_ev = n_ev + jnp.where(done, 0, 1).astype(jnp.int32)

        # Record the accepted step's (start-time, stepsize, start-state).
        ts = ts.at[n_acc].set(jnp.where(accept, t, ts[n_acc]))
        hs = hs.at[n_acc].set(jnp.where(accept, h_eff, hs[n_acc]))
        if traj is not None:
            traj = _tm(
                lambda buf, s: buf.at[n_acc].set(jnp.where(accept, s, buf[n_acc])),
                traj, state)

        new_t = jnp.where(accept, jnp.where(is_last, t1, t + h_eff), t)
        new_state = tree_where(accept, state_next, state)
        new_done = done | (accept & is_last)
        h_next = next_step_size(h_eff, ratio, order)
        # Keep the controller's proposal frozen once done (cosmetic).
        h_next = jnp.where(done, h, h_next)
        n_acc = n_acc + accept.astype(jnp.int32)
        return (new_state, new_t, h_next, new_done, n_acc, n_ev, ts, hs, traj), None

    init = (state0, t0, h_init, jnp.asarray(False), jnp.asarray(0, jnp.int32),
            jnp.asarray(0, jnp.int32), ts_buf, hs_buf, traj_buf)
    (state, t, h, done, n_acc, n_ev, ts, hs, traj), _ = lax.scan(
        body, init, None, length=max_steps)
    # A zero-length span is complete by construction (the first trial's
    # h_eff == 0 step accepts and sets done).
    return AdaptiveResult(state, ts, hs, n_acc, n_ev, traj, h,
                          done | (t0 == t1))


def _constant_grid(trial: TrialFn, state0: Pytree, ts: jax.Array, n: int,
                   record_states: bool) -> GridResult:
    """ConstantSteps path of :func:`integrate_grid`: a plain per-segment
    sub-grid scan (every trial accepted), emitting the same bookkeeping as
    the adaptive path so backward sweeps are controller-agnostic."""

    def seg(state, pair):
        step_ts, h = fixed_grid_times(pair[0], pair[1], n)

        def body(s, t):
            s1, _ = trial(s, t, h)
            return s1, (s if record_states else None)

        state1, ckpts = lax.scan(body, state, step_ts)
        hs = jnp.broadcast_to(h, (n,))
        return state1, (state1, step_ts, hs, ckpts)

    stateT, (tail, seg_ts, seg_hs, seg_ck) = lax.scan(
        seg, state0, segment_pairs(ts))
    n_seg = seg_ts.shape[0]
    n_acc = jnp.full((n_seg,), n, jnp.int32)
    n_trials = jnp.asarray(n_seg * n, jnp.int32)
    return GridResult(stateT, prepend_row(state0, tail), seg_ts, seg_hs,
                      n_acc, n_trials, seg_ck if record_states else None)


def _adaptive_grid(trial: TrialFn, state0: Pytree, ts: jax.Array,
                   controller: AdaptiveController, order: int,
                   record_states: bool) -> GridResult:
    """AdaptiveController path of :func:`integrate_grid`: per-segment bounded
    accept/reject loops, with the step proposal warm-started across segment
    boundaries through the scan carry."""
    h_start = controller.initial_step(ts[1] - ts[0])

    def seg(carry, pair):
        state, n_ev, h_prev = carry
        span = pair[1] - pair[0]
        h0 = jnp.sign(span) * jnp.minimum(jnp.abs(h_prev), jnp.abs(span))
        out = integrate_adaptive(trial, state, pair[0], pair[1], order=order,
                                 rtol=controller.rtol, atol=controller.atol,
                                 max_steps=controller.max_steps, h0=h0,
                                 record_states=record_states)
        ys = (out.state, out.ts, out.hs, out.n_accepted, out.state_traj,
              out.done)
        return (out.state, n_ev + out.n_evals, out.h_final), ys

    carry0 = (state0, jnp.asarray(0, jnp.int32), h_start)
    (stateT, n_ev, _), (tail, seg_ts, seg_hs, seg_acc, seg_traj,
                        seg_done) = lax.scan(seg, carry0, segment_pairs(ts))
    return GridResult(stateT, prepend_row(state0, tail), seg_ts, seg_hs,
                      seg_acc, n_ev, seg_traj, jnp.all(seg_done))


def integrate_grid(
    trial: TrialFn,
    state0: Pytree,
    ts: jax.Array,
    *,
    controller: StepController,
    order: int,
    record_states: bool = False,
) -> GridResult:
    """THE grid driver: integrate across an observation grid ``ts`` (shape
    (T,)) under the given :class:`StepController`.

    One compiled ``lax.scan`` over the T-1 segments whose carry (integrator
    state, and for adaptive control the warm-started step proposal) crosses
    segment boundaries. The recorded per-segment (t_i, h_i[, state_i])
    bookkeeping keeps the backward-pass residual set at O(T * step_bound)
    scalars + O(T * N_z) states, constant in the solver-step count.
    """
    if isinstance(controller, ConstantSteps):
        return _constant_grid(trial, state0, ts, controller.n, record_states)
    if isinstance(controller, AdaptiveController):
        return _adaptive_grid(trial, state0, ts, controller, order,
                              record_states)
    raise TypeError(f"unknown step controller {controller!r}")


def integrate_span(
    trial: TrialFn,
    state0: Pytree,
    t0: jax.Array,
    t1: jax.Array,
    *,
    controller: StepController,
    order: int,
    h0: Optional[jax.Array] = None,
) -> SpanResult:
    """Single-interval ``t0 -> t1`` driver (Backsolve's forward segments and
    reverse-time augmented re-integration)."""
    if isinstance(controller, ConstantSteps):
        def step(s, t, h):
            return trial(s, t, h)[0]

        state = integrate_fixed(step, state0, t0, t1, controller.n)
        n = jnp.asarray(controller.n, jnp.int32)
        _, h = fixed_grid_times(jnp.asarray(t0, jnp.float32),
                                jnp.asarray(t1, jnp.float32), controller.n)
        return SpanResult(state, n, n, h)
    if isinstance(controller, AdaptiveController):
        out = integrate_adaptive(trial, state0, t0, t1, order=order,
                                 rtol=controller.rtol, atol=controller.atol,
                                 max_steps=controller.max_steps, h0=h0)
        return SpanResult(out.state, out.n_accepted, out.n_evals, out.h_final)
    raise TypeError(f"unknown step controller {controller!r}")


def reverse_masked_scan(body: Callable, carry0: Pytree, ts: jax.Array,
                        hs: jax.Array, n_accepted: jax.Array,
                        max_steps: int, extras: Optional[Pytree] = None):
    """Scan i = n_accepted-1 .. 0 over recorded (t_i, h_i[, extras_i]) with
    identity pass-through for the padding slots i >= n_accepted.

    ``body(carry, t, h) -> carry`` is only applied to live slots; when
    ``extras`` is given the body is called as ``body(carry, t, h, extra)``
    with the i-th slice of every extras leaf (ACA's checkpointed states,
    per-segment metadata on the observation-grid path, ...).
    """
    idxs = jnp.arange(max_steps - 1, -1, -1)

    def wrapped(carry, i):
        live = i < n_accepted
        if extras is None:
            new_carry = body(carry, ts[i], hs[i])
        else:
            new_carry = body(carry, ts[i], hs[i], _tm(lambda b: b[i], extras))
        return tree_where(live, new_carry, carry), None

    carry, _ = lax.scan(wrapped, carry0, idxs)
    return carry


# --- legacy driver names (pre-object API), kept as thin wrappers -----------

def integrate_fixed_grid(step: StepFn, state0: Pytree, ts: jax.Array,
                         n_steps: int) -> Tuple[Pytree, Pytree]:
    """Deprecated: use :func:`integrate_grid` with ``ConstantSteps``."""
    res = _constant_grid(lambda s, t, h: (step(s, t, h), jnp.zeros(())),
                         state0, ts, n_steps, record_states=False)
    return res.state, res.traj


def integrate_adaptive_grid(trial: TrialFn, state0: Pytree, ts: jax.Array, *,
                            order: int, rtol: float, atol: float,
                            max_steps: int,
                            record_states: bool = False) -> GridResult:
    """Deprecated: use :func:`integrate_grid` with ``AdaptiveController``."""
    ctrl = AdaptiveController(rtol=rtol, atol=atol, max_steps=max_steps)
    return _adaptive_grid(trial, state0, ts, ctrl, order, record_states)
