"""Asynchronous Leapfrog (ALF) integrator primitives (Mutze 2013; MALI paper Algo 2/3).

The ALF step psi_h maps the augmented state ``(z, v)`` — ``v`` is the tracked
approximation of ``dz/dt`` — forward by ``h`` and is *explicitly invertible*,
which is the property MALI exploits to reconstruct the forward trajectory in
the backward pass at O(1) memory.

All functions are pytree-generic in ``z``/``v`` and jit/vmap/pjit-safe.
``eta`` is the damping coefficient of Appendix A.5 (``eta=1`` = plain ALF).
``eta == 0.5`` makes the damped update non-invertible (division by ``1-2*eta``)
and is rejected.

Dynamics signature used across the package::

    f(params, z, t) -> dz/dt        # same pytree structure as z
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]

_tm = jax.tree_util.tree_map


def tree_add(x, y):
    return _tm(jnp.add, x, y)


def tree_sub(x, y):
    return _tm(jnp.subtract, x, y)


def tree_scale(a, x):
    return _tm(lambda xi: a * xi, x)


def tree_zeros_like(x):
    return _tm(jnp.zeros_like, x)


def check_eta(eta: float) -> None:
    if not (0.0 < eta <= 1.0):
        raise ValueError(f"damping eta must be in (0, 1], got {eta}")
    if abs(eta - 0.5) < 1e-9:
        raise ValueError("eta == 0.5 makes the damped ALF step non-invertible")


BACKENDS = ("reference", "pallas")


def check_backend(backend: str) -> None:
    if backend not in BACKENDS:
        raise ValueError(f"unknown ALF backend {backend!r}; "
                         f"available: {BACKENDS}")


def alf_step(
    f: Dynamics,
    params: Pytree,
    z: Pytree,
    v: Pytree,
    t: jax.Array,
    h: jax.Array,
    eta: float = 1.0,
    backend: str = "reference",
) -> Tuple[Pytree, Pytree]:
    """One (damped) ALF step: (z, v) at time t -> (z', v') at time t + h.

    Paper Algo 2 / Appendix Algo 2:
        s1    = t + h/2
        k1    = z + v * h/2
        u1    = f(k1, s1)
        v_out = v + 2*eta*(u1 - v)
        z_out = k1 + v_out * h/2

    ``backend='pallas'`` fuses the elementwise algebra around the ``f``
    evaluation into two kernel launches; the ops carry closed-form
    custom_vjp rules, so this path is reverse-differentiable too.
    """
    s1 = t + h / 2
    if backend == "pallas":
        from repro.kernels.alf_step.ops import alf_midpoint, alf_update
        k1 = alf_midpoint(z, v, h, use_pallas=True)
        u1 = f(params, k1, s1)
        return alf_update(k1, v, u1, h, eta=eta, use_pallas=True)
    k1 = _tm(lambda zi, vi: zi + vi * (h / 2), z, v)
    u1 = f(params, k1, s1)
    v_out = _tm(lambda vi, ui: vi + 2.0 * eta * (ui - vi), v, u1)
    z_out = _tm(lambda ki, vo: ki + vo * (h / 2), k1, v_out)
    return z_out, v_out


def alf_inverse(
    f: Dynamics,
    params: Pytree,
    z_out: Pytree,
    v_out: Pytree,
    t_out: jax.Array,
    h: jax.Array,
    eta: float = 1.0,
    backend: str = "reference",
) -> Tuple[Pytree, Pytree]:
    """Exact inverse of :func:`alf_step` (paper Algo 3 / Appendix Algo 3).

    Reconstructs the step *input* (z, v) at time ``t_out - h`` from the step
    output. Exact up to float rounding: the midpoint ``k1`` is recovered
    algebraically, so ``f`` is re-evaluated at (numerically) the same point
    as in the forward step.

    ``backend='pallas'`` fuses the reconstruction into two launches: the
    midpoint kernel (to evaluate ``f``) and the one-pass ``alf_inverse``
    kernel for the whole (z_in, v_in) recovery. Forward-only by design —
    it runs inside MALI's backward, which is never differentiated.
    """
    s1 = t_out - h / 2
    if backend == "pallas":
        from repro.kernels.alf_step.ops import alf_inverse as alf_inverse_op
        from repro.kernels.alf_step.ops import alf_midpoint
        k1 = alf_midpoint(z_out, v_out, h, sign=-1.0, use_pallas=True)
        u1 = f(params, k1, s1)
        return alf_inverse_op(z_out, v_out, u1, h, eta=eta, use_pallas=True)
    k1 = _tm(lambda zi, vi: zi - vi * (h / 2), z_out, v_out)
    u1 = f(params, k1, s1)
    if eta == 1.0:
        v_in = _tm(lambda ui, vo: 2.0 * ui - vo, u1, v_out)
    else:
        inv = 1.0 / (1.0 - 2.0 * eta)
        v_in = _tm(lambda vo, ui: (vo - 2.0 * eta * ui) * inv, v_out, u1)
    z_in = _tm(lambda ki, vi: ki - vi * (h / 2), k1, v_in)
    return z_in, v_in


def alf_step_with_error(
    f: Dynamics,
    params: Pytree,
    z: Pytree,
    v: Pytree,
    t: jax.Array,
    h: jax.Array,
    eta: float = 1.0,
    backend: str = "reference",
) -> Tuple[Pytree, Pytree, Pytree]:
    """ALF step + embedded local-error estimate.

    The z-update of ALF equals the explicit-midpoint update with ``v`` in
    place of ``f(z, t)``: ``z_out = z + h * u1`` (for eta=1). The first-order
    (Euler-with-v) prediction is ``z + h * v``; their difference
    ``h * (u1 - v)`` is the standard embedded 1st-vs-2nd-order error
    estimate, and matches the leading local-truncation term of Thm 3.1
    (Eq. 19: L_z ~ (h^2/2) f_z (f - v)) up to the bounded factor f_z.

    ``backend='pallas'`` routes the elementwise algebra around the ``f``
    evaluation through the fused :mod:`repro.kernels.alf_step` kernels
    (one flattened [rows, 128] pass over the whole state pytree; interpret
    mode on CPU, compiled on TPU). The ops carry closed-form custom_vjp
    rules (themselves fused kernels), so every gradient consumer accepts
    this backend: MALI dispatches the fused inverse+VJP backward kernels,
    and direct backprop (Naive, ``SaveAt(steps=True)``, dense output)
    differentiates straight through the launches.
    """
    s1 = t + h / 2
    if backend == "pallas":
        from repro.kernels.alf_step.ops import alf_midpoint, alf_update
        k1 = alf_midpoint(z, v, h, use_pallas=True)
        u1 = f(params, k1, s1)
        z_out, v_out = alf_update(k1, v, u1, h, eta=eta, use_pallas=True)
    else:
        k1 = _tm(lambda zi, vi: zi + vi * (h / 2), z, v)
        u1 = f(params, k1, s1)
        v_out = _tm(lambda vi, ui: vi + 2.0 * eta * (ui - vi), v, u1)
        z_out = _tm(lambda ki, vo: ki + vo * (h / 2), k1, v_out)
    err = _tm(lambda ui, vi: h * (ui - vi), u1, v)
    return z_out, v_out, err


def init_velocity(f: Dynamics, params: Pytree, z0: Pytree, t0: jax.Array) -> Pytree:
    """Paper Sec 3.1: initialize the augmented state with v0 = f(z0, t0)."""
    return f(params, z0, t0)
