"""Runge-Kutta solver steps (pytree-generic) + the ALF solver adapter.

Each solver exposes::

    solver.step(f, params, z, t, h) -> (z_next, err)   # err=None if no pair
    solver.order                                        # classical order

These are the ``psi`` functions of paper Algo 1. ALF is special: it carries
the augmented state ``(z, v)`` and is handled by the integrators directly
(see core/mali.py); :data:`ALF` here only records metadata so the benchmark /
config layer can treat solver choice uniformly.

Tableaus: Euler, Heun2 (a.k.a. Heun-Euler when used with its embedded Euler
error — the solver ACA used in the paper), explicit midpoint, Bogacki-
Shampine 3(2) ("RK23"), classic RK4, and Dormand-Prince 5(4) ("Dopri5").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

_tm = jax.tree_util.tree_map

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]


def _weighted_sum(terms: Sequence[Tuple[float, Pytree]]) -> Optional[Pytree]:
    """sum(c_i * tree_i) skipping zero coefficients; None if all zero."""
    terms = [(c, k) for (c, k) in terms if c != 0.0]
    if not terms:
        return None
    acc = _tm(lambda x: terms[0][0] * x, terms[0][1])
    for c, k in terms[1:]:
        acc = _tm(lambda a, x: a + c * x, acc, k)
    return acc


@dataclasses.dataclass(frozen=True)
class ButcherTableau:
    name: str
    order: int
    c: Tuple[float, ...]
    a: Tuple[Tuple[float, ...], ...]
    b: Tuple[float, ...]
    b_err: Optional[Tuple[float, ...]] = None  # b - b_hat (error weights)
    fsal: bool = False

    def step(self, f: Dynamics, params: Pytree, z: Pytree, t: jax.Array,
             h: jax.Array) -> Tuple[Pytree, Optional[Pytree]]:
        ks = []
        for i, ci in enumerate(self.c):
            incr = _weighted_sum(list(zip(self.a[i], ks)))
            zi = z if incr is None else _tm(lambda zz, dd: zz + h * dd, z, incr)
            ks.append(f(params, zi, t + ci * h))
        upd = _weighted_sum(list(zip(self.b, ks)))
        z_next = _tm(lambda zz, dd: zz + h * dd, z, upd)
        err = None
        if self.b_err is not None:
            e = _weighted_sum(list(zip(self.b_err, ks)))
            err = _tm(lambda x: h * x, e)
        return z_next, err


EULER = ButcherTableau("euler", 1, c=(0.0,), a=((),), b=(1.0,))

# Heun's 2nd-order with embedded Euler -> the "Heun-Euler" adaptive pair.
HEUN2 = ButcherTableau(
    "heun2", 2,
    c=(0.0, 1.0), a=((), (1.0,)), b=(0.5, 0.5),
    b_err=(-0.5, 0.5),  # (heun - euler) weights
)

MIDPOINT = ButcherTableau(
    "midpoint", 2, c=(0.0, 0.5), a=((), (0.5,)), b=(0.0, 1.0),
)

# Bogacki-Shampine 3(2) — torchdiffeq's "bosh3" / scipy "RK23".
BOSH3 = ButcherTableau(
    "bosh3", 3,
    c=(0.0, 0.5, 0.75, 1.0),
    a=((), (0.5,), (0.0, 0.75), (2 / 9, 1 / 3, 4 / 9)),
    b=(2 / 9, 1 / 3, 4 / 9, 0.0),
    b_err=(2 / 9 - 7 / 24, 1 / 3 - 0.25, 4 / 9 - 1 / 3, -0.125),
    fsal=True,
)

RK4 = ButcherTableau(
    "rk4", 4,
    c=(0.0, 0.5, 0.5, 1.0),
    a=((), (0.5,), (0.0, 0.5), (0.0, 0.0, 1.0)),
    b=(1 / 6, 1 / 3, 1 / 3, 1 / 6),
)

# Dormand-Prince 5(4) — torchdiffeq default "dopri5".
_DP_B = (35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0)
_DP_BH = (5179 / 57600, 0.0, 7571 / 16695, 393 / 640, -92097 / 339200,
          187 / 2100, 1 / 40)
DOPRI5 = ButcherTableau(
    "dopri5", 5,
    c=(0.0, 0.2, 0.3, 0.8, 8 / 9, 1.0, 1.0),
    a=(
        (),
        (0.2,),
        (3 / 40, 9 / 40),
        (44 / 45, -56 / 15, 32 / 9),
        (19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729),
        (9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656),
        _DP_B[:-1] + (0.0,),
    ),
    b=_DP_B,
    b_err=tuple(b - bh for b, bh in zip(_DP_B, _DP_BH)),
    fsal=True,
)


@dataclasses.dataclass(frozen=True)
class AlfSolverMeta:
    """Marker for the ALF solver (augmented-state; handled by integrators)."""
    name: str = "alf"
    order: int = 2
    b_err: Optional[Tuple[float, ...]] = (1.0,)  # has an embedded estimate


ALF = AlfSolverMeta()

SOLVERS = {
    "euler": EULER,
    "heun2": HEUN2,
    "heun_euler": HEUN2,
    "midpoint": MIDPOINT,
    "bosh3": BOSH3,
    "rk23": BOSH3,
    "rk2": HEUN2,
    "rk4": RK4,
    "dopri5": DOPRI5,
    "alf": ALF,
}


def get_solver(name: str):
    try:
        return SOLVERS[name]
    except KeyError:
        raise ValueError(f"unknown solver {name!r}; available: {sorted(SOLVERS)}")
