"""Solver objects (the ``psi`` step functions of paper Algo 1) + registry.

The solver axis of the paper's Table 1 is a small object hierarchy:

* :class:`Solver` — the interface every solver implements: how to build the
  integrator state from ``z0`` (plain ``z`` for Runge-Kutta, the augmented
  ``(z, v)`` pair for ALF), how to advance it one (trial) step, and how to
  read ``z`` back out of it.
* :class:`RungeKutta` — a solver backed by a :class:`ButcherTableau`
  (order / FSAL / embedded-error metadata live on the tableau).
* :class:`ALF` — the Asynchronous Leapfrog solver of the paper (Algo 2/3),
  carrying its damping coefficient ``eta`` (Appendix A.5; ``eta=1`` is the
  plain invertible step MALI reconstructs in the backward pass).

Every solver is a frozen (hashable) dataclass so it can ride inside the
static configuration of a ``jax.custom_vjp``. ``get_solver`` resolves the
legacy string names ('alf' | 'euler' | 'heun_euler' | 'midpoint' | 'rk23' |
'rk4' | 'dopri5' ...) to registered instances.

Tableaus: Euler, Heun2 (a.k.a. Heun-Euler when used with its embedded Euler
error — the solver ACA used in the paper), explicit midpoint, Bogacki-
Shampine 3(2) ("RK23"), classic RK4, and Dormand-Prince 5(4) ("Dopri5").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax

from .alf import alf_step_with_error, check_backend, check_eta, init_velocity
from .dense import pad_dead_rows, shift_to_step_ends

_tm = jax.tree_util.tree_map

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]
# trial(state, t, h) -> (state_next, err_ratio); err_ratio <= 1 accepts.
TrialFn = Callable[[Pytree, jax.Array, jax.Array], Tuple[Pytree, jax.Array]]


def _weighted_sum(terms: Sequence[Tuple[float, Pytree]]) -> Optional[Pytree]:
    """sum(c_i * tree_i) skipping zero coefficients; None if all zero."""
    terms = [(c, k) for (c, k) in terms if c != 0.0]
    if not terms:
        return None
    acc = _tm(lambda x: terms[0][0] * x, terms[0][1])
    for c, k in terms[1:]:
        acc = _tm(lambda a, x: a + c * x, acc, k)
    return acc


@dataclasses.dataclass(frozen=True)
class ButcherTableau:
    name: str
    order: int
    c: Tuple[float, ...]
    a: Tuple[Tuple[float, ...], ...]
    b: Tuple[float, ...]
    b_err: Optional[Tuple[float, ...]] = None  # b - b_hat (error weights)
    fsal: bool = False

    def step(self, f: Dynamics, params: Pytree, z: Pytree, t: jax.Array,
             h: jax.Array) -> Tuple[Pytree, Optional[Pytree]]:
        ks = []
        for i, ci in enumerate(self.c):
            incr = _weighted_sum(list(zip(self.a[i], ks)))
            zi = z if incr is None else _tm(lambda zz, dd: zz + h * dd, z, incr)
            ks.append(f(params, zi, t + ci * h))
        upd = _weighted_sum(list(zip(self.b, ks)))
        z_next = _tm(lambda zz, dd: zz + h * dd, z, upd)
        err = None
        if self.b_err is not None:
            e = _weighted_sum(list(zip(self.b_err, ks)))
            err = _tm(lambda x: h * x, e)
        return z_next, err


EULER = ButcherTableau("euler", 1, c=(0.0,), a=((),), b=(1.0,))

# Heun's 2nd-order with embedded Euler -> the "Heun-Euler" adaptive pair.
HEUN2 = ButcherTableau(
    "heun2", 2,
    c=(0.0, 1.0), a=((), (1.0,)), b=(0.5, 0.5),
    b_err=(-0.5, 0.5),  # (heun - euler) weights
)

MIDPOINT = ButcherTableau(
    "midpoint", 2, c=(0.0, 0.5), a=((), (0.5,)), b=(0.0, 1.0),
)

# Bogacki-Shampine 3(2) — torchdiffeq's "bosh3" / scipy "RK23".
BOSH3 = ButcherTableau(
    "bosh3", 3,
    c=(0.0, 0.5, 0.75, 1.0),
    a=((), (0.5,), (0.0, 0.75), (2 / 9, 1 / 3, 4 / 9)),
    b=(2 / 9, 1 / 3, 4 / 9, 0.0),
    b_err=(2 / 9 - 7 / 24, 1 / 3 - 0.25, 4 / 9 - 1 / 3, -0.125),
    fsal=True,
)

RK4 = ButcherTableau(
    "rk4", 4,
    c=(0.0, 0.5, 0.5, 1.0),
    a=((), (0.5,), (0.0, 0.5), (0.0, 0.0, 1.0)),
    b=(1 / 6, 1 / 3, 1 / 3, 1 / 6),
)

# Dormand-Prince 5(4) — torchdiffeq default "dopri5".
_DP_B = (35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0)
_DP_BH = (5179 / 57600, 0.0, 7571 / 16695, 393 / 640, -92097 / 339200,
          187 / 2100, 1 / 40)
DOPRI5 = ButcherTableau(
    "dopri5", 5,
    c=(0.0, 0.2, 0.3, 0.8, 8 / 9, 1.0, 1.0),
    a=(
        (),
        (0.2,),
        (3 / 40, 9 / 40),
        (44 / 45, -56 / 15, 32 / 9),
        (19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729),
        (9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656),
        _DP_B[:-1] + (0.0,),
    ),
    b=_DP_B,
    b_err=tuple(b - bh for b, bh in zip(_DP_B, _DP_BH)),
    fsal=True,
)


class Solver:
    """Interface shared by every solver (Table 1's solver axis).

    ``init_state``/``output`` mediate between the user-facing state ``z``
    and the solver's internal state (ALF augments it with the tracked
    velocity ``v``); ``trial_fn`` closes a uniform trial step
    ``(state, t, h) -> (state_next, err_ratio)`` over a controller's error
    norm, so fixed- and adaptive-step drivers share one code path.
    """

    name: str = "?"
    order: int = 0
    stages: int = 1                 # f-evals per (trial) step
    has_error_estimate: bool = False

    def init_state(self, f: Dynamics, params: Pytree, z0: Pytree,
                   t0: jax.Array) -> Pytree:
        return z0

    def output(self, state: Pytree) -> Pytree:
        """Extract ``z`` from the solver state (structural — also works on
        stacked trajectories of states)."""
        return state

    def trial_fn(self, f: Dynamics, params: Pytree, controller) -> TrialFn:
        raise NotImplementedError

    def pallas_step_ops(self) -> Tuple[str, ...]:
        """Kernel-registry qualnames ("<package>.<op>") of the Pallas ops
        this solver's trial step launches; () when the step is pure jnp.
        Direct-backprop consumers (:func:`repro.core.naive.
        check_direct_backprop`) look each one up in ``NO_REVERSE_RULE`` and
        refuse the solver if any is recorded forward-only."""
        return ()

    def interpolant(self, f: Dynamics, params: Pytree, states: Pytree,
                    state_end: Pytree, ts: jax.Array, hs: jax.Array,
                    n_live: jax.Array):
        """Per-step endpoint data ``(y0, d0, y1, d1)`` for dense output.

        ``states`` is the recorded (bound, ...) buffer of accepted-step
        start solver states, ``state_end`` the final solver state, and
        ``ts``/``hs`` the recorded signed step times/sizes (rows past
        ``n_live`` are padding). The default re-evaluates ``f`` at both
        step endpoints — one batched ``vmap`` over the whole buffer, and
        for FSAL tableaus numerically identical to the first/last stage
        pair — while solvers whose state already carries a velocity
        (:class:`ALF`) override this to read the slope for free. Dead
        padding rows are backfilled with the end state so ``f`` never sees
        the zero padding.
        """
        ends = shift_to_step_ends(states, state_end, n_live)
        y0 = self.output(pad_dead_rows(states, state_end, n_live))
        y1 = self.output(pad_dead_rows(ends, state_end, n_live))
        eval_f = jax.vmap(lambda z, t: f(params, z, t))
        d0 = eval_f(y0, ts)
        d1 = eval_f(y1, ts + hs)
        return y0, d0, y1, d1

    def interpolant_fevals(self, bound: int) -> int:
        """Dynamics evaluations :meth:`interpolant` spends over a recorded
        buffer of ``bound`` rows (feeds ``Stats.n_fevals`` accounting on
        the dense/event paths). The default endpoint re-evaluation costs
        two batched passes; velocity-carrying solvers override to 0."""
        return 2 * bound


@dataclasses.dataclass(frozen=True)
class RungeKutta(Solver):
    """A Runge-Kutta solver defined by its Butcher tableau."""

    tableau: ButcherTableau = EULER

    @property
    def name(self) -> str:
        return self.tableau.name

    @property
    def order(self) -> int:
        return self.tableau.order

    @property
    def stages(self) -> int:
        return len(self.tableau.c)

    @property
    def has_error_estimate(self) -> bool:
        return self.tableau.b_err is not None

    @property
    def fsal(self) -> bool:
        return self.tableau.fsal

    def trial_fn(self, f: Dynamics, params: Pytree, controller) -> TrialFn:
        def trial(z, t, h):
            z1, err = self.tableau.step(f, params, z, t, h)
            return z1, controller.error_ratio(err, z, z1)

        return trial


@dataclasses.dataclass(frozen=True)
class ALF(Solver):
    """Asynchronous Leapfrog (paper Algo 2): the invertible solver MALI is
    defined on. State is the augmented ``(z, v)`` pair with
    ``v0 = f(z0, t0)`` (paper Sec 3.1); ``eta`` is the damping coefficient
    of Appendix A.5 (``eta == 0.5`` makes the step non-invertible and is
    rejected).

    ``backend='pallas'`` runs the step's elementwise state algebra through
    the fused :mod:`repro.kernels.alf_step` Pallas kernels (one flattened
    lane-aligned pass over the whole state pytree per step; interpret mode
    on CPU, compiled on TPU) instead of per-leaf jnp ops. The kernel is
    numerically identical and kernel-vs-reference parity is enforced in
    tests. The step ops carry closed-form custom_vjp rules, so every
    gradient consumer accepts this backend: MALI's backward dispatches the
    fused inverse+VJP kernels, and direct backprop (``Naive``, dense
    ``SaveAt(steps=True)``) differentiates through the launches."""

    eta: float = 1.0
    backend: str = "reference"

    name = "alf"
    order = 2
    stages = 1
    has_error_estimate = True       # embedded 1st-vs-2nd order estimate

    def __post_init__(self):
        check_eta(self.eta)
        check_backend(self.backend)

    def init_state(self, f, params, z0, t0):
        return (z0, init_velocity(f, params, z0, t0))

    def output(self, state):
        return state[0]

    def trial_fn(self, f, params, controller) -> TrialFn:
        def trial(state, t, h):
            z, v = state
            z1, v1, err = alf_step_with_error(f, params, z, v, t, h,
                                              self.eta, self.backend)
            return (z1, v1), controller.error_ratio(err, z, z1)

        return trial

    def pallas_step_ops(self) -> Tuple[str, ...]:
        if self.backend != "pallas":
            return ()
        return ("alf_step.alf_midpoint", "alf_step.alf_update")

    def interpolant(self, f, params, states, state_end, ts, hs, n_live):
        """ALF dense output from the velocity pair: the augmented state
        already tracks ``v ~ dz/dt`` at every node, so the Hermite slopes
        come off the recorded ``(z, v)`` record with ZERO extra ``f``
        evaluations (the property the midpoint step maintains — the same
        ``v`` the inverse reconstruction replays)."""
        ends = shift_to_step_ends(states, state_end, n_live)
        z0s, v0s = pad_dead_rows(states, state_end, n_live)
        z1s, v1s = pad_dead_rows(ends, state_end, n_live)
        return z0s, v0s, z1s, v1s

    def interpolant_fevals(self, bound: int) -> int:
        return 0


def Euler() -> RungeKutta:
    return RungeKutta(EULER)


def HeunEuler() -> RungeKutta:
    return RungeKutta(HEUN2)


def Midpoint() -> RungeKutta:
    return RungeKutta(MIDPOINT)


def Bosh3() -> RungeKutta:
    return RungeKutta(BOSH3)


def Rk4() -> RungeKutta:
    return RungeKutta(RK4)


def Dopri5() -> RungeKutta:
    return RungeKutta(DOPRI5)


SOLVERS = {
    "euler": RungeKutta(EULER),
    "heun2": RungeKutta(HEUN2),
    "heun_euler": RungeKutta(HEUN2),
    "midpoint": RungeKutta(MIDPOINT),
    "bosh3": RungeKutta(BOSH3),
    "rk23": RungeKutta(BOSH3),
    "rk2": RungeKutta(HEUN2),
    "rk4": RungeKutta(RK4),
    "dopri5": RungeKutta(DOPRI5),
    "alf": ALF(),
}


def get_solver(name) -> Solver:
    """Resolve a solver: pass through :class:`Solver` instances, look up
    legacy string names in the registry."""
    if isinstance(name, Solver):
        return name
    try:
        return SOLVERS[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; registered solver names: "
            f"{', '.join(sorted(SOLVERS))} (or pass a Solver instance)") \
            from None
