"""Naive method: backpropagate directly through the solver loop.

In JAX this is simply a *differentiable* integration loop: XLA keeps every
per-step intermediate alive for the backward pass, so residual memory grows
with the number of (trial) steps — including the rejected stepsize-search
trials in the adaptive case, exactly the paper's characterization (memory
N_z*N_f*N_t*m, graph depth N_f*N_t*m).

Supports every registered solver uniformly through the
:class:`~repro.core.solvers.Solver` interface (the ALF solver's augmented
(z, v) state with ``v0 = f(z0, t0)`` included); naive-through-ALF is the
gradient-equivalence oracle for MALI: both run the identical segmented
forward, so they must agree to float precision on the same fixed grid —
for the end state and for every point of an observation-grid trajectory.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax

from jax import lax

from .alf import tree_sub
from .integrate import as_time_grid, integrate_grid, scalar_time_grid
from .interface import (GradientMethod, bounds_cotangents, make_run_stats,
                        state_nbytes)
from .solvers import ALF, Solver, get_solver
from .stepsize import StepController, controller_from_kwargs

_tm = jax.tree_util.tree_map

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]


class NaiveConfig(NamedTuple):
    """Static (hashable) configuration of the diff-bounds custom_vjp."""
    f: Dynamics
    solver: Solver
    controller: StepController


def _naive_run(cfg: NaiveConfig, params, z0, ts):
    """The plain differentiable grid integration Naive() backpropagates
    through. Module-level so the diff_bounds wrapper below can re-trace it
    inside its backward."""
    state0 = cfg.solver.init_state(cfg.f, params, z0, ts[0])
    trial = cfg.solver.trial_fn(cfg.f, params, cfg.controller)
    res = integrate_grid(trial, state0, ts, controller=cfg.controller,
                         order=cfg.solver.order)
    init_evals = 1 if isinstance(cfg.solver, ALF) else 0
    return (cfg.solver.output(res.traj),
            make_run_stats(res.n_accepted, res.n_trials, cfg.solver.stages,
                           init_evals))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _naive_grid_db(cfg: NaiveConfig, params: Pytree, z0: Pytree,
                   ts: jax.Array):
    """Naive integration with analytic observation-time cotangents.

    Direct backprop through the step loop would yield the *discrete*
    dL/dts (the derivative of the step-size arithmetic), which differs
    from the continuous boundary terms by the solver's truncation error.
    This wrapper keeps the params/z0 path as ordinary AD (one extra
    forward re-trace in the backward) and substitutes the analytic
    :func:`~repro.core.interface.bounds_cotangents` for ``ts`` — so all
    four gradient methods agree on the diff_bounds semantics.
    """
    return _naive_run(cfg, params, z0, ts)


def _naive_grid_db_fwd(cfg, params, z0, ts):
    out = _naive_run(cfg, params, z0, ts)
    return out, (params, z0, ts, out[0])


def _naive_grid_db_bwd(cfg, res, g):
    g_traj = g[0]  # RunStats cotangents (g[1]) are zero/float0 — ignored.
    params, z0, ts, z_traj = res

    def run_traj(p, z):
        traj, _ = _naive_run(cfg, p, z, lax.stop_gradient(ts))
        return traj

    _, vjp_fn = jax.vjp(run_traj, params, z0)
    g_params, g_z0 = vjp_fn(g_traj)
    a_t0 = tree_sub(g_z0, _tm(lambda b: b[0], g_traj))
    g_ts = bounds_cotangents(cfg.f, params, z_traj, ts, g_traj, a_t0)
    return g_params, g_z0, g_ts


_naive_grid_db.defvjp(_naive_grid_db_fwd, _naive_grid_db_bwd)


def check_direct_backprop(solver: Solver, consumer: str) -> None:
    """Refuse solvers whose trial step dispatches forward-only kernel ops.

    Consumers that backpropagate directly through the recorded step sequence
    (``Naive()``, ``SaveAt(steps=True)``, dense output) call this instead of
    hardcoding per-solver knowledge: the solver reports the kernel ops its
    step launches (:meth:`Solver.pallas_step_ops`) and each is looked up in
    the central ``NO_REVERSE_RULE`` registry. Ops carrying a custom_vjp are
    absent there and pass; a future VJP-less op is rejected automatically,
    with its reviewed justification in the error."""
    from repro.kernels.registry import no_reverse_reason
    blocked = [(op, no_reverse_reason(op)) for op in solver.pallas_step_ops()]
    blocked = [(op, r) for op, r in blocked if r is not None]
    if blocked:
        detail = "; ".join(f"{op} (NO_REVERSE_RULE: {r})"
                           for op, r in blocked)
        raise ValueError(
            f"{consumer} backpropagates directly through the recorded step "
            f"sequence, but solver {solver.name!r} dispatches forward-only "
            f"kernel op(s): {detail}")


@dataclasses.dataclass(frozen=True)
class Naive(GradientMethod):
    """Direct backprop through the integration loop (Table 1 'naive' row):
    the memory-hungry oracle every memory-efficient method is checked
    against. Under ``solve(batching=PerSample())`` it is vmapped row-wise
    like every other method, which makes it the gradient oracle for the
    batched drivers too (per-row adaptive loops included). Reverse-time
    spans differentiate through the identical sign-agnostic driver, so
    naive is the oracle for both integration directions."""

    name = "naive"

    def default_solver(self) -> Solver:
        return ALF()

    def validate(self, solver, controller) -> None:
        super().validate(solver, controller)
        check_direct_backprop(solver, "Naive()")

    def integrate(self, f, params, z0, ts, solver, controller,
                  diff_bounds: bool = False):
        cfg = NaiveConfig(f, solver, controller)
        if diff_bounds:
            return _naive_grid_db(cfg, params, z0, ts)
        return _naive_run(cfg, params, z0, ts)

    def residual_bytes(self, z0, n_obs, solver, controller) -> int:
        # AD keeps every trial step's stage intermediates alive — grows with
        # the per-segment step budget (the Table 1 N_z*N_f*N_t*m column).
        state = 2 if isinstance(solver, ALF) else 1
        return ((n_obs - 1) * controller.step_bound * solver.stages
                * state * state_nbytes(z0))


def odeint_naive(f: Dynamics, params: Pytree, z0: Pytree, t0=0.0, t1=1.0, *,
                 ts=None, solver="alf", n_steps: int = 0,
                 eta: float = 1.0, rtol: float = 1e-2, atol: float = 1e-3,
                 max_steps: int = 64) -> Pytree:
    """Differentiable integration (legacy kwargs facade); with ``ts`` returns
    the (T, ...) trajectory (``traj[0] == z0``), otherwise z(t1) via the
    length-1 grid [t0, t1]."""
    sol = get_solver(solver)
    if isinstance(sol, ALF) and eta != sol.eta:
        sol = ALF(eta=float(eta))
    controller = controller_from_kwargs(n_steps, rtol, atol, max_steps)
    method = Naive()
    method.validate(sol, controller)
    scalar = ts is None
    grid = scalar_time_grid(t0, t1) if scalar else as_time_grid(ts)
    traj, _ = method.integrate(f, params, z0, grid, sol, controller)
    return _tm(lambda b: b[-1], traj) if scalar else traj
