"""Naive method: backpropagate directly through the solver loop.

In JAX this is simply a *differentiable* integration loop: XLA keeps every
per-step intermediate alive for the backward pass, so residual memory grows
with the number of (trial) steps — including the rejected stepsize-search
trials in the adaptive case, exactly the paper's characterization (memory
N_z*N_f*N_t*m, graph depth N_f*N_t*m).

Supports the RK tableaus and the ALF solver (augmented (z, v) state with
v0 = f(z0, t0)); the latter gives the gradient-equivalence oracle for MALI:
naive-ALF and MALI must agree to float precision on the same fixed grid —
both for the end state and for every point of an observation-grid
trajectory (``ts``), since both run the identical segmented forward.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from .alf import alf_step, alf_step_with_error, check_eta, init_velocity
from .integrate import (as_time_grid, integrate_adaptive_grid,
                        integrate_fixed_grid, scalar_time_grid)
from .solvers import ButcherTableau, get_solver
from .stepsize import error_ratio

_tm = jax.tree_util.tree_map

Pytree = Any
Dynamics = Callable[[Pytree, Pytree, jax.Array], Pytree]


def odeint_naive(f: Dynamics, params: Pytree, z0: Pytree, t0=0.0, t1=1.0, *,
                 ts=None, solver: str = "alf", n_steps: int = 0,
                 eta: float = 1.0, rtol: float = 1e-2, atol: float = 1e-3,
                 max_steps: int = 64) -> Pytree:
    """Differentiable integration; with ``ts`` returns the (T, ...) trajectory
    (``traj[0] == z0``), otherwise z(t1) via the length-1 grid [t0, t1]."""
    sol = get_solver(solver)
    scalar = ts is None
    grid = scalar_time_grid(t0, t1) if scalar else as_time_grid(ts)

    if solver == "alf":
        check_eta(eta)
        v0 = init_velocity(f, params, z0, grid[0])

        if n_steps > 0:
            def step(state, t, h):
                z, v = state
                return alf_step(f, params, z, v, t, h, eta)

            _, (z_traj, _) = integrate_fixed_grid(step, (z0, v0), grid,
                                                  n_steps)
        else:
            def trial(state, t, h):
                z, v = state
                z1, v1, err = alf_step_with_error(f, params, z, v, t, h, eta)
                return (z1, v1), error_ratio(err, z, z1, rtol, atol)

            out = integrate_adaptive_grid(trial, (z0, v0), grid, order=2,
                                          rtol=rtol, atol=atol,
                                          max_steps=max_steps)
            z_traj, _ = out.traj
        return _tm(lambda b: b[-1], z_traj) if scalar else z_traj

    assert isinstance(sol, ButcherTableau)
    if n_steps > 0:
        def step(z, t, h):
            z1, _ = sol.step(f, params, z, t, h)
            return z1

        _, z_traj = integrate_fixed_grid(step, z0, grid, n_steps)
        return _tm(lambda b: b[-1], z_traj) if scalar else z_traj

    if sol.b_err is None:
        raise ValueError(f"solver {solver!r} has no embedded error estimate; "
                         "pass n_steps for fixed-step integration")

    def trial(z, t, h):
        z1, err = sol.step(f, params, z, t, h)
        return z1, error_ratio(err, z, z1, rtol, atol)

    out = integrate_adaptive_grid(trial, z0, grid, order=sol.order, rtol=rtol,
                                  atol=atol, max_steps=max_steps)
    return _tm(lambda b: b[-1], out.traj) if scalar else out.traj
