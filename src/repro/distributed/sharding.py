"""Sharding rules: parameters, optimizer state, inputs, KV caches.

Mesh axes: ('pod', 'data', 'model') multi-pod, ('data', 'model') single-pod.
'pod' x 'data' is pure data parallelism; 'model' is tensor/expert parallel.

Strategies (ModelConfig.sharding):
  * 'dp'      — pure data parallel: params replicated, batch sharded over
    every mesh axis (incl. 'model') when divisible. Right for the <3B archs
    on a 256-chip pod: TP would make them collective-bound (measured in
    EXPERIMENTS.md §Perf).
  * 'tp'      — 1D: weights sharded over 'model' only (small archs).
  * 'fsdp_tp' — 2D: the same 'model' sharding plus the complementary big dim
    over 'data' (FSDP-style; GSPMD inserts the per-layer all-gathers).
    Required for the >8B archs: e.g. grok-1 bf16 params = 628 GB -> 2.45
    GB/chip at 16x16.

Every rule is divisibility-guarded: a dim is sharded only if the axis size
divides it, else that dim stays replicated (e.g. grok's 8 experts on a
16-way model axis fall back to d_ff-sharding).

Optimizer state inherits the param sharding leaf-for-leaf (ZeRO-1: the f32
master/m/v live fully sharded; nothing is replicated that isn't replicated
in the params).

xLSTM params are replicated (125M: DP-only is the right config — noted in
DESIGN.md); its activations shard on batch.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

Pytree = Any


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _maybe(dim: int, axis: Optional[str], mesh: Mesh) -> Optional[str]:
    """Shard `dim` over `axis` only if divisible."""
    if axis is None or axis not in mesh.axis_names:
        return None
    return axis if dim % _axis_size(mesh, axis) == 0 else None


def _leaf_spec(cfg: ModelConfig, mesh: Mesh, path: Tuple[str, ...],
               shape: Tuple[int, ...]) -> P:
    if cfg.sharding == "dp":
        return P()
    fsdp = cfg.sharding == "fsdp_tp"
    data = "data" if fsdp else None
    name = path[-1]

    # xLSTM mixers: replicate (see module docstring)
    if name in ("w_i", "w_f", "f_bias", "r_in", "out_norm") or \
            (name in ("w_up", "w_q", "w_k", "w_v", "w_down", "w_in", "bias")
             and _in_lstm_path(cfg, path)):
        return P()

    if len(shape) <= 1:
        return P()  # norms, biases, scalars

    if name == "embed":
        return P(_maybe(shape[0], data, mesh), _maybe(shape[1], "model", mesh))
    if name == "head":
        return P(_maybe(shape[0], data, mesh), _maybe(shape[1], "model", mesh))

    # attention
    if name == "wq":
        # shard fused (H*dh) only when it splits on whole heads
        ok = cfg.n_heads % _axis_size(mesh, "model") == 0
        return P(_maybe(shape[0], data, mesh),
                 _maybe(shape[1], "model", mesh) if ok else None)
    if name in ("wk", "wv"):
        # K/V: intra-head splits (kv_heads < model axis) force a psum into
        # EVERY attention tile (contraction over a sharded d_head); the
        # projections are tiny — replicate them and keep K/V activations
        # whole instead (measured on qwen3 prefill_32k; §Perf)
        ok = cfg.n_kv_heads % _axis_size(mesh, "model") == 0
        return P(_maybe(shape[0], data, mesh),
                 _maybe(shape[1], "model", mesh) if ok else None)
    if name == "wo":
        return P(_maybe(shape[0], "model", mesh), _maybe(shape[1], data, mesh))

    # dense mlp
    if name in ("w_gate", "w_up") and len(shape) == 2:
        return P(_maybe(shape[0], data, mesh), _maybe(shape[1], "model", mesh))
    if name == "w_down" and len(shape) == 2:
        return P(_maybe(shape[0], "model", mesh), _maybe(shape[1], data, mesh))

    # moe experts [E, D, F] / [E, F, D]
    if name in ("w_gate", "w_up") and len(shape) == 3:
        ep = _maybe(shape[0], "model", mesh)
        if ep:
            return P(ep, _maybe(shape[1], data, mesh), None)
        return P(None, _maybe(shape[1], data, mesh),
                 _maybe(shape[2], "model", mesh))
    if name == "w_down" and len(shape) == 3:
        ep = _maybe(shape[0], "model", mesh)
        if ep:
            return P(ep, None, _maybe(shape[2], data, mesh))
        return P(None, _maybe(shape[1], "model", mesh),
                 _maybe(shape[2], data, mesh))
    if name == "router":
        return P()

    # mamba
    if name == "in_proj":
        return P(_maybe(shape[0], data, mesh), _maybe(shape[1], "model", mesh))
    if name == "conv_w":
        return P(None, _maybe(shape[1], "model", mesh))
    if name == "x_proj":
        return P(_maybe(shape[0], "model", mesh), None)
    if name == "dt_proj":
        return P(None, _maybe(shape[1], "model", mesh))
    if name == "A_log":
        return P(_maybe(shape[0], "model", mesh), None)
    if name == "out_proj":
        return P(_maybe(shape[0], "model", mesh), _maybe(shape[1], data, mesh))

    return P()


def _in_lstm_path(cfg: ModelConfig, path: Tuple[str, ...]) -> bool:
    """True if this param belongs to an mLSTM/sLSTM mixer (pattern-level:
    any layer spec in the config uses those mixers and the path is a mixer)."""
    if "mixer" not in path:
        return False
    return any(spec.mixer in ("mlstm", "slstm")
               for spec in cfg.prelude + cfg.period)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
    return tuple(names)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_like: Pytree) -> Pytree:
    """NamedSharding tree matching ``params_like`` (arrays or ShapeDtype)."""

    def one(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        # scanned-period params carry a leading n_periods dim: apply the
        # rule to the per-layer shape, replicate the stack dim
        if "period" in names:
            spec = P(None, *_leaf_spec(cfg, mesh, names, shape[1:]))
        else:
            spec = _leaf_spec(cfg, mesh, names, shape)
        if len(spec) > len(shape):
            spec = P(*spec[:len(shape)])
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_like)


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_like: Pytree) -> Pytree:
    # pure-DP archs also spread the batch over the (otherwise idle) model
    # axis when it divides
    candidates = []
    if cfg.sharding == "dp":
        candidates.append(dp_axes(mesh) + ("model",))
    candidates.append(dp_axes(mesh))

    def one(leaf):
        nbatch = leaf.shape[0]
        lead = None
        for axes in candidates:
            total = 1
            for a in axes:
                total *= _axis_size(mesh, a)
            if total > 1 and nbatch % total == 0:
                lead = axes
                break
        return NamedSharding(mesh, P(lead, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map(one, batch_like)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_like: Pytree,
                    batch: int) -> Pytree:
    """KV/SSM cache: batch over DP when it divides; otherwise (long-context,
    batch=1) shard the KV *sequence* dim over 'data' (flash-decoding style
    split-KV) and heads over 'model'."""
    dp = dp_axes(mesh)
    if cfg.sharding == "dp":
        full = dp + ("model",)
        total = 1
        for a in full:
            total *= _axis_size(mesh, a)
        if batch % max(total, 1) == 0:
            dp = full
    dp_total = 1
    for a in dp:
        dp_total *= _axis_size(mesh, a)
    batch_on_dp = batch % max(dp_total, 1) == 0 and dp_total > 1

    def one(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        # period-stacked caches have a leading n_periods dim
        lead = ("period" in names)
        core = shape[1:] if lead else shape
        # KV cache leaves are the 'k'/'v' fields: [slots, B, S, K, dh].
        # Everything else (Mamba conv/ssm, LSTM c/n/m/h) is per-token-free
        # recurrent state — no sequence dim to split.
        is_kv = bool(names) and names[-1] in ("k", "v") and len(core) == 5

        def fits(dim_size, axis):
            sz = _axis_size(mesh, axis)
            return sz > 1 and dim_size % sz == 0

        spec: list = [None] * len(core)
        if len(core) >= 2 and batch_on_dp:
            spec[1] = dp
        elif is_kv and "data" in mesh.axis_names and fits(core[2], "data"):
            # long-context batch=1: split the KV sequence over 'data'
            # (flash-decoding style split-KV)
            spec[2] = "data"
        if "model" in mesh.axis_names:
            # shard the widest model-side dim that divides, scanning from
            # the heads dim outward (KV: [.., K, dh]; mLSTM: [.., H, dk, dv]);
            # for KV the sequence dim (2) is reserved for 'data' split-KV
            for d in range(3 if is_kv else 2, len(core)):
                if spec[d] is None and fits(core[d], "model"):
                    spec[d] = "model"
                    break
        p = P(*([None] + spec if lead else spec))
        return NamedSharding(mesh, p)

    return jax.tree_util.tree_map_with_path(one, cache_like)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def ambient_mesh() -> Optional[Mesh]:
    """The mesh of the innermost active ``with mesh:`` context, or None.

    This is how mesh-aware library code (``solve(batching=Sharded(...))``,
    the activation :func:`hint`) discovers the production/host mesh without
    threading it through every call signature.
    """
    from jax._src import mesh as mesh_lib
    mesh = mesh_lib.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def batch_sharding(mesh: Mesh, axis: str = "data") -> NamedSharding:
    """Leading-axis batch sharding for a fleet of ODE states: place the
    batch dim on ``axis``, replicate everything else (the device layout
    ``solve(batching=Sharded(axis))`` computes over — pre-placing inputs
    with this avoids a resharding transfer on entry)."""
    return NamedSharding(mesh, P(axis))


def model_axis_size() -> int:
    """Size of the ambient mesh's 'model' axis (1 when no mesh)."""
    import os
    if os.environ.get("REPRO_NO_HINTS"):
        return 1
    mesh = ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]


def hint(x, *dims: Optional[str]):
    """Activation-sharding hint usable INSIDE model code.

    ``dims`` name the wanted axis per tensor dim: 'batch' (-> every dp axis),
    'model', or None. A no-op when no mesh context is active (unit tests /
    single-host examples) or when an axis doesn't divide. GSPMD propagates
    most shardings fine; the explicit hints pin the cases where propagation
    picks a catastrophic layout (measured: mamba's scan replicated the batch
    dim across 'data' — 16x redundant memory/compute; EXPERIMENTS.md §Perf
    jamba iteration 1).
    """
    import os
    if os.environ.get("REPRO_NO_HINTS"):
        return x
    from jax._src import mesh as mesh_lib
    mesh = mesh_lib.thread_resources.env.physical_mesh
    if mesh.empty or mesh.size == 1:
        return x
    spec = []
    for dim, want in zip(x.shape, dims):
        if want == "batch":
            axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            spec.append(axes if axes and dim % max(total, 1) == 0 else None)
        elif want == "model" and "model" in mesh.axis_names:
            spec.append("model" if dim % mesh.shape["model"] == 0 else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def zero1_sharding(mesh: Mesh, leaf) -> NamedSharding:
    """ZeRO-1 spec for optimizer-state leaves of REPLICATED params: shard the
    largest divisible dim over ('data','model') (fallback 'data', then
    replicate). Params stay replicated; GSPMD turns the grad all-reduce into
    reduce-scatter + (post-update) all-gather."""
    shape = tuple(leaf.shape)
    size = 1
    for d in shape:
        size *= d
    if not shape or size < (1 << 16):
        return NamedSharding(mesh, P())
    for axes in ((("data", "model"),), (("data",),), (("model",),)):
        axes = axes[0]
        if not all(a in mesh.axis_names for a in axes):
            continue
        total = 1
        for a in axes:
            total *= _axis_size(mesh, a)
        # largest dim divisible by the axis product
        best = -1
        for i, d in enumerate(sorted(range(len(shape)),
                                     key=lambda i: -shape[i])):
            if shape[d] % total == 0:
                best = d
                break
        if best >= 0:
            spec = [None] * len(shape)
            spec[best] = axes
            return NamedSharding(mesh, P(*spec))
    return NamedSharding(mesh, P())


def opt_state_shardings(cfg: ModelConfig, mesh: Mesh, p_sh: Pytree,
                        params_like: Pytree):
    """Optimizer-state shardings: inherit the param sharding where the param
    is itself sharded; apply ZeRO-1 to leaves whose param is replicated."""
    def one(sh, leaf):
        if any(ax is not None for ax in sh.spec):
            return sh
        return zero1_sharding(mesh, leaf)

    return jax.tree_util.tree_map(one, p_sh, params_like)
