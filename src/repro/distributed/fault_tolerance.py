"""Fault tolerance & elasticity policy for the launcher.

Posture for 1000+ nodes (DESIGN.md §6):

* Checkpoint/restart: step-scoped async checkpoints (checkpoint/), restore
  via ``restore_latest`` after any failure. Training state is
  (params, opt_state, data_step) — the synthetic pipeline is a pure function
  of step, so resume is exact.
* Elastic re-mesh: on losing nodes, shrink the *data* axis (pure DP shrink is
  loss-free: global batch is re-sharded over fewer replicas; the 'model' axis
  is fixed by the param sharding). ``plan_elastic_mesh`` picks the largest
  data axis that divides the global batch.
* Straggler mitigation: shards are pure functions of (seed, step, shard), so
  a slow/lost host's shard is reassigned by renumbering — no data movement.
  ``reassign_shards`` computes the new host->shard map.
* Retry loop: ``run_with_recovery`` wraps the train loop; transient failures
  (preemption, DMA timeout — simulated by exceptions here) trigger
  restore+re-mesh up to ``max_failures``.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

log = logging.getLogger("repro.ft")


@dataclasses.dataclass
class MeshPlan:
    pod: int
    data: int
    model: int

    @property
    def n_devices(self) -> int:
        return self.pod * self.data * self.model


def plan_elastic_mesh(n_available: int, model_size: int, global_batch: int,
                      pods: int = 1) -> MeshPlan:
    """Largest (pod, data, model) mesh fitting the surviving devices.

    'model' is fixed (params are sharded over it); 'data' shrinks to the
    largest divisor of global_batch that fits.
    """
    if n_available < model_size:
        raise RuntimeError(
            f"cannot re-mesh: {n_available} devices < model axis {model_size}")
    max_data = n_available // (model_size * pods)
    data = max_data
    while data > 1 and global_batch % data:
        data -= 1
    if data < 1:
        raise RuntimeError("no valid data axis")
    return MeshPlan(pods, data, model_size)


def reassign_shards(healthy_hosts: Sequence[int], n_shards: int
                    ) -> Dict[int, List[int]]:
    """Round-robin shard ownership over surviving hosts (deterministic)."""
    hosts = sorted(healthy_hosts)
    if not hosts:
        raise RuntimeError("no healthy hosts")
    out: Dict[int, List[int]] = {h: [] for h in hosts}
    for s in range(n_shards):
        out[hosts[s % len(hosts)]].append(s)
    return out


@dataclasses.dataclass
class RecoveryStats:
    failures: int = 0
    restores: int = 0
    last_error: Optional[str] = None


def run_with_recovery(train_loop: Callable[[Optional[int]], int],
                      restore_step: Callable[[], Optional[int]],
                      max_failures: int = 3,
                      backoff_s: float = 0.0) -> Tuple[int, RecoveryStats]:
    """Run ``train_loop(resume_step) -> final_step`` with restart-on-failure.

    ``restore_step()`` returns the latest checkpointed step (None = fresh).
    """
    stats = RecoveryStats()
    while True:
        resume = restore_step()
        if resume is not None:
            stats.restores += 1
        try:
            final = train_loop(resume)
            return final, stats
        except (RuntimeError, OSError, ValueError) as e:
            stats.failures += 1
            stats.last_error = f"{type(e).__name__}: {e}"
            log.warning("training failure #%d: %s", stats.failures, e)
            if stats.failures > max_failures:
                raise
            if backoff_s:
                time.sleep(backoff_s)
