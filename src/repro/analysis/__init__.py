"""repro.analysis: odelint static checks + device-free trace audit.

Two layers guard the invariants JAX never checks for us:

* :mod:`repro.analysis.lint` — **odelint**, an AST linter (stdlib ``ast``,
  no third-party deps) with repo-specific rules R001–R005 over ``core/``,
  ``kernels/`` and ``launch/``;
* :mod:`repro.analysis.trace_audit` — a device-free ``jax.eval_shape``
  sweep of the Solver x GradientMethod x StepController x Batching x
  direction matrix, plus a jit retrace count (same static config twice
  must trace exactly once).

Entry point: ``PYTHONPATH=src python -m repro.analysis
[--json analysis_report.json]`` — exits non-zero on any violation. See
``src/repro/analysis/README.md`` for the rule catalogue.
"""
from .lint import Violation, lint_source, run_lint

__all__ = ["Violation", "lint_source", "run_lint"]
