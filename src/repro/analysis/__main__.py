"""Entry point: ``PYTHONPATH=src python -m repro.analysis``.

Runs odelint (R001–R005) and the device-free trace audit, prints a
summary, optionally writes ``analysis_report.json``, and exits non-zero
on any violation — the CI static-analysis job gates on this.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .lint import run_lint
from .trace_audit import run_trace_audit


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable report here")
    ap.add_argument("--repo", default=".",
                    help="repo root (directory holding src/ and tests/)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset, e.g. R001,R003")
    ap.add_argument("--skip-audit", action="store_true",
                    help="lint only (skip the eval_shape/retrace sweep)")
    args = ap.parse_args(argv)

    repo = Path(args.repo)
    rules = args.rules.split(",") if args.rules else None

    violations = run_lint(repo, rules=rules)
    for v in violations:
        print(v)
    print(f"odelint: {len(violations)} violation(s)")

    audit = None
    if not args.skip_audit:
        audit = run_trace_audit()
        for msg in audit["shape_failures"] + audit["retrace_failures"]:
            print("trace-audit:", msg)
        print(f"trace audit: {audit['combos']} matrix combos, "
              f"{len(audit['shape_failures'])} shape failure(s), "
              f"retrace counts {audit['retrace_counts']} "
              f"({audit['elapsed_s']}s)")

    ok = not violations and (audit is None or audit["ok"])
    if args.json:
        report = {
            "ok": ok,
            "lint": {
                "count": len(violations),
                "violations": [v.as_dict() for v in violations],
            },
            "trace_audit": audit,
        }
        Path(args.json).write_text(json.dumps(report, indent=2) + "\n")
        print(f"report written to {args.json}")
    print("analysis:", "OK" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
