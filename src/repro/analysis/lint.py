"""odelint driver: file discovery, rule dispatch, suppressions.

Rule scoping (which invariant lives where):

* R001 (traced branches)      -> core/, kernels/, cnf/
* R002 (custom_vjp hygiene)   -> core/, launch/, cnf/
* R003 (Pallas contracts)     -> kernels/
* R004 (registry complete)    -> repo-level (runtime introspection)
* R005 (signed buffers)       -> core/, cnf/

``lint_source`` is the in-memory entry point the fixture tests use;
``run_lint`` walks the real tree. Suppress a finding with
``# odelint: disable=RXXX -- <reason>`` on the offending line (the reason
is mandatory — see rules/common.py).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from .rules import AST_RULES, r004_registry
from .rules.common import (Violation, apply_suppressions,
                           parse_suppressions)

# rule id -> source subtrees (relative to src/repro) it applies to
RULE_SCOPE = {
    "R001": ("core", "kernels", "cnf"),
    "R002": ("core", "launch", "cnf"),
    "R003": ("kernels",),
    "R005": ("core", "cnf"),
}


def _load_allowlist(repo_src: Path) -> Dict[str, str]:
    """Parse NO_REVERSE_RULE out of kernels/registry.py via AST (no
    import needed, keeps lint_source usable without the package)."""
    reg = repo_src / "repro" / "kernels" / "registry.py"
    if not reg.exists():
        return {}
    tree = ast.parse(reg.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "NO_REVERSE_RULE"
                for t in node.targets) and isinstance(node.value, ast.Dict):
            out = {}
            for k, v in zip(node.value.keys, node.value.values):
                if isinstance(k, ast.Constant) and isinstance(
                        v, ast.Constant):
                    out[k.value] = v.value
            return out
    return {}


def lint_source(src: str, path: str = "<snippet>",
                rules: Optional[Sequence[str]] = None,
                ctx: Optional[dict] = None) -> List[Violation]:
    """Lint one source string with the given AST rules (default: all)."""
    ctx = dict(ctx or {})
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Violation("R000", path, e.lineno or 1,
                          f"syntax error: {e.msg}")]
    table, found = parse_suppressions(src, path)
    for rid in rules if rules is not None else sorted(AST_RULES):
        found.extend(AST_RULES[rid].check(tree, src, path, ctx))
    return apply_suppressions(sorted(found, key=lambda v: (v.path, v.line)),
                              table)


def _applicable_rules(rel: Path) -> List[str]:
    top = rel.parts[0] if rel.parts else ""
    return [rid for rid, scopes in RULE_SCOPE.items() if top in scopes]


def run_lint(repo_root, rules: Optional[Sequence[str]] = None,
             include_registry_checks: bool = True) -> List[Violation]:
    """Lint the repo. ``repo_root`` is the directory holding src/ and
    tests/."""
    repo_root = Path(repo_root)
    src_root = repo_root / "src"
    pkg_root = src_root / "repro"
    allowlist = _load_allowlist(src_root)

    out: List[Violation] = []
    for py in sorted(pkg_root.rglob("*.py")):
        rel = py.relative_to(pkg_root)
        applicable = _applicable_rules(rel)
        if rules is not None:
            applicable = [r for r in applicable if r in rules]
        if not applicable:
            continue
        ctx = {"no_reverse_rule": allowlist}
        if rel.parts[0] == "kernels" and len(rel.parts) >= 2 and \
                py.name == "ops.py":
            ctx["kernel_package"] = rel.parts[1]
        out.extend(lint_source(py.read_text(), str(py.relative_to(repo_root)),
                               applicable, ctx))

    if include_registry_checks and (rules is None or "R004" in rules):
        out.extend(r004_registry.check_registries(repo_root / "tests"))
    return sorted(out, key=lambda v: (v.path, v.line, v.rule))
