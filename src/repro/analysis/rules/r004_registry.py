"""R004 — registry completeness.

Every registered ``Solver`` / ``GradientMethod`` / ``Batching`` subclass
must (a) implement the full abstract interface of its base (every base
method whose body is ``raise NotImplementedError``), and (b) appear in at
least one test — by class name or by its registry key. A solver that can
be selected by string but is exercised nowhere is exactly how the matrix
rots as it grows (the ROADMAP's solver-zoo direction multiplies it).

This rule introspects the *live* registries (it imports ``repro.core``)
rather than re-deriving them from the AST — the point is to audit what a
user can actually reach through ``solve()``.
"""
from __future__ import annotations

import inspect
import re
from pathlib import Path
from typing import Dict, List, Set

from .common import Violation

RULE = "R004"


def _abstract_members(base: type) -> List[str]:
    """Names of `base` methods/properties whose body raises
    NotImplementedError (the repo's convention for 'abstract')."""
    out = []
    for name, member in vars(base).items():
        fn = member.fget if isinstance(member, property) else member
        if not callable(fn):
            continue
        try:
            src = inspect.getsource(fn)
        except (OSError, TypeError):
            continue
        if "raise NotImplementedError" in src:
            out.append(name)
    return out


def _overrides(cls: type, base: type, name: str) -> bool:
    for klass in cls.__mro__:
        if klass is base:
            return False
        if name in vars(klass):
            return True
    return False


def _load_registries():
    from repro.core import (ACA, MALI, SOLVERS, Backsolve, Batching,
                            GradientMethod, Naive, Solver)

    solvers: Dict[type, Set[str]] = {}
    for key, inst in SOLVERS.items():
        solvers.setdefault(type(inst), set()).add(key)
    # METHODS in repro.core.api is the legacy string tuple; the live
    # GradientMethod classes are the four paper rows.
    methods: Dict[type, Set[str]] = {
        MALI: {"mali"}, Naive: {"naive"}, ACA: {"aca"},
        Backsolve: {"adjoint", "backsolve"},
    }
    batchings = {sub: {sub.__name__} for sub in Batching.__subclasses__()}
    # Serve-layer policy registries (PR 8): every admission / scheduling /
    # cache-eviction policy reachable by string must carry the full
    # interface and show up in tests, same contract as the solver zoo.
    from repro.serve import (ADMISSION_POLICIES, CACHE_POLICIES,
                             SCHEDULING_POLICIES, AdmissionPolicy,
                             CachePolicy, SchedulingPolicy)

    def by_class(reg) -> Dict[type, Set[str]]:
        out: Dict[type, Set[str]] = {}
        for key, inst in reg.items():
            out.setdefault(type(inst), set()).add(key)
        return out

    # Train-layer registries (PR 9): loop drivers and telemetry sinks are
    # string-reachable through TrainerConfig, so they carry the same
    # completeness contract.
    from repro.train import EMITTERS, TRAIN_LOOPS, MetricsEmitter, TrainLoop

    emitters = {cls: {key} for key, cls in EMITTERS.items()}

    # CNF trace estimators (PR 10): string-reachable through
    # repro.cnf.get_estimator, so same completeness contract.
    from repro.cnf import TRACE_ESTIMATORS, TraceEstimator

    return [(Solver, solvers), (GradientMethod, methods),
            (Batching, batchings),
            (AdmissionPolicy, by_class(ADMISSION_POLICIES)),
            (SchedulingPolicy, by_class(SCHEDULING_POLICIES)),
            (CachePolicy, by_class(CACHE_POLICIES)),
            (TrainLoop, by_class(TRAIN_LOOPS)),
            (MetricsEmitter, emitters),
            (TraceEstimator, by_class(TRACE_ESTIMATORS))]


def check_registries(tests_dir) -> List[Violation]:
    out: List[Violation] = []
    tests_dir = Path(tests_dir)
    corpus = "\n".join(
        p.read_text() for p in sorted(tests_dir.glob("test_*.py")))

    for base, registry in _load_registries():
        required = _abstract_members(base)
        for cls, keys in sorted(registry.items(), key=lambda kv:
                                kv[0].__name__):
            path = inspect.getsourcefile(cls) or "<unknown>"
            try:
                line = inspect.getsourcelines(cls)[1]
            except (OSError, TypeError):
                line = 1
            for name in required:
                if not _overrides(cls, base, name):
                    out.append(Violation(
                        RULE, path, line,
                        f"registered {base.__name__} subclass "
                        f"`{cls.__name__}` does not implement abstract "
                        f"member `{name}`"))
            mentions = {cls.__name__} | keys
            if not any(re.search(rf"\b{re.escape(m)}\b", corpus)
                       for m in mentions):
                out.append(Violation(
                    RULE, path, line,
                    f"registered {base.__name__} `{cls.__name__}` "
                    f"(keys: {', '.join(sorted(keys))}) appears in no "
                    f"test under tests/ — add at least a smoke solve"))
    return out


def missing_interface(cls: type, base: type) -> List[str]:
    """Test hook: abstract members of `base` that `cls` fails to override."""
    return [name for name in _abstract_members(base)
            if not _overrides(cls, base, name)]
