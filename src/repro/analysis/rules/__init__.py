"""odelint rule modules. Each exposes ``check(tree, src, path, ctx)``
returning a list of :class:`~repro.analysis.rules.common.Violation`."""
from . import (r001_traced_branch, r002_custom_vjp, r003_pallas,
               r004_registry, r005_signed_buffer)
from .common import Violation

# Rule id -> (module, which file paths it applies to). R004 is repo-level
# (runtime registry introspection) and is dispatched separately by lint.py.
AST_RULES = {
    "R001": r001_traced_branch,
    "R002": r002_custom_vjp,
    "R003": r003_pallas,
    "R005": r005_signed_buffer,
}

__all__ = ["AST_RULES", "Violation", "r004_registry"]
