"""R002 — custom_vjp hygiene.

Three sub-checks, each motivated by a bug class this repo has actually
hit (the PR-2 float0 incident; see analysis/README.md):

* **R002a — explicit residuals.** Every ``*_fwd`` registered via
  ``defvjp`` must return a two-tuple whose second element is an explicit
  tuple literal (or a name assigned from one inside the function). A
  residual pytree built opaquely (dict comprehension, helper call) hides
  what the backward pass depends on and is how closure-captured state
  sneaks in.
* **R002b — module-level primal/fwd/bwd.** The functions handed to
  ``jax.custom_vjp``/``defvjp`` must be module-level ``def``s, not
  closures: a nested def can capture tracers from the enclosing trace,
  which breaks the residual contract invisibly (the tracer leaks around
  the custom_vjp boundary).
* **R002c — no arithmetic on integer Stats outside the primal.** The
  integer step/eval counters returned by a gradient method's custom_vjp
  carry *instantiated float0 tangents* under vmap-of-grad; any arithmetic
  on them outside the primal crashes jvp tracing (the PR-2 incident).
  Counters must be laundered through ``_detached``/``stop_gradient``
  (or ``int()`` on the host) before arithmetic.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from .common import Violation, dotted_name, own_nodes, target_names

RULE = "R002"

_COUNTER_ATTRS = {"n_accepted", "n_rejected", "n_fevals", "n_trials"}
_LAUNDER_FUNCS = {"_detached", "stop_gradient", "int", "float",
                  "make_run_stats"}
_ARITH_OPS = (ast.Add, ast.Sub, ast.Mult, ast.Div, ast.FloorDiv, ast.Mod,
              ast.Pow)


def _module_defs(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body
            if isinstance(n, ast.FunctionDef)}


def _custom_vjp_registrations(tree: ast.Module):
    """-> (primal names, {vjp object name: (fwd node, bwd node)}).
    Nodes are ast.Name/other expressions as written at the defvjp site."""
    primals: List = []
    defvjps: Dict[str, tuple] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            d = dotted_name(node.value.func)
            if d and d.endswith("custom_vjp") and node.value.args:
                primals.append((node.value.args[0], node))
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                base = dec.func if isinstance(dec, ast.Call) else dec
                d = dotted_name(base)
                if d and d.endswith("custom_vjp"):
                    primals.append((ast.Name(id=node.name), node))
        if isinstance(node, ast.Call):
            d = dotted_name(node.func)
            if d and d.endswith(".defvjp") and len(node.args) >= 2:
                obj = d.rsplit(".", 1)[0]
                defvjps[obj] = (node.args[0], node.args[1], node)
    return primals, defvjps


def _check_fwd_returns(fdef: ast.FunctionDef, path: str) -> List[Violation]:
    out = []
    tuple_names: Set[str] = set()
    for node in own_nodes(fdef):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Tuple):
            for t in node.targets:
                tuple_names.update(target_names(t))
    for node in own_nodes(fdef):
        if not isinstance(node, ast.Return) or node.value is None:
            continue
        ok = False
        if isinstance(node.value, ast.Tuple) and len(node.value.elts) == 2:
            res = node.value.elts[1]
            ok = isinstance(res, ast.Tuple) or (
                isinstance(res, ast.Name) and res.id in tuple_names)
        if not ok:
            out.append(Violation(
                RULE, path, node.lineno,
                f"custom_vjp fwd `{fdef.name}` must `return out, "
                f"(res1, res2, ...)` with the residuals an explicit "
                f"tuple literal — opaque residual pytrees hide what the "
                f"backward pass closes over"))
    return out


def _check_structure(tree: ast.Module, path: str) -> List[Violation]:
    out: List[Violation] = []
    defs = _module_defs(tree)
    primals, defvjps = _custom_vjp_registrations(tree)

    for fn_node, site in primals:
        if not (isinstance(fn_node, ast.Name) and fn_node.id in defs):
            name = dotted_name(fn_node) or ast.dump(fn_node)[:40]
            out.append(Violation(
                RULE, path, getattr(site, "lineno", 1),
                f"custom_vjp primal `{name}` is not a module-level "
                f"function — nested defs can close over live tracers"))

    for obj, (fwd, bwd, call) in defvjps.items():
        for role, fn_node in (("fwd", fwd), ("bwd", bwd)):
            if not isinstance(fn_node, ast.Name):
                out.append(Violation(
                    RULE, path, call.lineno,
                    f"`{obj}.defvjp` {role} must be a module-level named "
                    f"function (got a non-name expression) — lambdas/"
                    f"closures can capture tracers"))
                continue
            if fn_node.id not in defs:
                out.append(Violation(
                    RULE, path, call.lineno,
                    f"`{obj}.defvjp` {role} `{fn_node.id}` is not defined "
                    f"at module level in this file — closure-captured "
                    f"state cannot be audited"))
        if isinstance(fwd, ast.Name) and fwd.id in defs:
            out.extend(_check_fwd_returns(defs[fwd.id], path))
    return out


def _is_counter_read(node: ast.AST, raw: Set[str]) -> bool:
    """`<name>.n_accepted`-style read where <name> holds a raw (un-detached)
    integrate/custom_vjp result."""
    if isinstance(node, ast.Attribute) and node.attr in _COUNTER_ATTRS:
        base = node.value
        while isinstance(base, ast.Attribute):
            base = base.value
        return isinstance(base, ast.Name) and base.id in raw
    return False


def _check_counter_arith(tree: ast.Module, path: str) -> List[Violation]:
    out: List[Violation] = []
    primals, defvjps = _custom_vjp_registrations(tree)
    exempt = set()
    for fn_node, _ in primals:
        if isinstance(fn_node, ast.Name):
            exempt.add(fn_node.id)
    for fwd, bwd, _ in defvjps.values():
        for fn_node in (fwd, bwd):
            if isinstance(fn_node, ast.Name):
                exempt.add(fn_node.id)

    for fdef in [n for n in ast.walk(tree)
                 if isinstance(n, ast.FunctionDef)]:
        if fdef.name in exempt or fdef.name.endswith("_fwd") or \
                fdef.name.endswith("_bwd"):
            continue  # the primal owns counter arithmetic by design
        # Build a line-ordered event log so `rstats = _detached(rstats)`
        # launders only the uses BELOW it.
        events = []
        for node in own_nodes(fdef):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call):
                d = dotted_name(node.value.func) or ""
                names = [n for t in node.targets for n in target_names(t)]
                if "integrate" in d.split(".")[-1] or d in exempt:
                    events.append((node.lineno, "add", names))
                elif d.split(".")[-1] in _LAUNDER_FUNCS:
                    events.append((node.lineno, "remove", names))
        events.sort()
        if not any(kind == "add" for _, kind, _ in events):
            continue

        def raw_at(lineno: int) -> Set[str]:
            raw: Set[str] = set()
            for ln, kind, names in events:
                if ln >= lineno:
                    break
                (raw.update if kind == "add" else
                 raw.difference_update)(names)
            return raw

        for node in own_nodes(fdef):
            raw = raw_at(getattr(node, "lineno", 0))
            hit = None
            if isinstance(node, ast.BinOp) and \
                    isinstance(node.op, _ARITH_OPS):
                for side in (node.left, node.right):
                    if _is_counter_read(side, raw):
                        hit = side
            elif isinstance(node, ast.AugAssign) and \
                    isinstance(node.op, _ARITH_OPS):
                if _is_counter_read(node.value, raw):
                    hit = node.value
            if hit is not None:
                out.append(Violation(
                    RULE, path, node.lineno,
                    f"arithmetic on integer Stats counter "
                    f"`.{hit.attr}` outside the custom_vjp primal in "
                    f"`{fdef.name}` — integer outputs carry instantiated "
                    f"float0 tangents under vmap-of-grad; detach via "
                    f"`_detached`/`stop_gradient` first (PR-2 incident)"))
    return out


def check(tree: ast.AST, src: str, path: str, ctx) -> List[Violation]:
    out = _check_structure(tree, path)
    out.extend(_check_counter_arith(tree, path))
    return out
