"""R005 — signed-buffer discipline in replay paths.

The gradient methods replay a recorded, *signed* ``(t_i, h_i)`` step
buffer: reverse-time solves record negative steps, and the backward
sweeps reconstruct states by stepping ``-h_i`` from the endpoint. An
``abs(h)`` (or ``jnp.abs``/``lax.abs``) inside a backward/replay function
is an unsigned-step assumption — it reproduces forward-time results and
silently corrupts every reverse-time gradient (PR-4's time-as-an-axis
work made both directions first-class).

The rule flags any `abs` call inside functions matching the replay
naming convention (``*_bwd``, ``reverse_*``, ``*_replay*``). Forward
drivers may use ``abs`` freely for error control and span bookkeeping —
those comparisons are direction-agnostic by design.
"""
from __future__ import annotations

import ast
import re
from typing import List

from .common import Violation, dotted_name, iter_functions, own_nodes

RULE = "R005"

_REPLAY_NAME = re.compile(r"(_bwd$)|(^reverse_)|(_replay)")
_ABS_CALLS = {"abs", "jnp.abs", "lax.abs", "jax.numpy.abs", "jax.lax.abs",
              "np.abs", "numpy.abs"}


def check(tree: ast.AST, src: str, path: str, ctx) -> List[Violation]:
    out: List[Violation] = []
    for fdef, chain in iter_functions(tree):
        names = [f.name for f in chain] + [fdef.name]
        if not any(_REPLAY_NAME.search(n) for n in names):
            continue
        for node in own_nodes(fdef):
            if isinstance(node, ast.Call):
                d = dotted_name(node.func)
                if d in _ABS_CALLS:
                    out.append(Violation(
                        RULE, path, node.lineno,
                        f"`{d}` inside replay path `{fdef.name}` — the "
                        f"(t_i, h_i) record is signed; stripping the sign "
                        f"breaks reverse-time replay (keep the step's "
                        f"direction, compare magnitudes on the forward "
                        f"side only)"))
    return out
