"""R003 — Pallas kernel contracts.

* **R003a** every ``pl.pallas_call`` passes an explicit ``grid=`` — an
  implicit grid means the tiling was never thought about.
* **R003b** ``BlockSpec`` block shapes are static Python ints (untraced
  expressions) — a traced block dim fails at lowering on device even when
  interpret mode shrugs.
* **R003c** every ``X // Y`` inside a ``grid=`` expression is
  *divisibility-guarded* in the same function: an ``assert X % Y == 0``,
  or ``X``/its definition padded via ``% Y``. An unguarded floor division
  silently drops the remainder rows — the grid covers ``(X // Y) * Y``
  elements and the tail of the output buffer is never written.
* **R003d** kernel-ref writes cast explicitly: ``ref[...] = expr`` must
  end in ``.astype(ref.dtype)`` (f32 accumulate, storage-dtype write —
  the TPU contract; an implicit cast hides precision decisions).
* **R003e** every public op in ``kernels/*/ops.py`` either carries a
  ``jax.custom_vjp`` or appears in
  :data:`repro.kernels.registry.NO_REVERSE_RULE` with a real
  justification — forward-only kernels must be forward-only on purpose,
  and ``GradientMethod`` validation reads that registry. "Carries"
  covers both shapes the codebase uses: the op itself wrapped via
  ``custom_vjp(op)``, or a public keyword-facade delegating to an
  internal ``custom_vjp`` owner (recognized by its ``X.defvjp(...)``
  registration — a public def that *calls* ``X`` inherits X's rule).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from .common import (Violation, dotted_name, expr_tainted, function_taint,
                     iter_functions, own_nodes)

RULE = "R003"


# -- helpers ---------------------------------------------------------------

def _calls_named(fdef, suffix: str):
    for node in own_nodes(fdef):
        if isinstance(node, ast.Call):
            d = dotted_name(node.func)
            if d and (d == suffix or d.endswith("." + suffix)):
                yield node


def _def_exprs(fdef) -> Dict[str, ast.AST]:
    """name -> the expression last assigned to it (single-target only)."""
    defs: Dict[str, ast.AST] = {}
    for node in own_nodes(fdef):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            defs[node.targets[0].id] = node.value
    return defs


def _mod_guard_present(expr: Optional[ast.AST], divisor: str) -> bool:
    """Does `expr` contain `<anything> % divisor` (a padding pattern)?"""
    if expr is None:
        return False
    for node in ast.walk(expr):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mod) and \
                isinstance(node.right, ast.Name) and node.right.id == divisor:
            return True
    return False


def _assert_guards(fdef) -> Set[tuple]:
    """(dividend, divisor) pairs guarded by `assert X % Y == 0`-style
    asserts anywhere in the function."""
    out: Set[tuple] = set()
    for node in own_nodes(fdef):
        if not isinstance(node, ast.Assert):
            continue
        for sub in ast.walk(node.test):
            if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod) \
                    and isinstance(sub.left, ast.Name) \
                    and isinstance(sub.right, ast.Name):
                out.add((sub.left.id, sub.right.id))
    return out


# -- sub-checks ------------------------------------------------------------

def _check_pallas_calls(tree, path: str) -> List[Violation]:
    out: List[Violation] = []
    for fdef, chain in iter_functions(tree):
        env = set()
        for encl in chain:
            env |= function_taint(encl, env)
        tainted = function_taint(fdef, env)
        guards = _assert_guards(fdef)
        defs = _def_exprs(fdef)

        for call in _calls_named(fdef, "pallas_call"):
            grid_kw = next((kw.value for kw in call.keywords
                            if kw.arg == "grid"), None)
            if isinstance(grid_kw, ast.Name):     # grid=g: resolve g's def
                grid_kw = defs.get(grid_kw.id, grid_kw)
            if grid_kw is None:
                out.append(Violation(
                    RULE, path, call.lineno,
                    "pallas_call without an explicit grid= — state the "
                    "tiling (grid=(1,) if the kernel really is one "
                    "program)"))
                continue
            for node in ast.walk(grid_kw):
                if not (isinstance(node, ast.BinOp) and
                        isinstance(node.op, ast.FloorDiv) and
                        isinstance(node.left, ast.Name) and
                        isinstance(node.right, ast.Name)):
                    continue
                x, y = node.left.id, node.right.id
                guarded = (x, y) in guards or \
                    _mod_guard_present(defs.get(x), y)
                if not guarded:
                    # one level of indirection: X = A + pad, pad = (-A) % Y
                    src_expr = defs.get(x)
                    for ref in ast.walk(src_expr) if src_expr is not None \
                            else ():
                        if isinstance(ref, ast.Name) and \
                                _mod_guard_present(defs.get(ref.id), y):
                            guarded = True
                            break
                if not guarded:
                    out.append(Violation(
                        RULE, path, node.lineno,
                        f"grid uses `{x} // {y}` without a divisibility "
                        f"guard — when {y} does not divide {x} the tail "
                        f"rows are silently never written; add `assert "
                        f"{x} % {y} == 0` or pad {x} to a multiple"))

        for call in _calls_named(fdef, "BlockSpec"):
            if not call.args or not isinstance(call.args[0], ast.Tuple):
                continue
            for elt in call.args[0].elts:
                if expr_tainted(elt, tainted):
                    out.append(Violation(
                        RULE, path, call.lineno,
                        "BlockSpec block shape contains a traced value — "
                        "block dims must be static Python ints"))
    return out


def _check_ref_writes(tree, path: str) -> List[Violation]:
    out: List[Violation] = []
    for fdef, _ in iter_functions(tree):
        for node in own_nodes(fdef):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Subscript)):
                continue
            base = node.targets[0].value
            if not (isinstance(base, ast.Name) and base.id.endswith("_ref")):
                continue
            val = node.value
            ok = (isinstance(val, ast.Call) and
                  isinstance(val.func, ast.Attribute) and
                  val.func.attr == "astype" and len(val.args) == 1 and
                  isinstance(val.args[0], ast.Attribute) and
                  val.args[0].attr == "dtype")
            if not ok:
                out.append(Violation(
                    RULE, path, node.lineno,
                    f"write to `{base.id}` without an explicit "
                    f"`.astype({base.id}.dtype)` cast — accumulate in "
                    f"f32, cast once at the storage write"))
    return out


def _delegates_to_vjp(fdef, owners: Set[str]) -> bool:
    """Does this public def call one of the custom_vjp owners (the
    keyword-facade pattern: `def op(...): return _op(...)` with
    `_op.defvjp(...)` registered at module level)?"""
    for node in ast.walk(fdef):
        if isinstance(node, ast.Call):
            d = dotted_name(node.func)
            if d in owners:
                return True
    return False


def _check_ops_allowlist(tree, path: str, ctx) -> List[Violation]:
    """kernels/<pkg>/ops.py: public defs need a VJP or an allowlist entry."""
    out: List[Violation] = []
    pkg = ctx.get("kernel_package")
    allow = ctx.get("no_reverse_rule", {})
    has_vjp: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            d = dotted_name(node.func) or ""
            if d.endswith("custom_vjp") and node.args:
                tgt = dotted_name(node.args[0])
                if tgt:
                    has_vjp.add(tgt)
            elif d.endswith(".defvjp"):
                # `X.defvjp(fwd, bwd)` marks X as a completed custom_vjp
                # owner regardless of how the custom_vjp itself was
                # attached (direct call or functools.partial decorator).
                has_vjp.add(d[:-len(".defvjp")])
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef) or \
                node.name.startswith("_"):
            continue
        key = f"{pkg}.{node.name}"
        if node.name in has_vjp or _delegates_to_vjp(node, has_vjp):
            continue
        reason = allow.get(key)
        if reason is None:
            out.append(Violation(
                RULE, path, node.lineno,
                f"kernel op `{node.name}` defines no VJP and is not in "
                f"NO_REVERSE_RULE — register `{key}` with a justification "
                f"(repro/kernels/registry.py) or add a custom_vjp"))
        elif not isinstance(reason, str) or len(reason.strip()) < 20:
            out.append(Violation(
                RULE, path, node.lineno,
                f"NO_REVERSE_RULE entry `{key}` has a placeholder "
                f"justification — explain WHY forward-only is sound"))
    return out


def check(tree: ast.AST, src: str, path: str, ctx) -> List[Violation]:
    out = _check_pallas_calls(tree, path)
    out.extend(_check_ref_writes(tree, path))
    if ctx.get("kernel_package") and path.endswith("ops.py"):
        out.extend(_check_ops_allowlist(tree, path, ctx))
    return out
