"""R001 — no Python ``if``/``while`` on traced values.

A Python branch on a ``jnp``/``lax`` value either crashes at trace time
(``TracerBoolConversionError``) or, worse, silently bakes one side into
the jaxpr when the value happens to be concrete at trace time and traced
later (the classic "works in the test, wrong under vmap/jit" bug).
Control flow on traced values belongs in ``lax.cond`` /
``lax.while_loop`` / ``jnp.where``.

Static branches are fine and common (config flags, ``isinstance``,
``.ndim``/``.shape`` metadata) — the taint model in
:mod:`repro.analysis.rules.common` exempts them.
"""
from __future__ import annotations

import ast
from typing import List

from .common import (Violation, expr_tainted, function_taint, iter_functions,
                     own_nodes)

RULE = "R001"


def check(tree: ast.AST, src: str, path: str, ctx) -> List[Violation]:
    out: List[Violation] = []
    for fdef, chain in iter_functions(tree):
        env = set()
        for encl in chain:
            env |= function_taint(encl, env)
        tainted = function_taint(fdef, env)
        for node in own_nodes(fdef):
            if isinstance(node, (ast.If, ast.While)) and \
                    expr_tainted(node.test, tainted):
                kw = "if" if isinstance(node, ast.If) else "while"
                out.append(Violation(
                    RULE, path, node.lineno,
                    f"Python `{kw}` on a traced value in "
                    f"`{fdef.name}` — use lax.cond/lax.while_loop/"
                    f"jnp.where (or launder via .shape/.ndim metadata "
                    f"if the predicate is actually static)"))
    return out
