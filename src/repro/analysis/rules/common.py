"""Shared odelint machinery: violations, suppressions, and the local
taint analysis every value-sensitive rule builds on.

The taint model is deliberately local and name-based (no interprocedural
propagation): a value is *traced* ("tainted") when it is constructed by a
``jnp.``/``lax.``/``jax.numpy.``/``jax.lax.`` call inside the current
function, or derived from such a value. Function parameters are assumed
untraced — the rules catch branches on *locally constructed* array values,
which is exactly the class of bug that survives review (a parameter-level
branch is visible in the signature). Laundering escapes taint:

* ``isinstance``/``int``/``float``/``bool``/``len`` calls,
* anything rooted at ``np.``/``numpy.``/``math.``,
* array *metadata* attributes (``.shape``, ``.ndim``, ``.dtype``,
  ``.size``, ``.aval``, ``.weak_type``, ``.sharding``) — static under jit.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Set


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    message: str

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


# --------------------------------------------------------------------------
# Suppressions: "# odelint: disable=R001 -- <why>". The justification text
# after " -- " is mandatory; a bare disable does NOT suppress and is itself
# reported (R000) so the escape hatch stays auditable.
# --------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(
    r"#\s*odelint:\s*disable=([A-Z0-9, ]+?)\s*(?:--\s*(\S.*))?$")


def parse_suppressions(src: str, path: str):
    """-> ({lineno: {rule ids}}, [R000 violations for reason-less disables])."""
    table: Dict[int, Set[str]] = {}
    bad: List[Violation] = []
    for i, line in enumerate(src.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        if not m.group(2):
            bad.append(Violation(
                "R000", path, i,
                "odelint suppression without a justification — write "
                "'# odelint: disable=RXXX -- <reason>'"))
            continue
        table.setdefault(i, set()).update(rules)
    return table, bad


def apply_suppressions(violations: Iterable[Violation],
                       table: Dict[int, Set[str]]) -> List[Violation]:
    out = []
    for v in violations:
        suppressed = table.get(v.line, set())
        if v.rule in suppressed or "ALL" in suppressed:
            continue
        out.append(v)
    return out


# --------------------------------------------------------------------------
# Name helpers
# --------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """'jnp.linalg.norm' for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def target_names(target: ast.AST) -> List[str]:
    """All plain names bound by an assignment target (tuples unpacked)."""
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out = []
        for elt in target.elts:
            out.extend(target_names(elt))
        return out
    if isinstance(target, ast.Starred):
        return target_names(target.value)
    return []


# --------------------------------------------------------------------------
# Taint analysis
# --------------------------------------------------------------------------

TAINT_CALL_PREFIXES = ("jnp.", "lax.", "jax.numpy.", "jax.lax.")
LAUNDER_PREFIXES = ("np.", "numpy.", "math.", "os.", "dataclasses.")
LAUNDER_CALLS = {
    "int", "float", "bool", "str", "len", "isinstance", "issubclass",
    "type", "repr", "hash", "id", "callable", "getattr", "hasattr",
}
METADATA_ATTRS = {
    "shape", "ndim", "dtype", "size", "aval", "weak_type", "sharding",
    "itemsize", "nbytes",
}


def expr_tainted(node: ast.AST, tainted: Set[str]) -> bool:
    """Is this expression a traced (abstract under jit) value?"""
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Attribute):
        if node.attr in METADATA_ATTRS:
            return False                      # static metadata read
        return expr_tainted(node.value, tainted)
    if isinstance(node, ast.Call):
        d = dotted_name(node.func)
        if d is not None:
            if d in LAUNDER_CALLS or d.startswith(LAUNDER_PREFIXES):
                return False
            if d.startswith(TAINT_CALL_PREFIXES):
                return True
        if isinstance(node.func, ast.Attribute):
            # method call: x.astype(...) is traced iff x is
            if expr_tainted(node.func.value, tainted):
                return True
        return any(expr_tainted(a, tainted) for a in node.args) or any(
            expr_tainted(kw.value, tainted) for kw in node.keywords)
    if isinstance(node, ast.Lambda):
        return False
    if isinstance(node, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
        return False                          # `x is None`: structural, static
    if isinstance(node, (ast.Constant, ast.FunctionDef,
                         ast.AsyncFunctionDef)):
        return False
    return any(expr_tainted(c, tainted) for c in ast.iter_child_nodes(node))


def _collect_bindings(stmts, tainted: Set[str]) -> None:
    """One forward pass propagating taint through assignments/for-targets
    of a statement list (descends into control flow, not nested defs)."""
    for node in stmts:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        if isinstance(node, ast.Assign):
            if expr_tainted(node.value, tainted):
                for t in node.targets:
                    tainted.update(target_names(t))
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            if expr_tainted(node.value, tainted):
                tainted.update(target_names(node.target))
        elif isinstance(node, ast.AugAssign):
            if expr_tainted(node.value, tainted):
                tainted.update(target_names(node.target))
        elif isinstance(node, ast.For):
            if expr_tainted(node.iter, tainted):
                tainted.update(target_names(node.target))
        # walrus targets inside any expression of this statement
        for sub in ast.walk(node):
            if isinstance(sub, ast.NamedExpr):
                if expr_tainted(sub.value, tainted):
                    tainted.update(target_names(sub.target))
        for field in ("body", "orelse", "finalbody"):
            _collect_bindings(getattr(node, field, []) or [], tainted)
        for handler in getattr(node, "handlers", []) or []:
            _collect_bindings(handler.body, tainted)


def function_taint(fdef, inherited: Optional[Set[str]] = None) -> Set[str]:
    """Tainted local names of one function. Two passes so loop-carried
    taint (``x`` tainted late, used early in the loop) converges."""
    tainted: Set[str] = set(inherited or ())
    for _ in range(2):
        _collect_bindings(fdef.body, tainted)
    return tainted


def iter_functions(tree: ast.AST):
    """Yield (fdef, enclosing_chain) for every def, outermost first."""
    def visit(node, chain):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield child, chain
                yield from visit(child, chain + (child,))
            else:
                yield from visit(child, chain)
    yield from visit(tree, ())


def own_nodes(fdef):
    """Walk a function body WITHOUT descending into nested defs/lambdas."""
    stack = list(fdef.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))
