"""Device-free trace audit of the solve() configuration matrix.

Two instruments, neither of which touches a device:

* **Shape audit** — ``jax.eval_shape`` over the Solver x GradientMethod x
  StepController x Batching x direction matrix, asserting every
  ``Solution``'s output shapes/dtypes/weak-types against golden specs
  computed analytically from the inputs (trajectory ``(T, ...)``,
  batch-first ``(B, T, ...)``, f32 states, int32 counters). Gradient
  combos run ``eval_shape(grad(...))`` — abstract reverse-mode catches
  residual/shape bugs in every custom_vjp without executing a step.
  Known-invalid pairings (MALI x RungeKutta, ACA x ALF, adaptive Naive x
  estimate-free RK4) are asserted to raise their validation errors.

* **Retrace audit** — ``jax.jit(f).trace()`` is cached like execution is:
  tracing the same static config twice must run the Python body exactly
  once. Each case constructs FRESH (equal-valued) solver/controller/
  gradient/SaveAt/batching objects per call, which is exactly how user
  code behaves across training steps; an identity-based ``__hash__`` on
  any static argument shows up here as a second trace. (This caught
  ``SaveAt``/``Event``'s identity hashing — fixed in interface.py.)

A third sweep (:func:`run_serve_audit`) covers the serving layer's
chunked re-dispatch entry point: ``chunk_transition`` must be
spec-preserving (eval_shape golden check) and one trace must serve every
round (fresh equal-valued solver/config objects — the serve configs carry
the same value-hash contract as SaveAt).

A fourth sweep (:func:`run_train_audit`, PR 9) covers the training
subsystem: ``train_step`` must be spec-preserving on (params, opt_state)
so checkpoint restore templates match the live state, the frozen configs
that ride as jit statics must value-hash, and one trace must serve a run
rebuilt from fresh equal-valued configs (the checkpoint-resume path).

A fifth sweep (:func:`run_cnf_audit`, PR 10) covers the CNF subsystem:
``CNF.log_prob`` shapes across the trace-estimator x gradient-method
matrix, abstract reverse mode through params AND the integration bound
(``diff_bounds=True`` exercises the ts-cotangent slot of every
custom_vjp), the validation errors on unservable pairings, and the
value-hash contract on the frozen flow/estimator statics.

Emits the dict that ``python -m repro.analysis`` merges into
``analysis_report.json``.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

D = 3          # state dim
B = 4          # batch size
T = 5          # observation-grid length
F32 = jnp.float32


def _dynamics():
    def f(params, z, t):
        return jnp.tanh(z @ params["w"]) + t * params["b"]
    return f


def _param_specs():
    return {"w": jax.ShapeDtypeStruct((D, D), F32),
            "b": jax.ShapeDtypeStruct((D,), F32)}


def _method_solver_pairs():
    from repro.core import (ACA, ALF, MALI, Backsolve, Bosh3, Dopri5,
                            HeunEuler, Naive)
    return [
        ("mali/alf", MALI(), ALF()),
        ("mali/alf-eta0.9", MALI(), ALF(eta=0.9)),
        ("mali/alf-pallas", MALI(), ALF(backend="pallas")),
        ("naive/alf", Naive(), ALF()),
        ("naive/heun_euler", Naive(), HeunEuler()),
        ("aca/heun_euler", ACA(), HeunEuler()),
        ("aca/bosh3", ACA(), Bosh3()),
        ("aca/dopri5", ACA(), Dopri5()),
        ("backsolve/dopri5", Backsolve(), Dopri5()),
        ("backsolve/alf", Backsolve(), ALF()),
    ]


def _controllers():
    from repro.core import AdaptiveController, ConstantSteps
    return [("const4", ConstantSteps(4)),
            ("adaptive", AdaptiveController(1e-2, 1e-3, 16))]


def _expect(combo: str, actual, shape, dtype) -> List[str]:
    errs = []
    if tuple(actual.shape) != tuple(shape):
        errs.append(f"{combo}: shape {actual.shape} != golden {shape}")
    if actual.dtype != dtype:
        errs.append(f"{combo}: dtype {actual.dtype} != golden {dtype}")
    if getattr(actual, "weak_type", False):
        errs.append(f"{combo}: output is weakly typed — a Python-scalar "
                    f"promotion leaked into the solve")
    return errs


def run_shape_audit():
    """-> (n_combos, [failure strings])."""
    from repro.core import (ACA, ALF, MALI, Dopri5, Lockstep, Naive,
                            PerSample, SaveAt, solve)

    f = _dynamics()
    p_spec = _param_specs()
    failures: List[str] = []
    combos = 0

    def grid(t0, t1):
        return jnp.linspace(t0, t1, T).astype(F32)

    def case(name, gradient, solver, controller, t0, t1,
             batching: Optional[object]):
        nonlocal combos
        combos += 1
        batched = batching is not None
        z_spec = jax.ShapeDtypeStruct((B, D) if batched else (D,), F32)

        def run(z0, params):
            return solve(f, params, z0, t0, t1, solver=solver,
                         controller=controller, gradient=gradient,
                         saveat=SaveAt(ts=grid(t0, t1)), batching=batching)

        try:
            sol = jax.eval_shape(run, z_spec, p_spec)
        except Exception as e:  # noqa: BLE001 — report, don't abort sweep
            failures.append(f"{name}: eval_shape raised "
                            f"{type(e).__name__}: {e}")
            return
        ys_shape = (B, T, D) if batched else (T, D)
        failures.extend(_expect(name + ".ys", sol.ys, ys_shape, F32))
        failures.extend(_expect(name + ".ts", sol.ts, (T,), F32))
        for counter in ("n_accepted", "n_rejected", "n_fevals"):
            a = getattr(sol.stats, counter)
            if a.dtype != jnp.int32:
                failures.append(f"{name}.stats.{counter}: dtype "
                                f"{a.dtype} != int32")

    for pname, gradient, solver in _method_solver_pairs():
        for cname, controller in _controllers():
            for dname, (t0, t1) in (("fwd", (0.0, 1.0)),
                                    ("rev", (1.0, 0.0))):
                for bname, batching in (("unbatched", None),
                                        ("lockstep", Lockstep())):
                    case(f"{pname}/{cname}/{dname}/{bname}",
                         gradient, solver, controller, t0, t1, batching)
                if controller.adaptive:
                    # PerSample requires adaptive control (warns degenerate
                    # under ConstantSteps, by design).
                    case(f"{pname}/{cname}/{dname}/per_sample",
                         gradient, solver, controller, t0, t1, PerSample())

    # Gradient shapes: abstract reverse-mode through every gradient method.
    from repro.core import (AdaptiveController, Backsolve, ConstantSteps,
                            HeunEuler)
    grad_cases = [
        ("grad/mali/alf", MALI(), ALF(), ConstantSteps(4)),
        ("grad/mali/alf-pallas", MALI(), ALF(backend="pallas"),
         ConstantSteps(4)),
        ("grad/naive/alf", Naive(), ALF(), AdaptiveController(1e-2, 1e-3, 8)),
        ("grad/naive/alf-pallas", Naive(), ALF(backend="pallas"),
         AdaptiveController(1e-2, 1e-3, 8)),
        ("grad/aca/heun_euler", ACA(), HeunEuler(),
         AdaptiveController(1e-2, 1e-3, 8)),
        ("grad/backsolve/dopri5", Backsolve(), Dopri5(), ConstantSteps(4)),
    ]
    for name, gradient, solver, controller in grad_cases:
        for dname, (t0, t1) in (("fwd", (0.0, 1.0)), ("rev", (1.0, 0.0))):
            combos += 1

            def loss(params, z0):
                sol = solve(f, params, z0, t0, t1, solver=solver,
                            controller=controller, gradient=gradient,
                            saveat=SaveAt(ts=grid(t0, t1)))
                return jnp.sum(sol.ys)

            try:
                g = jax.eval_shape(jax.grad(loss), p_spec,
                                   jax.ShapeDtypeStruct((D,), F32))
            except Exception as e:  # noqa: BLE001
                failures.append(f"{name}/{dname}: eval_shape(grad) raised "
                                f"{type(e).__name__}: {e}")
                continue
            for key, spec in _param_specs().items():
                failures.extend(_expect(f"{name}/{dname}.grad[{key}]",
                                        g[key], spec.shape, spec.dtype))

    # Invalid pairings must be REJECTED at validation, not traced.
    # (Naive x Pallas ALF is no longer here: the fused step ops carry
    # custom_vjp rules now, so direct backprop through the launch is valid
    # and audited in the grad cases above.)
    from repro.core import Rk4
    invalid = [
        ("invalid/mali/dopri5", MALI(), Dopri5(), "ALF solver only"),
        ("invalid/aca/alf", ACA(), ALF(), "Runge-Kutta"),
        ("invalid/naive-adaptive/rk4", Naive(), Rk4(), "error estimate"),
    ]
    for name, gradient, solver, needle in invalid:
        combos += 1
        try:
            jax.eval_shape(
                lambda z0, params: solve(f, params, z0, 0.0, 1.0,
                                         solver=solver, gradient=gradient),
                jax.ShapeDtypeStruct((D,), F32), p_spec)
            failures.append(f"{name}: expected ValueError, traced fine")
        except ValueError as e:
            if needle not in str(e):
                failures.append(f"{name}: error lacks {needle!r}: {e}")
        except Exception as e:  # noqa: BLE001
            failures.append(f"{name}: expected ValueError, got "
                            f"{type(e).__name__}: {e}")
    return combos, failures


# --------------------------------------------------------------------------
# Serve audit (PR 8): the chunked re-dispatch entry point
# --------------------------------------------------------------------------

def _serve_dynamics(params, z, t):
    # module-level for the same reason as _event_cond: jit hashes the
    # vector field by identity, and the engine passes one stable object.
    del params, t
    return -z


def _serve_slot_specs(b: int):
    """Abstract SlotBatch for ALF state (z, v) at batch width ``b``."""
    from repro.serve import SlotBatch
    f32 = jnp.float32
    vec = jax.ShapeDtypeStruct((b, D), F32)
    row = jax.ShapeDtypeStruct((b,), f32)
    return SlotBatch(
        state=(vec, vec), t=row, t1=row, h=row, rtol=row, atol=row,
        budget=jax.ShapeDtypeStruct((b,), jnp.int32),
        active=jax.ShapeDtypeStruct((b,), jnp.bool_),
        reached=jax.ShapeDtypeStruct((b,), jnp.bool_),
        n_trials=jax.ShapeDtypeStruct((b,), jnp.int32),
        n_accepted=jax.ShapeDtypeStruct((b,), jnp.int32))


def run_serve_audit():
    """Audit the serve engine's dispatch path without touching a device.

    Shape side: ``chunk_transition`` must be SPEC-PRESERVING — the output
    SlotBatch has exactly the input's shapes/dtypes, which is what lets
    the engine re-dispatch the same compiled executable every round
    without reallocation. Config side: the frozen request/engine config
    dataclasses must be value-hashed (the PR 6 lesson — identity-hashed
    statics retrace per fresh instance). Returns
    (n_combos, [shape failures], {retrace-case: count}).
    """
    from repro.core import ALF
    from repro.serve import EngineConfig, RequestConfig, chunk_transition

    failures: List[str] = []
    combos = 0

    for b, chunk_steps in ((1, 1), (4, 8), (8, 32)):
        combos += 1
        name = f"serve:chunk_transition/b{b}/c{chunk_steps}"
        slots = _serve_slot_specs(b)
        try:
            out = jax.eval_shape(
                lambda p, s, c=chunk_steps: chunk_transition(
                    p, s, f=_serve_dynamics, solver=ALF(eta=0.9),
                    chunk_steps=c), {}, slots)
        except Exception as e:  # noqa: BLE001 — report, don't abort sweep
            failures.append(f"{name}: eval_shape raised "
                            f"{type(e).__name__}: {e}")
            continue
        ins = jax.tree_util.tree_leaves_with_path(slots)
        outs = jax.tree_util.tree_leaves_with_path(out)
        for (path_i, leaf_i), (path_o, leaf_o) in zip(ins, outs):
            where = jax.tree_util.keystr(path_i)
            if path_i != path_o:
                failures.append(f"{name}: output tree path {path_o} != "
                                f"input {path_i}")
            elif (tuple(leaf_o.shape) != tuple(leaf_i.shape)
                  or leaf_o.dtype != leaf_i.dtype):
                failures.append(
                    f"{name}{where}: {leaf_o.shape}/{leaf_o.dtype} != "
                    f"input spec {leaf_i.shape}/{leaf_i.dtype} — "
                    "dispatch is no longer shape-preserving")

    # Value-hash contract on the frozen configs that ride as jit statics
    # (dense-lane solves, cache keys, the dispatcher's solver argument).
    config_cases = [
        ("serve:RequestConfig",
         lambda: RequestConfig(t1=2.0, rtol=1e-4, atol=1e-5,
                               max_steps=64, dense=True)),
        ("serve:EngineConfig",
         lambda: EngineConfig(slots=4, chunk_steps=8, solver=ALF(eta=0.9))),
    ]
    for name, fresh in config_cases:
        combos += 1
        a, b2 = fresh(), fresh()
        if a != b2 or hash(a) != hash(b2):
            failures.append(
                f"{name}: fresh equal-valued instances compare/hash "
                "unequal — statics keyed on this retrace every round")

    # Retrace count through a dispatch-shaped jit boundary with a FRESH
    # equal-valued solver per trace (how the engine builds its config).
    traces = {"n": 0}

    def body(params, slots, *, solver, chunk_steps):
        traces["n"] += 1
        return chunk_transition(params, slots, f=_serve_dynamics,
                                solver=solver, chunk_steps=chunk_steps)

    jitted = jax.jit(body, static_argnames=("solver", "chunk_steps"))
    slots = jax.tree_util.tree_map(
        lambda spec: jnp.zeros(spec.shape, spec.dtype), _serve_slot_specs(4))
    for _ in range(2):
        jitted.trace({}, slots, solver=ALF(eta=0.9), chunk_steps=8)
    return combos, failures, {"serve:dispatch/alf-eta0.9": traces["n"]}


# --------------------------------------------------------------------------
# Train audit (PR 9): the training subsystem's jit boundary
# --------------------------------------------------------------------------

def run_train_audit():
    """Audit the train step without touching a device.

    Shape side: ``repro.train.loop.train_step`` must be SPEC-PRESERVING on
    (params, opt_state) — the output leaves carry exactly the input's tree
    paths/shapes/dtypes. That property is what makes (a) the jitted step
    re-dispatchable without reallocation and (b) the checkpoint restore
    template (``state_tree``) structurally identical to the live state.
    Config side: the frozen configs that ride as jit statics
    (ModelConfig / OptimizerConfig / TrainerConfig) must hash by VALUE, so
    a run rebuilt from a checkpoint manifest (fresh, equal-valued
    instances) reuses the original trace. Returns
    (n_combos, [failures], {retrace-case: count}).
    """
    from repro.configs import smoke_config
    from repro.core.ode_block import OdeSettings
    from repro.launch.specs import param_specs
    from repro.optim.optimizer import OptimizerConfig, init_opt_state
    from repro.train import TrainerConfig
    from repro.train.loop import train_step

    failures: List[str] = []
    combos = 0
    bt, st = 2, 8

    def fresh_cfg():
        return smoke_config("qwen3-1.7b",
                            OdeSettings(mode="per_block", method="mali",
                                        solver="alf", n_steps=2))

    def fresh_opt():
        return OptimizerConfig(total_steps=10, warmup_steps=2)

    cfg, opt_cfg = fresh_cfg(), fresh_opt()
    p_spec = param_specs(cfg)
    o_spec = jax.eval_shape(lambda p: init_opt_state(opt_cfg, p), p_spec)
    b_spec = {"tokens": jax.ShapeDtypeStruct((bt, st), jnp.int32),
              "labels": jax.ShapeDtypeStruct((bt, st), jnp.int32)}

    combos += 1
    name = "train:step/mali-smoke"
    try:
        p2, o2, _, metrics = jax.eval_shape(
            lambda p, o, b: train_step(p, o, None, b, cfg=cfg,
                                       opt_cfg=opt_cfg), p_spec, o_spec,
            b_spec)
    except Exception as e:  # noqa: BLE001 — report, don't abort sweep
        failures.append(f"{name}: eval_shape raised {type(e).__name__}: {e}")
    else:
        for tag, got, want in (("params", p2, p_spec), ("opt", o2, o_spec)):
            ins = jax.tree_util.tree_leaves_with_path(want)
            outs = jax.tree_util.tree_leaves_with_path(got)
            for (path_i, leaf_i), (path_o, leaf_o) in zip(ins, outs):
                where = jax.tree_util.keystr(path_i)
                if path_i != path_o:
                    failures.append(f"{name}.{tag}: output tree path "
                                    f"{path_o} != input {path_i}")
                elif (tuple(leaf_o.shape) != tuple(leaf_i.shape)
                      or leaf_o.dtype != leaf_i.dtype):
                    failures.append(
                        f"{name}.{tag}{where}: {leaf_o.shape}/{leaf_o.dtype}"
                        f" != input spec {leaf_i.shape}/{leaf_i.dtype} — "
                        "the step is no longer spec-preserving")
        for key in ("loss", "lr", "grad_norm", "ode_accepted",
                    "ode_rejected", "ode_fevals"):
            if key not in metrics:
                failures.append(f"{name}: metrics lacks {key!r}")
        for key in ("ode_accepted", "ode_rejected", "ode_fevals"):
            if key in metrics and metrics[key].dtype != jnp.int32:
                failures.append(f"{name}: metrics[{key!r}] dtype "
                                f"{metrics[key].dtype} != int32")

    # Value-hash contract on the frozen configs that ride as jit statics.
    for cname, fresh in (("train:ModelConfig", fresh_cfg),
                         ("train:OptimizerConfig", fresh_opt),
                         ("train:TrainerConfig",
                          lambda: TrainerConfig(steps=10))):
        combos += 1
        a, b2 = fresh(), fresh()
        if a != b2 or hash(a) != hash(b2):
            failures.append(
                f"{cname}: fresh equal-valued instances compare/hash "
                "unequal — statics keyed on this retrace every step")

    # Retrace count with FRESH equal-valued configs per trace (how a
    # checkpoint-restored run rebuilds its statics).
    traces = {"n": 0}

    def body(p, o, b, *, cfg, opt_cfg):
        traces["n"] += 1
        return train_step(p, o, None, b, cfg=cfg, opt_cfg=opt_cfg)

    jitted = jax.jit(body, static_argnames=("cfg", "opt_cfg"))
    zeros = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), (p_spec, o_spec, b_spec))
    for _ in range(2):
        jitted.trace(*zeros, cfg=fresh_cfg(), opt_cfg=fresh_opt())
    return combos, failures, {"train:step/mali-smoke": traces["n"]}


# --------------------------------------------------------------------------
# CNF audit (PR 10): augmented solves + grad-through-bounds
# --------------------------------------------------------------------------

def _cnf_vfield(params, z, t):
    # module-level on purpose: CNF is a frozen dataclass that rides as a
    # jit static, and dataclass equality compares ``vfield`` by identity —
    # a fresh lambda per instance would retrace (correctly).
    return jnp.tanh(z @ params["w"]) + t * params["b"]


def run_cnf_audit():
    """Audit the CNF subsystem without touching a device.

    Shape side: ``CNF.log_prob`` must emit (B,) f32 logp/logdet/kinetic
    for every trace-estimator x gradient-method pairing, and abstract
    reverse mode must go through BOTH params and the integration bound
    ``t1`` (``diff_bounds=True`` threads a ts-cotangent through every
    custom_vjp — ``eval_shape(grad)`` catches a residual/shape mismatch
    in any of them without executing a step). Invalid pairings
    (diff_bounds x steps-trajectory, diff_bounds x Sharded, Hutchinson
    without a key) must raise their validation errors rather than
    silently returning zero bound-gradients. Returns
    (n_combos, [failures], {retrace-case: count}).
    """
    from repro.cnf import CNF, Exact, Hutchinson
    from repro.core import ALF, MALI, ConstantSteps, Naive, SaveAt, solve
    from repro.core.interface import Sharded

    failures: List[str] = []
    combos = 0
    p_spec = _param_specs()
    x_spec = jax.ShapeDtypeStruct((B, D), F32)
    t1_spec = jax.ShapeDtypeStruct((), F32)
    key = jax.random.PRNGKey(0)

    estimators = [("exact", Exact(), False),
                  ("hutchinson", Hutchinson(), True),
                  ("hutchinson_gaussian", Hutchinson(dist="gaussian"), True)]
    methods = [("mali", MALI(), ALF()), ("naive", Naive(), ALF())]

    for est_name, est, needs_key in estimators:
        flow = CNF(_cnf_vfield, dim=D, estimator=est)
        for m_name, gradient, solver in methods:
            name = f"cnf:logprob/{m_name}/{est_name}"

            def logp(p, x, t1, *, fl=flow, sv=solver, gr=gradient,
                     k=(key if needs_key else None)):
                return fl.log_prob(p, x, k, solver=sv,
                                   controller=ConstantSteps(4), gradient=gr,
                                   t1=t1, diff_bounds=True)

            combos += 1
            try:
                res = jax.eval_shape(
                    lambda p, x, fn=logp: fn(p, x, jnp.float32(1.0)),
                    p_spec, x_spec)
            except Exception as e:  # noqa: BLE001 — report, don't abort
                failures.append(f"{name}: eval_shape raised "
                                f"{type(e).__name__}: {e}")
                continue
            for field in ("logp", "logdet", "kinetic"):
                failures.extend(_expect(f"{name}.{field}",
                                        getattr(res, field), (B,), F32))

            combos += 1
            gname = f"cnf:grad/{m_name}/{est_name}"
            try:
                g_p, g_t1 = jax.eval_shape(
                    jax.grad(lambda p, x, t1, fn=logp:
                             -jnp.mean(fn(p, x, t1).logp),
                             argnums=(0, 2)), p_spec, x_spec, t1_spec)
            except Exception as e:  # noqa: BLE001 — report, don't abort
                failures.append(f"{gname}: eval_shape(grad) raised "
                                f"{type(e).__name__}: {e}")
                continue
            failures.extend(_expect(f"{gname}.d_t1", g_t1, (), F32))
            ins = jax.tree_util.tree_leaves_with_path(p_spec)
            outs = jax.tree_util.tree_leaves_with_path(g_p)
            for (path_i, leaf_i), (path_o, leaf_o) in zip(ins, outs):
                where = jax.tree_util.keystr(path_i)
                if path_i != path_o or \
                        tuple(leaf_o.shape) != tuple(leaf_i.shape):
                    failures.append(
                        f"{gname}.d_params{where}: {leaf_o.shape} != "
                        f"param spec {leaf_i.shape}")

    # Validation errors on the pairings diff_bounds cannot serve: no fixed
    # observation grid (steps trajectory), closed-over grid (Sharded), and
    # a Hutchinson solve with no probe key.
    f = _dynamics()
    z_spec = jax.ShapeDtypeStruct((D,), F32)
    invalid = [
        ("cnf:invalid/diff_bounds+steps",
         lambda: jax.eval_shape(
             lambda z, p: solve(f, p, z, 0.0, 1.0,
                                controller=ConstantSteps(4),
                                saveat=SaveAt(steps=True),
                                diff_bounds=True), z_spec, p_spec)),
        ("cnf:invalid/diff_bounds+sharded",
         lambda: jax.eval_shape(
             lambda z, p: solve(f, p, z, 0.0, 1.0,
                                controller=ConstantSteps(4),
                                batching=Sharded(),
                                diff_bounds=True), x_spec, p_spec)),
        ("cnf:invalid/hutchinson-no-key",
         lambda: Hutchinson().init_noise(None, jnp.zeros((D,), F32))),
    ]
    for name, thunk in invalid:
        combos += 1
        try:
            thunk()
        except ValueError:
            pass
        except Exception as e:  # noqa: BLE001 — wrong error class
            failures.append(f"{name}: raised {type(e).__name__} "
                            f"({e}), want ValueError")
        else:
            failures.append(f"{name}: validation silently passed "
                            "(want ValueError)")

    # Retrace contract: CNF/estimator are frozen dataclasses, so a fresh
    # equal-valued flow must reuse the trace (the training-step path).
    traces = {"n": 0}

    def body(p, x, k, *, flow, solver, controller, gradient):
        traces["n"] += 1
        return flow.log_prob(p, x, k, solver=solver, controller=controller,
                             gradient=gradient)

    jitted = jax.jit(body, static_argnames=("flow", "solver", "controller",
                                            "gradient"))
    zeros_p = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), p_spec)
    x = jnp.zeros((B, D), F32)
    for _ in range(2):
        jitted.trace(zeros_p, x, key,
                     flow=CNF(_cnf_vfield, dim=D, estimator=Hutchinson()),
                     solver=ALF(), controller=ConstantSteps(4),
                     gradient=MALI())
    return combos, failures, {"cnf:logprob/mali-hutchinson": traces["n"]}


# --------------------------------------------------------------------------
# Retrace audit
# --------------------------------------------------------------------------

def _event_cond(z, t):
    # module-level on purpose: Event equality hashes cond_fn by identity,
    # so retrace-free reuse requires a stable function object (a fresh
    # lambda per step WOULD retrace, correctly).
    return jnp.sum(z) - 10.0


def retrace_cases():
    """Each case: (name, fresh() -> static kwargs dict). fresh() is called
    once per trace so every static object is a new, equal-valued instance."""
    from repro.core import (ACA, ALF, MALI, AdaptiveController, Backsolve,
                            ConstantSteps, Dopri5, Event, Lockstep, SaveAt)

    def mali_grid():
        return dict(solver=ALF(eta=0.9), controller=ConstantSteps(4),
                    gradient=MALI(),
                    saveat=SaveAt(ts=np.linspace(0.0, 1.0, T)),
                    batching=None, event=None)

    def aca_batched():
        return dict(solver=Dopri5(),
                    controller=AdaptiveController(1e-2, 1e-3, 16),
                    gradient=ACA(), saveat=SaveAt(),
                    batching=Lockstep(), event=None)

    def backsolve_event():
        return dict(solver=Dopri5(),
                    controller=AdaptiveController(1e-2, 1e-3, 16),
                    gradient=Backsolve(), saveat=SaveAt(),
                    batching=None, event=Event(_event_cond, direction=+1))

    return [("mali/alf/const/ts-grid", mali_grid),
            ("aca/dopri5/adaptive/lockstep", aca_batched),
            ("backsolve/dopri5/event", backsolve_event)]


def count_traces(fresh, repeats: int = 2) -> int:
    """Trace a jitted solve `repeats` times with freshly built static
    config objects; return how many times the Python body actually ran
    (1 == the jit cache recognized the configs as equal)."""
    from repro.core import solve

    f = _dynamics()
    traces = {"n": 0}

    def body(z0, params, *, solver, controller, gradient, saveat, batching,
             event):
        traces["n"] += 1
        return solve(f, params, z0, 0.0, 1.0, solver=solver,
                     controller=controller, gradient=gradient,
                     saveat=saveat, batching=batching, event=event)

    jitted = jax.jit(body, static_argnames=(
        "solver", "controller", "gradient", "saveat", "batching", "event"))
    kwargs0 = fresh()
    batched = kwargs0["batching"] is not None
    z0 = jnp.zeros((B, D) if batched else (D,), F32)
    params = {"w": jnp.eye(D, dtype=F32) * 0.1, "b": jnp.zeros((D,), F32)}
    for _ in range(repeats):
        jitted.trace(z0, params, **fresh())   # device-free AOT trace
    return traces["n"]


def run_retrace_audit():
    results = {}
    for name, fresh in retrace_cases():
        results[name] = count_traces(fresh)
    return results


def run_trace_audit() -> dict:
    t0 = time.time()
    combos, failures = run_shape_audit()
    retrace = run_retrace_audit()
    serve_combos, serve_failures, serve_retrace = run_serve_audit()
    combos += serve_combos
    failures += serve_failures
    retrace.update(serve_retrace)
    train_combos, train_failures, train_retrace = run_train_audit()
    combos += train_combos
    failures += train_failures
    retrace.update(train_retrace)
    cnf_combos, cnf_failures, cnf_retrace = run_cnf_audit()
    combos += cnf_combos
    failures += cnf_failures
    retrace.update(cnf_retrace)
    retrace_failures = [f"retrace:{name}: traced {n} times (want 1) — a "
                        f"static config object hashes by identity"
                        for name, n in retrace.items() if n != 1]
    return {
        "combos": combos,
        "shape_failures": failures,
        "retrace_counts": retrace,
        "retrace_failures": retrace_failures,
        "elapsed_s": round(time.time() - t0, 2),
        "ok": not failures and not retrace_failures,
    }
