"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --ckpt-dir /tmp/run1

Wires together the full substrate: config -> mesh -> sharded params/opt ->
synthetic data stream -> jitted train step (microbatching / ZeRO-1 grad
shardings / optional int8-EF compression) -> async checkpointing ->
restart-on-failure (fault_tolerance.run_with_recovery). On this CPU
container use ``--smoke`` (reduced config, 1-device mesh); on a real slice
the same code path runs the full config on ``make_production_mesh()``.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from typing import Optional

import jax
import numpy as np

from repro.checkpoint.checkpoint import AsyncCheckpointer, restore_latest
from repro.configs import get_config, smoke_config
from repro.core.ode_block import OdeSettings
from repro.data.synthetic import DataConfig, make_batch
from repro.distributed.fault_tolerance import run_with_recovery
from repro.distributed.sharding import (batch_shardings, opt_state_shardings,
                                        param_shardings, replicated)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import init_lm
from repro.optim.compression import init_ef_state
from repro.optim.optimizer import (OptimizerConfig, OptState, init_opt_state)

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainConfig:
    arch: str = "qwen3-1.7b"
    smoke: bool = True
    ode: bool = True
    ode_steps: int = 2
    steps: int = 50
    global_batch: int = 8
    seq_len: int = 64
    microbatches: int = 1
    compress: bool = False
    ckpt_dir: str = ""
    ckpt_every: int = 20
    keep: int = 3
    seed: int = 0
    log_every: int = 10
    production_mesh: bool = False   # needs a real multi-chip slice
    multi_pod: bool = False


def build(tc: TrainConfig):
    ode = (OdeSettings(mode="per_block", method="mali", solver="alf",
                       n_steps=tc.ode_steps)
           if tc.ode else OdeSettings(mode="off"))
    cfg = (smoke_config(tc.arch, ode) if tc.smoke
           else get_config(tc.arch, ode))
    mesh = (make_production_mesh(multi_pod=tc.multi_pod)
            if tc.production_mesh else make_host_mesh())
    opt_cfg = OptimizerConfig(total_steps=tc.steps,
                              warmup_steps=max(tc.steps // 20, 1))
    return cfg, mesh, opt_cfg


def train(tc: TrainConfig) -> int:
    cfg, mesh, opt_cfg = build(tc)
    dcfg = DataConfig(seed=tc.seed, global_batch=tc.global_batch,
                      seq_len=tc.seq_len)
    ckpt = AsyncCheckpointer(tc.ckpt_dir, keep=tc.keep) if tc.ckpt_dir else None

    with mesh:
        key = jax.random.PRNGKey(tc.seed)
        params = init_lm(key, cfg)
        opt_state = init_opt_state(opt_cfg, params)
        ef = init_ef_state(params) if tc.compress else None

        p_sh = param_shardings(cfg, mesh, params)
        o_sh = OptState(replicated(mesh),
                        *(opt_state_shardings(cfg, mesh, p_sh, params),) * 3)
        params = jax.device_put(params, p_sh)
        opt_state = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), opt_state,
            OptState(o_sh.step, o_sh.m, o_sh.v, o_sh.master))

        step_fn = jax.jit(make_train_step(
            cfg, opt_cfg, microbatches=tc.microbatches,
            compress=tc.compress, grad_shardings=p_sh))

        def train_loop(resume: Optional[int]) -> int:
            nonlocal params, opt_state, ef
            start = 0
            if resume is not None and ckpt is not None:
                got = restore_latest(tc.ckpt_dir, {"params": params,
                                                   "opt": opt_state})
                if got is not None:
                    start, tree, _meta = got
                    params = jax.device_put(tree["params"], p_sh)
                    opt_state = tree["opt"]
                    log.info("resumed from step %d", start)
            b_sh = None
            t0 = time.time()
            for step in range(start, tc.steps):
                batch = make_batch(cfg, dcfg, step)
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                if b_sh is None:
                    b_sh = batch_shardings(cfg, mesh, batch)
                batch = {k: jax.device_put(v, b_sh[k])
                         for k, v in batch.items()}
                if tc.compress:
                    params, opt_state, ef, metrics = step_fn(
                        params, opt_state, ef, batch)
                else:
                    params, opt_state, metrics = step_fn(
                        params, opt_state, batch)
                if step % tc.log_every == 0 or step == tc.steps - 1:
                    loss = float(metrics["loss"])
                    if not np.isfinite(loss):
                        raise RuntimeError(f"non-finite loss at step {step}")
                    dt = time.time() - t0
                    log.info("step %d loss %.4f lr %.2e gnorm %.2f (%.2fs)",
                             step, loss, float(metrics["lr"]),
                             float(metrics["grad_norm"]), dt)
                    print(f"step={step} loss={loss:.4f}", flush=True)
                if ckpt is not None and (step + 1) % tc.ckpt_every == 0:
                    ckpt.save(step + 1, {"params": params, "opt": opt_state},
                              metadata={"loss": float(metrics["loss"])})
            return tc.steps

        def restore_step() -> Optional[int]:
            if ckpt is None:
                return None
            got = restore_latest(tc.ckpt_dir, {"params": params,
                                               "opt": opt_state})
            return got[0] if got else None

        final, stats = run_with_recovery(train_loop, restore_step,
                                         max_failures=3)
        if ckpt is not None:
            ckpt.save(final, {"params": params, "opt": opt_state},
                      metadata={"final": True})
            ckpt.close()
        log.info("done: step %d (failures=%d)", final, stats.failures)
        return final


def main() -> None:
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ode", default="on", choices=["on", "off"])
    ap.add_argument("--ode-steps", type=int, default=2)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="full assigned config (needs a real TPU slice)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    a = ap.parse_args()
    tc = TrainConfig(arch=a.arch, smoke=a.smoke, ode=a.ode == "on",
                     ode_steps=a.ode_steps, steps=a.steps,
                     global_batch=a.global_batch, seq_len=a.seq_len,
                     microbatches=a.microbatches, compress=a.compress,
                     ckpt_dir=a.ckpt_dir, ckpt_every=a.ckpt_every,
                     production_mesh=a.production_mesh,
                     multi_pod=a.multi_pod)
    train(tc)


if __name__ == "__main__":
    main()
