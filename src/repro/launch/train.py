"""Training CLI — a thin front-end over :class:`repro.train.Trainer`.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --ckpt-dir /tmp/run1

The subsystem behind the flags lives in :mod:`repro.train`: native
``solve()``-based continuous-depth steps, registered TrainLoop drivers,
resumable (config-fingerprinted) checkpoints, fault recovery and
structured telemetry. Killing a run and re-launching with the same flags
resumes from the latest checkpoint and reproduces the uninterrupted loss
trace; re-launching with different integrator flags fails fast with
ConfigMismatchError instead of corrupting the run.

``TrainConfig``/``train`` are kept as thin compatibility delegators for
older callers (same field names, same return).
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

from repro.train import Trainer, TrainerConfig

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainConfig:
    """Legacy flat config; ``train(tc)`` maps it onto TrainerConfig."""
    arch: str = "qwen3-1.7b"
    smoke: bool = True
    ode: bool = True
    ode_steps: int = 2
    steps: int = 50
    global_batch: int = 8
    seq_len: int = 64
    microbatches: int = 1
    compress: bool = False
    ckpt_dir: str = ""
    ckpt_every: int = 20
    keep: int = 3
    seed: int = 0
    log_every: int = 10
    production_mesh: bool = False
    multi_pod: bool = False


def _to_trainer_config(tc: TrainConfig) -> TrainerConfig:
    return TrainerConfig(
        arch=tc.arch, smoke=tc.smoke, ode=tc.ode, ode_steps=tc.ode_steps,
        steps=tc.steps, global_batch=tc.global_batch, seq_len=tc.seq_len,
        microbatches=tc.microbatches,
        loop="compressed" if tc.compress else "standard",
        ckpt_dir=tc.ckpt_dir, ckpt_every=tc.ckpt_every, keep=tc.keep,
        seed=tc.seed, log_every=tc.log_every,
        production_mesh=tc.production_mesh, multi_pod=tc.multi_pod)


def train(tc: TrainConfig) -> int:
    """Legacy entry point: run a TrainConfig to completion."""
    return Trainer(_to_trainer_config(tc)).train()


def main(argv=None) -> None:
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ode", default="on", choices=["on", "off"])
    ap.add_argument("--ode-steps", type=int, default=2)
    ap.add_argument("--ode-method", default="mali",
                    choices=["mali", "naive", "aca", "adjoint"])
    ap.add_argument("--ode-backend", default="auto",
                    choices=["auto", "reference", "pallas"])
    ap.add_argument("--ode-batch-axis", default="",
                    help="mesh axis for Sharded() solve batching ('' = off)")
    ap.add_argument("--loop", default="", help="TRAIN_LOOPS key "
                    "(default: standard, or compressed with --compress)")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-jsonl", default="",
                    help="write per-step StepRecord rows to this JSONL file")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="full assigned config (needs a real TPU slice)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    a = ap.parse_args(argv)
    loop = a.loop or ("compressed" if a.compress else "standard")
    cfg = TrainerConfig(
        arch=a.arch, smoke=a.smoke, ode=a.ode == "on",
        ode_steps=a.ode_steps, ode_method=a.ode_method,
        ode_backend=a.ode_backend, ode_batch_axis=a.ode_batch_axis,
        steps=a.steps, global_batch=a.global_batch, seq_len=a.seq_len,
        microbatches=a.microbatches, loop=loop, ckpt_dir=a.ckpt_dir,
        ckpt_every=a.ckpt_every, keep=a.keep, seed=a.seed,
        log_every=a.log_every,
        emit="jsonl" if a.metrics_jsonl else "stdout",
        metrics_path=a.metrics_jsonl,
        production_mesh=a.production_mesh, multi_pod=a.multi_pod)
    final = Trainer(cfg).train()
    print(f"final_step={final}", flush=True)


if __name__ == "__main__":
    main()
