"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

MUST be the very first two lines (before any other import, including
repro.*, since jax locks the device count on first init):
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse       # noqa: E402
import dataclasses    # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402
from typing import Optional  # noqa: E402

import jax            # noqa: E402

from repro.configs import (ARCHS, DEFAULT_ODE, get_config,  # noqa: E402
                           get_shape_cell)
from repro.configs.base import SHAPE_CELLS, cell_applicable  # noqa: E402
from repro.core.ode_block import OdeSettings  # noqa: E402
from repro.distributed.sharding import (batch_shardings,  # noqa: E402
                                        cache_shardings, opt_state_shardings,
                                        param_shardings, replicated)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import analyze  # noqa: E402
from repro.launch.specs import (batch_specs, decode_token_specs,  # noqa: E402
                                param_specs, serve_state_specs)
from repro.launch.steps import (make_decode_step, make_prefill_step,  # noqa: E402
                                make_train_step)
from repro.models.lm import ServeState  # noqa: E402
from repro.optim.optimizer import (OptimizerConfig, OptState,  # noqa: E402
                                   init_opt_state)


def _active_params(cfg, params_like) -> float:
    """Active (per-token) parameter count: MoE routed experts scaled by
    top_k/E; embedding table excluded (gather, not matmul)."""
    total = 0.0
    moe_frac = (cfg.moe_top_k / cfg.moe_experts) if cfg.moe_experts else 1.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_like)[0]:
        names = [getattr(p, "key", getattr(p, "name", getattr(p, "idx", "")))
                 for p in path]
        names = [str(n) for n in names]
        size = 1
        for s in leaf.shape:
            size *= s
        if names[-1] == "embed":
            continue
        if names[-1] in ("w_gate", "w_up", "w_down") and len(leaf.shape) >= 3 \
                and "mlp" in names:
            size *= moe_frac
        total += size
    return total


def _ode_units(cfg, kind: str) -> float:
    """f-eval flop multiplier per block vs a single discrete fwd pass (=2N).

    MALI fixed-step with n steps: fwd = (n+1) evals; train bwd = per-step
    (inverse 1 + vjp 3) + v0-vjp 3 evals (bwd eval ~ 2x fwd)."""
    if cfg.ode.mode == "off":
        return 6.0 if kind == "train" else 2.0
    n = cfg.ode.n_steps
    fwd = 2.0 * (n + 1)
    if kind != "train":
        return fwd
    bwd = 8.0 * n + 6.0
    return fwd + bwd


def _model_flops(cfg, cell, params_like) -> float:
    n_active = _active_params(cfg, params_like)
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    return _ode_units(cfg, cell.kind) / 2.0 * 2.0 * n_active * tokens


def _opt_sharding_tree(cfg, p_sh, mesh, params_like):
    rep = replicated(mesh)
    z = opt_state_shardings(cfg, mesh, p_sh, params_like)
    return OptState(rep, z, z, z)


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        val = getattr(ma, attr, None)
        if val is not None:
            out[attr] = int(val)
    if not out:
        out["repr"] = str(ma)
    return out


def run_cell(arch: str, shape: str, multi_pod: bool,
             ode: Optional[OdeSettings] = DEFAULT_ODE,
             microbatches: int = 1, out_dir: str = "reports/dryrun",
             save_hlo: bool = False, variant: str = "",
             attn_bwd: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    ode_tag = "ode" if (ode and ode.mode != "off") else "discrete"
    tag = f"{arch}__{shape}__{mesh_name}__{ode_tag}"
    if variant:
        tag += f"__{variant}"
    cell = get_shape_cell(shape)
    cfg = get_config(arch, ode=ode)
    if attn_bwd:
        cfg = dataclasses.replace(cfg, attn_bwd=attn_bwd)
    ok, reason = cell_applicable(cfg, cell)
    record = {"arch": arch, "shape": shape, "mesh": mesh_name,
              "ode": ode_tag, "microbatches": microbatches,
              "variant": variant}
    if not ok:
        record.update(status="skipped", reason=reason)
        return record

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    params_like = param_specs(cfg)
    p_sh = param_shardings(cfg, mesh, params_like)
    model_flops = _model_flops(cfg, cell, params_like)
    n_active = _active_params(cfg, params_like)
    record["active_params"] = n_active
    record["model_flops"] = model_flops

    with mesh:
        if cell.kind == "train":
            opt_cfg = OptimizerConfig()
            opt_like = jax.eval_shape(
                lambda p: init_opt_state(opt_cfg, p), params_like)
            o_sh = _opt_sharding_tree(cfg, p_sh, mesh, params_like)
            b_like = batch_specs(cfg, cell)
            b_sh = batch_shardings(cfg, mesh, b_like)
            # pin grads to their params' sharding (replicated for 'dp')
            # right after backward — blocks the ZeRO-1 opt-state sharding
            # from propagating into the loss graph (measured 10x flop blowup
            # otherwise; see EXPERIMENTS.md §Perf)
            step = make_train_step(cfg, opt_cfg, microbatches=microbatches,
                                   grad_shardings=p_sh)
            rep = replicated(mesh)
            metrics_sh = {"lr": rep, "grad_norm": rep, "loss": rep,
                          "ode_accepted": rep, "ode_rejected": rep,
                          "ode_fevals": rep}
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, metrics_sh),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_like, opt_like, b_like)
        elif cell.kind == "prefill":
            b_like = batch_specs(cfg, cell)
            b_sh = batch_shardings(cfg, mesh, b_like)
            st_like = serve_state_specs(cfg, cell)
            st_sh = ServeState(
                cache_shardings(cfg, mesh, st_like.cache, cell.global_batch),
                replicated(mesh))
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh, st_sh),
                             out_shardings=(replicated(mesh), st_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_like, b_like, st_like)
        else:  # decode
            tok_like = decode_token_specs(cfg, cell)
            tok_sh = batch_shardings(cfg, mesh, {"t": tok_like})["t"]
            st_like = serve_state_specs(cfg, cell)
            st_sh = ServeState(
                cache_shardings(cfg, mesh, st_like.cache, cell.global_batch),
                replicated(mesh))
            step = make_decode_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, tok_sh, st_sh),
                             out_shardings=(replicated(mesh), st_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_like, tok_like, st_like)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = _mem_dict(compiled)
    roof = analyze(compiled, chips=chips, model_flops=model_flops,
                   default_group=16)
    record.update(
        status="ok", chips=chips, lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1), memory=mem,
        roofline=roof.to_dict())

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(record, f, indent=2, default=float)
    if save_hlo:
        with open(os.path.join(out_dir, tag + ".hlo.txt"), "w") as f:
            f.write(compiled.as_text())
    print(compiled.memory_analysis())
    try:
        print({k: v for k, v in (compiled.cost_analysis() or {}).items()
               if k in ("flops", "bytes accessed")})
    except Exception:
        pass
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--ode", default="on", choices=["on", "off"])
    ap.add_argument("--ode-steps", type=int, default=2)
    ap.add_argument("--fused-bwd", default="on", choices=["on", "off"])
    ap.add_argument("--attn-bwd", default="flash", choices=["flash", "autodiff"])
    ap.add_argument("--variant", default="", help="tag for A/B records")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = ([c.name for c in SHAPE_CELLS] if args.shape == "all"
              else [args.shape])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    ode = (dataclasses.replace(DEFAULT_ODE, n_steps=args.ode_steps,
                               fused_bwd=args.fused_bwd == "on")
           if args.ode == "on" else OdeSettings(mode="off"))

    summary_path = os.path.join(args.out, "summary.jsonl")
    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                label = f"{arch} x {shape} x {'multi' if multi else 'single'}"
                print(f"=== {label} ===", flush=True)
                try:
                    rec = run_cell(arch, shape, multi, ode,
                                   microbatches=args.microbatches,
                                   out_dir=args.out, save_hlo=args.save_hlo,
                                   variant=args.variant,
                                   attn_bwd=args.attn_bwd)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "pod2x16x16" if multi else "pod16x16",
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                with open(summary_path, "a") as f:
                    f.write(json.dumps(rec, default=float) + "\n")
                st = rec.get("status")
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "error"
                print(f"--- {label}: {st}", flush=True)
    print(f"dry-run complete: {n_ok} ok, {n_skip} skipped (per assignment "
          f"rule), {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
