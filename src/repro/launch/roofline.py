"""Roofline analysis from the compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips * 819e9 B/s HBM)
    collective = collective_wire_bytes / (chips * 50e9 B/s per ICI link)

FLOPs/bytes come from ``compiled.cost_analysis()`` (whole-program totals —
the CPU backend reports unpartitioned-program totals, so we divide by chip
count). Collective bytes are NOT in cost_analysis: we parse the post-SPMD
HLO text, sum operand bytes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute, apply ring-algorithm wire
multipliers (AR 2(n-1)/n, AG/RS (n-1)/n, A2A (n-1)/n, CP 1), and multiply
collectives inside ``while`` bodies (scan-over-layers, MALI's backward scan)
by the loop trip count recovered from the loop-condition constant.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Tuple

# TPU v5e
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<?")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _wire_multiplier(kind: str, group: int) -> float:
    g = max(group, 1)
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g
    if kind in ("all-gather", "reduce-scatter", "all-to-all"):
        return (g - 1) / g
    return 1.0  # collective-permute


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes_per_chip: float = 0.0
    op_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    op_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    unscoped_loops: int = 0


def collective_stats(hlo: str, default_group: int) -> CollectiveStats:
    """Sum collective wire bytes with while-trip multiplication: walk the
    computation call graph from the entry (same machinery as hlo_cost)."""
    from .hlo_cost import _INST_RE, _TRIP_RE, _called, split_computations
    comps, entry = split_computations(hlo)
    stats = CollectiveStats()
    memo: Dict[str, Tuple[float, Dict[str, int], Dict[str, float]]] = {}

    def one_collective(line: str, kind: str) -> Tuple[float, int]:
        m = _INST_RE.match(line)
        rbytes = _shape_bytes(m.group("type")) if m else _shape_bytes(line)
        gm = _GROUPS_RE.search(line)
        if gm:
            group = len([x for x in gm.group(1).split(",") if x.strip()])
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            group = int(gi.group(2)) if gi else default_group
        return rbytes * _wire_multiplier(kind, group), group

    def walk(name: str, stack=()):
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return (0.0, {}, {})
        total = 0.0
        counts: Dict[str, int] = {}
        byts: Dict[str, float] = {}

        def acc(sub_total, sub_counts, sub_bytes, mult=1):
            nonlocal total
            total += sub_total * mult
            for k, v in sub_counts.items():
                counts[k] = counts.get(k, 0) + v * mult
            for k, v in sub_bytes.items():
                byts[k] = byts.get(k, 0.0) + v * mult

        for line in comps[name]:
            m = _INST_RE.match(line)
            if not m:
                continue
            op = m.group("op")
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLL_KINDS:
                wire, _ = one_collective(line, base)
                acc(wire, {base: 1}, {base: wire})
                continue
            called = _called(line)
            if op == "while":
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 1
                if not tm:
                    for kind_, sub in called:
                        if kind_ == "condition":
                            for cl in comps.get(sub, []):
                                for cm in re.finditer(r"constant\((\d+)\)", cl):
                                    trips = max(trips, int(cm.group(1)))
                for _, sub in called:
                    acc(*walk(sub, stack + (name,)), mult=trips)
                continue
            for _, sub in called:
                acc(*walk(sub, stack + (name,)))
        memo[name] = (total, counts, byts)
        return memo[name]

    total, counts, byts = walk(entry)
    stats.wire_bytes_per_chip = total
    stats.op_counts = counts
    stats.op_bytes = byts
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll: CollectiveStats
    chips: int
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        # flops/hbm_bytes are per-device (post-SPMD shapes)
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll.wire_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        # model_flops is a GLOBAL number; flops is per-device
        return (self.model_flops / (self.flops * self.chips)
                if self.flops else 0.0)

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_wire_bytes_per_chip": self.coll.wire_bytes_per_chip,
            "collective_ops": self.coll.op_counts,
            "collective_bytes_by_op": self.coll.op_bytes,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analyze(compiled, chips: int, model_flops: float = 0.0,
            default_group: int = 16) -> Roofline:
    """Three-term roofline from the compiled artifact.

    flops/bytes come from our loop-aware HLO cost model (hlo_cost.py) —
    XLA's cost_analysis() counts while bodies once and would undercount the
    scanned-layers + MALI-backward-scan program by >20x (verified).
    Numbers are PER-DEVICE (post-SPMD HLO shapes are per-shard), so the
    roofline terms divide by a single chip's peak, not the fleet's.
    """
    hlo = compiled.as_text()
    from .hlo_cost import analyze_hlo
    cost = analyze_hlo(hlo)
    coll = collective_stats(hlo, default_group)
    return Roofline(flops=cost.flops, hbm_bytes=cost.bytes, coll=coll,
                    chips=chips, model_flops=model_flops)


def model_flops_estimate(cfg, cell, n_params_active: float,
                         ode_evals: int) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (fwd-only),
    scaled by the number of ODE f-evals per block (paper technique makes
    each block ode_evals-x deeper in compute at equal params)."""
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    per_token = (6.0 if cell.kind == "train" else 2.0) * n_params_active
    return per_token * tokens * ode_evals
