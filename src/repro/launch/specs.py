"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
against these; train.py/serve.py feed real arrays of the same specs.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import init_cache, init_lm
from repro.models.lm import ServeState

Pytree = Any


def batch_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """Training / prefill batch input specs at the cell's global shape."""
    b, s = cell.global_batch, cell.seq_len
    if cfg.input_mode == "embeds":
        specs = {"embeds": jax.ShapeDtypeStruct(
            (b, s, cfg.d_model), jnp.dtype(cfg.compute_dtype))}
    else:
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cell.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return specs


def decode_token_specs(cfg: ModelConfig, cell: ShapeCell) -> Any:
    b = cell.global_batch
    if cfg.input_mode == "embeds":
        return jax.ShapeDtypeStruct((b, 1, cfg.d_model),
                                    jnp.dtype(cfg.compute_dtype))
    return jax.ShapeDtypeStruct((b, 1), jnp.int32)


def param_specs(cfg: ModelConfig) -> Pytree:
    """Abstract parameter tree (no allocation)."""
    return jax.eval_shape(lambda k: init_lm(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def serve_state_specs(cfg: ModelConfig, cell: ShapeCell) -> ServeState:
    cache = jax.eval_shape(
        lambda: init_cache(cfg, cell.global_batch, cell.seq_len))
    return ServeState(cache, jax.ShapeDtypeStruct((), jnp.int32))


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """All step-function inputs for this (arch x shape) cell."""
    out: Dict[str, Any] = {"batch": batch_specs(cfg, cell)}
    if cell.kind == "decode":
        out["tokens"] = decode_token_specs(cfg, cell)
        out["state"] = serve_state_specs(cfg, cell)
    elif cell.kind == "prefill":
        out["state"] = serve_state_specs(cfg, cell)
    return out
