"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization, while smoke tests must see the
default single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate mesh over whatever devices exist (smoke / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
