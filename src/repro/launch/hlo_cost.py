"""HLO-text cost model with loop-trip multiplication.

``compiled.cost_analysis()`` counts every while-loop body ONCE and reports
per-device numbers (verified experimentally — see EXPERIMENTS.md §Dry-run).
Scan-over-layers + MALI's backward scan + chunked-loss scans make that a
>20x undercount for this framework, so we parse the post-SPMD HLO text and
account per computation with a symbol table (operand types are not inline
in compiled HLO — they resolve through each computation's definitions):

  flops:
    dot       2 * prod(result_dims) * prod(lhs contracting dim sizes)
    elementwise / transcendental / compare ...   prod(result_dims)
    reduce    prod(operand_dims)
  bytes (HBM-traffic proxy):
    fusion    operand bytes + result bytes of the fusion instruction only
              (internals are register/VMEM-resident — the TPU model)
    other     operand + result bytes
  control flow:
    while     (condition + body) * trip_count, from the while op's
              backend_config known_trip_count (fallback: largest integer
              constant in the condition computation)
    call/conditional/reduce-to_apply: called computations once

Collectives are handled separately in roofline.py (wire-byte multipliers).
Validated against closed forms in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "compare", "select", "and", "or",
    "not", "xor", "clamp", "floor", "ceil", "round-nearest-afz", "sign",
    "cosine", "sine", "atan2", "erf", "logistic",
    "round-nearest-even", "cbrt", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "is-finite",
}

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier",
    # dtype converts: XLA-CPU legalizes EVERY bf16 elementwise op as
    # convert->f32 op->convert, inflating instruction-boundary bytes ~5x on
    # bf16-heavy programs. On the TPU target converts fuse into the
    # producer/consumer (native bf16 VPU ops), so they carry no HBM traffic
    # of their own. Verified against jamba train_4k: 264 converts of a
    # 9.4 GB MoE intermediate in one loop body, all CPU legalization.
    "convert",
}

# type group: tuple types may contain /*index=N*/ comments (with '=') and
# one level of nested parens (tiled layouts); allow both.
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(?P<name>%[\w.\-]+)\s*=\s*"
    r"(?P<type>\((?:[^()]|\([^()]*\))*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>[a-z][\w\-]*)\(")

_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _dims(dim_str: str) -> List[int]:
    return [int(d) for d in dim_str.split(",") if d]


def _count_elems(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in _dims(dims):
            n *= d
        total += n
    return total


def _count_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _operand_section(line: str) -> str:
    i = line.find("(")
    if i < 0:
        return ""
    depth = 0
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                return line[i + 1:j]
    return line[i + 1:]


_OPERAND_NAME_RE = re.compile(r"%[\w.\-]+")


def _called(line: str) -> List[Tuple[str, str]]:
    out = []
    for key in ("calls=", "to_apply=", "condition=", "body=",
                "true_computation=", "false_computation="):
        for m in re.finditer(re.escape(key) + r"%?([\w.\-]+)", line):
            out.append((key[:-1], m.group(1)))
    return out


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0


def split_computations(hlo: str) -> Tuple[Dict[str, List[str]], str]:
    comps: Dict[str, List[str]] = {}
    entry = ""
    current = None
    for raw in hlo.splitlines():
        stripped = raw.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped.endswith("{") and "->" in stripped and "=" not in \
                stripped.split("(")[0]:
            m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)", stripped)
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry = current
            continue
        if stripped == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(stripped)
    return comps, entry


def analyze_hlo(hlo: str) -> CompCost:
    comps, entry = split_computations(hlo)
    memo: Dict[str, CompCost] = {}

    # symbol tables: computation -> {inst name -> result type str}
    symtabs: Dict[str, Dict[str, str]] = {}
    for cname, lines in comps.items():
        tab = {}
        for line in lines:
            m = _INST_RE.match(line)
            if m:
                tab[m.group("name")] = m.group("type")
        symtabs[cname] = tab

    def operand_bytes(cname: str, line: str) -> int:
        tab = symtabs[cname]
        total = 0
        for nm in _OPERAND_NAME_RE.findall(_operand_section(line)):
            total += _count_bytes(tab.get(nm, ""))
        return total

    def operand_elems(cname: str, line: str) -> int:
        tab = symtabs[cname]
        total = 0
        for nm in _OPERAND_NAME_RE.findall(_operand_section(line)):
            total += _count_elems(tab.get(nm, ""))
        return total

    def dot_flops(cname: str, line: str, rtype: str) -> float:
        tab = symtabs[cname]
        names = _OPERAND_NAME_RE.findall(_operand_section(line))
        if not names:
            return 0.0
        lhs_dims: List[int] = []
        for dt, dims in _SHAPE_RE.findall(tab.get(names[0], "")):
            lhs_dims = _dims(dims)
            break
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
        contract = 1
        if m:
            for idx in _dims(m.group(1)):
                if idx < len(lhs_dims):
                    contract *= lhs_dims[idx]
        return 2.0 * _count_elems(rtype) * contract

    def cost_of(name: str, stack=()) -> CompCost:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return CompCost()
        total = CompCost()
        for line in comps[name]:
            m = _INST_RE.match(line)
            if not m:
                continue
            rtype, op = m.group("type"), m.group("op")
            if op in _FREE_OPS:
                continue
            called = _called(line)

            if op == "fusion":
                for _, sub in called:
                    total.flops += cost_of(sub, stack + (name,)).flops
                total.bytes += operand_bytes(name, line) + _count_bytes(rtype)
                continue
            if op == "while":
                tm = _TRIP_RE.search(line)
                cond = body = None
                for kind, sub in called:
                    if kind == "condition":
                        cond = sub
                    elif kind == "body":
                        body = sub
                if tm:
                    trips = int(tm.group(1))
                else:
                    trips = 1
                    for cl in comps.get(cond, []):
                        for cm in re.finditer(r"constant\((\d+)\)", cl):
                            trips = max(trips, int(cm.group(1)))
                for sub in (cond, body):
                    if sub:
                        c = cost_of(sub, stack + (name,))
                        total.flops += c.flops * trips
                        total.bytes += c.bytes * trips
                continue
            if called:  # call / conditional / reduce / map / sort / scatter
                for _, sub in called:
                    c = cost_of(sub, stack + (name,))
                    total.flops += c.flops
                    total.bytes += c.bytes
                if op in ("reduce", "reduce-window", "scatter"):
                    total.flops += operand_elems(name, line)
                total.bytes += operand_bytes(name, line) + _count_bytes(rtype)
                continue

            if op == "dot":
                total.flops += dot_flops(name, line, rtype)
            elif op in ("convolution",):
                # not used by this framework's models (mamba conv is shifts)
                total.flops += 2.0 * _count_elems(rtype)
            elif op in _ELEMENTWISE:
                total.flops += _count_elems(rtype)
            total.bytes += operand_bytes(name, line) + _count_bytes(rtype)
        memo[name] = total
        return total

    return cost_of(entry)


# ---------------------------------------------------------------------------
# Kernel-launch accounting (jaxpr level)
# ---------------------------------------------------------------------------
# On CPU, interpret-mode pallas_call lowers to plain HLO, so launches are
# invisible in compiled HLO text; the stable place to count them is the
# jaxpr, where each launch is one `pallas_call` primitive regardless of
# target. This is the roofline check that a fused op really IS one launch —
# e.g. one fused MALI backward step must show exactly two (alf_bwd_pre +
# alf_bwd_post, one on each side of the f-eval linearization).

def _sub_jaxprs(params):
    """Yield every sub-jaxpr reachable from one eqn's params (pjit/closed
    jaxprs, scan bodies, cond branches — tuples/lists included)."""
    for val in params.values():
        stack = [val]
        while stack:
            v = stack.pop()
            if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                stack.append(v.jaxpr)
            elif hasattr(v, "eqns"):
                yield v
            elif isinstance(v, (tuple, list)):
                stack.extend(v)


def _count_pallas(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for sub in _sub_jaxprs(eqn.params):
            n += _count_pallas(sub)
    return n


def count_pallas_launches(fn, *args) -> int:
    """Number of pallas_call launches in one trace of ``fn(*args)``
    (recursing through pjit/scan/cond sub-jaxprs; scan bodies count ONCE —
    this is launches per traced program region, i.e. per step for a
    per-step function)."""
    import jax  # lazy so the text-only cost model stays jax-free
    return _count_pallas(jax.make_jaxpr(fn)(*args).jaxpr)
