"""Step-function builders: the jit targets for training and serving.

``make_train_step`` returns the full production step — loss, grads
(optionally micro-batched accumulation, optionally int8 error-feedback
gradient compression), clip, AdamW/SGD update — as a pure function
(params, opt_state[, ef_state], batch) -> (params, opt_state[, ef], metrics).

The gradient computation itself lives in :mod:`repro.train.loop`
(``loss_and_grads``) — this module keeps the legacy closure-style builder
interface on top of it for callers that pass explicit ``grad_shardings``.
The metrics dict includes the step's integration accounting
(``ode_accepted`` / ``ode_rejected`` / ``ode_fevals``), threaded out of
the jitted step as the loss function's RunStats aux (float0-safe: the
counters are laundered inside the model per R002c).
"""
from __future__ import annotations

from typing import Any, Callable

import jax

from repro.configs.base import ModelConfig
from repro.models import decode_step, lm_loss, prefill
from repro.models.lm import ServeState
from repro.optim.compression import EFState, compress_grads
from repro.optim.optimizer import OptimizerConfig, OptState, apply_updates
from repro.train.loop import loss_and_grads

Pytree = Any
_tm = jax.tree_util.tree_map


def make_loss_fn(cfg: ModelConfig) -> Callable[[Pytree, Pytree], jax.Array]:
    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig, *,
                    microbatches: int = 1, compress: bool = False,
                    grad_shardings=None):
    """grad_shardings: optional NamedSharding tree applied to the gradients
    before the optimizer — with ZeRO-1-sharded optimizer state this turns
    the DP gradient all-reduce into a reduce-scatter (the update then runs
    sharded and the new params are all-gathered by out_shardings)."""

    def constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.lax.with_sharding_constraint(grads, grad_shardings)

    def finish(params, opt_state, loss, stats, grads):
        params, opt_state, metrics = apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        metrics["ode_accepted"] = stats.n_accepted
        metrics["ode_rejected"] = stats.n_rejected
        metrics["ode_fevals"] = stats.n_fevals
        return params, opt_state, metrics

    if compress:
        def train_step(params, opt_state: OptState, ef: EFState, batch):
            loss, stats, grads = loss_and_grads(params, batch, cfg=cfg,
                                                microbatches=microbatches)
            grads, ef = compress_grads(constrain(grads), ef)
            params, opt_state, metrics = finish(params, opt_state, loss,
                                                stats, grads)
            return params, opt_state, ef, metrics
        return train_step

    def train_step(params, opt_state: OptState, batch):
        loss, stats, grads = loss_and_grads(params, batch, cfg=cfg,
                                            microbatches=microbatches)
        params, opt_state, metrics = finish(params, opt_state, loss, stats,
                                            constrain(grads))
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, state: ServeState):
        return prefill(params, cfg, batch, state)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, tokens, state: ServeState):
        return decode_step(params, cfg, tokens, state)
    return serve_step
