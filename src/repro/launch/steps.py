"""Step-function builders: the jit targets for training and serving.

``make_train_step`` returns the full production step — loss, grads
(optionally micro-batched accumulation, optionally int8 error-feedback
gradient compression), clip, AdamW/SGD update — as a pure function
(params, opt_state[, ef_state], batch) -> (params, opt_state[, ef], metrics).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import decode_step, lm_loss, prefill
from repro.models.lm import ServeState
from repro.optim.compression import EFState, compress_grads
from repro.optim.optimizer import OptimizerConfig, OptState, apply_updates

Pytree = Any
_tm = jax.tree_util.tree_map


def make_loss_fn(cfg: ModelConfig) -> Callable[[Pytree, Pytree], jax.Array]:
    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch)
    return loss_fn


def _split_microbatches(batch: Pytree, n: int) -> Pytree:
    return _tm(lambda a: a.reshape((n, a.shape[0] // n) + a.shape[1:]), batch)


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig, *,
                    microbatches: int = 1, compress: bool = False,
                    grad_shardings=None):
    """grad_shardings: optional NamedSharding tree applied to the gradients
    before the optimizer — with ZeRO-1-sharded optimizer state this turns
    the DP gradient all-reduce into a reduce-scatter (the update then runs
    sharded and the new params are all-gathered by out_shardings)."""
    loss_fn = make_loss_fn(cfg)

    def grads_of(params, batch):
        if microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        mbs = _split_microbatches(batch, microbatches)

        def acc(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + loss, _tm(jnp.add, g_acc, g)), None

        zeros = _tm(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = lax.scan(acc, (jnp.float32(0.0), zeros), mbs)
        inv = 1.0 / microbatches
        return loss * inv, _tm(lambda g: g * inv, grads)

    def constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.lax.with_sharding_constraint(grads, grad_shardings)

    if compress:
        def train_step(params, opt_state: OptState, ef: EFState, batch):
            loss, grads = grads_of(params, batch)
            grads = constrain(grads)
            grads, ef = compress_grads(grads, ef)
            params, opt_state, metrics = apply_updates(
                opt_cfg, params, grads, opt_state)
            metrics["loss"] = loss
            return params, opt_state, ef, metrics
        return train_step

    def train_step(params, opt_state: OptState, batch):
        loss, grads = grads_of(params, batch)
        grads = constrain(grads)
        params, opt_state, metrics = apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, state: ServeState):
        return prefill(params, cfg, batch, state)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, tokens, state: ServeState):
        return decode_step(params, cfg, tokens, state)
    return serve_step
