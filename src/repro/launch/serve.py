"""Serving driver: LM prefill/decode AND the continuous-batching ODE loop.

Two serving paths share this driver:

* **LM path** (default) — batched prefill + autoregressive decode::

      PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
          --prompt-len 32 --decode-tokens 16 --batch 4

  Greedy decoding over the synthetic token stream; prints per-phase timings
  and tokens/s. The same prefill/decode step functions are what the dry-run
  lowers at the assigned 32k/500k shapes on the production mesh.

* **ODE path** (``--mode ode``) — the ``repro.serve`` serving loop::

      PYTHONPATH=src python -m repro.launch.serve --mode ode --batch 64 \
          --requests 256 --rate 100 [--ode-engine continuous|static] \
          [--chunk-steps 32] [--seed 0] [--d-state 32] [--t1 1.0] \
          [--rtol 1e-3 --atol 1e-4 --max-steps 512] [--production-mesh]

  Requests (each one initial state of a shared MLP vector field, with its
  own stiffness scale) arrive as a Poisson stream (``--rate``; omit for
  all-at-once) and are served by a :class:`repro.serve.
  ContinuousBatchingEngine` — ``--batch`` slots advanced in
  ``--chunk-steps`` chunked re-dispatch rounds, finished rows backfilled
  from the queue between rounds. ``--ode-engine static`` runs the
  no-backfill static-fleet baseline (the pre-PR-8 one-shot fleet) on the
  same stream for comparison. Prints the :class:`repro.serve.ServeReport`:
  p50/p99 latency, solves/s, f-evals/request, occupancy —
  ``benchmarks/serve_load.py`` tracks the same numbers in CI.

Per-mode ``--batch`` defaults live in ``MODE_DEFAULT_BATCH`` (one place),
and the resolved value is printed in each run's header.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DEFAULT_ODE, get_config, smoke_config
from repro.core.ode_block import OdeSettings
from repro.distributed.sharding import (cache_shardings, param_shardings,
                                        replicated)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_lm
from repro.models.lm import ServeState, init_serve_state

# One place for the per-mode --batch defaults (main() used to hardcode
# them inline in two spots). For ode, batch == engine slots (fleet width).
MODE_DEFAULT_BATCH = {"lm": 4, "ode": 64}


def serve(arch: str, *, smoke: bool = True, ode: bool = True,
          prompt_len: int = 32, decode_tokens: int = 16, batch: int = 4,
          production_mesh: bool = False, seed: int = 0):
    settings = DEFAULT_ODE if ode else OdeSettings(mode="off")
    cfg = smoke_config(arch, settings) if smoke else get_config(arch, settings)
    mesh = make_production_mesh() if production_mesh else make_host_mesh()
    s_max = prompt_len + decode_tokens
    rng = np.random.default_rng(seed)

    with mesh:
        params = init_lm(jax.random.PRNGKey(seed), cfg)
        params = jax.device_put(params, param_shardings(cfg, mesh, params))
        state = init_serve_state(cfg, batch, s_max)
        st_sh = ServeState(cache_shardings(cfg, mesh, state.cache, batch),
                           replicated(mesh))
        state = jax.device_put(state, st_sh)

        prefill = jax.jit(make_prefill_step(cfg), donate_argnums=(2,))
        decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

        if cfg.input_mode == "embeds":
            prompt = {"embeds": jnp.asarray(rng.standard_normal(
                (batch, prompt_len, cfg.d_model)).astype(np.float32))}
        else:
            prompt = {"tokens": jnp.asarray(rng.integers(
                0, cfg.vocab_size, (batch, prompt_len)).astype(np.int32))}

        t0 = time.time()
        logits, state = prefill(params, prompt, state)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        out_tokens = []
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        t0 = time.time()
        for _ in range(decode_tokens):
            if cfg.input_mode == "embeds":
                # stub frontend: feed the token id through a fixed projection
                inp = jnp.tile(tok[..., None].astype(jnp.float32),
                               (1, 1, cfg.d_model)) * 1e-3
            else:
                inp = tok
            logits, state = decode(params, inp, state)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            out_tokens.append(np.asarray(tok[:, 0]))
        jax.block_until_ready(logits)
        t_decode = time.time() - t0

    toks = np.stack(out_tokens, 1)
    print(f"arch={cfg.name} batch={batch} prompt={prompt_len} "
          f"decode={decode_tokens}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({batch * prompt_len / max(t_prefill, 1e-9):.0f} tok/s)")
    print(f"decode:  {t_decode * 1e3:.1f} ms "
          f"({batch * decode_tokens / max(t_decode, 1e-9):.0f} tok/s)")
    print("sample:", toks[0][:12].tolist())
    return toks


def mlp_field(rng: np.random.Generator, d_state: int):
    """The serving vector field: shared two-layer MLP with per-request
    stiffness in the state (``d scale/dt = 0``). Returns (f, params)."""
    w1 = jnp.asarray(rng.standard_normal((d_state, d_state)) * 0.4,
                     jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((d_state, d_state)) * 0.4,
                     jnp.float32)
    params = {"w1": w1, "w2": w2}

    def f(p, z, t):
        h = jnp.tanh(z["y"] @ p["w1"])
        return {"y": z["scale"] * (h @ p["w2"] - z["y"]),
                "scale": jnp.zeros_like(z["scale"])}

    return f, params


def serve_ode(*, batch: int = 64, d_state: int = 32, t1: float = 1.0,
              engine: str = "continuous", chunk_steps: int = 32,
              n_requests: int = 256, rate: float = 0.0, rtol: float = 1e-3,
              atol: float = 1e-4, max_steps: int = 512,
              production_mesh: bool = False, seed: int = 0):
    """Serve a stream of Neural-ODE solve requests through the
    ``repro.serve`` engine stack.

    ``batch`` engine slots advance in ``chunk_steps``-trial dispatch
    rounds; ``engine='continuous'`` backfills retired rows from the queue
    between rounds, ``engine='static'`` runs the no-backfill fleet
    baseline. ``rate`` > 0 makes arrivals Poisson at that rate (requests/s
    of serving-clock time); 0 submits everything at t=0 (closed loop).
    Returns the run's :class:`repro.serve.ServeReport`.
    """
    from repro.core import ALF
    from repro.serve import (ENGINES, EngineConfig, Request, RequestConfig,
                             format_report, poisson_arrivals)

    if engine not in ENGINES:
        raise ValueError(f"unknown ode engine {engine!r}; "
                         f"choose from {sorted(ENGINES)}")
    mesh = make_production_mesh() if production_mesh else make_host_mesh()
    rng = np.random.default_rng(seed)
    f, params = mlp_field(rng, d_state)

    config = RequestConfig(t0=0.0, t1=t1, rtol=rtol, atol=atol,
                           max_steps=max_steps)
    if rate > 0.0:
        arrivals = poisson_arrivals(rng, rate, n_requests)
    else:
        arrivals = np.zeros(n_requests)
    requests = []
    for i in range(n_requests):
        z0 = {"y": rng.standard_normal(d_state).astype(np.float32),
              "scale": np.full((d_state,),
                               10.0 ** rng.uniform(0.0, 1.0), np.float32)}
        requests.append(Request(z0=z0, config=config,
                                arrival=float(arrivals[i])))

    print(f"ode serve: engine={engine} batch(slots)={batch} "
          f"chunk_steps={chunk_steps} d={d_state} t1={t1} "
          f"rtol={rtol} atol={atol} max_steps={max_steps} "
          f"requests={n_requests} "
          f"rate={rate if rate > 0 else 'all-at-once'} seed={seed}")

    with mesh:
        eng = ENGINES[engine](
            f, params,
            config=EngineConfig(slots=batch, chunk_steps=chunk_steps,
                                solver=ALF(eta=0.9)),
            vf_id=f"mlp-d{d_state}-seed{seed}")
        eng.submit(requests)
        report = eng.run()
    print(format_report(report))
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", default="lm", choices=["lm", "ode"],
                    help="lm: prefill/decode serving; ode: continuous-"
                         "batching ODE serving loop")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=None,
                    help="lm: requests per step; ode: engine batch slots "
                         f"(defaults: {MODE_DEFAULT_BATCH})")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ode", default="on", choices=["on", "off"])
    ap.add_argument("--ode-engine", default="continuous",
                    choices=["continuous", "static"],
                    help="continuous: chunked backfill; static: one-shot "
                         "fleet baseline")
    ap.add_argument("--chunk-steps", type=int, default=32,
                    help="adaptive trials per dispatch round (ode)")
    ap.add_argument("--requests", type=int, default=256,
                    help="number of ODE requests to serve")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate in requests/s "
                         "(0 = submit all at t=0)")
    ap.add_argument("--d-state", type=int, default=32,
                    help="ODE state dimension per request")
    ap.add_argument("--t1", type=float, default=1.0,
                    help="integration span end (ode)")
    ap.add_argument("--rtol", type=float, default=1e-3)
    ap.add_argument("--atol", type=float, default=1e-4)
    ap.add_argument("--max-steps", type=int, default=512,
                    help="per-request adaptive trial budget (ode)")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--production-mesh", action="store_true")
    a = ap.parse_args()
    batch = MODE_DEFAULT_BATCH[a.mode] if a.batch is None else a.batch
    if a.mode == "ode":
        serve_ode(batch=batch, d_state=a.d_state, t1=a.t1,
                  engine=a.ode_engine, chunk_steps=a.chunk_steps,
                  n_requests=a.requests, rate=a.rate, rtol=a.rtol,
                  atol=a.atol, max_steps=a.max_steps,
                  production_mesh=a.production_mesh, seed=a.seed)
        return
    serve(a.arch, smoke=a.smoke, ode=a.ode == "on", prompt_len=a.prompt_len,
          decode_tokens=a.decode_tokens, batch=batch,
          production_mesh=a.production_mesh, seed=a.seed)


if __name__ == "__main__":
    main()
