"""Serving driver: LM prefill/decode AND the batched-ODE solve fleet.

Two serving paths share this driver:

* **LM path** (default) — batched prefill + autoregressive decode::

      PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
          --prompt-len 32 --decode-tokens 16 --batch 4

  Greedy decoding over the synthetic token stream; prints per-phase timings
  and tokens/s. The same prefill/decode step functions are what the dry-run
  lowers at the assigned 32k/500k shapes on the production mesh.

* **ODE path** (``--mode ode``) — a fleet of independent Neural-ODE solves
  served data-parallel, the batched ``solve()`` capping the Batching axis::

      PYTHONPATH=src python -m repro.launch.serve --mode ode --batch 64 \
          [--ode-batching per_sample|lockstep] [--production-mesh]

  Each request is one initial state; the fleet is integrated by
  ``solve(..., batching=Sharded(axis='data', inner=...))`` — shard_map
  over the mesh's 'data' axis (production: 16-way, host: all local
  devices), with per-shard :class:`~repro.core.interface.PerSample`
  adaptive control by default so one stiff request never re-trials its
  shard-mates. Prints solves/s, total/ per-request f-evals from
  ``Solution.stats.per_sample``, and the request-level step spread — the
  numbers ``benchmarks/batched_throughput.py`` tracks in CI.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DEFAULT_ODE, get_config, smoke_config
from repro.core.ode_block import OdeSettings
from repro.distributed.sharding import (batch_sharding,
                                        cache_shardings, param_shardings,
                                        replicated)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_lm
from repro.models.lm import ServeState, init_serve_state


def serve(arch: str, *, smoke: bool = True, ode: bool = True,
          prompt_len: int = 32, decode_tokens: int = 16, batch: int = 4,
          production_mesh: bool = False, seed: int = 0):
    settings = DEFAULT_ODE if ode else OdeSettings(mode="off")
    cfg = smoke_config(arch, settings) if smoke else get_config(arch, settings)
    mesh = make_production_mesh() if production_mesh else make_host_mesh()
    s_max = prompt_len + decode_tokens
    rng = np.random.default_rng(seed)

    with mesh:
        params = init_lm(jax.random.PRNGKey(seed), cfg)
        params = jax.device_put(params, param_shardings(cfg, mesh, params))
        state = init_serve_state(cfg, batch, s_max)
        st_sh = ServeState(cache_shardings(cfg, mesh, state.cache, batch),
                           replicated(mesh))
        state = jax.device_put(state, st_sh)

        prefill = jax.jit(make_prefill_step(cfg), donate_argnums=(2,))
        decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

        if cfg.input_mode == "embeds":
            prompt = {"embeds": jnp.asarray(rng.standard_normal(
                (batch, prompt_len, cfg.d_model)).astype(np.float32))}
        else:
            prompt = {"tokens": jnp.asarray(rng.integers(
                0, cfg.vocab_size, (batch, prompt_len)).astype(np.int32))}

        t0 = time.time()
        logits, state = prefill(params, prompt, state)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        out_tokens = []
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        t0 = time.time()
        for _ in range(decode_tokens):
            if cfg.input_mode == "embeds":
                # stub frontend: feed the token id through a fixed projection
                inp = jnp.tile(tok[..., None].astype(jnp.float32),
                               (1, 1, cfg.d_model)) * 1e-3
            else:
                inp = tok
            logits, state = decode(params, inp, state)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            out_tokens.append(np.asarray(tok[:, 0]))
        jax.block_until_ready(logits)
        t_decode = time.time() - t0

    toks = np.stack(out_tokens, 1)
    print(f"arch={cfg.name} batch={batch} prompt={prompt_len} "
          f"decode={decode_tokens}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({batch * prompt_len / max(t_prefill, 1e-9):.0f} tok/s)")
    print(f"decode:  {t_decode * 1e3:.1f} ms "
          f"({batch * decode_tokens / max(t_decode, 1e-9):.0f} tok/s)")
    print("sample:", toks[0][:12].tolist())
    return toks


def serve_ode(*, batch: int = 64, d_state: int = 32, t1: float = 1.0,
              batching: str = "per_sample", rtol: float = 1e-3,
              atol: float = 1e-4, max_steps: int = 512,
              production_mesh: bool = False, seed: int = 0):
    """Serve a fleet of independent Neural-ODE solves (one per request)
    data-parallel over the mesh — the batched-solve serving path.

    Each request integrates a shared MLP vector field from its own initial
    state with its own stiffness scale (requests are heterogeneous, like
    production traffic), under ``Sharded(axis='data',
    inner=PerSample()|Lockstep())``. Returns the final states.
    """
    from repro.core import (ALF, AdaptiveController, Lockstep, MALI,
                            PerSample, Sharded, solve)

    mesh = make_production_mesh() if production_mesh else make_host_mesh()
    inner = PerSample() if batching == "per_sample" else Lockstep()
    rng = np.random.default_rng(seed)

    # Shared vector field; per-request state {"y", "scale"} — 'scale'
    # spreads request stiffness over a decade (d scale/dt = 0).
    w1 = jnp.asarray(rng.standard_normal((d_state, d_state)) * 0.4,
                     jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((d_state, d_state)) * 0.4,
                     jnp.float32)
    params = {"w1": w1, "w2": w2}

    def f(p, z, t):
        h = jnp.tanh(z["y"] @ p["w1"])
        return {"y": z["scale"] * (h @ p["w2"] - z["y"]),
                "scale": jnp.zeros_like(z["scale"])}

    z0 = {
        "y": jnp.asarray(rng.standard_normal((batch, d_state)), jnp.float32),
        "scale": jnp.asarray(
            10.0 ** rng.uniform(0.0, 1.0, (batch, 1)), jnp.float32),
    }

    with mesh:
        z0 = jax.device_put(z0, batch_sharding(mesh, "data"))
        run = jax.jit(lambda z: solve(
            f, params, z, 0.0, t1, solver=ALF(eta=0.9),
            controller=AdaptiveController(rtol, atol, max_steps),
            gradient=MALI(),
            batching=Sharded(axis="data", inner=inner)))
        sol = run(z0)                       # compile + warm
        jax.block_until_ready(sol.ys)
        t0 = time.time()
        sol = run(z0)
        jax.block_until_ready(sol.ys)
        dt = time.time() - t0

    per = sol.stats.per_sample
    print(f"ode fleet: batch={batch} d={d_state} "
          f"mesh=data:{mesh.shape['data']} inner={inner.name}")
    print(f"solve: {dt * 1e3:.1f} ms ({batch / max(dt, 1e-9):.0f} solves/s)")
    print(f"f-evals: total={int(sol.stats.n_fevals)} "
          f"per-request min/median/max = {int(jnp.min(per.n_fevals))}/"
          f"{int(jnp.median(per.n_fevals))}/{int(jnp.max(per.n_fevals))}")
    print(f"steps: accepted={int(sol.stats.n_accepted)} "
          f"rejected={int(sol.stats.n_rejected)}")
    return sol


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", default="lm", choices=["lm", "ode"],
                    help="lm: prefill/decode serving; ode: batched-ODE fleet")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=None,
                    help="requests per step (default: 4 for lm, 64 for ode)")
    ap.add_argument("--ode", default="on", choices=["on", "off"])
    ap.add_argument("--ode-batching", default="per_sample",
                    choices=["per_sample", "lockstep"],
                    help="inner batching of the sharded ODE fleet")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--production-mesh", action="store_true")
    a = ap.parse_args()
    if a.mode == "ode":
        serve_ode(batch=64 if a.batch is None else a.batch,
                  batching=a.ode_batching,
                  production_mesh=a.production_mesh)
        return
    serve(a.arch, smoke=a.smoke, ode=a.ode == "on", prompt_len=a.prompt_len,
          decode_tokens=a.decode_tokens,
          batch=4 if a.batch is None else a.batch,
          production_mesh=a.production_mesh)


if __name__ == "__main__":
    main()
