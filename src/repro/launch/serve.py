"""Serving driver: batched prefill + autoregressive decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --prompt-len 32 --decode-tokens 16 --batch 4

Greedy decoding over the synthetic token stream; prints per-phase timings
and tokens/s. The same prefill/decode step functions are what the dry-run
lowers at the assigned 32k/500k shapes on the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DEFAULT_ODE, get_config, smoke_config
from repro.core.ode_block import OdeSettings
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        param_shardings, replicated)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_lm
from repro.models.lm import ServeState, init_serve_state


def serve(arch: str, *, smoke: bool = True, ode: bool = True,
          prompt_len: int = 32, decode_tokens: int = 16, batch: int = 4,
          production_mesh: bool = False, seed: int = 0):
    settings = DEFAULT_ODE if ode else OdeSettings(mode="off")
    cfg = smoke_config(arch, settings) if smoke else get_config(arch, settings)
    mesh = make_production_mesh() if production_mesh else make_host_mesh()
    s_max = prompt_len + decode_tokens
    rng = np.random.default_rng(seed)

    with mesh:
        params = init_lm(jax.random.PRNGKey(seed), cfg)
        params = jax.device_put(params, param_shardings(cfg, mesh, params))
        state = init_serve_state(cfg, batch, s_max)
        st_sh = ServeState(cache_shardings(cfg, mesh, state.cache, batch),
                           replicated(mesh))
        state = jax.device_put(state, st_sh)

        prefill = jax.jit(make_prefill_step(cfg), donate_argnums=(2,))
        decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

        if cfg.input_mode == "embeds":
            prompt = {"embeds": jnp.asarray(rng.standard_normal(
                (batch, prompt_len, cfg.d_model)).astype(np.float32))}
        else:
            prompt = {"tokens": jnp.asarray(rng.integers(
                0, cfg.vocab_size, (batch, prompt_len)).astype(np.int32))}

        t0 = time.time()
        logits, state = prefill(params, prompt, state)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        out_tokens = []
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        t0 = time.time()
        for _ in range(decode_tokens):
            if cfg.input_mode == "embeds":
                # stub frontend: feed the token id through a fixed projection
                inp = jnp.tile(tok[..., None].astype(jnp.float32),
                               (1, 1, cfg.d_model)) * 1e-3
            else:
                inp = tok
            logits, state = decode(params, inp, state)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            out_tokens.append(np.asarray(tok[:, 0]))
        jax.block_until_ready(logits)
        t_decode = time.time() - t0

    toks = np.stack(out_tokens, 1)
    print(f"arch={cfg.name} batch={batch} prompt={prompt_len} "
          f"decode={decode_tokens}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({batch * prompt_len / max(t_prefill, 1e-9):.0f} tok/s)")
    print(f"decode:  {t_decode * 1e3:.1f} ms "
          f"({batch * decode_tokens / max(t_decode, 1e-9):.0f} tok/s)")
    print("sample:", toks[0][:12].tolist())
    return toks


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ode", default="on", choices=["on", "off"])
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--production-mesh", action="store_true")
    a = ap.parse_args()
    serve(a.arch, smoke=a.smoke, ode=a.ode == "on", prompt_len=a.prompt_len,
          decode_tokens=a.decode_tokens, batch=a.batch,
          production_mesh=a.production_mesh)


if __name__ == "__main__":
    main()
