"""Deterministic, shardable synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — this is the
straggler/elasticity story: any host can regenerate any shard of any step,
so re-sharding after a node loss or reassigning a slow host's shard is a
metadata operation, with no data movement (DESIGN.md §6).

Token streams are Zipf-ish (heavy-headed) so CE losses are non-degenerate;
'embeds' mode generates Gaussian frame/patch embeddings for the stub-
frontend archs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Pytree = Any


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128


def _shard_key(seed: int, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))


def make_batch(cfg: ModelConfig, dcfg: DataConfig, step: int,
               shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
    """One shard of one step's global batch, as host numpy."""
    assert dcfg.global_batch % n_shards == 0
    b = dcfg.global_batch // n_shards
    rng = _shard_key(dcfg.seed, step, shard)
    s = dcfg.seq_len
    if cfg.input_mode == "embeds":
        emb = rng.standard_normal((b, s, cfg.d_model), np.float32) * 0.02
        labels = rng.zipf(1.5, (b, s)).clip(1, cfg.vocab_size) - 1
        return {"embeds": emb, "labels": labels.astype(np.int32)}
    toks = rng.zipf(1.5, (b, s + 1)).clip(1, cfg.vocab_size) - 1
    toks = toks.astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class SyntheticStream:
    """Iterator over global batches placed with an optional NamedSharding."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig,
                 sharding: Optional[jax.sharding.NamedSharding] = None,
                 start_step: int = 0):
        self.cfg = cfg
        self.dcfg = dcfg
        self.sharding = sharding
        self.step = start_step

    def __iter__(self) -> Iterator[Pytree]:
        return self

    def __next__(self) -> Pytree:
        batch = make_batch(self.cfg, self.dcfg, self.step)
        self.step += 1
        out = {k: jnp.asarray(v) for k, v in batch.items()}
        if self.sharding is not None:
            out = {k: jax.device_put(v, self.sharding) for k, v in out.items()}
        return out
