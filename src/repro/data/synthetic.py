"""Deterministic, shardable synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — this is the
straggler/elasticity story: any host can regenerate any shard of any step,
so re-sharding after a node loss or reassigning a slow host's shard is a
metadata operation, with no data movement (DESIGN.md §6).

Token streams are Zipf-ish (heavy-headed) so CE losses are non-degenerate;
'embeds' mode generates Gaussian frame/patch embeddings for the stub-
frontend archs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Pytree = Any


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128


def _shard_key(seed: int, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))


def make_batch(cfg: ModelConfig, dcfg: DataConfig, step: int,
               shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
    """One shard of one step's global batch, as host numpy."""
    assert dcfg.global_batch % n_shards == 0
    b = dcfg.global_batch // n_shards
    rng = _shard_key(dcfg.seed, step, shard)
    s = dcfg.seq_len
    if cfg.input_mode == "embeds":
        emb = rng.standard_normal((b, s, cfg.d_model), np.float32) * 0.02
        labels = rng.zipf(1.5, (b, s)).clip(1, cfg.vocab_size) - 1
        return {"embeds": emb, "labels": labels.astype(np.int32)}
    toks = rng.zipf(1.5, (b, s + 1)).clip(1, cfg.vocab_size) - 1
    toks = toks.astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_image_batch(dcfg: DataConfig, step: int, shard: int = 0,
                     n_shards: int = 1,
                     shape: tuple = (28, 28, 1)) -> Dict[str, np.ndarray]:
    """One shard of one step's MNIST-shaped image batch (the repro.cnf
    pipeline's data feed), as host numpy.

    Same determinism contract as :func:`make_batch`: a pure function of
    (seed, step, shard), so any host can regenerate any shard. Images are
    smooth multi-blob intensity fields quantized to 256 levels in [0, 1)
    — structured enough that a flow beats the raw-Gaussian baseline,
    with a quantization grid that makes dequantized bits/dim meaningful.
    Returned flattened: ``{"image": (b, H*W*C) float32}``.
    """
    assert dcfg.global_batch % n_shards == 0
    b = dcfg.global_batch // n_shards
    rng = _shard_key(dcfg.seed, step, shard)
    h, w, c = shape
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    centers = rng.uniform(0, [h, w], (b, 3, 2)).astype(np.float32)
    widths = rng.uniform(h / 10, h / 4, (b, 3)).astype(np.float32)
    img = np.zeros((b, h, w), np.float32)
    for k in range(3):
        d2 = ((yy[None] - centers[:, k, 0, None, None]) ** 2
              + (xx[None] - centers[:, k, 1, None, None]) ** 2)
        img += np.exp(-d2 / (2 * widths[:, k, None, None] ** 2))
    img /= img.max(axis=(1, 2), keepdims=True).clip(1e-6)
    img = np.floor(img * 255.0) / 256.0  # 256-level quantization grid
    img = np.repeat(img[..., None], c, axis=-1)
    return {"image": img.reshape(b, h * w * c).astype(np.float32)}


class SyntheticStream:
    """Iterator over global batches placed with an optional NamedSharding."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig,
                 sharding: Optional[jax.sharding.NamedSharding] = None,
                 start_step: int = 0):
        self.cfg = cfg
        self.dcfg = dcfg
        self.sharding = sharding
        self.step = start_step

    def __iter__(self) -> Iterator[Pytree]:
        return self

    def __next__(self) -> Pytree:
        batch = make_batch(self.cfg, self.dcfg, self.step)
        self.step += 1
        out = {k: jnp.asarray(v) for k, v in batch.items()}
        if self.sharding is not None:
            out = {k: jax.device_put(v, self.sharding) for k, v in out.items()}
        return out
