from .synthetic import DataConfig, SyntheticStream, make_batch

__all__ = ["DataConfig", "SyntheticStream", "make_batch"]
