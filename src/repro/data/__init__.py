from .synthetic import DataConfig, SyntheticStream, make_batch
