from .synthetic import (DataConfig, SyntheticStream, make_batch,
                        make_image_batch)

__all__ = ["DataConfig", "SyntheticStream", "make_batch", "make_image_batch"]
