"""The Trainer: resumable, fault-tolerant continuous-depth training.

Composes the whole substrate behind one object::

    from repro.train import Trainer, TrainerConfig
    t = Trainer(TrainerConfig(steps=20, ckpt_dir="/tmp/run1"))
    t.train()
    t.loss_trace()      # per-step losses (records survive restarts)

The model's residual branches are native ``solve()`` calls —
``gradient=MALI(...)`` (or naive/aca/adjoint), ``ALF(backend='pallas')``
when an accelerator is present (``ode_backend='auto'``), and
``Sharded(axis, inner=Lockstep())`` batching over the ambient mesh when
``ode_batch_axis`` names one. The loop driver is a registered
:class:`~repro.train.loop.TrainLoop`; the jitted step is the module-level
value-hash-keyed ``jitted_train_step`` (one trace per distinct config
*value*, not instance).

Resumability: every checkpoint carries ``(params, opt, ef, rng)`` plus the
:func:`~repro.train.state.config_fingerprint` of the integrator/optimizer
settings, and a resume under a different config raises
:class:`~repro.train.state.ConfigMismatchError` instead of silently
continuing a different trajectory. Failures inside the loop restart from
the latest checkpoint via ``run_with_recovery``; because batches are pure
functions of (seed, step) and the step is deterministic, the recomputed
post-checkpoint steps reproduce the uninterrupted run's loss trace
bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.checkpoint import AsyncCheckpointer, list_checkpoints
from repro.configs import get_config, smoke_config
from repro.core.ode_block import OdeSettings
from repro.data.synthetic import DataConfig, make_batch
from repro.distributed.fault_tolerance import run_with_recovery
from repro.distributed.sharding import (batch_shardings, opt_state_shardings,
                                        param_shardings, replicated)
from repro.launch.hlo_cost import count_pallas_launches
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_lm
from repro.optim.optimizer import OptimizerConfig, OptState, init_opt_state
from repro.train.loop import get_train_loop, train_step
from repro.train.metrics import (MetricsEmitter, StepRecord, make_emitter,
                                 ode_residual_bytes)
from repro.train.state import (TrainState, config_fingerprint,
                               restore_train_state, state_tree)

log = logging.getLogger("repro.train")

# The paper's default pairings (GradientMethod.default_solver()).
_SOLVER_FOR = {"mali": "alf", "naive": "alf", "aca": "heun_euler",
               "adjoint": "dopri5"}


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    """Value-hashable run description (frozen: equal values reuse traces)."""
    arch: str = "qwen3-1.7b"
    smoke: bool = True              # reduced config; --full on a real slice
    ode: bool = True                # continuous depth on/off
    ode_steps: int = 2              # 0 = adaptive controller
    ode_method: str = "mali"        # mali | naive | aca | adjoint
    ode_backend: str = "auto"       # auto | reference | pallas
    ode_batch_axis: str = ""        # mesh axis for Sharded() solves; '' = off
    steps: int = 50
    global_batch: int = 8
    seq_len: int = 64
    microbatches: int = 1
    loop: str = "standard"          # TRAIN_LOOPS key
    ckpt_dir: str = ""
    ckpt_every: int = 20
    keep: int = 3
    seed: int = 0
    log_every: int = 10
    emit: str = "stdout"            # EMITTERS key
    metrics_path: str = ""          # for emit='jsonl'
    production_mesh: bool = False   # needs a real multi-chip slice
    multi_pod: bool = False
    max_failures: int = 3

    def ode_settings(self) -> OdeSettings:
        if not self.ode:
            return OdeSettings(mode="off")
        backend = self.ode_backend
        if backend == "auto":
            backend = ("pallas" if jax.default_backend() != "cpu"
                       else "reference")
        return OdeSettings(
            mode="per_block", method=self.ode_method,
            solver=_SOLVER_FOR[self.ode_method], n_steps=self.ode_steps,
            backend=backend, batch_axis=self.ode_batch_axis or None)


def build(tc: TrainerConfig):
    """(model config, mesh, optimizer config) for one run description."""
    ode = tc.ode_settings()
    cfg = (smoke_config(tc.arch, ode) if tc.smoke
           else get_config(tc.arch, ode))
    mesh = (make_production_mesh(multi_pod=tc.multi_pod)
            if tc.production_mesh else make_host_mesh())
    opt_cfg = OptimizerConfig(total_steps=tc.steps,
                              warmup_steps=max(tc.steps // 20, 1))
    return cfg, mesh, opt_cfg


class Trainer:
    """One training run. ``step_hook(step)`` (if given) runs before each
    step on the host — the fault-injection point for recovery tests."""

    def __init__(self, config: TrainerConfig,
                 emitter: Optional[MetricsEmitter] = None,
                 step_hook: Optional[Callable[[int], None]] = None):
        self.config = config
        self.cfg, self.mesh, self.opt_cfg = build(config)
        self.loop = get_train_loop(config.loop)
        self.emitter = emitter if emitter is not None else make_emitter(
            config.emit, config.metrics_path)
        self.step_hook = step_hook
        self.records: Dict[int, StepRecord] = {}
        self.pallas_launches = 0
        self._state: Optional[TrainState] = None

    @property
    def state(self) -> Optional[TrainState]:
        """Final :class:`TrainState` after :meth:`train` (None before)."""
        return self._state

    def loss_trace(self):
        """Per-step losses in step order. Restarted steps overwrite their
        first attempt, so after a recovery this equals the uninterrupted
        run's trace (the continuity property the tests assert)."""
        return [self.records[s].loss for s in sorted(self.records)]

    def train(self) -> int:
        tc = self.config
        cfg, mesh, opt_cfg = self.cfg, self.mesh, self.opt_cfg
        dcfg = DataConfig(seed=tc.seed, global_batch=tc.global_batch,
                          seq_len=tc.seq_len)
        fingerprint = config_fingerprint(
            cfg, opt_cfg, arch=tc.arch, loop=tc.loop,
            microbatches=tc.microbatches, seed=tc.seed,
            global_batch=tc.global_batch, seq_len=tc.seq_len)
        ckpt = (AsyncCheckpointer(tc.ckpt_dir, keep=tc.keep)
                if tc.ckpt_dir else None)
        residual_bytes = ode_residual_bytes(
            cfg, tc.global_batch // max(tc.microbatches, 1), tc.seq_len)
        compress = self.loop.name == "compressed"

        with mesh:
            params = init_lm(jax.random.PRNGKey(tc.seed), cfg)
            p_sh = param_shardings(cfg, mesh, params)
            o_sh = OptState(replicated(mesh),
                            *(opt_state_shardings(cfg, mesh, p_sh,
                                                  params),) * 3)
            params = jax.device_put(params, p_sh)
            opt_state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s),
                init_opt_state(opt_cfg, params),
                OptState(o_sh.step, o_sh.m, o_sh.v, o_sh.master))
            state = TrainState(params, opt_state,
                               self.loop.init_carry(params),
                               jax.random.PRNGKey(tc.seed + 1))
            zero1 = mesh.size > 1
            b_sh = None

            def put_batch(step: int):
                nonlocal b_sh
                batch = {k: jax.numpy.asarray(v)
                         for k, v in make_batch(cfg, dcfg, step).items()}
                if b_sh is None:
                    b_sh = batch_shardings(cfg, mesh, batch)
                return {k: jax.device_put(v, b_sh[k])
                        for k, v in batch.items()}

            batch0 = put_batch(0)
            carry0 = state.ef
            self.pallas_launches = count_pallas_launches(
                lambda p, o, b: train_step(
                    p, o, carry0, b, cfg=cfg, opt_cfg=opt_cfg,
                    microbatches=tc.microbatches, compress=compress,
                    zero1=False),
                state.params, state.opt, batch0)

            def train_loop(resume: Optional[int]) -> int:
                nonlocal state
                start = 0
                if resume is not None and ckpt is not None:
                    got = restore_train_state(tc.ckpt_dir, state, fingerprint)
                    if got is not None:
                        start, restored, _meta = got
                        state = TrainState(
                            jax.device_put(restored.params, p_sh),
                            restored.opt, restored.ef, restored.rng)
                        log.info("resumed from step %d", start)
                for step in range(start, tc.steps):
                    if self.step_hook is not None:
                        self.step_hook(step)
                    t0 = time.time()
                    batch = put_batch(step) if step else batch0
                    p, o, carry, metrics = self.loop.step(
                        state.params, state.opt, state.ef, batch, cfg=cfg,
                        opt_cfg=opt_cfg, microbatches=tc.microbatches,
                        zero1=zero1)
                    loss = float(metrics["loss"])   # syncs the step
                    if not np.isfinite(loss):
                        raise RuntimeError(f"non-finite loss at step {step}")
                    state = TrainState(p, o, carry,
                                       jax.random.fold_in(state.rng, step))
                    rec = StepRecord(
                        step=step, loss=loss, lr=float(metrics["lr"]),
                        grad_norm=float(metrics["grad_norm"]),
                        wall_s=time.time() - t0,
                        fevals=int(metrics["ode_fevals"]),
                        accepted=int(metrics["ode_accepted"]),
                        rejected=int(metrics["ode_rejected"]),
                        residual_bytes=residual_bytes,
                        pallas_launches=self.pallas_launches)
                    self.records[step] = rec
                    self.emitter.emit(rec)
                    if step % tc.log_every == 0 or step == tc.steps - 1:
                        log.info("step %d loss %.4f lr %.2e gnorm %.2f "
                                 "fevals %d", step, loss, rec.lr,
                                 rec.grad_norm, rec.fevals)
                    if ckpt is not None and (step + 1) % tc.ckpt_every == 0:
                        ckpt.save(step + 1, state_tree(state),
                                  metadata={**fingerprint, "loss": loss})
                return tc.steps

            def restore_step() -> Optional[int]:
                if ckpt is None:
                    return None
                ckpt.wait()   # a crash may race an in-flight save
                ckpts = list_checkpoints(tc.ckpt_dir)
                return ckpts[-1][0] if ckpts else None

            final, rstats = run_with_recovery(
                train_loop, restore_step, max_failures=tc.max_failures)
            if ckpt is not None:
                ckpt.save(final, state_tree(state),
                          metadata={**fingerprint, "final": True})
                ckpt.close()
            self.emitter.close()
            self._state = state
            log.info("done: step %d (failures=%d)", final, rstats.failures)
            return final
