"""Structured per-step training telemetry.

A :class:`StepRecord` is one row of the run's metrics table: optimizer
scalars (loss, lr, grad-norm), wall time, and the continuous-depth
accounting — dynamics evaluations and accepted/rejected trials from the
step's ``solve()`` calls (threaded out of the jitted step as RunStats
aux), the analytic MALI backward-residual footprint
(:func:`ode_residual_bytes` — the paper's O(1)-in-steps memory claim as a
number), and the pallas kernel launches per step
(``launch.hlo_cost.count_pallas_launches``, counted once at trace time).

:class:`MetricsEmitter` is the registered sink axis (R004): stdout JSON
lines, a JSONL file, or an in-memory list for tests.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Type

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class StepRecord:
    """One training step's telemetry row (all host scalars)."""
    step: int
    loss: float
    lr: float
    grad_norm: float
    wall_s: float           # wall time of this step (s)
    fevals: int             # dynamics evaluations across the step's solves
    accepted: int           # accepted solver trials
    rejected: int           # rejected solver trials
    residual_bytes: int     # analytic backward-residual footprint (static)
    pallas_launches: int    # pallas_call count in the step's jaxpr (static)

    def as_row(self) -> Dict:
        return dataclasses.asdict(self)


def ode_residual_bytes(cfg: ModelConfig, batch_size: int,
                       seq_len: int) -> int:
    """Analytic backward-residual bytes of one train step's solves.

    Per residual branch this is the gradient method's
    ``residual_bytes(z0, n_obs, solver, controller)`` — for MALI the
    per-observation (z, v) pairs, constant in step count; for Naive/ACA it
    grows with the step budget (paper Table 1) — times the number of ODE
    branches in the unrolled depth. Static shapes only; 0 with
    ``ode.mode='off'``.
    """
    if cfg.ode.mode == "off":
        return 0
    solver, controller, gradient, _ = cfg.ode.as_objects()
    z0 = jax.ShapeDtypeStruct((batch_size, seq_len, cfg.d_model),
                              jnp.float32)
    n_obs = 2 if cfg.ode.obs_times is None else len(cfg.ode.obs_times)
    per = gradient.residual_bytes(z0, n_obs, solver, controller)
    branches = sum(1 + (spec.mlp != "none") for spec in cfg.layers())
    return per * branches


class MetricsEmitter:
    """Base of the metrics-sink axis; registered in :data:`EMITTERS`."""

    name: str = "?"

    def emit(self, record: StepRecord) -> None:
        raise NotImplementedError

    def close(self) -> None:
        """Flush/release the sink (default: nothing to do)."""


class StdoutEmitter(MetricsEmitter):
    """One JSON line per step on stdout."""

    name = "stdout"

    def emit(self, record: StepRecord) -> None:
        print(json.dumps(record.as_row()), flush=True)


class JsonlEmitter(MetricsEmitter):
    """Append-only JSONL file (one row per step)."""

    name = "jsonl"

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "a")

    def emit(self, record: StepRecord) -> None:
        self._f.write(json.dumps(record.as_row()) + "\n")
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class MemoryEmitter(MetricsEmitter):
    """In-memory record list (tests / programmatic consumers)."""

    name = "memory"

    def __init__(self):
        self.records: List[StepRecord] = []

    def emit(self, record: StepRecord) -> None:
        self.records.append(record)


EMITTERS: Dict[str, Type[MetricsEmitter]] = {
    "stdout": StdoutEmitter,
    "jsonl": JsonlEmitter,
    "memory": MemoryEmitter,
}


def make_emitter(name: str, path: str = "") -> MetricsEmitter:
    try:
        cls = EMITTERS[name]
    except KeyError:
        raise ValueError(f"unknown metrics emitter {name!r}; "
                         f"choose from {sorted(EMITTERS)}") from None
    if cls is JsonlEmitter:
        if not path:
            raise ValueError("emitter 'jsonl' needs a file path")
        return cls(path)
    return cls()
