"""Train-step construction and the TrainLoop registry.

The step is a *pure module-level function* jitted once with value-hashed
static configs (``ModelConfig`` / ``OptimizerConfig`` are frozen dataclasses
hashing by value), so fresh-but-equal config instances reuse one trace —
the retrace contract ``analysis.trace_audit.run_train_audit`` checks.

Gradients come from ``jax.value_and_grad(lm_loss_and_stats, has_aux=True)``:
the continuous-depth model's residual branches are native
``solve(..., gradient=MALI(...))`` calls, and the aux
:class:`~repro.core.interface.RunStats` threads the per-step integration
accounting (f-evals, accepted/rejected trials) out of the jitted step —
the counters are laundered inside the model (R002c), so summing them over
a microbatch scan here is float0-safe.

:class:`TrainLoop` is the registered driver axis (R004 lint: every
registered loop overrides every abstract member and appears in tests):
:class:`StandardLoop` carries no extra state, :class:`CompressedLoop`
threads int8 error-feedback compression state through the step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core.interface import RunStats
from repro.distributed.sharding import ambient_mesh, param_shardings
from repro.models.lm import lm_loss_and_stats
from repro.models.transformer import add_run_stats, zero_run_stats
from repro.optim.compression import EFState, compress_grads, init_ef_state
from repro.optim.optimizer import OptimizerConfig, OptState, apply_updates

Pytree = Any
_tm = jax.tree_util.tree_map


def _split_microbatches(batch: Pytree, n: int) -> Pytree:
    return _tm(lambda a: a.reshape((n, a.shape[0] // n) + a.shape[1:]), batch)


def loss_and_grads(params: Pytree, batch: Pytree, *, cfg: ModelConfig,
                   microbatches: int = 1
                   ) -> Tuple[jax.Array, RunStats, Pytree]:
    """(mean loss, summed RunStats, mean grads) for one global batch.

    With ``microbatches > 1`` the global batch is split on its leading axis
    and accumulated through a ``lax.scan`` (sequential — peak memory is one
    microbatch's activations). Loss and grads are averaged over
    microbatches; the integration counters are *summed* (they count work
    actually done, so the total must not shrink with the split).
    """
    vg = jax.value_and_grad(lm_loss_and_stats, has_aux=True)

    def one(p, b):
        (loss, stats), grads = vg(p, cfg, b)
        return loss, stats, grads

    if microbatches <= 1:
        return one(params, batch)
    mbs = _split_microbatches(batch, microbatches)

    def acc(carry, mb):
        loss_acc, stats_acc, g_acc = carry
        loss, stats, g = one(params, mb)
        return (loss_acc + loss, add_run_stats(stats_acc, stats),
                _tm(jnp.add, g_acc, g)), None

    zeros = _tm(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, stats, grads), _ = lax.scan(
        acc, (jnp.float32(0.0), zero_run_stats(), zeros), mbs)
    inv = 1.0 / microbatches
    return loss * inv, stats, _tm(lambda g: g * inv, grads)


def train_step(params: Pytree, opt_state: OptState, ef: Optional[EFState],
               batch: Pytree, *, cfg: ModelConfig, opt_cfg: OptimizerConfig,
               microbatches: int = 1, compress: bool = False,
               zero1: bool = False
               ) -> Tuple[Pytree, OptState, Optional[EFState], Dict]:
    """One full training step as a pure function.

    ``zero1=True`` constrains the gradients to the parameter shardings of
    the ambient mesh before the optimizer: with ZeRO-1-sharded optimizer
    state this turns the DP gradient all-reduce into a reduce-scatter.
    ``compress=True`` routes the (constrained) gradients through int8
    error-feedback compression, threading ``ef``.
    """
    loss, stats, grads = loss_and_grads(params, batch, cfg=cfg,
                                        microbatches=microbatches)
    if zero1:
        mesh = ambient_mesh()
        if mesh is not None and mesh.size > 1:
            grads = jax.lax.with_sharding_constraint(
                grads, param_shardings(cfg, mesh, grads))
    if compress:
        grads, ef = compress_grads(grads, ef)
    params, opt_state, metrics = apply_updates(opt_cfg, params, grads,
                                               opt_state)
    metrics["loss"] = loss
    metrics["ode_accepted"] = stats.n_accepted
    metrics["ode_rejected"] = stats.n_rejected
    metrics["ode_fevals"] = stats.n_fevals
    return params, opt_state, ef, metrics


# One module-level jit: every Trainer instance (and every fresh-but-equal
# config) shares this cache. cfg/opt_cfg hash by value, so a restored run
# rebuilds its configs from the checkpoint manifest without retracing.
jitted_train_step = jax.jit(
    train_step, static_argnames=("cfg", "opt_cfg", "microbatches",
                                 "compress", "zero1"))


class TrainLoop:
    """Base of the training-loop axis: how one optimizer step is driven.

    A loop owns the step's *extra state* (``carry`` — e.g. error-feedback
    compression state) and maps ``(params, opt_state, carry, batch)`` to
    their successors plus a metrics dict. Subclasses are frozen dataclasses
    registered in :data:`TRAIN_LOOPS`.
    """

    name: str = "?"

    def init_carry(self, params: Pytree) -> Pytree:
        """Initial extra state for this loop (None when stateless)."""
        raise NotImplementedError

    def step(self, params: Pytree, opt_state: OptState, carry: Pytree,
             batch: Pytree, *, cfg: ModelConfig, opt_cfg: OptimizerConfig,
             microbatches: int = 1, zero1: bool = False
             ) -> Tuple[Pytree, OptState, Pytree, Dict]:
        """One optimizer step; returns (params, opt_state, carry, metrics)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class StandardLoop(TrainLoop):
    """Plain AdamW step (no gradient compression; carry is None)."""

    name = "standard"

    def init_carry(self, params: Pytree) -> None:
        return None

    def step(self, params, opt_state, carry, batch, *, cfg, opt_cfg,
             microbatches=1, zero1=False):
        params, opt_state, _, metrics = jitted_train_step(
            params, opt_state, None, batch, cfg=cfg, opt_cfg=opt_cfg,
            microbatches=microbatches, compress=False, zero1=zero1)
        return params, opt_state, None, metrics


@dataclasses.dataclass(frozen=True)
class CompressedLoop(TrainLoop):
    """int8 error-feedback gradient compression; carry is the EF residual
    (part of the resumable state — dropping it on restore silently changes
    the gradient stream)."""

    name = "compressed"

    def init_carry(self, params: Pytree) -> EFState:
        return init_ef_state(params)

    def step(self, params, opt_state, carry, batch, *, cfg, opt_cfg,
             microbatches=1, zero1=False):
        params, opt_state, carry, metrics = jitted_train_step(
            params, opt_state, carry, batch, cfg=cfg, opt_cfg=opt_cfg,
            microbatches=microbatches, compress=True, zero1=zero1)
        return params, opt_state, carry, metrics


TRAIN_LOOPS: Dict[str, TrainLoop] = {
    "standard": StandardLoop(),
    "compressed": CompressedLoop(),
}


def get_train_loop(name: str) -> TrainLoop:
    try:
        return TRAIN_LOOPS[name]
    except KeyError:
        raise ValueError(f"unknown train loop {name!r}; "
                         f"choose from {sorted(TRAIN_LOOPS)}") from None
