"""repro.train — the continuous-depth training subsystem.

First-class training on the composable ``solve()`` API: the
:class:`~repro.train.trainer.Trainer` composes the continuous-depth LM
(whose residual branches are native ``solve(..., gradient=MALI(...))``
calls) with a registered :class:`~repro.train.loop.TrainLoop` driver,
resumable checkpoint state (:mod:`repro.train.state` — params, optimizer,
error-feedback, RNG *and* the solver/gradient config fingerprint), fault
recovery (:func:`repro.distributed.fault_tolerance.run_with_recovery`),
and structured telemetry (:mod:`repro.train.metrics`).

``repro.launch.train`` is a thin CLI over this package; see
``src/repro/train/README.md`` for the architecture.
"""
from .loop import (TRAIN_LOOPS, CompressedLoop, StandardLoop, TrainLoop,
                   get_train_loop, loss_and_grads, train_step)
from .metrics import (EMITTERS, JsonlEmitter, MemoryEmitter, MetricsEmitter,
                      StdoutEmitter, StepRecord, make_emitter,
                      ode_residual_bytes)
from .state import (ConfigMismatchError, TrainState, config_fingerprint,
                    restore_train_state, state_tree)
from .trainer import Trainer, TrainerConfig

__all__ = [
    "Trainer", "TrainerConfig",
    "TrainLoop", "StandardLoop", "CompressedLoop", "TRAIN_LOOPS",
    "get_train_loop", "loss_and_grads", "train_step",
    "StepRecord", "MetricsEmitter", "StdoutEmitter", "JsonlEmitter",
    "MemoryEmitter", "EMITTERS", "make_emitter", "ode_residual_bytes",
    "TrainState", "ConfigMismatchError", "config_fingerprint",
    "restore_train_state", "state_tree",
]
