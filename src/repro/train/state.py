"""Resumable training state: what a checkpoint must carry to continue a
run *bit-identically*.

The state is more than (params, optimizer): the RNG key and — critically —
the solver/gradient configuration are part of it. A run trained with
``gradient=MALI(...)`` produces a different parameter trajectory than one
trained with ``Naive()`` at the same seed (different rounding, different
step placement under adaptive control), so silently resuming under a
different integrator corrupts the run while looking healthy. Every
checkpoint therefore embeds a :func:`config_fingerprint` of the model's
ODE settings + optimizer config + data/loop knobs, and
:func:`restore_train_state` refuses a mismatched resume with
:class:`ConfigMismatchError`.

``ConfigMismatchError`` deliberately subclasses plain ``Exception`` — not
RuntimeError/OSError/ValueError — so it propagates straight through
``distributed.fault_tolerance.run_with_recovery`` (which retries those
three) instead of being retried forever against the same checkpoint.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax

from repro.checkpoint.checkpoint import restore_latest
from repro.configs.base import ModelConfig
from repro.optim.compression import EFState
from repro.optim.optimizer import OptimizerConfig, OptState

Pytree = Any


class TrainState(NamedTuple):
    """Everything array-valued a resume needs (the fingerprint rides in the
    checkpoint manifest next to it)."""
    params: Pytree
    opt: OptState
    ef: Optional[EFState]    # error-feedback carry (None for StandardLoop)
    rng: jax.Array           # PRNG key folded per step


class ConfigMismatchError(Exception):
    """A checkpoint's config fingerprint disagrees with the current run's.

    Not a RuntimeError/ValueError subclass on purpose: run_with_recovery
    retries those, and a config mismatch never heals by retrying.
    """


def config_fingerprint(cfg: ModelConfig, opt_cfg: OptimizerConfig, *,
                       arch: str, loop: str, microbatches: int, seed: int,
                       global_batch: int, seq_len: int) -> Dict[str, Any]:
    """JSON-able config payload + a stable short hash over it.

    Covers everything that steers the parameter trajectory: the full ODE
    settings (method/solver/steps/tolerances/backend), the optimizer
    schedule, the data shape/seed, and the loop/microbatch split.
    """
    payload = {
        "arch": arch,
        "ode": dataclasses.asdict(cfg.ode),
        "opt": dataclasses.asdict(opt_cfg),
        "loop": loop,
        "microbatches": microbatches,
        "seed": seed,
        "global_batch": global_batch,
        "seq_len": seq_len,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
    }
    digest = hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()).hexdigest()[:16]
    return {"config": payload, "config_hash": digest}


def state_tree(state: TrainState) -> Dict[str, Any]:
    """The checkpointed pytree. ``ef=None`` contributes no leaves, so a
    StandardLoop checkpoint and its restore template agree structurally."""
    return {"params": state.params, "opt": state.opt, "ef": state.ef,
            "rng": state.rng}


def restore_train_state(ckpt_dir: str, like: TrainState,
                        fingerprint: Dict[str, Any]
                        ) -> Optional[Tuple[int, TrainState, dict]]:
    """Restore the latest checkpoint into ``like``'s structure.

    Returns (step, state, metadata) or None when the directory holds no
    checkpoint. Raises :class:`ConfigMismatchError` when the checkpoint
    was written under a different config fingerprint (different
    integrator/optimizer/data settings — resuming would silently change
    the training trajectory).
    """
    got = restore_latest(ckpt_dir, state_tree(like))
    if got is None:
        return None
    step, tree, meta = got
    saved = meta.get("config_hash")
    want = fingerprint["config_hash"]
    if saved is not None and saved != want:
        saved_cfg = meta.get("config", {})
        diff = {k: (saved_cfg.get(k), fingerprint["config"].get(k))
                for k in set(saved_cfg) | set(fingerprint["config"])
                if saved_cfg.get(k) != fingerprint["config"].get(k)}
        raise ConfigMismatchError(
            f"checkpoint at step {step} in {ckpt_dir!r} was written under a "
            f"different training config (hash {saved} != {want}); "
            f"differing fields: {diff}. Resuming would silently change the "
            "parameter trajectory — restart with the original config or a "
            "fresh ckpt dir.")
    state = TrainState(params=tree["params"], opt=tree["opt"],
                       ef=tree["ef"], rng=tree["rng"])
    return step, state, meta
