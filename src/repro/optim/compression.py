"""Gradient compression: int8 quantization with error feedback (1-bit-Adam /
EF-SGD family). Used around the data-parallel gradient reduction: each
replica quantizes its local gradient contribution, the residual is carried
to the next step, so compression error does not accumulate.

In the GSPMD execution model the all-reduce is implicit in the sharding, so
this module exposes the quantize/dequantize pair + error-feedback state; the
train step applies Q(g + e) -> dequant -> optimizer, e' = (g + e) - deq.
On a real deployment the int8 payload is what crosses ICI/DCN (a shard_map
psum over the int8 payload with i32 accumulation); here the numerics —
which is what affects training — are exact, and the bytes saving is
accounted analytically in the roofline (§Perf discussion).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any
_tm = jax.tree_util.tree_map


class EFState(NamedTuple):
    error: Pytree   # f32 residual per param


def init_ef_state(params: Pytree) -> EFState:
    return EFState(_tm(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Pytree, ef: EFState) -> Tuple[Pytree, EFState]:
    """Error-feedback int8 round-trip: returns (deq_grads, new_ef)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    out = _tm(one, grads, ef.error)
    deq = _tm(lambda o: o[0], out, is_leaf=lambda o: isinstance(o, tuple))
    err = _tm(lambda o: o[1], out, is_leaf=lambda o: isinstance(o, tuple))
    return deq, EFState(err)


def compressed_bytes(grads: Pytree) -> int:
    """Wire bytes if the DP reduction carried int8 payloads (for §Roofline)."""
    return sum(l.size for l in jax.tree_util.tree_leaves(grads))
