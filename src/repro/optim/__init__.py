from .optimizer import (OptimizerConfig, OptState, apply_updates,
                        init_opt_state, lr_schedule)
from .compression import EFState, compress_grads, init_ef_state

__all__ = ["OptimizerConfig", "OptState", "apply_updates",
           "init_opt_state", "lr_schedule", "EFState", "compress_grads",
           "init_ef_state"]
