"""Optimizers: AdamW (low-precision moments + f32 master weights) and SGD,
with warmup+cosine schedule and global-norm clipping.

Memory posture for the large archs (DESIGN.md §6): params live in bf16; the
optimizer carries an f32 master copy plus bf16 m/v by default (8 bytes/param
of state). All optimizer state is sharded exactly like the parameters (and
additionally over 'data' for fsdp_tp archs) — ZeRO-1 falls out of the
sharding spec, not the math.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any
_tm = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # 'adamw' | 'sgd'
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    momentum_dtype: str = "bfloat16"   # m/v storage dtype
    master_dtype: str = "float32"      # master weight copy ('' = none)
    momentum: float = 0.9              # sgd


class OptState(NamedTuple):
    step: jax.Array
    m: Pytree
    v: Pytree          # sgd: zeros-like placeholder (empty leaves)
    master: Pytree     # f32 master copy ('' master_dtype -> params alias)


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.peak_lr * warm * decay


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Tuple[Pytree, jax.Array]:
    """max_norm <= 0 disables clipping (norm still computed for metrics)."""
    norm = global_norm(grads)
    if max_norm <= 0:
        return grads, norm
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return _tm(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
               grads), norm


def init_opt_state(cfg: OptimizerConfig, params: Pytree) -> OptState:
    mdt = jnp.dtype(cfg.momentum_dtype)
    m = _tm(lambda p: jnp.zeros(p.shape, mdt), params)
    if cfg.name == "adamw":
        v = _tm(lambda p: jnp.zeros(p.shape, mdt), params)
    else:
        v = _tm(lambda p: jnp.zeros((0,), jnp.float32), params)
    if cfg.master_dtype:
        master = _tm(lambda p: p.astype(jnp.dtype(cfg.master_dtype)), params)
    else:
        master = _tm(lambda p: jnp.zeros((0,), jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), m, v, master)


def apply_updates(cfg: OptimizerConfig, params: Pytree, grads: Pytree,
                  state: OptState) -> Tuple[Pytree, OptState, dict]:
    """One optimizer step; returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    mdt = jnp.dtype(cfg.momentum_dtype)

    def current_master(p, mw):
        return mw.astype(jnp.float32) if cfg.master_dtype else p.astype(jnp.float32)

    if cfg.name == "adamw":
        bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v, mw):
            gf = g.astype(jnp.float32)
            mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
            vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
            mhat = mf / bc1
            vhat = vf / bc2
            w = current_master(p, mw)
            w = w - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * w)
            new_master = w.astype(jnp.dtype(cfg.master_dtype)) if cfg.master_dtype else mw
            return w.astype(p.dtype), mf.astype(mdt), vf.astype(mdt), new_master

        out = _tm(upd, params, grads, state.m, state.v, state.master)
        new_params = _tm(lambda o: o[0], out, is_leaf=lambda o: isinstance(o, tuple))
        new_m = _tm(lambda o: o[1], out, is_leaf=lambda o: isinstance(o, tuple))
        new_v = _tm(lambda o: o[2], out, is_leaf=lambda o: isinstance(o, tuple))
        new_master = _tm(lambda o: o[3], out, is_leaf=lambda o: isinstance(o, tuple))
        return (new_params, OptState(step, new_m, new_v, new_master),
                {"lr": lr, "grad_norm": gnorm})

    # SGD + momentum (the paper's Cifar/ImageNet optimizer)
    def upd_sgd(p, g, m, mw):
        gf = g.astype(jnp.float32)
        w = current_master(p, mw)
        gf = gf + cfg.weight_decay * w
        mf = cfg.momentum * m.astype(jnp.float32) + gf
        w = w - lr * mf
        new_master = w.astype(jnp.dtype(cfg.master_dtype)) if cfg.master_dtype else mw
        return w.astype(p.dtype), mf.astype(mdt), new_master

    out = _tm(upd_sgd, params, grads, state.m, state.master)
    new_params = _tm(lambda o: o[0], out, is_leaf=lambda o: isinstance(o, tuple))
    new_m = _tm(lambda o: o[1], out, is_leaf=lambda o: isinstance(o, tuple))
    new_master = _tm(lambda o: o[2], out, is_leaf=lambda o: isinstance(o, tuple))
    return (new_params, OptState(step, new_m, state.v, new_master),
            {"lr": lr, "grad_norm": gnorm})
