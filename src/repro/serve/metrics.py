"""Serving metrics: per-request records and the aggregate load report.

Everything is measured on the engine's *virtual clock* (wall-calibrated:
it advances by measured dispatch time and jumps over idle gaps), so the
numbers compose consistently:

* **latency** — ``completion - arrival`` per request; p50/p99 over the
  run. Queue wait is included: a request that sat behind a straggler pays
  for it here, which is exactly the effect continuous batching removes.
* **solves_per_s** — completed requests / busy duration.
* **fevals_per_request** — mean dynamics evaluations per request (cache
  hits contribute 0, which is the point of the interpolant cache).
* **backfill_occupancy** — mean fraction of batch slots active at
  dispatch, sampled once per chunk round. The static fleet's occupancy
  decays as stragglers strand finished rows; continuous batching holds it
  near 1 under load.
* **cache_hit_rate** — interpolant-cache hits / lookups.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

__all__ = ["RequestRecord", "ServeReport", "percentile", "summarize",
           "format_report"]


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    """One served request's accounting row.

    ``lane`` is how it was served: ``batch`` (chunked slots), ``dense``
    (per-request dense solve), ``eval`` (dense solve + interpolant
    queries) or ``event``. ``completed=False`` marks a budget-exhausted
    solve whose end state was returned anyway (truncated span).
    """
    rid: int
    arrival: float
    completion: float
    n_fevals: int
    n_accepted: int
    completed: bool
    lane: str = "batch"
    cache_hit: bool = False

    @property
    def latency(self) -> float:
        return self.completion - self.arrival


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile (numpy's default) over a small
    host-side list; q in [0, 100]. Returns nan for an empty input."""
    if not values:
        return math.nan
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile: q must be in [0, 100], got {q}")
    xs = sorted(values)
    pos = (len(xs) - 1) * (q / 100.0)
    lo = math.floor(pos)
    hi = math.ceil(pos)
    if lo == hi:
        return xs[lo]
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)


@dataclasses.dataclass(frozen=True)
class ServeReport:
    """Aggregate metrics for one serving run (one engine, one workload)."""
    engine: str
    n_requests: int
    n_completed: int
    n_rejected: int
    duration_s: float
    p50_latency_s: float
    p99_latency_s: float
    mean_latency_s: float
    solves_per_s: float
    fevals_per_request: float
    backfill_occupancy: float
    rounds: int
    cache_hits: int
    cache_misses: int
    cache_evictions: int
    cache_hit_rate: float


def summarize(engine: str, records: List[RequestRecord], *, duration: float,
              occupancy: Sequence[float], rounds: int, cache=None,
              n_rejected: int = 0) -> ServeReport:
    """Fold a run's records into a :class:`ServeReport`."""
    lat = [r.latency for r in records]
    n_done = sum(1 for r in records if r.completed)
    mean_lat = sum(lat) / len(lat) if lat else math.nan
    mean_occ = (sum(occupancy) / len(occupancy)) if occupancy else 0.0
    fevals = [r.n_fevals for r in records]
    return ServeReport(
        engine=engine,
        n_requests=len(records),
        n_completed=n_done,
        n_rejected=n_rejected,
        duration_s=duration,
        p50_latency_s=percentile(lat, 50.0),
        p99_latency_s=percentile(lat, 99.0),
        mean_latency_s=mean_lat,
        solves_per_s=(n_done / duration) if duration > 0 else 0.0,
        fevals_per_request=(sum(fevals) / len(fevals)) if fevals
        else math.nan,
        backfill_occupancy=mean_occ,
        rounds=rounds,
        cache_hits=cache.hits if cache is not None else 0,
        cache_misses=cache.misses if cache is not None else 0,
        cache_evictions=cache.evictions if cache is not None else 0,
        cache_hit_rate=cache.hit_rate if cache is not None else 0.0,
    )


def format_report(report: ServeReport,
                  title: Optional[str] = None) -> str:
    """Human-readable multi-line rendering (the CLI prints this)."""
    head = title if title is not None else f"serve[{report.engine}]"
    lines = [
        f"== {head} ==",
        f"  requests     {report.n_requests} "
        f"({report.n_completed} completed, {report.n_rejected} rejected)",
        f"  duration     {report.duration_s:.3f} s over "
        f"{report.rounds} dispatch rounds",
        f"  latency      p50 {report.p50_latency_s * 1e3:.2f} ms | "
        f"p99 {report.p99_latency_s * 1e3:.2f} ms | "
        f"mean {report.mean_latency_s * 1e3:.2f} ms",
        f"  throughput   {report.solves_per_s:.1f} solves/s | "
        f"{report.fevals_per_request:.1f} f-evals/request",
        f"  occupancy    {report.backfill_occupancy * 100.0:.1f}% "
        f"of batch slots busy",
    ]
    lookups = report.cache_hits + report.cache_misses
    if lookups:
        lines.append(
            f"  cache        {report.cache_hits}/{lookups} hits "
            f"({report.cache_hit_rate * 100.0:.1f}%), "
            f"{report.cache_evictions} evictions")
    return "\n".join(lines)
