"""Request types, admission control and the serving queue.

The serving loop decouples *arrival* from *dispatch*: a load generator (or
the CLI) stamps every :class:`Request` with an arrival time on the serving
clock, the :class:`Scheduler` releases requests into its queue as the clock
passes their stamps (applying an :class:`AdmissionPolicy` at release time),
and the engine (:mod:`repro.serve.engine`) drains the queue in the order
chosen by a :class:`SchedulingPolicy` whenever batch slots free up.

Everything here is host-side Python over *concrete* values — no tracing.
The one JAX-facing contract is :class:`RequestConfig`: it rides as a jit
**static argument** on the dense-lane solve and as part of the interpolant
cache key, so equality/hashing must be by VALUE (the PR-6 lesson — an
identity-hashed static config retraces on every fresh instance). It is a
frozen dataclass of plain scalars, which gives exactly that; the trace
audit's retrace counter holds it to the contract.

Policies are small registered hierarchies (``ADMISSION_POLICIES``,
``SCHEDULING_POLICIES``) so odelint R004 can enforce that every reachable
policy implements the full interface and appears in at least one test —
the same completeness contract the Solver/GradientMethod registries carry.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence

Pytree = Any

_rid_counter = itertools.count()


@dataclasses.dataclass(frozen=True)
class RequestConfig:
    """Per-request solve configuration: span, tolerances, trial budget.

    Frozen dataclass of plain scalars => value-based ``__eq__``/``__hash__``
    for free, so a fresh-but-equal config reuses jit caches keyed on it
    statically (dense lane) and maps to the same interpolant-cache bucket.

    ``dense=True`` requests dense output (``Solution.evaluate``-able) and
    routes the request through the engine's dense lane + interpolant cache
    instead of the chunked batch slots.
    """
    t0: float = 0.0
    t1: float = 1.0
    rtol: float = 1e-3
    atol: float = 1e-4
    max_steps: int = 512
    dense: bool = False

    def __post_init__(self):
        if float(self.t0) == float(self.t1):
            raise ValueError(
                f"RequestConfig: empty span t0 == t1 == {self.t0}; pass "
                "t1 > t0 (forward) or t1 < t0 (reverse time)")
        if self.rtol < 0.0 or self.atol < 0.0:
            raise ValueError(
                f"RequestConfig: tolerances must be non-negative, got "
                f"rtol={self.rtol}, atol={self.atol}")
        if self.rtol == 0.0 and self.atol == 0.0:
            raise ValueError("RequestConfig: rtol and atol cannot both be 0")
        if not isinstance(self.max_steps, int) or self.max_steps < 1:
            raise ValueError(
                f"RequestConfig: max_steps must be a positive integer, got "
                f"{self.max_steps!r}")
        # Normalize to plain floats so two configs built from np scalars /
        # Python floats with equal values hash identically.
        object.__setattr__(self, "t0", float(self.t0))
        object.__setattr__(self, "t1", float(self.t1))
        object.__setattr__(self, "rtol", float(self.rtol))
        object.__setattr__(self, "atol", float(self.atol))

    @property
    def span(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass
class Request:
    """One serving request: its own initial state, span/tolerance config,
    arrival stamp, and optional dense/event extras.

    * plain request (default) — integrate ``z0`` over ``[t0, t1]``, return
      ``z(t1)``; served by the continuous-batching chunk lane;
    * ``config.dense=True`` and/or ``eval_ts`` — dense solve with
      interpolant caching; ``eval_ts`` additionally evaluates the cached
      trajectory at those times (repeat queries on a hot trajectory cost
      zero incremental f-evals);
    * ``event`` — a terminating :class:`repro.core.Event`; served by a
      per-request event solve (the bisection/refine machinery needs the
      dense detection pass, which has no chunked-slot equivalent).
    """
    z0: Pytree
    config: RequestConfig = dataclasses.field(default_factory=RequestConfig)
    arrival: float = 0.0
    eval_ts: Optional[Any] = None
    event: Optional[Any] = None
    rid: int = dataclasses.field(default_factory=lambda: next(_rid_counter))

    @property
    def wants_dense(self) -> bool:
        return self.config.dense or self.eval_ts is not None


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------

class AdmissionPolicy:
    """Decides, at arrival time, whether a request enters the queue."""

    name: str = "?"

    def admit(self, queue_depth: int, request: Request) -> bool:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class AdmitAll(AdmissionPolicy):
    """No admission control: every arrival is queued (benchmarks use this
    so offered load is identical across engines)."""

    name = "admit_all"

    def admit(self, queue_depth: int, request: Request) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class BoundedQueue(AdmissionPolicy):
    """Classic load shedding: reject arrivals once the queue holds
    ``max_depth`` waiting requests (the engine's in-flight slots do not
    count — a full fleet with an empty queue still admits)."""

    max_depth: int = 256

    name = "bounded"

    def __post_init__(self):
        if not isinstance(self.max_depth, int) or self.max_depth < 1:
            raise ValueError(
                f"BoundedQueue: max_depth must be a positive integer, got "
                f"{self.max_depth!r}")

    def admit(self, queue_depth: int, request: Request) -> bool:
        return queue_depth < self.max_depth


# ---------------------------------------------------------------------------
# Scheduling (queue ordering)
# ---------------------------------------------------------------------------

class SchedulingPolicy:
    """Orders the waiting queue when batch slots free up."""

    name: str = "?"

    def select(self, waiting: Sequence[Request], k: int) -> List[int]:
        """Indices (into ``waiting``) of up to ``k`` requests to dispatch
        next, in dispatch order."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FIFO(SchedulingPolicy):
    """Arrival order — the fairness baseline."""

    name = "fifo"

    def select(self, waiting: Sequence[Request], k: int) -> List[int]:
        return list(range(min(k, len(waiting))))


@dataclasses.dataclass(frozen=True)
class ShortestSpanFirst(SchedulingPolicy):
    """Shortest-job-first proxy: dispatch the smallest integration spans
    first (span length is the only service-time signal known before
    solving; ties fall back to arrival order). Trades worst-case fairness
    for p50 latency."""

    name = "shortest_span"

    def select(self, waiting: Sequence[Request], k: int) -> List[int]:
        order = sorted(range(len(waiting)),
                       key=lambda i: (abs(waiting[i].config.span), i))
        return order[:min(k, len(waiting))]


ADMISSION_POLICIES: Dict[str, AdmissionPolicy] = {
    "admit_all": AdmitAll(),
    "bounded": BoundedQueue(),
}

SCHEDULING_POLICIES: Dict[str, SchedulingPolicy] = {
    "fifo": FIFO(),
    "shortest_span": ShortestSpanFirst(),
}


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

class Scheduler:
    """Arrival-stamped request queue with admission control.

    ``schedule()`` registers future arrivals; ``release(now)`` moves every
    request whose stamp has passed through the admission policy into the
    waiting queue; ``take(k)`` hands up to ``k`` waiting requests to the
    engine in policy order. All counters are plain ints (host-side).
    """

    def __init__(self,
                 admission: Optional[AdmissionPolicy] = None,
                 policy: Optional[SchedulingPolicy] = None):
        self.admission = admission if admission is not None else AdmitAll()
        self.policy = policy if policy is not None else FIFO()
        if not isinstance(self.admission, AdmissionPolicy):
            raise TypeError(
                f"admission must be an AdmissionPolicy, got "
                f"{self.admission!r}")
        if not isinstance(self.policy, SchedulingPolicy):
            raise TypeError(
                f"policy must be a SchedulingPolicy, got {self.policy!r}")
        self._pending: deque[Request] = deque()   # future, by arrival stamp
        self._waiting: List[Request] = []         # arrived + admitted
        self.n_submitted = 0
        self.n_admitted = 0
        self.n_rejected = 0
        self.rejected: List[Request] = []

    # -- load side ---------------------------------------------------------

    def schedule(self, requests: Sequence[Request]) -> None:
        """Register a batch of future arrivals (sorted by stamp)."""
        self.n_submitted += len(requests)
        merged = sorted(itertools.chain(self._pending, requests),
                        key=lambda r: r.arrival)
        self._pending = deque(merged)

    # -- engine side -------------------------------------------------------

    def release(self, now: float) -> int:
        """Admit every pending request whose arrival stamp has passed.
        Returns how many were admitted this call."""
        n = 0
        while self._pending and self._pending[0].arrival <= now:
            req = self._pending.popleft()
            if self.admission.admit(len(self._waiting), req):
                self._waiting.append(req)
                self.n_admitted += 1
                n += 1
            else:
                self.n_rejected += 1
                self.rejected.append(req)
        return n

    def next_arrival(self) -> Optional[float]:
        return self._pending[0].arrival if self._pending else None

    @property
    def depth(self) -> int:
        return len(self._waiting)

    @property
    def drained(self) -> bool:
        return not self._pending and not self._waiting

    def take(self, k: int,
             pred: Optional[Callable[[Request], bool]] = None
             ) -> List[Request]:
        """Remove and return up to ``k`` waiting requests in policy order;
        ``pred`` filters candidates (the engine uses it to split the dense
        bypass lane from the chunk lane)."""
        if k <= 0 or not self._waiting:
            return []
        if pred is None:
            candidates = list(range(len(self._waiting)))
        else:
            candidates = [i for i, r in enumerate(self._waiting) if pred(r)]
        if not candidates:
            return []
        view = [self._waiting[i] for i in candidates]
        picked_local = self.policy.select(view, k)
        picked = [candidates[j] for j in picked_local]
        out = [self._waiting[i] for i in picked]
        for i in sorted(picked, reverse=True):
            del self._waiting[i]
        return out
