"""Load generation: Poisson arrivals over a stiffness-heterogeneous mix.

The serving story only becomes measurable under realistic traffic, which
for ODE inference has two defining features this module reproduces:

* **Poisson arrivals** — independent requesters, exponential inter-arrival
  gaps at a chosen offered rate;
* **heterogeneous service times** — per-request decay rates drawn
  log-uniformly across decades (the pattern from
  ``benchmarks/batched_throughput.py``): a stiff row needs ~10-100x the
  accepted steps of a tame one, which is exactly the straggler regime
  continuous batching exists for. The decay rate rides *inside the state
  pytree* (``d lam/dt = 0``), so one compiled vector field serves every
  stiffness without retracing.

All randomness flows through a caller-supplied ``numpy.random.Generator``
— the same seed yields the identical request stream, so engines can be
compared on literally the same trace and tests are deterministic.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .scheduler import Request, RequestConfig

__all__ = ["decay_dynamics", "poisson_arrivals",
           "mixed_stiffness_requests", "hot_trajectory_requests"]


def decay_dynamics(params, z, t):
    """Per-sample exponential decay with the rate in the state:
    ``dy/dt = -lam * y``, ``dlam/dt = 0``. Module-level on purpose — a
    stable function object keeps it one jit cache entry everywhere."""
    del params, t
    return {"y": -z["lam"] * z["y"], "lam": jnp.zeros_like(z["lam"])}


def poisson_arrivals(rng: np.random.Generator, rate: float,
                     n: int) -> np.ndarray:
    """``n`` arrival stamps of a Poisson process at ``rate`` arrivals per
    second, starting at t=0 (first stamp is one exponential gap in)."""
    if rate <= 0.0:
        raise ValueError(f"poisson_arrivals: rate must be > 0, got {rate}")
    if n < 0:
        raise ValueError(f"poisson_arrivals: n must be >= 0, got {n}")
    gaps = rng.exponential(scale=1.0 / rate, size=n)
    return np.cumsum(gaps)


def mixed_stiffness_requests(
        rng: np.random.Generator, n: int, *,
        rate: float = 50.0,
        d_state: int = 8,
        lam_decades: Tuple[float, float] = (0.0, 2.0),
        t1: float = 1.0,
        rtol: float = 1e-3,
        atol: float = 1e-4,
        max_steps: int = 512,
        arrivals: Optional[Sequence[float]] = None) -> List[Request]:
    """Build ``n`` chunk-lane requests with Poisson arrivals and decay
    rates log-uniform over ``lam_decades`` (default: two decades, 1-100).

    Each request's state is ``{"y": N(0,1)^d, "lam": 10^U(lo,hi)}`` —
    stiffness varies per request, span/tolerances are shared, so service
    time is the only heterogeneity and engine comparisons isolate the
    scheduling effect. Pass ``arrivals`` to pin stamps explicitly (e.g. to
    replay one trace through two engines after the generator has moved).
    """
    lo, hi = lam_decades
    if arrivals is None:
        arrivals = poisson_arrivals(rng, rate, n)
    elif len(arrivals) != n:
        raise ValueError(
            f"mixed_stiffness_requests: got {len(arrivals)} arrival "
            f"stamps for n={n} requests")
    config = RequestConfig(t0=0.0, t1=t1, rtol=rtol, atol=atol,
                           max_steps=max_steps)
    requests = []
    for i in range(n):
        lam = 10.0 ** rng.uniform(lo, hi)
        z0 = {"y": rng.standard_normal(d_state).astype(np.float32),
              "lam": np.full((d_state,), lam, dtype=np.float32)}
        requests.append(Request(z0=z0, config=config,
                                arrival=float(arrivals[i])))
    return requests


def hot_trajectory_requests(
        rng: np.random.Generator, *,
        n_repeats: int = 8,
        d_state: int = 8,
        lam: float = 5.0,
        t1: float = 1.0,
        rtol: float = 1e-3,
        atol: float = 1e-4,
        max_steps: int = 512,
        arrival: float = 0.0,
        n_eval_ts: int = 4) -> List[Request]:
    """One "hot" trajectory queried ``1 + n_repeats`` times: identical
    (config, z0) dense requests with differing ``eval_ts``. The first pays
    the dense solve and fills the interpolant cache; every repeat should
    hit and report **zero** incremental f-evals — the cache acceptance
    criterion, made into a workload."""
    config = RequestConfig(t0=0.0, t1=t1, rtol=rtol, atol=atol,
                           max_steps=max_steps, dense=True)
    z0 = {"y": rng.standard_normal(d_state).astype(np.float32),
          "lam": np.full((d_state,), float(lam), dtype=np.float32)}
    t_lo, t_hi = (0.0, t1) if t1 > 0 else (t1, 0.0)
    requests = []
    for _ in range(1 + n_repeats):
        eval_ts = np.sort(rng.uniform(t_lo, t_hi,
                                      n_eval_ts)).astype(np.float32)
        requests.append(Request(z0={k: v.copy() for k, v in z0.items()},
                                config=config, arrival=arrival,
                                eval_ts=eval_ts))
    return requests
