"""Interpolant cache: dense solutions as a serving-layer cache line.

A ``SaveAt(dense=True)`` solve returns a :class:`~repro.core.Solution`
whose cubic-Hermite interpolant answers ``evaluate(t)`` for ANY ``t`` in
the span from recorded knots alone — zero further dynamics evaluations.
That makes a dense solution the natural cache value for serving: the first
request for a trajectory pays the solve, every subsequent ``evaluate``
query on the same (vector field, config, z0) is a pure table read.

Keys are content hashes over the triple the trajectory is a function of:

* ``vf_id`` — caller-supplied identity of (vector field, params). The
  cache cannot see through a Python callable or a params pytree, so the
  engine owns naming them; stale params under a reused id is the caller's
  bug, exactly like any externally-keyed cache.
* ``RequestConfig`` — span, tolerances, budget (different tolerances are
  different trajectories; value-hashed per the PR-6 contract).
* ``z0`` bytes + shape + dtype per leaf, plus the pytree structure.

Eviction is pluggable via the registered :class:`CachePolicy` hierarchy
(odelint R004 enforces registry completeness): :class:`LRU` with a bounded
capacity, or :class:`NoCache` to turn the layer off without touching
engine code. Hit/miss/eviction counters feed the serve report's
``cache_hit_rate``.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, Dict, Optional

import jax
import numpy as np

from .scheduler import RequestConfig

Pytree = Any


class CachePolicy:
    """Admission + eviction strategy for the interpolant cache."""

    name: str = "?"

    def admit(self, key: str) -> bool:
        """Whether to store a freshly solved entry at all."""
        raise NotImplementedError

    def victim(self, store: "OrderedDict[str, Any]") -> Optional[str]:
        """Key to evict when the store is over capacity (None = stop)."""
        raise NotImplementedError

    @property
    def capacity(self) -> int:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class LRU(CachePolicy):
    """Least-recently-used eviction over a bounded store; ``get`` hits
    refresh recency."""

    max_entries: int = 64

    name = "lru"

    def __post_init__(self):
        if not isinstance(self.max_entries, int) or self.max_entries < 1:
            raise ValueError(
                f"LRU: max_entries must be a positive integer, got "
                f"{self.max_entries!r}")

    def admit(self, key: str) -> bool:
        return True

    def victim(self, store: "OrderedDict[str, Any]") -> Optional[str]:
        if len(store) <= self.max_entries:
            return None
        return next(iter(store))    # oldest = least recently used

    @property
    def capacity(self) -> int:
        return self.max_entries


@dataclasses.dataclass(frozen=True)
class NoCache(CachePolicy):
    """Caching disabled: admit nothing, every lookup misses. Lets load
    tests measure the uncached baseline through the identical engine
    path."""

    name = "none"

    def admit(self, key: str) -> bool:
        return False

    def victim(self, store: "OrderedDict[str, Any]") -> Optional[str]:
        return None

    @property
    def capacity(self) -> int:
        return 0


CACHE_POLICIES: Dict[str, CachePolicy] = {
    "lru": LRU(),
    "none": NoCache(),
}


class InterpolantCache:
    """Bounded store of dense solutions, keyed by content hash.

    The stored value is whatever the engine puts in — in practice a dense
    :class:`~repro.core.Solution` whose ``evaluate(t)`` reads interpolant
    knots (0 f-evals). ``hits``/``misses``/``evictions`` are cumulative
    over the cache's lifetime and feed ``ServeReport.cache_hit_rate``.
    """

    def __init__(self, policy: Optional[CachePolicy] = None):
        self.policy = policy if policy is not None else LRU()
        if not isinstance(self.policy, CachePolicy):
            raise TypeError(
                f"policy must be a CachePolicy, got {self.policy!r}")
        self._store: "OrderedDict[str, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def key(vf_id: str, config: RequestConfig, z0: Pytree) -> str:
        """Content hash of (vector-field id, request config, z0 bytes)."""
        if not isinstance(config, RequestConfig):
            raise TypeError(
                f"config must be a RequestConfig, got {config!r}")
        h = hashlib.sha1()
        h.update(repr(vf_id).encode())
        h.update(repr(dataclasses.astuple(config)).encode())
        leaves, treedef = jax.tree_util.tree_flatten(z0)
        h.update(repr(treedef).encode())
        for leaf in leaves:
            arr = np.asarray(leaf)
            h.update(str(arr.shape).encode())
            h.update(str(arr.dtype).encode())
            h.update(np.ascontiguousarray(arr).tobytes())
        return h.hexdigest()

    def get(self, key: str) -> Optional[Any]:
        entry = self._store.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)     # refresh recency
        self.hits += 1
        return entry

    def put(self, key: str, value: Any) -> None:
        if not self.policy.admit(key):
            return
        self._store[key] = value
        self._store.move_to_end(key)
        while True:
            victim = self.policy.victim(self._store)
            if victim is None:
                return
            del self._store[victim]
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: str) -> bool:
        return key in self._store

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
