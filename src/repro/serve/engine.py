"""Continuous-batching ODE engine: chunked re-dispatch over masked slots.

The chunk lane is the tentpole: a fixed fleet of ``slots`` batch rows, each
carrying one in-flight request's *entire* adaptive-integration state —
solver state, current time, target time, warm step proposal, tolerances and
trial budget — through :func:`chunk_transition`, a vmapped masked scan of
``chunk_steps`` accept/reject trials per dispatch round. The per-row loop
body is arithmetic-identical to :func:`repro.core.integrate.
integrate_adaptive` (same accept predicate, same step-size controller, same
end clipping), so chunking at round boundaries is invisible to the
numerics: a request's trajectory is bit-equal to the one ``solve()``
produces in a single unchunked scan, and the parity test holds the engine
to it. Rows that reach their target (or exhaust their budget) retire
between rounds and their slots are immediately backfilled from the
scheduler queue — a stiff straggler keeps exactly one row busy instead of
holding a whole static batch hostage.

``chunk_transition`` is a module-level function jitted once with
``(f, solver, chunk_steps)`` static: every round of every engine instance
with equal config reuses one compiled executable (the trace audit counts
traces across fresh equal-valued configs), and the transition is
shape-preserving — slots go in and come out with identical specs, so no
round ever reallocates.

Two engines share the dispatch machinery:

* :class:`ContinuousBatchingEngine` — retire + backfill every round;
* :class:`StaticFleetEngine` — the pre-serve baseline: form a batch from
  the queue, integrate it to completion with NO backfill, complete every
  member at batch end (this is what ``launch/serve.py --mode ode`` used to
  do with one ``Sharded(inner=PerSample())`` fleet).

Dense/event requests bypass the slots: each runs a per-request
``solve(saveat=SaveAt(dense=True))`` / ``solve(event=...)`` whose dense
interpolant lands in the :class:`repro.serve.cache.InterpolantCache`, so
repeated ``evaluate(t)`` queries on a hot trajectory cost zero f-evals.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.integrate import tree_where
from repro.core.solve import solve
from repro.core.interface import SaveAt
from repro.core.solvers import ALF, Solver
from repro.core.stepsize import (AdaptiveController, error_ratio,
                                 initial_step_size, next_step_size)

from .cache import InterpolantCache
from .metrics import RequestRecord, ServeReport, summarize
from .scheduler import Request, Scheduler

_tm = jax.tree_util.tree_map

Pytree = Any


class SlotBatch(NamedTuple):
    """The fleet's whole in-flight state, batch axis first on every leaf.

    One row == one request mid-integration; ``active=False`` rows are empty
    slots that ride through the masked scan as no-ops (their trials update
    nothing and count nothing). A pytree, so it passes through jit whole.
    """
    state: Pytree          # stacked solver state (B, ...)
    t: jax.Array           # (B,) f32 current time
    t1: jax.Array          # (B,) f32 target time
    h: jax.Array           # (B,) f32 signed warm-started step proposal
    rtol: jax.Array        # (B,) f32 per-request relative tolerance
    atol: jax.Array        # (B,) f32 per-request absolute tolerance
    budget: jax.Array      # (B,) int32 per-request trial budget (max_steps)
    active: jax.Array      # (B,) bool slot occupied
    reached: jax.Array     # (B,) bool hit t1
    n_trials: jax.Array    # (B,) int32 trials spent so far
    n_accepted: jax.Array  # (B,) int32 accepted steps so far


class _RowTolerance:
    """Controller shim closing the shared error norm over ONE row's traced
    (rtol, atol) pair — how per-request tolerances ride through
    ``Solver.trial_fn``, whose contract only needs ``error_ratio``. Not a
    registered StepController: it exists only inside the chunk trace."""

    def __init__(self, rtol: jax.Array, atol: jax.Array):
        self.rtol = rtol
        self.atol = atol

    def error_ratio(self, err, z0, z1) -> jax.Array:
        if err is None:
            raise ValueError(
                "the serve engine's per-row adaptive control needs a "
                "solver with an embedded error estimate")
        return error_ratio(err, z0, z1, self.rtol, self.atol)


def chunk_transition(params: Pytree, slots: SlotBatch, *, f, solver: Solver,
                     chunk_steps: int) -> SlotBatch:
    """One dispatch round: advance every row by up to ``chunk_steps``
    adaptive trials of its own solve. Pure and shape-preserving (the output
    SlotBatch has exactly the input's specs).

    Per-row semantics match ``integrate_adaptive``'s masked scan body:
    done rows (empty slot / target reached / budget exhausted) ride along
    as no-ops, accepted steps warm-start the next proposal through the
    carry, and the final step clips to land exactly on ``t1``.
    """

    def row(slot: SlotBatch) -> SlotBatch:
        trial = solver.trial_fn(f, params,
                                _RowTolerance(slot.rtol, slot.atol))

        def body(carry, _):
            state, t, h, reached, n_tr, n_acc = carry
            done = (~slot.active) | reached | (n_tr >= slot.budget)
            remaining = slot.t1 - t
            is_last = jnp.abs(h) >= jnp.abs(remaining)
            h_eff = jnp.where(is_last, remaining, h)
            state_next, ratio = trial(state, t, h_eff)
            accept = (ratio <= 1.0) & (~done)
            n_tr = n_tr + jnp.where(done, 0, 1).astype(jnp.int32)
            new_t = jnp.where(accept, jnp.where(is_last, slot.t1, t + h_eff),
                              t)
            new_state = tree_where(accept, state_next, state)
            new_reached = reached | (accept & is_last)
            h_next = next_step_size(h_eff, ratio, solver.order)
            h_next = jnp.where(done, h, h_next)
            n_acc = n_acc + accept.astype(jnp.int32)
            return (new_state, new_t, h_next, new_reached, n_tr, n_acc), None

        carry0 = (slot.state, slot.t, slot.h, slot.reached, slot.n_trials,
                  slot.n_accepted)
        (state, t, h, reached, n_tr, n_acc), _ = lax.scan(
            body, carry0, None, length=chunk_steps)
        return slot._replace(state=state, t=t, h=h, reached=reached,
                             n_trials=n_tr, n_accepted=n_acc)

    return jax.vmap(row)(slots)


# Jitted once per (f, solver, chunk_steps, slot specs): the engine passes
# the SAME static objects every round, so serving never retraces — the
# trace audit dispatches twice with fresh equal-valued configs and asserts
# one trace.
dispatch_chunk = jax.jit(chunk_transition,
                         static_argnames=("f", "solver", "chunk_steps"))


@functools.partial(jax.jit, static_argnames=("f", "solver"))
def _init_state(params, z0, t0, *, f, solver: Solver):
    return solver.init_state(f, params, z0, t0)


@jax.jit
def _write_row(slots: SlotBatch, idx, row: SlotBatch) -> SlotBatch:
    return _tm(lambda buf, r: buf.at[idx].set(r), slots, row)


@jax.jit
def _deactivate(slots: SlotBatch, idx) -> SlotBatch:
    return slots._replace(active=slots.active.at[idx].set(False))


@functools.partial(jax.jit, static_argnames=("f", "solver", "controller"))
def _dense_solve(params, z0, t0, t1, *, f, solver, controller):
    return solve(f, params, z0, t0, t1, solver=solver, controller=controller,
                 saveat=SaveAt(dense=True))


@functools.partial(jax.jit,
                   static_argnames=("f", "solver", "controller", "event"))
def _event_solve(params, z0, t0, t1, *, f, solver, controller, event):
    return solve(f, params, z0, t0, t1, solver=solver, controller=controller,
                 event=event)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine configuration (frozen => value-hashed, so equal
    configs share every jit cache downstream of the dispatcher).

    ``slots`` is the fleet width B (concurrent in-flight requests),
    ``chunk_steps`` the trials per dispatch round — the backfill
    granularity: retired rows are only refilled *between* rounds, so small
    chunks react to arrivals faster at more dispatch overhead (the
    tradeoff `serve/README.md` documents against mid-scan backfill).
    """
    slots: int = 8
    chunk_steps: int = 32
    solver: Solver = dataclasses.field(default_factory=lambda: ALF(eta=0.9))

    def __post_init__(self):
        if not isinstance(self.slots, int) or self.slots < 1:
            raise ValueError(
                f"EngineConfig: slots must be a positive integer, got "
                f"{self.slots!r}")
        if not isinstance(self.chunk_steps, int) or self.chunk_steps < 1:
            raise ValueError(
                f"EngineConfig: chunk_steps must be a positive integer, "
                f"got {self.chunk_steps!r}")
        if not isinstance(self.solver, Solver):
            raise TypeError(
                f"EngineConfig: solver must be a Solver, got "
                f"{self.solver!r}")
        if not self.solver.has_error_estimate:
            raise ValueError(
                f"EngineConfig: solver {self.solver.name!r} has no "
                "embedded error estimate; per-request adaptive control "
                "needs one (use ALF or an embedded RK pair)")


class _EngineBase:
    """Shared machinery of both engines: slot insert/retire, the dense and
    event bypass lanes, the serving clock, and report assembly.

    The clock is *virtual*: it advances by the measured wall time of each
    dispatch (``timer`` defaults to ``time.perf_counter``) and jumps over
    idle gaps to the next arrival — so a load run never sleeps, latency is
    ``completion - arrival`` on one consistent axis, and tests inject a
    deterministic fake timer for wall-time-free assertions.
    """

    name = "?"

    # Backstop against scheduler/engine bugs, far above any real run.
    MAX_ROUNDS = 1_000_000

    def __init__(self, f, params, *, config: Optional[EngineConfig] = None,
                 scheduler: Optional[Scheduler] = None,
                 cache: Optional[InterpolantCache] = None,
                 vf_id: str = "vf",
                 timer: Callable[[], float] = time.perf_counter):
        self.f = f
        self.params = params
        self.config = config if config is not None else EngineConfig()
        if not isinstance(self.config, EngineConfig):
            raise TypeError(
                f"config must be an EngineConfig, got {self.config!r}")
        self.scheduler = scheduler if scheduler is not None else Scheduler()
        self.cache = cache if cache is not None else InterpolantCache()
        self.vf_id = vf_id
        self.timer = timer

        self.now = 0.0
        self.rounds = 0
        self.occupancy: List[float] = []
        self.records: List[RequestRecord] = []
        self.results: Dict[int, Pytree] = {}
        self.event_times: Dict[int, float] = {}

        self.slots: Optional[SlotBatch] = None
        self.inflight: List[Optional[Request]] = [None] * self.config.slots

    # -- request intake ----------------------------------------------------

    def submit(self, requests: Sequence[Request]) -> None:
        self.scheduler.schedule(list(requests))

    # -- slot plumbing -----------------------------------------------------

    def _alloc_slots(self, state_template: Pytree) -> SlotBatch:
        b = self.config.slots
        f32 = jnp.float32
        return SlotBatch(
            state=_tm(lambda leaf: jnp.zeros((b,) + leaf.shape, leaf.dtype),
                      state_template),
            t=jnp.zeros((b,), f32), t1=jnp.zeros((b,), f32),
            h=jnp.zeros((b,), f32),
            rtol=jnp.ones((b,), f32), atol=jnp.ones((b,), f32),
            budget=jnp.zeros((b,), jnp.int32),
            active=jnp.zeros((b,), bool), reached=jnp.zeros((b,), bool),
            n_trials=jnp.zeros((b,), jnp.int32),
            n_accepted=jnp.zeros((b,), jnp.int32))

    def _insert(self, idx: int, req: Request) -> None:
        cfg = req.config
        z0 = _tm(jnp.asarray, req.z0)
        state0 = _init_state(self.params, z0, jnp.float32(cfg.t0),
                             f=self.f, solver=self.config.solver)
        if self.slots is None:
            self.slots = self._alloc_slots(state0)
        template = _tm(lambda buf: buf[0], self.slots.state)
        t_def = jax.tree_util.tree_structure(template)
        r_def = jax.tree_util.tree_structure(state0)
        t_shapes = [leaf.shape for leaf in jax.tree_util.tree_leaves(template)]
        r_shapes = [leaf.shape for leaf in jax.tree_util.tree_leaves(state0)]
        if t_def != r_def or t_shapes != r_shapes:
            raise ValueError(
                f"request {req.rid}: z0 state structure/shapes do not "
                f"match this engine's fleet (engine: {t_def}/{t_shapes}, "
                f"request: {r_def}/{r_shapes}); one engine serves one "
                "vector field at one state shape — run another engine for "
                "other shapes")
        f32 = jnp.float32
        row = SlotBatch(
            state=state0,
            t=f32(cfg.t0), t1=f32(cfg.t1),
            h=jnp.asarray(initial_step_size(cfg.rtol, cfg.atol,
                                            f32(cfg.span)), f32),
            rtol=f32(cfg.rtol), atol=f32(cfg.atol),
            budget=jnp.int32(cfg.max_steps),
            active=jnp.asarray(True), reached=jnp.asarray(False),
            n_trials=jnp.int32(0), n_accepted=jnp.int32(0))
        self.slots = _write_row(self.slots, jnp.int32(idx), row)
        self.inflight[idx] = req

    def _n_active(self) -> int:
        if self.slots is None:
            return 0
        return int(np.sum(np.asarray(self.slots.active)))

    def _init_fevals(self) -> int:
        # Matches solve()'s accounting: ALF spends one dynamics evaluation
        # on v0 = f(z0, t0) at state init.
        return 1 if isinstance(self.config.solver, ALF) else 0

    def _retire_row(self, idx: int, completion: float) -> None:
        """Record + free one finished row (reached t1 or budget out)."""
        req = self.inflight[idx]
        assert req is not None
        reached = bool(np.asarray(self.slots.reached[idx]))
        n_tr = int(np.asarray(self.slots.n_trials[idx]))
        n_acc = int(np.asarray(self.slots.n_accepted[idx]))
        state_row = _tm(lambda buf: np.asarray(buf[idx]), self.slots.state)
        self.results[req.rid] = self.config.solver.output(state_row)
        self.records.append(RequestRecord(
            rid=req.rid, arrival=req.arrival, completion=completion,
            n_fevals=n_tr * self.config.solver.stages + self._init_fevals(),
            n_accepted=n_acc, completed=reached, lane="batch"))
        self.slots = _deactivate(self.slots, jnp.int32(idx))
        self.inflight[idx] = None

    def _finished_rows(self) -> List[int]:
        active = np.asarray(self.slots.active)
        reached = np.asarray(self.slots.reached)
        exhausted = (np.asarray(self.slots.n_trials)
                     >= np.asarray(self.slots.budget))
        return [int(i) for i in
                np.nonzero(active & (reached | exhausted))[0]]

    def _dispatch(self) -> None:
        """One measured chunk round: advance the fleet, advance the clock."""
        self.occupancy.append(self._n_active() / self.config.slots)
        t_start = self.timer()
        self.slots = dispatch_chunk(self.params, self.slots, f=self.f,
                                    solver=self.config.solver,
                                    chunk_steps=self.config.chunk_steps)
        jax.block_until_ready(self.slots)
        self.now += max(self.timer() - t_start, 0.0)
        self.rounds += 1
        if self.rounds > self.MAX_ROUNDS:
            raise RuntimeError(
                f"serve engine exceeded {self.MAX_ROUNDS} dispatch rounds "
                "— a request is neither finishing nor exhausting its "
                "budget (file a bug with the request mix)")

    # -- dense / event bypass lane ----------------------------------------

    def _serve_bypass(self) -> None:
        """Serve every queued dense/event request immediately (they run as
        per-request solves and never occupy a batch slot)."""
        while True:
            reqs = self.scheduler.take(1, pred=lambda r: r.wants_dense
                                       or r.event is not None)
            if not reqs:
                return
            req = reqs[0]
            if req.event is not None:
                self._serve_event(req)
            else:
                self._serve_dense(req)

    def _controller(self, cfg) -> AdaptiveController:
        return AdaptiveController(cfg.rtol, cfg.atol, cfg.max_steps)

    def _serve_dense(self, req: Request) -> None:
        cfg = req.config
        key = self.cache.key(self.vf_id, cfg, req.z0)
        t_start = self.timer()
        sol = self.cache.get(key)
        hit = sol is not None
        if not hit:
            sol = _dense_solve(self.params, _tm(jnp.asarray, req.z0),
                               jnp.float32(cfg.t0), jnp.float32(cfg.t1),
                               f=self.f, solver=self.config.solver,
                               controller=self._controller(cfg))
            jax.block_until_ready(sol.ys)
            self.cache.put(key, sol)
        if req.eval_ts is not None:
            out = sol.evaluate(jnp.asarray(req.eval_ts, jnp.float32))
        else:
            out = sol.ys
        out = _tm(np.asarray, out)
        self.now += max(self.timer() - t_start, 0.0)
        self.results[req.rid] = out
        # The whole point of the interpolant cache: a hit re-reads the
        # recorded cubic-Hermite coefficients — zero incremental f-evals.
        fevals = 0 if hit else int(sol.stats.n_fevals)
        completed = True if hit else bool(np.asarray(
            sol.stats.span_complete))
        self.records.append(RequestRecord(
            rid=req.rid, arrival=req.arrival, completion=self.now,
            n_fevals=fevals,
            n_accepted=0 if hit else int(sol.stats.n_accepted),
            completed=completed,
            lane="eval" if req.eval_ts is not None else "dense",
            cache_hit=hit))

    def _serve_event(self, req: Request) -> None:
        cfg = req.config
        t_start = self.timer()
        sol = _event_solve(self.params, _tm(jnp.asarray, req.z0),
                           jnp.float32(cfg.t0), jnp.float32(cfg.t1),
                           f=self.f, solver=self.config.solver,
                           controller=self._controller(cfg),
                           event=req.event)
        jax.block_until_ready(sol.ys)
        self.now += max(self.timer() - t_start, 0.0)
        self.results[req.rid] = _tm(np.asarray, sol.ys)
        self.event_times[req.rid] = float(np.asarray(sol.stats.event_time))
        self.records.append(RequestRecord(
            rid=req.rid, arrival=req.arrival, completion=self.now,
            n_fevals=int(sol.stats.n_fevals),
            n_accepted=int(sol.stats.n_accepted),
            completed=True, lane="event"))

    # -- reporting ---------------------------------------------------------

    def report(self) -> ServeReport:
        return summarize(self.name, self.records, duration=self.now,
                         occupancy=self.occupancy, rounds=self.rounds,
                         cache=self.cache,
                         n_rejected=self.scheduler.n_rejected)

    def run(self) -> ServeReport:
        raise NotImplementedError


class ContinuousBatchingEngine(_EngineBase):
    """vLLM-style continuous batching: every dispatch round retires the
    rows that finished and backfills their slots from the queue, so fleet
    occupancy tracks offered load and a straggler costs one slot, not B."""

    name = "continuous"

    def _backfill(self) -> None:
        if self.slots is None:
            free = list(range(self.config.slots))
        else:
            free = [int(i) for i in
                    np.nonzero(~np.asarray(self.slots.active))[0]]
        if not free:
            return
        reqs = self.scheduler.take(
            len(free),
            pred=lambda r: not r.wants_dense and r.event is None)
        for idx, req in zip(free, reqs):
            self._insert(idx, req)

    def run(self) -> ServeReport:
        """Drain the scheduler: serve until no request is pending, waiting
        or in flight. Returns the run's :class:`ServeReport`."""
        while True:
            self.scheduler.release(self.now)
            self._serve_bypass()
            self._backfill()
            if self._n_active() == 0:
                if self.scheduler.drained:
                    return self.report()
                nxt = self.scheduler.next_arrival()
                if nxt is not None:
                    # Idle: jump the virtual clock to the next arrival.
                    self.now = max(self.now, nxt)
                continue
            self._dispatch()
            for idx in self._finished_rows():
                self._retire_row(idx, self.now)


class StaticFleetEngine(_EngineBase):
    """The baseline the tentpole is measured against: form one batch from
    the queue, integrate the whole batch to completion with no backfill,
    and hand every member its result when the *batch* finishes — exactly
    the one-shot ``Sharded(inner=PerSample())`` fleet semantics the old
    ``launch/serve.py --mode ode`` had. Quick requests wait on the batch's
    stiffest straggler; arrivals during a batch wait for the next one."""

    name = "static"

    def _reset_slots(self) -> None:
        if self.slots is not None:
            self.slots = _tm(jnp.zeros_like, self.slots)
        self.inflight = [None] * self.config.slots

    def run(self) -> ServeReport:
        while True:
            self.scheduler.release(self.now)
            self._serve_bypass()
            if self.scheduler.depth == 0:
                if self.scheduler.drained:
                    return self.report()
                nxt = self.scheduler.next_arrival()
                if nxt is not None:
                    self.now = max(self.now, nxt)
                continue
            reqs = self.scheduler.take(
                self.config.slots,
                pred=lambda r: not r.wants_dense and r.event is None)
            if not reqs:
                continue
            self._reset_slots()
            for idx, req in enumerate(reqs):
                self._insert(idx, req)
            # No backfill: the batch runs until every member is done.
            while True:
                unfinished = [i for i, r in enumerate(self.inflight)
                              if r is not None] if self.slots is None else [
                    i for i in range(self.config.slots)
                    if self.inflight[i] is not None
                    and i not in self._finished_rows()]
                if not unfinished:
                    break
                self._dispatch()
            # Everyone completes together, at batch end.
            for idx in self._finished_rows():
                self._retire_row(idx, self.now)


ENGINES = {
    "continuous": ContinuousBatchingEngine,
    "static": StaticFleetEngine,
}
