"""repro.serve — continuous-batching ODE serving on top of ``solve()``.

Request queue + admission control (:mod:`scheduler`), chunked re-dispatch
engines (:mod:`engine`), the dense-interpolant cache (:mod:`cache`),
Poisson load generation (:mod:`loadgen`) and metrics (:mod:`metrics`).
See ``src/repro/serve/README.md`` for the design tradeoffs.
"""
from .cache import CACHE_POLICIES, CachePolicy, InterpolantCache, LRU, NoCache
from .engine import (ENGINES, ContinuousBatchingEngine, EngineConfig,
                     SlotBatch, StaticFleetEngine, chunk_transition,
                     dispatch_chunk)
from .loadgen import (decay_dynamics, hot_trajectory_requests,
                      mixed_stiffness_requests, poisson_arrivals)
from .metrics import RequestRecord, ServeReport, format_report, percentile, \
    summarize
from .scheduler import (ADMISSION_POLICIES, SCHEDULING_POLICIES, AdmitAll,
                        AdmissionPolicy, BoundedQueue, FIFO, Request,
                        RequestConfig, Scheduler, SchedulingPolicy,
                        ShortestSpanFirst)

__all__ = [
    "ADMISSION_POLICIES", "AdmissionPolicy", "AdmitAll", "BoundedQueue",
    "CACHE_POLICIES", "CachePolicy", "ContinuousBatchingEngine",
    "ENGINES", "EngineConfig", "FIFO", "InterpolantCache", "LRU",
    "NoCache", "Request", "RequestConfig", "RequestRecord",
    "SCHEDULING_POLICIES", "Scheduler", "SchedulingPolicy", "ServeReport",
    "ShortestSpanFirst", "SlotBatch", "StaticFleetEngine",
    "chunk_transition", "decay_dynamics", "dispatch_chunk",
    "format_report", "hot_trajectory_requests", "mixed_stiffness_requests",
    "percentile", "poisson_arrivals", "summarize",
]
