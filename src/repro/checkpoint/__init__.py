from .checkpoint import (AsyncCheckpointer, list_checkpoints,
                         restore_checkpoint, restore_latest, save_checkpoint,
                         prune_checkpoints)

__all__ = ["AsyncCheckpointer", "list_checkpoints", "restore_checkpoint",
           "restore_latest", "save_checkpoint", "prune_checkpoints"]
