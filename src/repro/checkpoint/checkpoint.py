"""Checkpointing: flat-npz + json manifest, atomic, async, keep-k.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, with a final atomic
rename from a ".tmp" staging dir so a crash mid-write never corrupts the
latest checkpoint. An async writer thread overlaps serialization with the
next training steps (device->host copy happens on the caller thread so the
arrays are immutable snapshots).

restore_latest() is the fault-tolerance entry point (distributed/
fault_tolerance.py): after a failure+re-mesh the launcher resumes from here;
arrays are re-placed against the (possibly different) new mesh by the
caller's device_put.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any

_SEP = "/"


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """npz can't represent ml_dtypes (bfloat16, fp8): store them bit-exactly
    as same-width unsigned ints; restore views them back via the tree_like
    dtype."""
    if arr.dtype.kind == "V" or arr.dtype.name in (
            "bfloat16", "float8_e4m3fn", "float8_e5m2"):
        return arr.view({1: np.uint8, 2: np.uint16}[arr.dtype.itemsize])
    return arr


def _from_savable(arr: np.ndarray, like_dtype) -> np.ndarray:
    like = np.dtype(like_dtype)
    if (like.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2")
            and arr.dtype.kind == "u"
            and arr.dtype.itemsize == like.itemsize):
        return arr.view(like)   # bit-exact ml_dtypes round-trip
    return arr.astype(like)


def _flatten_with_paths(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = _to_savable(np.asarray(jax.device_get(leaf)))
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def save_checkpoint(ckpt_dir: str, step: int, tree: Pytree,
                    metadata: Optional[dict] = None) -> str:
    """Synchronous atomic save; returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {"step": step, "keys": sorted(flat),
                "metadata": metadata or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_checkpoints(ckpt_dir: str) -> List[Tuple[int, str]]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            full = os.path.join(ckpt_dir, name)
            if os.path.exists(os.path.join(full, "manifest.json")):
                out.append((int(name[5:]), full))
    return sorted(out)


def prune_checkpoints(ckpt_dir: str, keep: int) -> None:
    ckpts = list_checkpoints(ckpt_dir)
    for _, path in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(path)


def restore_checkpoint(path: str, tree_like: Pytree) -> Tuple[Pytree, dict]:
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path_entries, like in paths:
        key = _SEP.join(_path_str(p) for p in path_entries)
        arr = data[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {like.shape}")
        leaves.append(_from_savable(arr, like.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["metadata"]


def restore_latest(ckpt_dir: str, tree_like: Pytree
                   ) -> Optional[Tuple[int, Pytree, dict]]:
    ckpts = list_checkpoints(ckpt_dir)
    if not ckpts:
        return None
    step, path = ckpts[-1]
    tree, meta = restore_checkpoint(path, tree_like)
    return step, tree, meta


class AsyncCheckpointer:
    """Background writer: save() snapshots to host then enqueues the write."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._errors: List[BaseException] = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, flat, metadata = item
            try:
                final = os.path.join(self.ckpt_dir, f"step_{step:08d}")
                tmp = final + ".tmp"
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp, exist_ok=True)
                np.savez(os.path.join(tmp, "arrays.npz"), **flat)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump({"step": step, "keys": sorted(flat),
                               "metadata": metadata or {}}, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                prune_checkpoints(self.ckpt_dir, self.keep)
            except BaseException as e:  # surfaced on next save/wait/close
                self._errors.append(e)
            finally:
                self._q.task_done()

    def save(self, step: int, tree: Pytree, metadata: Optional[dict] = None):
        if self._errors:
            raise RuntimeError(f"async checkpoint failed: {self._errors[0]}")
        os.makedirs(self.ckpt_dir, exist_ok=True)
        flat = _flatten_with_paths(tree)   # device->host on caller thread
        self._q.put((step, flat, metadata))

    def wait(self):
        """Block until all enqueued saves hit disk (writer stays alive)."""
        self._q.join()
        if self._errors:
            raise RuntimeError(f"async checkpoint failed: {self._errors[0]}")

    def close(self):
        self._q.put(None)
        self._thread.join()
        if self._errors:
            raise RuntimeError(f"async checkpoint failed: {self._errors[0]}")
