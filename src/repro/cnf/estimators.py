"""Trace-estimator axis of the CNF likelihood (paper §4.4 / FFJORD).

The instantaneous change of variables needs ``tr(df/dz)`` along the flow;
how that trace is computed is an axis of its own, mirroring the
solver/gradient registries in ``repro.core``:

* :class:`Exact` — sum of per-basis-vector JVPs (d dynamics
  linearizations per state; exact, affordable at toy dimension).
* :class:`Hutchinson` — the stochastic estimator ``E[eps^T J eps]`` with
  Rademacher or Gaussian probes (1 extra JVP per state; the image-scale
  FFJORD setting).

Fixed-noise-per-solve semantics: the probe ``eps`` is sampled ONCE per
solve (:meth:`TraceEstimator.init_noise`) and then rides in the solve
carry as an augmented-state component with zero dynamics — NOT in Python
state — so adaptive accept/reject re-evaluations of a trial step see the
same noise, the estimate is a deterministic function of (params, x, key)
under any step schedule, and the component maps correctly under
``PerSample`` vmap and ``Sharded`` shard_map (params are closed over;
state is what the batching axis maps).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp


class TraceEstimator:
    """Base of the trace-estimation axis. Subclasses are frozen
    dataclasses (hashable — they ride inside the static CNF object)
    implementing:

    * ``init_noise(key, x)`` — the per-solve probe pytree (``None`` for
      deterministic estimators), shaped like ``x``;
    * ``value_and_trace(f, z, eps)`` — one dynamics evaluation plus the
      trace estimate at a single state ``z`` of shape (d,);
    * ``trace_fevals(dim)`` — f-eval-equivalents the trace costs per
      dynamics evaluation (the ``Stats``-style accounting benchmarks
      report).
    """

    name: str = "?"

    def init_noise(self, key: Optional[jax.Array],
                   x: jax.Array) -> Optional[jax.Array]:
        raise NotImplementedError

    def value_and_trace(self, f: Callable[[jax.Array], jax.Array],
                        z: jax.Array,
                        eps: Optional[jax.Array]) -> Tuple[jax.Array,
                                                           jax.Array]:
        raise NotImplementedError

    def trace_fevals(self, dim: int) -> int:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Exact(TraceEstimator):
    """Exact ``tr(df/dz)``: linearize ``f`` once at ``z`` and push the d
    basis vectors through the JVP (O(d) f-eval-equivalents per state —
    the oracle the Hutchinson estimator is checked against)."""

    name = "exact"

    def init_noise(self, key, x):
        return None  # deterministic — no probe leaf in the solve carry

    def value_and_trace(self, f, z, eps):
        fz, jvp_fn = jax.linearize(f, z)
        basis = jnp.eye(z.shape[-1], dtype=z.dtype)
        diag = jax.vmap(lambda e: jnp.vdot(e, jvp_fn(e)))(basis)
        return fz, jnp.sum(diag)

    def trace_fevals(self, dim: int) -> int:
        return dim


@dataclasses.dataclass(frozen=True)
class Hutchinson(TraceEstimator):
    """Stochastic trace ``eps^T (df/dz) eps``: unbiased for any probe
    distribution with identity covariance. ``dist='rademacher'`` (default;
    minimum-variance among sign probes) or ``'gaussian'``. One JVP per
    state regardless of d — the image-scale estimator."""

    dist: str = "rademacher"

    name = "hutchinson"

    def __post_init__(self):
        if self.dist not in ("rademacher", "gaussian"):
            raise ValueError(
                f"Hutchinson(dist={self.dist!r}): pass 'rademacher' or "
                "'gaussian'")

    def init_noise(self, key, x):
        if key is None:
            raise ValueError(
                "Hutchinson trace estimation draws one probe per solve: "
                "pass key= (a jax.random.PRNGKey) to log_prob/sample, or "
                "use estimator=Exact()")
        if self.dist == "gaussian":
            return jax.random.normal(key, x.shape, x.dtype)
        return jax.random.rademacher(key, x.shape, x.dtype)

    def value_and_trace(self, f, z, eps):
        fz, jv = jax.jvp(f, (z,), (eps,))
        return fz, jnp.vdot(eps, jv)

    def trace_fevals(self, dim: int) -> int:
        return 1


TRACE_ESTIMATORS = {
    "exact": Exact(),
    "hutchinson": Hutchinson(),
    "hutchinson_gaussian": Hutchinson(dist="gaussian"),
}


def get_estimator(est: Union[str, TraceEstimator]) -> TraceEstimator:
    """Resolve an estimator object or registry key (the string surface
    mirrors ``get_solver``)."""
    if isinstance(est, TraceEstimator):
        return est
    if est in TRACE_ESTIMATORS:
        return TRACE_ESTIMATORS[est]
    raise ValueError(f"unknown trace estimator {est!r}; available: "
                     f"{tuple(sorted(TRACE_ESTIMATORS))}")
