"""CNF training objectives: NLL in nats, bits/dim, kinetic regularizer.

bits/dim is the paper's §4.4 image metric: for pixels quantized to
``n_bins`` levels and rescaled to [0, 1], the dequantized continuous NLL
converts as ``bpd = nll_nats / (dim * ln 2) + log2(n_bins)`` (the
log2(n_bins) term is the volume of one quantization bin per dimension).
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from .flow import CNFResult


def nll_nats(result: CNFResult) -> jnp.ndarray:
    """Mean negative log likelihood in nats (the 2D-toy reporting unit)."""
    return -jnp.mean(result.logp)


def bits_per_dim(result: CNFResult, dim: int,
                 n_bins: int = 256) -> jnp.ndarray:
    """Mean NLL in bits per dimension for ``n_bins``-quantized data scaled
    to [0, 1] (paper Table 3 units)."""
    return nll_nats(result) / (dim * math.log(2.0)) + math.log2(n_bins)


def cnf_loss(result: CNFResult, kinetic_reg: float = 0.0) -> jnp.ndarray:
    """Training objective: mean NLL + the RNODE kinetic-energy regularizer
    (Finlay et al. 2020; the paper's §4.4 uses coefficient 0.05 at image
    scale)."""
    loss = nll_nats(result)
    if kinetic_reg:
        loss = loss + kinetic_reg * jnp.mean(result.kinetic)
    return loss
