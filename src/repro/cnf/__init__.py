"""repro.cnf — FFJORD-class continuous normalizing flows on solve().

See README.md in this directory for the estimator catalogue and the
fixed-noise-per-solve rationale.
"""
from .estimators import (TRACE_ESTIMATORS, Exact, Hutchinson, TraceEstimator,
                         get_estimator)
from .flow import CNF, CNFResult
from .losses import bits_per_dim, cnf_loss, nll_nats

__all__ = ["CNF", "CNFResult", "TraceEstimator", "Exact", "Hutchinson",
           "TRACE_ESTIMATORS", "get_estimator", "nll_nats", "bits_per_dim",
           "cnf_loss"]
