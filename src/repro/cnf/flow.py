"""FFJORD-class continuous normalizing flow on top of ``solve()``.

A :class:`CNF` turns any vector field ``f(params, z, t)`` from
``repro.models`` into a density model via the instantaneous change of
variables (Chen et al. 2018)::

    d z / dt      = f(z, t)
    d logdet / dt = +tr(df/dz)         (so log p(x) = log N(z_T; 0, I)
    d kinetic/ dt = |f|^2               + logdet_T)
    d eps / dt    = 0                  (fixed Hutchinson probe; see
                                        repro.cnf.estimators)

The augmented state rides through the ordinary ``solve()`` front door, so
every axis composes: MALI's O(T * N_z) residual claim survives the
augmentation (benchmarks/cnf_bits_dim.py proves it end-to-end),
``ALF(backend='pallas')`` fuses the augmented step algebra, ``Sharded``
batching shard_maps the flow, and ``diff_bounds=True`` makes the
integration span trainable (the FFJORD ``end_time`` parameter).

Density direction convention (matches the pre-subsystem cnf_toy example):
``log_prob`` integrates data -> base over [t0, t1] accumulating
``+tr``; ``sample`` runs the same augmented dynamics in reverse time
(t1 -> t0) from base noise — the existing reverse-time solve path, no
separate inverse model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import Solution, solve
from repro.core.interface import Batching, SaveAt

from .estimators import Hutchinson, TraceEstimator, get_estimator

Pytree = Any
VectorField = Callable[[Pytree, jax.Array, jax.Array], jax.Array]


class CNFResult(NamedTuple):
    """``log_prob`` output: per-sample log density (nats), the logdet and
    kinetic-energy integrals, and the underlying :class:`Solution` (stats,
    residual accounting, event/batching metadata)."""
    logp: jax.Array
    logdet: jax.Array
    kinetic: jax.Array
    solution: Solution


@dataclasses.dataclass(frozen=True)
class CNF:
    """A continuous normalizing flow: static (hashable) model object
    pairing a vector field with a trace estimator and a default span.

    ``vfield(params, z, t)`` maps a SINGLE state of shape (dim,) to its
    velocity; batch axes are handled here (vmapped inside the augmented
    dynamics for batch-shaped states, mapped by the ``batching`` axis
    otherwise), so one field definition serves unbatched, Lockstep,
    PerSample and Sharded solves.
    """

    vfield: VectorField
    dim: int
    estimator: TraceEstimator = Hutchinson()
    t0: float = 0.0
    t1: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "estimator", get_estimator(self.estimator))

    # -- augmented dynamics -------------------------------------------------

    def _aug(self, params, state, t):
        z, _, _, eps = state

        def one(zi, ei):
            fz, tr = self.estimator.value_and_trace(
                lambda zz: self.vfield(params, zz, t), zi, ei)
            return fz, tr, jnp.sum(fz ** 2)

        if z.ndim == 1:
            dz, dld, dk = one(z, eps)
        else:
            dz, dld, dk = jax.vmap(one)(z, eps)
        d_eps = None if eps is None else jnp.zeros_like(eps)
        return (dz, dld, dk, d_eps)

    def _state0(self, x, key):
        bshape = x.shape[:-1]
        zeros = jnp.zeros(bshape, x.dtype)
        return (x, zeros, zeros, self.estimator.init_noise(key, x))

    def _base_logp(self, z):
        return (-0.5 * jnp.sum(z ** 2, -1)
                - 0.5 * self.dim * math.log(2.0 * math.pi))

    # -- densities & sampling ----------------------------------------------

    def log_prob(self, params: Pytree, x: jax.Array,
                 key: Optional[jax.Array] = None, *,
                 solver=None, controller=None, gradient=None,
                 t0=None, t1=None, diff_bounds: bool = False,
                 batching: Optional[Batching] = None) -> CNFResult:
        """Per-sample ``log p(x)`` in nats for ``x`` of shape (..., dim).

        ``key`` seeds the per-solve trace probe (required for Hutchinson;
        ignored by Exact). ``t0``/``t1`` override the flow's span — pass
        traced values with ``diff_bounds=True`` to train them. All solve
        axes (solver/controller/gradient/batching) pass straight through.
        """
        t0 = self.t0 if t0 is None else t0
        t1 = self.t1 if t1 is None else t1
        sol = solve(self._aug, params, self._state0(x, key), t0, t1,
                    solver=solver, controller=controller, gradient=gradient,
                    batching=batching, diff_bounds=diff_bounds)
        zT, logdet, kinetic, _ = sol.ys
        return CNFResult(self._base_logp(zT) + logdet, logdet, kinetic, sol)

    def sample(self, params: Pytree, key: jax.Array, n: int, *,
               solver=None, controller=None, gradient=None,
               saveat: Optional[SaveAt] = None,
               batching: Optional[Batching] = None) -> Solution:
        """Draw ``n`` samples: z ~ N(0, I), then the SAME augmented
        dynamics integrated in reverse time t1 -> t0 (the sign-agnostic
        solve path — no separate inverse network). Returns the
        :class:`Solution`; ``ys[0]`` is the (n, dim) sample batch, or the
        (T, n, dim) flow path under ``saveat=SaveAt(ts=descending_grid)``
        (the Fig. 6-style visualization)."""
        k_base, k_eps = jax.random.split(key)
        z = jax.random.normal(k_base, (n, self.dim))
        return solve(self._aug, params, self._state0(z, k_eps),
                     self.t1, self.t0, solver=solver, controller=controller,
                     gradient=gradient, saveat=saveat, batching=batching)
