"""musicgen-large [audio]: 48L d=2048 32H (MHA kv=32) d_ff=8192 vocab=2048.

Decoder-only transformer over EnCodec tokens [arXiv:2306.05284]. The EnCodec
frontend is a stub: input_specs() provides precomputed frame embeddings
(input_mode='embeds'); the backbone is the assigned spec.
"""
from .base import LayerSpec, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="musicgen-large",
    d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab_size=2048,
    input_mode="embeds",
    sharding="dp",
    **uniform_pattern(48, LayerSpec(mixer="attn", mlp="dense")),
)
