"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
Mamba:attention 7:1 interleave, MoE 16 experts top-2 every 2 layers
[arXiv:2403.19887]. Period of 8: attention at index 4, MoE at odd indices.
Mamba-dominant ⇒ sub-quadratic ⇒ runs the long_500k cell.
"""
from .base import LayerSpec, ModelConfig

_PERIOD = tuple(
    LayerSpec(mixer=("attn" if j == 4 else "mamba"),
              mlp=("moe" if j % 2 == 1 else "dense"))
    for j in range(8))

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=65536,
    moe_experts=16, moe_top_k=2, moe_d_ff=14336,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    prelude=(), period=_PERIOD, n_periods=4,
    sharding="fsdp_tp",
    subquadratic=True,
)
