"""gemma2-2b [dense]: 26L d=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.

Local/global alternating attention (window 4096), attn-logit softcap 50,
final-logit softcap 30, tied embeddings, head_dim 256 [arXiv:2408.00118].
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    d_model=2304, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=9216, vocab_size=256000,
    attn_softcap=50.0, final_softcap=30.0, sliding_window=4096,
    tie_embeddings=True,
    sharding="dp",
    prelude=(),
    period=(LayerSpec(mixer="attn", mlp="dense", attn_kind="local"),
            LayerSpec(mixer="attn", mlp="dense", attn_kind="global")),
    n_periods=13,
)
