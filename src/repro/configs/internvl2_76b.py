"""internvl2-76b [vlm]: 80L d=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

InternViT + InternLM2 [arXiv:2404.16821]. The ViT frontend is a stub:
input_specs() provides precomputed patch embeddings (input_mode='embeds');
the backbone (InternLM2-style GQA transformer) is the assigned spec.
"""
from .base import LayerSpec, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="internvl2-76b",
    d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=28672, vocab_size=128256,
    input_mode="embeds",
    sharding="fsdp_tp",
    **uniform_pattern(80, LayerSpec(mixer="attn", mlp="dense")),
)
