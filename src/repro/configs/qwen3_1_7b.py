"""qwen3-1.7b [dense]: 28L d=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.

qk_norm + GQA [hf:Qwen/Qwen3-*].
"""
from .base import LayerSpec, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=6144, vocab_size=151936,
    qk_norm=True, rope_theta=1e6,
    sharding="dp",
    **uniform_pattern(28, LayerSpec(mixer="attn", mlp="dense")),
)
