"""xlstm-125m [ssm]: 12L d=768 4H, no FFN (d_ff=0), vocab=50304.

sLSTM + mLSTM blocks [arXiv:2405.04517]. Ratio choice (documented per
DESIGN.md): 5 mLSTM : 1 sLSTM per 6-layer period (the paper's xLSTM[7:1]
ratio rounded to this depth). Sub-quadratic — runs the long_500k cell.
"""
from .base import LayerSpec, ModelConfig

_PERIOD = tuple([LayerSpec(mixer="mlstm", mlp="none")] * 5
                + [LayerSpec(mixer="slstm", mlp="none")])

CONFIG = ModelConfig(
    name="xlstm-125m",
    d_model=768, n_heads=4, n_kv_heads=4, d_head=192,
    d_ff=0, vocab_size=50304,
    prelude=(), period=_PERIOD, n_periods=2,
    subquadratic=True,
    sharding="dp",
)
