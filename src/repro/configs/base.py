"""Config system: architecture, layer-pattern, shape-cell and run configs.

Layer patterns are expressed as (prelude, period, n_periods): the prelude
layers are unrolled, the period is repeated ``n_periods`` times under a
single ``lax.scan`` with stacked parameters — HLO size stays O(period)
regardless of depth, which both matches production practice and keeps the
512-fake-device AOT compiles tractable. All layers inside one period may be
heterogeneous (Jamba's mamba/attn interleave, Gemma-2's local/global
alternation); layers across periods must repeat exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.ode_block import OdeSettings


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One sub-layer of a period."""
    mixer: str = "attn"           # 'attn' | 'mamba' | 'mlstm' | 'slstm'
    mlp: str = "dense"            # 'dense' | 'moe' | 'none'
    attn_kind: str = "global"     # 'global' | 'local'  (gemma2 alternation)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # depth pattern
    prelude: Tuple[LayerSpec, ...]
    period: Tuple[LayerSpec, ...]
    n_periods: int
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared_experts: int = 0
    moe_d_ff: int = 0              # per-(routed)-expert hidden dim
    moe_capacity_factor: float = 1.25
    moe_eval_capacity_factor: float = 2.0
    # dense-FFN override for prelude layers (DeepSeek layer-0 dense)
    prelude_d_ff: int = 0
    # attention details
    qk_norm: bool = False
    attn_softcap: float = 0.0      # gemma2: 50.0
    final_softcap: float = 0.0     # gemma2: 30.0
    sliding_window: int = 0        # local-attn window (gemma2: 4096)
    rope_theta: float = 10000.0
    # ssm (mamba) details
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # xlstm details
    lstm_proj_factor: float = 2.0
    # embedding / head
    tie_embeddings: bool = False
    input_mode: str = "tokens"     # 'tokens' | 'embeds' (vlm stub frontend)
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # long-seq attention backward: 'flash' (FA2-style custom_vjp, O(S*d)
    # residuals) or 'autodiff' (AD through the scan; stacks O(S^2) tiles —
    # kept as the reference/baseline path; EXPERIMENTS.md §Perf)
    attn_bwd: str = "flash"
    # the paper's technique
    ode: OdeSettings = dataclasses.field(default_factory=OdeSettings)
    # sharding strategy: 'tp' (model-axis only) or 'fsdp_tp' (2D over
    # (data, model) — required for the >8B archs on a 16x16 pod)
    sharding: str = "tp"
    # sub-quadratic? (controls long_500k eligibility)
    subquadratic: bool = False

    @property
    def n_layers(self) -> int:
        return len(self.prelude) + len(self.period) * self.n_periods

    def layers(self) -> Tuple[LayerSpec, ...]:
        return self.prelude + self.period * self.n_periods

    def with_ode(self, ode: OdeSettings) -> "ModelConfig":
        return dataclasses.replace(self, ode=ode)

    def validate(self) -> "ModelConfig":
        if self.period and self.n_periods <= 0:
            raise ValueError("n_periods must be positive when period non-empty")
        has_moe = any(l.mlp == "moe" for l in self.prelude + self.period)
        if has_moe and (self.moe_experts <= 0 or self.moe_top_k <= 0):
            raise ValueError(f"{self.name}: moe layers need moe_experts/top_k")
        self.ode.validate()
        return self


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""
    name: str                      # train_4k / prefill_32k / decode_32k / long_500k
    seq_len: int
    global_batch: int
    kind: str                      # 'train' | 'prefill' | 'decode'


SHAPE_CELLS = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def get_shape_cell(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise ValueError(f"unknown shape cell {name!r}")


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """Assignment rule: long_500k only for sub-quadratic archs."""
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, ("skip: long_500k requires sub-quadratic attention; "
                       f"{cfg.name} has full/global attention layers")
    return True, ""


def uniform_pattern(n_layers: int, spec: LayerSpec) -> dict:
    """Homogeneous depth: scan all layers as 1-layer periods."""
    return dict(prelude=(), period=(spec,), n_periods=n_layers)
