"""Config registry: assigned architectures + reduced smoke variants."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.ode_block import OdeSettings

from .base import (SHAPE_CELLS, LayerSpec, ModelConfig, ShapeCell,
                   cell_applicable, get_shape_cell, uniform_pattern)
from .deepseek_moe_16b import CONFIG as _deepseek
from .gemma2_2b import CONFIG as _gemma2
from .granite_20b import CONFIG as _granite
from .grok_1_314b import CONFIG as _grok
from .internvl2_76b import CONFIG as _internvl2
from .jamba_v01_52b import CONFIG as _jamba
from .musicgen_large import CONFIG as _musicgen
from .qwen3_1_7b import CONFIG as _qwen3
from .stablelm_1_6b import CONFIG as _stablelm
from .xlstm_125m import CONFIG as _xlstm

ARCHS: Dict[str, ModelConfig] = {
    c.name: c.validate() for c in (
        _musicgen, _internvl2, _stablelm, _qwen3, _granite, _gemma2,
        _xlstm, _deepseek, _grok, _jamba)
}

# The paper's own setting: continuous-depth ("Neural-ODE-18"-style) variants
# are obtained with get_config(name, ode=OdeSettings(mode='per_block', ...)).
DEFAULT_ODE = OdeSettings(mode="per_block", method="mali", solver="alf",
                          n_steps=2)


def get_config(name: str, ode: Optional[OdeSettings] = None) -> ModelConfig:
    if name not in ARCHS:
        raise ValueError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    cfg = ARCHS[name]
    if ode is not None:
        cfg = cfg.with_ode(ode)
    return cfg.validate()


def smoke_config(name: str, ode: Optional[OdeSettings] = None) -> ModelConfig:
    """Reduced same-family config: tiny widths/depth, same layer pattern."""
    cfg = get_config(name, ode)
    n_kv = min(cfg.n_kv_heads, 2)
    n_heads = max(2, min(cfg.n_heads, 4))
    n_heads = max(n_heads, n_kv) - (max(n_heads, n_kv) % n_kv)
    d_head = 16
    d_model = 64
    reduced = dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv, d_head=d_head,
        d_ff=(128 if cfg.d_ff else 0), vocab_size=256,
        moe_experts=(4 if cfg.moe_experts else 0),
        moe_top_k=(2 if cfg.moe_top_k else 0),
        moe_d_ff=(32 if cfg.moe_d_ff else 0),
        # dropless in smoke (cap >= N both train and serve) for exact
        # train-vs-serve consistency tests
        moe_capacity_factor=2.0, moe_eval_capacity_factor=2.0,
        prelude_d_ff=(64 if cfg.prelude_d_ff else 0),
        n_periods=min(cfg.n_periods, 2),
        mamba_d_state=8,
        sliding_window=(8 if cfg.sliding_window else 0),
        param_dtype="float32", compute_dtype="float32",
        sharding="tp",
    )
    return reduced.validate()


__all__ = ["ARCHS", "get_config", "smoke_config", "DEFAULT_ODE",
           "ModelConfig", "LayerSpec", "ShapeCell", "SHAPE_CELLS",
           "get_shape_cell", "cell_applicable", "uniform_pattern",
           "OdeSettings"]
