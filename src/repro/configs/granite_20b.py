"""granite-20b [dense]: 52L d=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.

llama-arch code model [arXiv:2405.04324].
"""
from .base import LayerSpec, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="granite-20b",
    d_model=6144, n_heads=48, n_kv_heads=1, d_head=128,
    d_ff=24576, vocab_size=49152,
    sharding="fsdp_tp",
    **uniform_pattern(52, LayerSpec(mixer="attn", mlp="dense")),
)
