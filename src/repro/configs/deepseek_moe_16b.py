"""deepseek-moe-16b [moe]: 28L d=2048 16H (MHA kv=16) vocab=102400,
MoE 64 routed experts top-6 + 2 shared, per-expert d_ff=1408, layer-0 dense
(d_ff=10944) [arXiv:2401.06066].
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab_size=102400,
    moe_experts=64, moe_top_k=6, moe_shared_experts=2, moe_d_ff=1408,
    prelude=(LayerSpec(mixer="attn", mlp="dense"),),
    prelude_d_ff=10944,
    period=(LayerSpec(mixer="attn", mlp="moe"),),
    n_periods=27,
    sharding="fsdp_tp",
)
