"""grok-1-314b [moe]: 64L d=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2 [hf:xai-org/grok-1].
"""
from .base import LayerSpec, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="grok-1-314b",
    d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=32768, vocab_size=131072,
    moe_experts=8, moe_top_k=2, moe_d_ff=32768,
    sharding="fsdp_tp",
    **uniform_pattern(64, LayerSpec(mixer="attn", mlp="moe")),
)
