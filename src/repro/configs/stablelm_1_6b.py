"""stablelm-1.6b [dense]: 24L d=2048 32H (MHA kv=32) d_ff=5632 vocab=100352.

[hf:stabilityai/stablelm-2-1_6b]
"""
from .base import LayerSpec, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=5632, vocab_size=100352,
    sharding="dp",
    **uniform_pattern(24, LayerSpec(mixer="attn", mlp="dense")),
)
