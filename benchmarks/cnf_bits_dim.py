"""CNF workload benchmark (paper §4.4): trace-estimator cost and the
memory-vs-depth proof on the AUGMENTED state.

Two claims, one module:

* **Estimator cost** — Exact trace spends O(d) f-eval-equivalents per
  dynamics evaluation, Hutchinson spends 1; measured as wall-clock
  throughput of ``log_prob`` at a trace-bound dimension plus the analytic
  f-eval accounting both estimators report.

* **Memory** — MALI's O(T * N_z) backward-residual claim must survive the
  CNF augmentation (z, logdet, kinetic, eps): AOT-compile
  ``grad(cnf_loss)`` at image dimension for growing step budgets and read
  ``memory_analysis().temp_size_in_bytes`` from the compiled artifact —
  flat (growth <= 1.05x) for MALI across an 8->128 spread, linear for
  Naive. Everything is lowered from ShapeDtypeStructs; no training runs.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.cnf import CNF, Exact, Hutchinson, cnf_loss
from repro.core import ALF, ConstantSteps, MALI, Naive
from repro.models import init_mlp_vfield, mlp_vfield

from .common import Row, time_fn

TP_DIM, TP_BATCH, TP_STEPS = 16, 64, 8
MEM_DIM, MEM_BATCH, MEM_HIDDEN = 28 * 28, 4, 32
MEM_STEPS = (8, 32, 128)
MEM_METHODS = (("mali", MALI()), ("naive", Naive()))


def _throughput_rows() -> List[Row]:
    rows: List[Row] = []
    fp = init_mlp_vfield(jax.random.PRNGKey(0), TP_DIM, hidden=32)
    x = jax.random.normal(jax.random.PRNGKey(1), (TP_BATCH, TP_DIM))
    key = jax.random.PRNGKey(2)
    for name, est in (("exact", Exact()), ("hutchinson", Hutchinson())):
        flow = CNF(mlp_vfield, TP_DIM, estimator=est)

        @jax.jit
        def logp(p, xx):
            return flow.log_prob(p, xx, key,
                                 controller=ConstantSteps(TP_STEPS)).logp

        us = time_fn(logp, fp, x)
        rows.append((f"cnf_bits_dim/logprob_us/{name}/d={TP_DIM}", us,
                     f"B={TP_BATCH} alf n={TP_STEPS}"))
        rows.append((f"cnf_bits_dim/trace_fevals_per_eval/{name}",
                     est.trace_fevals(TP_DIM),
                     "f-eval-equivalents per dynamics evaluation"))
    return rows


def _temp_bytes(gradient, n_steps: int) -> int:
    flow = CNF(mlp_vfield, MEM_DIM, estimator=Hutchinson())
    p_spec = jax.eval_shape(
        lambda k: init_mlp_vfield(k, MEM_DIM, hidden=MEM_HIDDEN),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    x_spec = jax.ShapeDtypeStruct((MEM_BATCH, MEM_DIM), jnp.float32)
    k_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def loss(p, x, key):
        res = flow.log_prob(p, x, key, solver=ALF(),
                            controller=ConstantSteps(n_steps),
                            gradient=gradient)
        return cnf_loss(res, kinetic_reg=0.05)

    c = jax.jit(jax.grad(loss)).lower(p_spec, x_spec, k_spec).compile()
    ma = c.memory_analysis()
    return int(ma.temp_size_in_bytes) if ma else -1


def _memory_rows() -> List[Row]:
    rows: List[Row] = []
    for name, gradient in MEM_METHODS:
        series = []
        for n in MEM_STEPS:
            b = _temp_bytes(gradient, n)
            series.append(b)
            rows.append((f"cnf_bits_dim/temp_bytes/{name}/n={n}", b,
                         f"AOT grad(cnf_loss) d={MEM_DIM} B={MEM_BATCH} "
                         "hutchinson"))
        growth = series[-1] / max(series[0], 1)
        rows.append((
            f"cnf_bits_dim/growth_{MEM_STEPS[0]}to{MEM_STEPS[-1]}/{name}",
            growth,
            "flat~1 (<=1.05) expected for mali; ~N_t for naive"))
    return rows


def run() -> List[Row]:
    return _throughput_rows() + _memory_rows()
