"""Serving under load: continuous batching vs the static fleet.

The serving claim of PR 8, made measurable: on a Poisson request stream
with two decades of per-request stiffness (the ``batched_throughput``
mix), a fleet that retires finished rows and backfills from the queue
between chunk rounds must beat the same fleet run one-shot — on p99
latency AND solves/s — because a static batch completes at its stiffest
straggler's pace while continuous batching strands at most one slot per
straggler.

Protocol: a closed-loop warmup run (all arrivals at t=0) compiles the
dispatch path; the mean wall time of a warm dispatch round ``tau`` is
then measured once, and both engines run on an injected tick clock that
advances exactly ``tau`` per round. The clock stays wall-calibrated (the
numbers are real seconds for this machine) but the dispatch kernel is
fixed-shape — every round costs the same compute regardless of occupancy
— so replacing per-round wall jitter with its mean leaves *scheduling*
as the only variable between engines and makes the ratios deterministic
given the seed. Capacity ``mu`` is measured closed-loop on the tick
clock; the load run offers Poisson arrivals at ``0.75 * mu`` —
comfortably inside continuous capacity, outside the static fleet's (its
capacity is lower by the straggler factor), so the static queue grows
and its tail latency diverges. Both engines replay the IDENTICAL request
trace (same z0s, same stamps) through the same compiled kernels.

Also emits the interpolant-cache section: one hot dense trajectory
queried repeatedly must report hit rate ``k/(k+1)`` and **zero**
incremental f-evals per hit (the acceptance criterion of the cache).

Emits: per-engine p50/p99 latency, solves/s, occupancy, f-evals/request,
the static/continuous ratios (>1 == continuous wins), and the cache rows.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import ALF
from repro.serve import (ContinuousBatchingEngine, EngineConfig,
                         InterpolantCache, LRU, StaticFleetEngine,
                         decay_dynamics, hot_trajectory_requests,
                         mixed_stiffness_requests)

from .common import Row

SLOTS = 8
CHUNK_STEPS = 16
D_STATE = 16
N_REQUESTS = 64
# ~2.6 decades of stiffness: a lam=200 straggler needs ~30x the trials of
# a lam=0.5 row — the regime where one-shot batching strands whole fleets.
LAM_DECADES = (np.log10(0.5), np.log10(200.0))
MAX_STEPS = 2048
LOAD_FRACTION = 0.75          # offered rate as a fraction of capacity
EVAL_REPEATS = 6              # hot-trajectory repeat queries


def _config() -> EngineConfig:
    return EngineConfig(slots=SLOTS, chunk_steps=CHUNK_STEPS,
                        solver=ALF(eta=0.9))


def _requests(seed: int, rate: float):
    return mixed_stiffness_requests(
        np.random.default_rng(seed), N_REQUESTS, rate=rate,
        d_state=D_STATE, lam_decades=LAM_DECADES, max_steps=MAX_STEPS)


def _tick_timer(tau: float):
    """Deterministic clock: the engine samples the timer twice per
    dispatch, so advancing tau/2 per call charges exactly tau per round."""
    state = {"t": 0.0}

    def timer() -> float:
        state["t"] += tau / 2.0
        return state["t"]

    return timer


def run() -> List[Row]:
    rows: List[Row] = []

    # Closed-loop warmup compiles the dispatch/init kernels (shared by
    # both engines — same statics, same shapes), then a second warm run
    # measures the mean wall time of one dispatch round: the tick-clock
    # calibration tau (measuring during the compile run would overstate
    # it ~100x).
    warm = ContinuousBatchingEngine(decay_dynamics, None, config=_config())
    warm.submit(_requests(seed=0, rate=1e9))   # ~all arrive at t=0
    warm.run()
    timed = ContinuousBatchingEngine(decay_dynamics, None, config=_config())
    timed.submit(_requests(seed=0, rate=1e9))
    timed_rep = timed.run()
    tau = timed_rep.duration_s / max(timed_rep.rounds, 1)
    rows.append(("serve/round_wall_s", tau,
                 f"warm dispatch round, slots={SLOTS}, "
                 f"chunk={CHUNK_STEPS}"))

    cap = ContinuousBatchingEngine(decay_dynamics, None, config=_config(),
                                   timer=_tick_timer(tau))
    cap.submit(_requests(seed=0, rate=1e9))
    mu = cap.run().solves_per_s
    rows.append(("serve/capacity_solves_per_s", mu,
                 f"closed loop, slots={SLOTS}, chunk={CHUNK_STEPS}"))

    rate = LOAD_FRACTION * mu
    reports = {}
    for cls in (ContinuousBatchingEngine, StaticFleetEngine):
        eng = cls(decay_dynamics, None, config=_config(),
                  timer=_tick_timer(tau))
        # Identical trace for both engines: same seed -> same z0s/stamps.
        eng.submit(_requests(seed=1, rate=rate))
        rep = reports[eng.name] = eng.run()
        rows.append((f"serve/p50_latency_s/{rep.engine}",
                     rep.p50_latency_s, f"poisson rate={rate:.1f}/s"))
        rows.append((f"serve/p99_latency_s/{rep.engine}",
                     rep.p99_latency_s,
                     f"{rep.n_completed}/{rep.n_requests} completed"))
        rows.append((f"serve/solves_per_s/{rep.engine}",
                     rep.solves_per_s, f"{rep.rounds} dispatch rounds"))
        rows.append((f"serve/occupancy/{rep.engine}",
                     rep.backfill_occupancy,
                     "mean busy slot fraction at dispatch"))
        rows.append((f"serve/fevals_per_request/{rep.engine}",
                     rep.fevals_per_request,
                     f"lam in 10^[{LAM_DECADES[0]:.1f},"
                     f"{LAM_DECADES[1]:.1f}]"))

    # The headline ratios: >1 == continuous batching wins.
    cont, stat = reports["continuous"], reports["static"]
    rows.append(("serve/p99_static_over_continuous",
                 stat.p99_latency_s / max(cont.p99_latency_s, 1e-12),
                 ">1 == backfill beats one-shot fleet on tail latency"))
    rows.append(("serve/solves_continuous_over_static",
                 cont.solves_per_s / max(stat.solves_per_s, 1e-12),
                 ">1 == backfill beats one-shot fleet on throughput"))

    # Interpolant cache: one hot trajectory, repeated evaluate(t) queries.
    cache = InterpolantCache(LRU(max_entries=16))
    eng = ContinuousBatchingEngine(decay_dynamics, None, config=_config(),
                                   cache=cache, vf_id="decay")
    eng.submit(hot_trajectory_requests(np.random.default_rng(2),
                                       n_repeats=EVAL_REPEATS,
                                       d_state=D_STATE,
                                       max_steps=MAX_STEPS))
    cache_rep = eng.run()
    hit_fevals = [r.n_fevals for r in eng.records if r.cache_hit]
    rows.append(("serve/cache_hit_rate", cache_rep.cache_hit_rate,
                 f"{cache_rep.cache_hits} hits / "
                 f"{cache_rep.cache_hits + cache_rep.cache_misses} "
                 f"lookups on one hot trajectory"))
    rows.append(("serve/cache_hit_incremental_fevals",
                 max(hit_fevals) if hit_fevals else -1,
                 "MUST be 0 — hits read the dense interpolant"))
    return rows
