"""Paper Table 2 (reduced-scale analogue): train a continuous-depth
classifier with MALI, then evaluate with DIFFERENT solvers/stepsizes
WITHOUT retraining — accuracy must be stable; the discrete ("one-step
Euler / ResNet") model collapses when re-discretized."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core.api import odeint

from .common import Row, adam_train, mlp_field, mlp_field_init, spirals

SOLVER_GRID = (("alf", 4), ("alf", 8), ("alf", 16),
               ("euler", 8), ("euler", 16), ("rk2", 8), ("rk4", 8),
               ("dopri5", 8))


def _model_apply(params, x, solver: str, n_steps: int):
    method = "mali" if solver == "alf" else "naive"
    feat = odeint(mlp_field, params["field"], x, 0.0, 1.0, method=method,
                  solver=solver, n_steps=n_steps)
    return feat @ params["head"] + params["b"]


def _discrete_apply(params, x, n_blocks: int):
    """n_blocks residual Euler blocks sharing f (the ResNet re-discretization
    experiment: trained with n=1, evaluated at other n)."""
    h = 1.0 / n_blocks
    z = x
    for i in range(n_blocks):
        z = z + h * mlp_field(params["field"], z, i * h)
    return z @ params["head"] + params["b"]


def _l2(tree):
    return sum(jnp.sum(l ** 2) for l in jax.tree_util.tree_leaves(tree))


def _train(apply_fn, params, x, y, steps=1500, lr=5e-3):
    def loss_fn(p):
        logits = apply_fn(p, x)
        logp = jax.nn.log_softmax(logits)
        ce = -jnp.take_along_axis(logp, y[:, None], 1).mean()
        # smooth-field regularizer: keeps ||f|| moderate so the learned
        # dynamics is a genuine continuous model (paper: a model that is
        # "invariant to discretization scheme"), not one that exploits the
        # training grid
        return ce + 1e-3 * _l2(p["field"])

    return adam_train(loss_fn, params, steps=steps, lr=lr)


def _acc(apply_fn, params, x, y) -> float:
    return float((apply_fn(params, x).argmax(-1) == y).mean())


def run() -> List[Row]:
    rows: List[Row] = []
    x, y = spirals(512)
    xt, yt = spirals(512, seed=1)
    key = jax.random.PRNGKey(0)
    kf, kh = jax.random.split(key)
    params0 = {"field": mlp_field_init(kf),
               "head": 0.5 * jax.random.normal(kh, (2, 2)), "b": jnp.zeros(2)}

    # --- continuous model trained with MALI (alf, 4 steps) ---
    node, train_loss = _train(
        lambda p, xx: _model_apply(p, xx, "alf", 8), params0, x, y)
    rows.append(("invariance/node/train_loss", train_loss, "mali alf n=8"))
    for solver, n in SOLVER_GRID:
        a = _acc(lambda p, xx: _model_apply(p, xx, solver, n), node, xt, yt)
        rows.append((f"invariance/node/test_acc/{solver}/n={n}", a,
                     "no retraining"))

    # --- discrete 1-step-Euler baseline re-discretized ---
    res, _ = _train(lambda p, xx: _discrete_apply(p, xx, 1), params0, x, y)
    for n in (1, 2, 4, 8):
        a = _acc(lambda p, xx: _discrete_apply(p, xx, n), res, xt, yt)
        rows.append((f"invariance/resnet/test_acc/euler_blocks={n}", a,
                     "trained at n=1 (paper: collapses off n=1)"))
    return rows
