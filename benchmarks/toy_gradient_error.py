"""Paper Fig. 4 (a,b): gradient error vs integration time T on the analytic
toy (Eq. 6/7), at the paper's adaptive tolerances (rtol=1e-5, atol=1e-6).

Two readouts per (method, T):
  * error vs the closed form (what Fig. 4 plots), and
  * MALI's reverse-accuracy invariant — |g_mali - g_naive(alf)| / |g_naive| —
    which must sit at float-rounding level for every T (the adjoint has no
    such guarantee).
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core.api import odeint

from .common import ALPHA, Row, Z0, toy_exact, toy_f

TS = (1.0, 2.0, 5.0, 10.0, 20.0)
RTOL, ATOL = 1e-5, 1e-6
METHOD_SOLVER = (("mali", None), ("naive", "alf"), ("aca", "heun_euler"),
                 ("adjoint", "dopri5"))


def _grad(method, solver, T, max_steps):
    def loss(p, z):
        return odeint(toy_f, p, z, 0.0, T, method=method, solver=solver,
                      n_steps=0, rtol=RTOL, atol=ATOL,
                      max_steps=max_steps) ** 2

    return jax.grad(loss, argnums=(0, 1))(
        {"alpha": jnp.float32(ALPHA)}, jnp.float32(Z0))


def run() -> List[Row]:
    rows: List[Row] = []
    for T in TS:
        # ALF at rtol=1e-5 needs h ~ (tol)^(1/3) ~ 0.02 -> bound the trial
        # budget accordingly (rejected trials included)
        max_steps = int(T * 160) + 64
        _, dz0_x, dalpha_x = toy_exact(T)
        grads = {}
        for method, solver in METHOD_SOLVER:
            gp, gz = _grad(method, solver, T, max_steps)
            grads[method] = (float(gp["alpha"]), float(gz))
            rel_z0 = abs(float(gz) - dz0_x) / abs(dz0_x)
            rel_a = abs(float(gp["alpha"]) - dalpha_x) / abs(dalpha_x)
            rows.append((f"toy_grad_err/dz0/{method}/T={T}", rel_z0,
                         f"rtol={RTOL}"))
            rows.append((f"toy_grad_err/dalpha/{method}/T={T}", rel_a,
                         f"rtol={RTOL}"))
        # reverse-accuracy invariant: MALI == backprop through its own
        # forward (same ALF discretization) to float rounding
        na, nz = grads["naive"]
        ma, mz = grads["mali"]
        rows.append((f"toy_grad_err/mali_vs_naive_alf/dalpha/T={T}",
                     abs(ma - na) / max(abs(na), 1e-30),
                     "reverse-accuracy invariant (~fp eps)"))
        rows.append((f"toy_grad_err/mali_vs_naive_alf/dz0/T={T}",
                     abs(mz - nz) / max(abs(nz), 1e-30),
                     "reverse-accuracy invariant (~fp eps)"))
    return rows
