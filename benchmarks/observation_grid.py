"""Observation-grid benchmark: chained per-interval solves vs one
native-grid ``SaveAt(ts=...)`` solve.

Chaining re-enters the integrator once per interval (T-1 separate custom_vjp
calls stitched together in Python — the pre-refactor latent-ODE rollout);
the native grid runs one compiled scan whose carry crosses segment
boundaries. We compare grad wall-clock and the backward-pass residual/temp
memory from the AOT artifact, plus MALI's residual invariance in the
per-segment step count (the Table 1 claim, now per observation grid).

Uses the composable object API (`solve` + Solver/StepController/
GradientMethod/SaveAt); the analytic ``Solution.stats.residual_bytes``
estimate is emitted next to the measured AOT temp bytes so the two
trajectories can be compared.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core import (ALF, AdaptiveController, ConstantSteps, MALI, Naive,
                        SaveAt, solve)

from .common import Row, mlp_field, mlp_field_init, time_fn

T_OBS = 16       # observation grid size
N_SUB = 4        # fixed sub-steps per segment
BATCH, DIM = 64, 2

GRADIENTS = {"mali": MALI(), "naive": Naive()}


def _setup():
    params = mlp_field_init(jax.random.PRNGKey(0))
    z0 = jax.random.normal(jax.random.PRNGKey(1), (BATCH, DIM))
    ts = jnp.linspace(0.0, 1.0, T_OBS)
    return params, z0, ts


def _loss_native(method, n_sub=N_SUB):
    def loss(p, z, ts):
        sol = solve(mlp_field, p, z, solver=ALF(),
                    controller=ConstantSteps(n_sub),
                    gradient=GRADIENTS[method],
                    saveat=SaveAt(ts=ts))
        return jnp.sum(sol.ys ** 2)
    return loss


def _loss_chained(method):
    def loss(p, z, ts):
        zs = [z]
        for k in range(T_OBS - 1):
            z = solve(mlp_field, p, z, ts[k], ts[k + 1], solver=ALF(),
                      controller=ConstantSteps(N_SUB),
                      gradient=GRADIENTS[method]).ys
            zs.append(z)
        return jnp.sum(jnp.stack(zs) ** 2)
    return loss


def _temp_bytes(grad_fn, *args) -> int:
    c = jax.jit(grad_fn).lower(*args).compile()
    ma = c.memory_analysis()
    return int(ma.temp_size_in_bytes) if ma else -1


def run() -> List[Row]:
    rows: List[Row] = []
    params, z0, ts = _setup()

    for method in ("mali", "naive"):
        for variant, make in (("native", _loss_native),
                              ("chained", _loss_chained)):
            grad_fn = jax.grad(make(method), argnums=(0, 1))
            us = time_fn(jax.jit(grad_fn), params, z0, ts)
            rows.append((f"obs_grid/grad_us/{method}/{variant}", us,
                         f"T={T_OBS},n_steps={N_SUB}"))
            b = _temp_bytes(grad_fn, params, z0, ts)
            rows.append((f"obs_grid/temp_bytes/{method}/{variant}", b,
                         f"T={T_OBS},n_steps={N_SUB}"))

    # MALI's native-grid residuals must stay flat as per-segment step count
    # grows (naive's grow with it) — Table 1, per observation grid. The
    # analytic Stats estimate should track the measured AOT trajectory.
    for method in ("mali", "naive"):
        series = []
        for n_sub in (2, 16):
            series.append(_temp_bytes(
                jax.grad(_loss_native(method, n_sub), argnums=(0, 1)),
                params, z0, ts))
            # the stats estimate is shape-analytic — no solve needed
            est = GRADIENTS[method].residual_bytes(
                z0, T_OBS, ALF(), ConstantSteps(n_sub))
            rows.append((f"obs_grid/stats_residual_bytes/{method}/n={n_sub}",
                         est, "Solution.stats analytic estimate"))
        growth = series[-1] / max(series[0], 1)
        rows.append((f"obs_grid/residual_growth_2to16/{method}", growth,
                     "flat~1 expected for mali; ~n_steps for naive"))

    # Per-step record of the same problem, sized through the documented
    # Solution accessors (num_steps/step_mask) rather than ad-hoc
    # n_accepted arithmetic on the padded buffer.
    sol = solve(mlp_field, params, z0, 0.0, 1.0, solver=ALF(),
                controller=AdaptiveController(1e-3, 1e-4, 256),
                saveat=SaveAt(steps=True))
    rows.append(("obs_grid/step_record_live_rows", int(jnp.sum(sol.step_mask)),
                 f"num_steps={int(sol.num_steps)},"
                 f"span_complete={bool(sol.stats.span_complete)}"))
    return rows
