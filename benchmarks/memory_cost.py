"""Paper Fig. 4(c) / Table 1 memory column: backward-pass live memory vs
number of solver steps, from the AOT-compiled artifact (temp_size_in_bytes).
MALI/adjoint must stay flat; naive/ACA grow with N_t."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core.api import odeint

from .common import Row

D = 8192
STEPS = (4, 16, 64)
METHOD_SOLVER = (("mali", None), ("naive", "alf"), ("aca", "heun_euler"),
                 ("adjoint", "heun_euler"))


def _f(params, z, t):
    return jnp.tanh(params["w"] * z) * params["a"]


def _temp_bytes(method, solver, n_steps) -> int:
    params = {"w": jnp.ones((D,), jnp.float32) * 0.5,
              "a": jnp.ones((D,), jnp.float32)}
    z0 = jnp.ones((D,), jnp.float32)

    def loss(p, z):
        return jnp.sum(odeint(_f, p, z, 0.0, 1.0, method=method,
                              solver=solver, n_steps=n_steps) ** 2)

    c = jax.jit(jax.grad(loss, argnums=(0, 1))).lower(params, z0).compile()
    ma = c.memory_analysis()
    return int(ma.temp_size_in_bytes) if ma else -1


def _mali_backend_temp_bytes(backend: str, n_steps: int) -> int:
    """Backward residual footprint of a MALI train step, per step-algebra
    backend — the O(1)-in-steps property must survive kernel fusion (the
    fused backward reconstructs in place exactly like the reference)."""
    from repro.core import ALF, ConstantSteps, MALI, solve

    params = {"w": jnp.ones((D,), jnp.float32) * 0.5,
              "a": jnp.ones((D,), jnp.float32)}
    z0 = jnp.ones((D,), jnp.float32)

    def loss(p, z):
        sol = solve(_f, p, z, 0.0, 1.0, solver=ALF(backend=backend),
                    controller=ConstantSteps(n_steps), gradient=MALI())
        return jnp.sum(sol.ys ** 2)

    c = jax.jit(jax.grad(loss, argnums=(0, 1))).lower(params, z0).compile()
    ma = c.memory_analysis()
    return int(ma.temp_size_in_bytes) if ma else -1


def run() -> List[Row]:
    rows: List[Row] = []
    for method, solver in METHOD_SOLVER:
        series = []
        for n in STEPS:
            b = _temp_bytes(method, solver, n)
            series.append(b)
            rows.append((f"memory/temp_bytes/{method}/n={n}", b,
                         f"state={D}xf32"))
        growth = series[-1] / max(series[0], 1)
        rows.append((f"memory/growth_{STEPS[0]}to{STEPS[-1]}/{method}",
                     growth,
                     "flat~1 expected for mali/adjoint; ~N_t for naive/aca"))
    for backend in ("reference", "pallas"):
        series = []
        for n in STEPS:
            b = _mali_backend_temp_bytes(backend, n)
            series.append(b)
            rows.append((f"memory/bwd_temp_bytes/mali_{backend}/n={n}", b,
                         f"state={D}xf32"))
        growth = series[-1] / max(series[0], 1)
        rows.append(
            (f"memory/bwd_growth_{STEPS[0]}to{STEPS[-1]}/mali_{backend}",
             growth, "flat~1 expected: O(1)-in-steps survives fusion"))
    return rows
