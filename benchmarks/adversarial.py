"""Paper Table 3 (reduced scale): FGSM robustness of the Neural ODE vs the
ResNet sharing the same f, and cross-solver attack transfer — the attack
gradient is derived with one solver, inference runs another (possible only
because the continuous model is solver-invariant)."""
from __future__ import annotations

import sys
from typing import List

import jax
import jax.numpy as jnp

sys.path.insert(0, "examples")

from .common import Row  # noqa: E402

EPS = (0.1, 0.3)
ATTACK_SOLVERS = (("alf", 4), ("rk4", 4))
INFER_SOLVERS = (("alf", 4), ("euler", 8), ("dopri5", 4))


def run() -> List[Row]:
    from image_recognition import (accuracy, forward, init_params, make_data,
                                   train)
    rows: List[Row] = []
    x, y = make_data(2048, seed=0)
    xt, yt = make_data(1024, seed=1)
    p0 = init_params(jax.random.PRNGKey(0))
    res, _ = train(p0, x, y, "resnet", 400)
    node, _ = train(p0, x, y, "node", 400)

    def fgsm(params, xx, yy, eps, mode, **kw):
        def loss(xi):
            logp = jax.nn.log_softmax(forward(params, xi, mode, **kw))
            return -jnp.take_along_axis(logp, yy[:, None], 1).mean()

        g = jax.grad(loss)(xx)
        return xx + eps * jnp.sign(g)

    for eps in EPS:
        x_adv_res = fgsm(res, xt, yt, eps, "resnet")
        a = accuracy(res, x_adv_res, yt, "resnet")
        rows.append((f"fgsm/resnet/eps={eps}", a, "white-box"))

        for a_solver, a_n in ATTACK_SOLVERS:
            x_adv = fgsm(node, xt, yt, eps, "node",
                         solver=a_solver, n_steps=a_n)
            for i_solver, i_n in INFER_SOLVERS:
                acc = accuracy(node, x_adv, yt, "node",
                               solver=i_solver, n_steps=i_n)
                rows.append(
                    (f"fgsm/node/eps={eps}/attack={a_solver}/infer={i_solver}",
                     acc, "paper Table 3 cross-solver cell"))
    return rows
