"""Memory-vs-depth proof on the FULL train step (paper Table 1, taken
end-to-end): AOT-compile ``repro.train.loop.train_step`` for a smoke LM at
growing ODE step budgets and read the backward temp footprint from the
compiled artifact (``memory_analysis().temp_size_in_bytes``).

MALI reconstructs states via psi^-1, so its temp bytes must stay flat
(growth ~1.0x, acceptance <= 1.05x) across a 64x step spread while
Naive/ACA checkpoint per-step residuals and grow linearly. Everything is
lowered from ShapeDtypeStructs — no parameters are materialized, so the
sweep is trace+compile only.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core.ode_block import OdeSettings
from repro.launch.specs import param_specs
from repro.optim.optimizer import OptimizerConfig, init_opt_state
from repro.train.loop import train_step
from repro.train.metrics import ode_residual_bytes

from .common import Row

ARCH = "qwen3-1.7b"
STEPS = (8, 32, 128, 512)
METHODS = (("mali", "alf"), ("naive", "alf"), ("aca", "heun_euler"))
B, S = 2, 16


def _cfg(method: str, solver: str, n_steps: int):
    ode = OdeSettings(mode="per_block", method=method, solver=solver,
                      n_steps=n_steps)
    base = smoke_config(ARCH, ode)
    # one period, no prelude: depth enough for the ODE branches to dominate
    # temps, small enough that 12 AOT compiles stay cheap
    return dataclasses.replace(base, prelude=(), n_periods=1).validate()


def _temp_bytes(method: str, solver: str, n_steps: int) -> int:
    cfg = _cfg(method, solver, n_steps)
    opt_cfg = OptimizerConfig()
    p_spec = param_specs(cfg)
    o_spec = jax.eval_shape(lambda p: init_opt_state(opt_cfg, p), p_spec)
    b_spec = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
              "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def step(p, o, b):
        p2, o2, _, metrics = train_step(p, o, None, b, cfg=cfg,
                                        opt_cfg=opt_cfg)
        return p2, o2, metrics["loss"]

    c = jax.jit(step).lower(p_spec, o_spec, b_spec).compile()
    ma = c.memory_analysis()
    return int(ma.temp_size_in_bytes) if ma else -1


def run() -> List[Row]:
    rows: List[Row] = []
    for method, solver in METHODS:
        series = []
        for n in STEPS:
            b = _temp_bytes(method, solver, n)
            series.append(b)
            rows.append((f"train_memory/temp_bytes/{method}/n={n}", b,
                         f"{ARCH} smoke 1-period B={B} S={S}"))
            rows.append((f"train_memory/residual_bytes/{method}/n={n}",
                         ode_residual_bytes(_cfg(method, solver, n), B, S),
                         "analytic Table-1 backward residual"))
        growth = series[-1] / max(series[0], 1)
        rows.append((f"train_memory/growth_{STEPS[0]}to{STEPS[-1]}/{method}",
                     growth,
                     "flat~1 (<=1.05) expected for mali; "
                     "~N_t for naive/aca"))
    return rows
