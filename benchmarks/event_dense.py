"""Dense-output and event-solve benchmarks (the time-axis redesign paths).

Two comparisons:

* **Dense-eval throughput** — one ``SaveAt(dense=True)`` solve answers Q
  arbitrary-time queries through ``Solution.evaluate`` (polynomial
  arithmetic only) vs re-integrating a ``SaveAt(ts=...)`` grid per query
  batch. This is the serving-path shape: CNF likelihood / latent-ODE
  decoding at query times not known when the solve ran.
* **Event-solve latency** — the native ``solve(..., event=Event(...))``
  (one dense-recording detection pass + interpolant bisection + one
  re-solve) vs the naive stop-and-restart loop (chunked Python solves
  until the sign flips, then bisection by re-integration — the only way
  to express a hitting time before events were first-class).

Both paths land in ``BENCH_core.json`` via ``benchmarks.run`` so CI tracks
their trajectory alongside the older paper benches.
"""
from __future__ import annotations

import math
from typing import List

import jax
import jax.numpy as jnp

from repro.core import (ALF, ConstantSteps, Event, MALI,
                        SaveAt, solve)

from .common import Row, time_fn

DIM = 32
N_QUERIES = 256
ALPHA = 0.7
T_END = 3.0
N_CHUNKS = 24          # stop-and-restart granularity
N_BISECT = 20          # matches Event(max_bisections=) refinement depth


def _f(params, z, t):
    return -params * z


def _setup():
    params = jnp.float32(ALPHA)
    z0 = jnp.linspace(0.8, 2.0, DIM)
    return params, z0


# --- dense-eval throughput -------------------------------------------------

def _dense_eval(params, z0, queries):
    sol = solve(_f, params, z0, 0.0, T_END, solver=ALF(),
                controller=ConstantSteps(64), saveat=SaveAt(dense=True))
    return sol.evaluate(queries)


def _grid_resolve(params, z0, queries):
    # Re-integrating to answer the same queries (queries must be sorted to
    # form a legal grid — the historical workaround for arbitrary-t asks).
    sol = solve(_f, params, z0, solver=ALF(), controller=ConstantSteps(64),
                gradient=MALI(), saveat=SaveAt(ts=queries))
    return sol.ys


# --- event solve vs stop-and-restart ---------------------------------------

def _native_event(params, z0):
    ev = Event(lambda z, t: z[0] - 0.5, direction=-1,
               max_bisections=N_BISECT)
    sol = solve(_f, params, z0, 0.0, T_END, solver=ALF(),
                controller=ConstantSteps(64), gradient=MALI(), event=ev)
    return sol.stats.event_time


def _restart_event(params, z0):
    """The pre-event workaround: chunked solves in a Python loop, sign
    check per chunk, then bisection where each iteration re-integrates
    from the chunk start. Every chunk/bisection is its own compiled
    solve."""
    cond = lambda z: float(z[0]) - 0.5
    chunk = T_END / N_CHUNKS
    steps_per_chunk = max(64 // N_CHUNKS, 2)
    z = z0
    t = 0.0
    z_prev, t_prev = z, t
    for _ in range(N_CHUNKS):
        z_next = solve(_f, params, z, t, t + chunk, solver=ALF(),
                       controller=ConstantSteps(steps_per_chunk),
                       gradient=MALI()).ys
        if cond(z_next) <= 0.0 < cond(z):
            z_prev, t_prev = z, t
            break
        z, t = z_next, t + chunk
        z_prev, t_prev = z, t
    else:
        return T_END
    # bisect by re-integration from the bracketing chunk start
    lo, hi = t_prev, t_prev + chunk
    for _ in range(N_BISECT):
        mid = 0.5 * (lo + hi)
        z_mid = solve(_f, params, z_prev, t_prev, mid, solver=ALF(),
                      controller=ConstantSteps(steps_per_chunk),
                      gradient=MALI()).ys
        if cond(z_mid) <= 0.0:
            hi = mid
        else:
            lo = mid
    return 0.5 * (lo + hi)


def run() -> List[Row]:
    rows: List[Row] = []
    params, z0 = _setup()
    queries = jnp.linspace(0.0, T_END, N_QUERIES)

    dense_fn = jax.jit(_dense_eval)
    grid_fn = jax.jit(_grid_resolve)
    us_dense = time_fn(dense_fn, params, z0, queries)
    us_grid = time_fn(grid_fn, params, z0, queries)
    rows.append((f"event_dense/dense_eval_us/Q={N_QUERIES}", us_dense,
                 "one dense solve + evaluate(Q)"))
    rows.append((f"event_dense/grid_resolve_us/Q={N_QUERIES}", us_grid,
                 "SaveAt(ts=Q-grid) re-integration"))
    rows.append(("event_dense/dense_eval_speedup", us_grid / max(us_dense, 1),
                 "grid_us / dense_us (>1 = dense wins)"))

    native_fn = jax.jit(_native_event)
    us_native = time_fn(native_fn, params, z0)
    # stop-and-restart is a Python loop of separate solves — time it whole
    # (jit applies per inner solve; the loop structure is the cost).
    us_restart = time_fn(_restart_event, params, z0, warmup=1, iters=3)
    t_native = float(native_fn(params, z0))
    t_restart = float(_restart_event(params, z0))
    t_exact = math.log(z0[0].item() / 0.5) / ALPHA
    rows.append(("event_dense/event_native_us", us_native,
                 f"t_event={t_native:.5f} (exact {t_exact:.5f})"))
    rows.append(("event_dense/event_restart_us", us_restart,
                 f"t_event={t_restart:.5f} (naive loop)"))
    rows.append(("event_dense/event_speedup", us_restart / max(us_native, 1),
                 "restart_us / native_us (>1 = native wins)"))
    rows.append(("event_dense/event_time_err", abs(t_native - t_exact),
                 "native event time vs analytic"))
    return rows
