"""Paper Fig. 5 (right) analogue: wall-clock per training step for each
gradient method at equal discretization. Expectation (Table 1 computation
column): MALI ~ ACA < naive; adjoint pays the reverse re-integration.

A method-swap experiment is a one-argument change on the object API: the
(gradient, solver) pairs below are the whole configuration matrix."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core import (ACA, ALF, Backsolve, ConstantSteps, HeunEuler, MALI,
                        Naive, solve)

from .common import Row, mlp_field, mlp_field_init, spirals, time_fn

N_STEPS = 8
CONFIGS = (("mali", MALI(), ALF()), ("naive", Naive(), ALF()),
           ("aca", ACA(), HeunEuler()),
           ("adjoint", Backsolve(), HeunEuler()),
           # the end-to-end fused train step: Pallas forward AND the fused
           # inverse+VJP backward kernels (interpret mode on CPU, so the
           # number is a correctness-of-the-path datapoint there, a perf
           # one on TPU)
           ("pallas_backward", MALI(), ALF(backend="pallas")))


def _pallas_bwd_launches() -> int:
    """Launches in one whole pallas MALI train step: 2 forward (midpoint +
    update) + 2 backward (bwd_pre + bwd_post) — the roofline check that the
    backward elementwise algebra collapsed to one launch per side of the
    f-eval linearization."""
    from repro.launch.hlo_cost import count_pallas_launches

    params = {"w": jnp.ones((64,), jnp.float32)}

    def f(p, z, t):
        return jnp.tanh(p["w"] * z)

    def loss(p, z):
        return jnp.sum(solve(f, p, z, 0.0, 1.0,
                             solver=ALF(backend="pallas"),
                             controller=ConstantSteps(N_STEPS),
                             gradient=MALI()).ys)

    return count_pallas_launches(jax.grad(loss, argnums=(0, 1)), params,
                                 jnp.ones((64,), jnp.float32))


def run() -> List[Row]:
    rows: List[Row] = []
    x, y = spirals(1024)
    params = {"field": mlp_field_init(jax.random.PRNGKey(0), d_hidden=64),
              "head": jnp.zeros((2, 2)), "b": jnp.zeros(2)}
    controller = ConstantSteps(N_STEPS)

    for name, gradient, solver in CONFIGS:
        def loss_fn(p):
            feat = solve(mlp_field, p["field"], x, 0.0, 1.0, solver=solver,
                         controller=controller, gradient=gradient).ys
            logits = feat @ p["head"] + p["b"]
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, y[:, None], 1).mean()

        step = jax.jit(jax.grad(loss_fn))
        us = time_fn(step, params)
        rows.append((f"speed/train_step_us/{name}", us,
                     f"n_steps={N_STEPS} batch=1024 (CPU relative)"))

    rows.append(("speed/pallas_bwd_launches_per_step",
                 float(_pallas_bwd_launches()),
                 "whole train step: 2 fwd (midpoint+update) + 2 bwd "
                 "(bwd_pre+bwd_post) expected"))
    return rows
