"""Render EXPERIMENTS.md tables from reports/dryrun/summary.jsonl.

    PYTHONPATH=src python -m benchmarks.report [--summary reports/dryrun/summary.jsonl]

Prints the §Dry-run and §Roofline markdown tables (single-pod roofline per
the assignment; multi-pod pass/fail only).
"""
from __future__ import annotations

import argparse
import json
from collections import OrderedDict


def load(path):
    best = OrderedDict()
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            key = (r["arch"], r["shape"], r["mesh"])
            best[key] = r
    return best


def fmt_bytes(b):
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024 or unit == "TiB":
            return f"{b:.1f} {unit}"
        b /= 1024


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f} s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f} ms"
    return f"{x * 1e6:.1f} us"


def dryrun_table(recs):
    print("| arch | shape | 16x16 | 2x16x16 | bytes/dev (args+temp) | "
          "compile s |")
    print("|---|---|---|---|---|---|")
    archs = sorted({a for a, _, _ in recs})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for arch in archs:
        for shape in shapes:
            single = recs.get((arch, shape, "pod16x16"))
            multi = recs.get((arch, shape, "pod2x16x16"))
            if single is None and multi is None:
                continue
            r = single or multi
            if r["status"] == "skipped":
                print(f"| {arch} | {shape} | skip | skip | — | — |")
                continue

            def st(x):
                return {"ok": "PASS", "error": "FAIL",
                        None: "—"}.get(x and x["status"], "—")

            mem = ""
            cs = ""
            if single and single["status"] == "ok":
                m = single["memory"]
                per_dev = (m.get("argument_size_in_bytes", 0)
                           + m.get("temp_size_in_bytes", 0)) / 256
                mem = fmt_bytes(per_dev)
                cs = f"{single['compile_s']:.0f}"
            print(f"| {arch} | {shape} | {st(single)} | {st(multi)} | "
                  f"{mem} | {cs} |")


def roofline_table(recs):
    print("| arch | shape | t_comp | t_mem | t_coll | bottleneck | "
          "useful/HLO flops | dominant-term driver |")
    print("|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in recs.items():
        if mesh != "pod16x16" or r["status"] != "ok":
            continue
        f = r["roofline"]
        drivers = {
            "compute": "MXU occupancy (flops/chip)",
            "memory": "HBM traffic (remat + activations)",
            "collective": "ICI wire bytes (TP all-reduces)",
        }
        print(f"| {arch} | {shape} | {fmt_s(f['t_compute_s'])} | "
              f"{fmt_s(f['t_memory_s'])} | {fmt_s(f['t_collective_s'])} | "
              f"{f['bottleneck']} | {f['useful_flops_ratio']:.3f} | "
              f"{drivers[f['bottleneck']]} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--summary", default="reports/dryrun_final/summary.jsonl")
    ap.add_argument("--table", default="both",
                    choices=["dryrun", "roofline", "both"])
    a = ap.parse_args()
    recs = load(a.summary)
    if a.table in ("dryrun", "both"):
        print("### Dry-run matrix\n")
        dryrun_table(recs)
        print()
    if a.table in ("roofline", "both"):
        print("### Roofline (single-pod 16x16, per-chip terms)\n")
        roofline_table(recs)


if __name__ == "__main__":
    main()
