"""Paper Table 7: damped MALI with eta in {1.0, 0.95, 0.9, 0.85} — task
metric must be robust to eta (spirals test accuracy here)."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core.api import odeint

from .common import Row, adam_train, mlp_field, mlp_field_init, spirals

ETAS = (1.0, 0.95, 0.9, 0.85)


def run() -> List[Row]:
    rows: List[Row] = []
    x, y = spirals(512)
    xt, yt = spirals(512, seed=1)
    key = jax.random.PRNGKey(0)
    kf, kh = jax.random.split(key)

    for eta in ETAS:
        params = {"field": mlp_field_init(kf),
                  "head": 0.5 * jax.random.normal(kh, (2, 2)),
                  "b": jnp.zeros(2)}

        def apply_fn(p, xx):
            feat = odeint(mlp_field, p["field"], xx, 0.0, 1.0,
                          method="mali", n_steps=4, eta=eta)
            return feat @ p["head"] + p["b"]

        def loss_fn(p):
            logp = jax.nn.log_softmax(apply_fn(p, x))
            return -jnp.take_along_axis(logp, y[:, None], 1).mean()

        params, _ = adam_train(loss_fn, params, steps=1500, lr=5e-3)
        acc = float((apply_fn(params, xt).argmax(-1) == yt).mean())
        rows.append((f"damped/test_acc/eta={eta}", acc, "1500 adam steps"))
    return rows
