"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only toy_gradient_error ...]

Emits ``name,value,derived`` CSV to stdout. Roofline numbers come from the
dry-run (reports/dryrun/) and are summarized here if present.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from .common import print_rows

BENCHES = ("toy_gradient_error", "memory_cost", "solver_invariance",
           "speed", "damped", "adversarial", "observation_grid")


def _dryrun_summary_rows():
    path = os.path.join("reports", "dryrun_final", "summary.jsonl")
    if not os.path.exists(path):
        path = os.path.join("reports", "dryrun", "summary.jsonl")
    if not os.path.exists(path):
        return []
    best = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("status") != "ok":
                continue
            key = (r["arch"], r["shape"], r["mesh"])
            best[key] = r  # last write wins (most recent run)
    rows = []
    for (arch, shape, mesh), r in sorted(best.items()):
        roof = r["roofline"]
        t_dom = max(roof["t_compute_s"], roof["t_memory_s"],
                    roof["t_collective_s"])
        frac = roof["t_compute_s"] / t_dom if t_dom else 0.0
        rows.append((f"roofline/{arch}/{shape}/{mesh}/bottleneck_frac",
                     frac, roof["bottleneck"]))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {BENCHES}")
    args = ap.parse_args()
    names = args.only or BENCHES

    print("name,value,derived")
    failures = 0
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # keep the harness going; report at exit
            print(f"{name}/ERROR,nan,{type(e).__name__}: {e}",
                  file=sys.stderr)
            failures += 1
            continue
        print_rows(rows)
        print(f"{name}/wall_s,{time.time() - t0:.1f},harness")
    print_rows(_dryrun_summary_rows())
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
