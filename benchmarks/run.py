"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only toy_gradient_error ...]
                                            [--json [BENCH_core.json]]

Emits ``name,value,derived`` CSV to stdout; with ``--json`` additionally
writes a perf-trajectory artifact (per-bench rows + wall-clock, plus the
run's totals) that CI uploads so bench numbers are comparable across
commits. Roofline numbers come from the dry-run (reports/dryrun/) and are
summarized here if present.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

from .common import print_rows

BENCHES = ("toy_gradient_error", "memory_cost", "solver_invariance",
           "speed", "damped", "adversarial", "observation_grid",
           "batched_throughput", "event_dense", "serve_load",
           "train_memory", "cnf_bits_dim")


def _dryrun_summary_rows():
    path = os.path.join("reports", "dryrun_final", "summary.jsonl")
    if not os.path.exists(path):
        path = os.path.join("reports", "dryrun", "summary.jsonl")
    if not os.path.exists(path):
        return []
    best = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("status") != "ok":
                continue
            key = (r["arch"], r["shape"], r["mesh"])
            best[key] = r  # last write wins (most recent run)
    rows = []
    for (arch, shape, mesh), r in sorted(best.items()):
        roof = r["roofline"]
        t_dom = max(roof["t_compute_s"], roof["t_memory_s"],
                    roof["t_collective_s"])
        frac = roof["t_compute_s"] / t_dom if t_dom else 0.0
        rows.append((f"roofline/{arch}/{shape}/{mesh}/bottleneck_frac",
                     frac, roof["bottleneck"]))
    return rows


def _write_json(path: str, benches, extra_rows, t_start: float,
                failures: int) -> None:
    payload = {
        "schema": "bench_core/v1",
        "generated_unix": time.time(),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "total_wall_s": time.time() - t_start,
        "failures": failures,
        "benches": [
            {
                "bench": name,
                "wall_s": wall,
                "rows": [{"name": n, "value": float(v), "derived": d}
                         for (n, v, d) in rows],
            }
            for (name, wall, rows) in benches
        ],
        "extra_rows": [{"name": n, "value": float(v), "derived": d}
                       for (n, v, d) in extra_rows],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {BENCHES}")
    ap.add_argument("--json", nargs="?", const="BENCH_core.json",
                    default=None, metavar="PATH",
                    help="also write the perf-trajectory JSON artifact "
                         "(default path: BENCH_core.json)")
    args = ap.parse_args()
    names = args.only or BENCHES

    t_start = time.time()
    print("name,value,derived")
    failures = 0
    bench_results = []
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # keep the harness going; report at exit
            print(f"{name}/ERROR,nan,{type(e).__name__}: {e}",
                  file=sys.stderr)
            failures += 1
            continue
        wall = time.time() - t0
        print_rows(rows)
        print(f"{name}/wall_s,{wall:.1f},harness")
        bench_results.append((name, wall, list(rows)))
    extra = _dryrun_summary_rows()
    print_rows(extra)
    if args.json:
        _write_json(args.json, bench_results, extra, t_start, failures)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
