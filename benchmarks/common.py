"""Shared benchmark utilities: timing, CSV row emission, tiny problems."""
from __future__ import annotations

import math
import time
from typing import Callable, Iterable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]   # (name, value, derived/notes)


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock microseconds per call (CPU; relative numbers)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def print_rows(rows: Iterable[Row]) -> None:
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{derived}")


# --- the paper's §4.1 toy problem (analytic gradients) ---------------------
# alpha=0.5 keeps e^{2*alpha*T} inside f32 range out to the paper's T=20
# (the paper plots the same sweep; fp64 there, fp32 here).

ALPHA, Z0 = 0.5, 1.0


def toy_f(params, z, t):
    return params["alpha"] * z


def toy_exact(T: float):
    L = (Z0 * math.exp(ALPHA * T)) ** 2
    dz0 = 2 * Z0 * math.exp(2 * ALPHA * T)
    dalpha = 2 * T * Z0 ** 2 * math.exp(2 * ALPHA * T)
    return L, dz0, dalpha


# --- two-spirals toy classification (for solver-invariance / speed) --------

def spirals(n: int = 512, seed: int = 0):
    rng = np.random.default_rng(seed)
    n2 = n // 2
    theta = np.sqrt(rng.uniform(0, 1, n2)) * 3 * np.pi
    r = theta / (3 * np.pi)
    x0 = np.stack([r * np.cos(theta), r * np.sin(theta)], -1)
    x1 = -x0
    x = np.concatenate([x0, x1]) + rng.normal(0, 0.02, (n, 2))
    y = np.concatenate([np.zeros(n2), np.ones(n2)]).astype(np.int32)
    perm = rng.permutation(n)
    return jnp.asarray(x[perm], jnp.float32), jnp.asarray(y[perm])


def mlp_field_init(key, d_hidden: int = 32, d: int = 2):
    k1, k2 = jax.random.split(key)
    return {
        "w1": 0.5 * jax.random.normal(k1, (d + 1, d_hidden)),
        "b1": jnp.zeros((d_hidden,)),
        "w2": 0.5 * jax.random.normal(k2, (d_hidden, d)),
        "b2": jnp.zeros((d,)),
    }


def mlp_field(params, z, t):
    """Concatenate-time MLP vector field (the usual Neural-ODE toy f)."""
    t_col = jnp.broadcast_to(jnp.asarray(t, z.dtype), z.shape[:-1] + (1,))
    h = jnp.tanh(jnp.concatenate([z, t_col], -1) @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def adam_train(loss_fn, params, steps: int = 1000, lr: float = 5e-3):
    """Minimal Adam loop for the toy benchmarks/examples."""
    tm = jax.tree_util.tree_map
    m = tm(jnp.zeros_like, params)
    v = tm(jnp.zeros_like, params)

    @jax.jit
    def step(carry, i):
        p, m, v = carry
        l, g = jax.value_and_grad(loss_fn)(p)
        m = tm(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = tm(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        t = i + 1.0
        mhat = tm(lambda a: a / (1 - 0.9 ** t), m)
        vhat = tm(lambda a: a / (1 - 0.999 ** t), v)
        p = tm(lambda pp, mm, vv: pp - lr * mm / (jnp.sqrt(vv) + 1e-8),
               p, mhat, vhat)
        return (p, m, v), l

    (params, _, _), losses = jax.lax.scan(
        step, (params, m, v), jnp.arange(steps, dtype=jnp.float32))
    return params, float(losses[-1])
