"""Batched-solve throughput: Lockstep vs PerSample vs Sharded.

The batching axis exists because adaptive step control over a batch is a
semantic choice: ``Lockstep()`` (the Chen et al. 2018 concatenated-system
``odeint``) lets the stiffest sample set the trial schedule for everyone,
while ``PerSample()`` lets each row accept/reject on its own. On a
stiffness-heterogeneous batch the difference is the headline number of this
benchmark: total forward f-evals (the serving-cost unit — every trial costs
one dynamics evaluation per row) must come out LOWER for ``PerSample()``.

Problem: dz/dt = -lam * z with per-sample decay rates log-spaced over two
decades — the classic heterogeneous-stiffness serving mix (each user's ODE
has its own conditioning). ``lam`` rides in the state pytree with
d(lam)/dt = 0 so every batching mode sees the same dynamics. The solver is
the *damped* ALF of Appendix A.5 (eta=0.9): undamped ALF's tracked
velocity carries a marginally-stable oscillation (eigenvalue -1) whose
amplitude never decays on stiff rows, pinning the embedded error estimate
and with it the adaptive step size — damping is what makes adaptive ALF
viable on this stiffness mix at all.

Emits: per-mode total f-evals + accepted/rejected, the lockstep/per-sample
f-eval ratio (>1 == PerSample wins), per-sample step-count spread, forward
wall-clock per mode, and a Sharded() run on the host mesh (the serving
path; single-device CPU in CI — the number checks the path, not the
speedup).
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ALF, AdaptiveController, Lockstep, MALI, PerSample,
                        Sharded, solve)
from repro.distributed.sharding import batch_sharding
from repro.launch.mesh import make_host_mesh

from .common import Row, time_fn

BATCH = 16
LAM_MIN, LAM_MAX = 0.5, 50.0      # two-decade stiffness spread
ETA = 0.9                         # Appendix A.5 damping (see docstring)
RTOL, ATOL = 1e-3, 1e-4
MAX_STEPS = 512


def _dyn(params, z, t):
    return {"y": -z["lam"] * z["y"], "lam": jnp.zeros_like(z["lam"])}


def _batch():
    lam = jnp.logspace(np.log10(LAM_MIN), np.log10(LAM_MAX), BATCH,
                       dtype=jnp.float32)
    return {"y": jnp.ones((BATCH, 1), jnp.float32), "lam": lam[:, None]}


def _solve(z0, batching):
    return solve(_dyn, {}, z0, 0.0, 1.0, solver=ALF(eta=ETA),
                 controller=AdaptiveController(RTOL, ATOL, MAX_STEPS),
                 gradient=MALI(), batching=batching)


def run() -> List[Row]:
    rows: List[Row] = []
    z0 = _batch()

    sols = {}
    for name, batching in (("lockstep", Lockstep()),
                           ("per_sample", PerSample())):
        sol = sols[name] = _solve(z0, batching)
        per = sol.stats.per_sample
        rows.append((f"batched/fevals_total/{name}",
                     int(sol.stats.n_fevals),
                     f"B={BATCH},lam=[{LAM_MIN},{LAM_MAX}]"))
        rows.append((f"batched/accepted_total/{name}",
                     int(sol.stats.n_accepted),
                     f"rejected={int(sol.stats.n_rejected)}"))
        rows.append((f"batched/steps_spread/{name}",
                     int(jnp.max(per.n_accepted) - jnp.min(per.n_accepted)),
                     f"min={int(jnp.min(per.n_accepted))},"
                     f"max={int(jnp.max(per.n_accepted))}"))
        fwd = jax.jit(lambda z, b=batching: _solve(z, b).ys["y"])
        rows.append((f"batched/fwd_us/{name}", time_fn(fwd, z0),
                     "jit forward wall-clock"))

    # The point of the axis: per-sample adaptivity must not pay the
    # stiffest row's schedule for every row.
    ratio = int(sols["lockstep"].stats.n_fevals) / max(
        int(sols["per_sample"].stats.n_fevals), 1)
    rows.append(("batched/fevals_lockstep_over_per_sample", ratio,
                 ">1 == PerSample saves f-evals on heterogeneous batch"))

    # numerical sanity: both modes solve the same ODE
    err = float(jnp.max(jnp.abs(sols["lockstep"].ys["y"]
                                - sols["per_sample"].ys["y"])))
    rows.append(("batched/lockstep_vs_per_sample_maxdiff", err,
                 "same ODE, independent schedules"))

    # Sharded: the serving path (data-parallel shard_map over the mesh).
    mesh = make_host_mesh()
    with mesh:
        z_sh = jax.device_put(z0, batch_sharding(mesh, "data"))
        sharded = Sharded(axis="data", inner=PerSample())
        sol = _solve(z_sh, sharded)
        rows.append(("batched/fevals_total/sharded",
                     int(sol.stats.n_fevals),
                     f"shards={mesh.shape['data']},inner=per_sample"))
        fwd = jax.jit(lambda z: _solve(z, sharded).ys["y"])
        rows.append(("batched/fwd_us/sharded", time_fn(fwd, z_sh),
                     f"host mesh, {mesh.shape['data']} device(s)"))
    return rows
