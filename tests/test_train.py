"""repro.train subsystem: Trainer determinism, telemetry, resumable
checkpoints (bit-equality + config fingerprint), fault-injected recovery
continuity, registry completeness, and MALI-vs-Naive gradient parity on
the full LM loss."""
import dataclasses
import json
import warnings

import jax
import numpy as np
import pytest

from repro.analysis.rules.r004_registry import missing_interface
from repro.analysis.trace_audit import run_train_audit
from repro.configs import smoke_config
from repro.core.ode_block import OdeSettings
from repro.data.synthetic import DataConfig, make_batch
from repro.launch.train import main as train_main
from repro.models import init_lm, lm_loss
from repro.train import (CompressedLoop, ConfigMismatchError, JsonlEmitter,
                         MemoryEmitter, MetricsEmitter, StandardLoop,
                         StdoutEmitter, TRAIN_LOOPS, Trainer, TrainerConfig,
                         TrainLoop, config_fingerprint, get_train_loop,
                         make_emitter, ode_residual_bytes,
                         restore_train_state, state_tree)

TINY = dict(steps=6, global_batch=4, seq_len=16, ode_steps=2,
            ckpt_every=2, keep=5, log_every=100, emit="memory")


def tiny_trainer(**kw) -> Trainer:
    return Trainer(TrainerConfig(**{**TINY, **kw}))


@pytest.fixture(scope="module")
def clean_run():
    """One uninterrupted tiny MALI run, shared as the reference trace."""
    t = tiny_trainer()
    final = t.train()
    assert final == TINY["steps"]
    return t


# ---------------------------------------------------------------------------
# Determinism + telemetry
# ---------------------------------------------------------------------------

def test_same_seed_same_trace(clean_run):
    again = tiny_trainer()
    again.train()
    assert again.loss_trace() == clean_run.loss_trace()
    assert all(np.isfinite(v) for v in again.loss_trace())


def test_step_records_account_for_the_odes(clean_run):
    recs = [clean_run.records[s] for s in sorted(clean_run.records)]
    assert [r.step for r in recs] == list(range(TINY["steps"]))
    # fixed-step solves: the feval budget is static, identical every step
    assert recs[0].fevals > 0
    assert len({(r.fevals, r.accepted, r.rejected) for r in recs}) == 1
    assert recs[0].rejected == 0
    want = ode_residual_bytes(clean_run.cfg, TINY["global_batch"],
                              TINY["seq_len"])
    assert want > 0
    assert all(r.residual_bytes == want for r in recs)
    # backend='auto' resolves to the reference interpreter on CPU
    assert all(r.pallas_launches == 0 for r in recs)
    row = recs[0].as_row()
    assert set(row) >= {"step", "loss", "lr", "grad_norm", "wall_s",
                        "fevals", "residual_bytes", "pallas_launches"}


def test_memory_emitter_collects_every_step(clean_run):
    assert isinstance(clean_run.emitter, MemoryEmitter)
    assert len(clean_run.emitter.records) == TINY["steps"]
    assert [r.step for r in clean_run.emitter.records] == \
        list(range(TINY["steps"]))


def test_jsonl_emitter_round_trips(tmp_path, clean_run):
    path = str(tmp_path / "metrics.jsonl")
    em = JsonlEmitter(path)
    for rec in clean_run.emitter.records:
        em.emit(rec)
    em.close()
    rows = [json.loads(line) for line in open(path)]
    assert len(rows) == TINY["steps"]
    assert rows[0]["loss"] == pytest.approx(clean_run.loss_trace()[0])


def test_make_emitter_validation():
    assert isinstance(make_emitter("stdout"), StdoutEmitter)
    with pytest.raises(ValueError, match="jsonl"):
        make_emitter("jsonl")          # needs a path
    with pytest.raises(ValueError, match="unknown"):
        make_emitter("bogus")


# ---------------------------------------------------------------------------
# Checkpointing: bit-equality, fingerprint, fault-injected recovery
# ---------------------------------------------------------------------------

def _fingerprint(t: Trainer):
    tc = t.config
    return config_fingerprint(t.cfg, t.opt_cfg, arch=tc.arch, loop=tc.loop,
                              microbatches=tc.microbatches, seed=tc.seed,
                              global_batch=tc.global_batch,
                              seq_len=tc.seq_len)


def test_checkpoint_restores_bit_identical_state(tmp_path):
    t = tiny_trainer(ckpt_dir=str(tmp_path / "run"))
    final = t.train()
    got = restore_train_state(str(tmp_path / "run"), t.state,
                              _fingerprint(t))
    assert got is not None
    step, restored, meta = got
    assert step == final
    assert meta["final"] is True
    live = jax.tree_util.tree_leaves(state_tree(t.state))
    back = jax.tree_util.tree_leaves(state_tree(restored))
    assert len(live) == len(back)
    for a, b in zip(live, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fault_injection_reproduces_clean_loss_trace(tmp_path, clean_run):
    fired = []

    def hook(step):
        if step == 3 and not fired:
            fired.append(step)
            raise RuntimeError("injected node failure")

    t = Trainer(TrainerConfig(**TINY, ckpt_dir=str(tmp_path / "faulty"),
                              max_failures=2), step_hook=hook)
    final = t.train()
    assert final == TINY["steps"]
    assert fired == [3]
    # recomputed post-checkpoint steps overwrite their first attempt, so
    # the recovered trace equals the uninterrupted run's, bit-for-bit
    assert t.loss_trace() == clean_run.loss_trace()


def test_resume_under_different_config_refuses(tmp_path):
    d = str(tmp_path / "run")
    tiny_trainer(ckpt_dir=d).train()
    other = tiny_trainer(ckpt_dir=d, ode_method="naive")
    with pytest.raises(ConfigMismatchError, match="ode"):
        other.train()
    # deliberately NOT one of run_with_recovery's retried exception types
    assert not issubclass(ConfigMismatchError,
                          (RuntimeError, ValueError, OSError))


# ---------------------------------------------------------------------------
# Loop/emitter registries (R004 surface)
# ---------------------------------------------------------------------------

def test_train_loop_registry():
    assert isinstance(get_train_loop("standard"), StandardLoop)
    assert isinstance(get_train_loop("compressed"), CompressedLoop)
    assert set(TRAIN_LOOPS) == {"standard", "compressed"}
    with pytest.raises(ValueError, match="unknown"):
        get_train_loop("bogus")
    for loop in TRAIN_LOOPS.values():
        assert missing_interface(type(loop), TrainLoop) == []
    for emitter_cls in (StdoutEmitter, JsonlEmitter, MemoryEmitter):
        assert missing_interface(emitter_cls, MetricsEmitter) == []


def test_compressed_loop_trains_and_carries_ef():
    t = tiny_trainer(steps=3, loop="compressed")
    assert t.train() == 3
    assert t.state.ef is not None
    assert all(np.isfinite(v) for v in t.loss_trace())


def test_microbatch_accumulation_trains():
    t = tiny_trainer(steps=3, microbatches=2)
    assert t.train() == 3
    assert all(np.isfinite(v) for v in t.loss_trace())


# ---------------------------------------------------------------------------
# Gradient parity + legacy-path hygiene
# ---------------------------------------------------------------------------

def test_mali_matches_naive_gradients_on_lm_loss():
    def grads(method, solver):
        cfg = smoke_config("qwen3-1.7b",
                           OdeSettings(mode="per_block", method=method,
                                       solver=solver, n_steps=2))
        params = init_lm(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg, DataConfig(seed=0, global_batch=2,
                                           seq_len=8), 0)
        return jax.grad(lm_loss)(params, cfg, batch)

    g_mali = grads("mali", "alf")
    g_naive = grads("naive", "alf")
    for a, b in zip(jax.tree_util.tree_leaves(g_mali),
                    jax.tree_util.tree_leaves(g_naive)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-5)


def test_train_flow_avoids_legacy_odeint():
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        tiny_trainer(steps=2).train()
    legacy = [w for w in caught
              if issubclass(w.category, DeprecationWarning)
              and "odeint" in str(w.message)]
    assert legacy == []


# ---------------------------------------------------------------------------
# CLI + static analysis hooks
# ---------------------------------------------------------------------------

def test_cli_smoke_and_resume(tmp_path, capsys):
    argv = ["--smoke", "--steps", "6", "--global-batch", "4",
            "--seq-len", "16", "--ckpt-dir", str(tmp_path / "cli"),
            "--log-every", "100"]
    train_main(argv)
    assert "final_step=6" in capsys.readouterr().out
    train_main(argv)    # restores the final checkpoint, runs 0 new steps
    assert "final_step=6" in capsys.readouterr().out


def test_residual_bytes_off_mode_is_zero():
    cfg = smoke_config("qwen3-1.7b", OdeSettings(mode="off"))
    assert ode_residual_bytes(cfg, 4, 16) == 0


def test_run_train_audit_is_clean():
    combos, failures, retrace = run_train_audit()
    assert combos >= 4
    assert failures == []
    assert retrace == {"train:step/mali-smoke": 1}


def test_trainer_config_is_value_hashable():
    a = TrainerConfig(**TINY)
    b = TrainerConfig(**TINY)
    assert a == b and hash(a) == hash(b)
    assert dataclasses.replace(a, ode_method="naive") != a
