"""ALF solver unit tests: invertibility (the paper's key property), local/
global truncation order (Thm 3.1 / A.3), damping (Thm 3.2), stability."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import mlp_dynamics, mlp_params
from repro.core.alf import (alf_inverse, alf_step, alf_step_with_error,
                            check_eta, init_velocity)


def _decay(params, z, t):
    return params * z


@pytest.mark.parametrize("eta", [1.0, 0.9, 0.7, 0.25])
def test_alf_inverse_roundtrip_scalar(eta):
    params = jnp.float32(-0.7)
    z = jnp.float32(1.3)
    v = _decay(params, z, 0.0)
    h = jnp.float32(0.37)
    z1, v1 = alf_step(_decay, params, z, v, jnp.float32(0.0), h, eta)
    z0, v0 = alf_inverse(_decay, params, z1, v1, h, h, eta)
    np.testing.assert_allclose(z0, z, rtol=1e-6)
    np.testing.assert_allclose(v0, v, rtol=1e-6)


@pytest.mark.parametrize("eta", [1.0, 0.8])
def test_alf_inverse_roundtrip_pytree(eta):
    key = jax.random.PRNGKey(1)
    d = 6
    params = mlp_params(key, d)
    f = mlp_dynamics()
    z = {"a": jax.random.normal(jax.random.PRNGKey(2), (d,)),
         "b": jax.random.normal(jax.random.PRNGKey(3), (d,))}

    def f_tree(p, zt, t):
        return {"a": f(p, zt["a"], t), "b": -f(p, zt["b"], t)}

    v = init_velocity(f_tree, params, z, jnp.float32(0.0))
    h = jnp.float32(0.21)
    z1, v1 = alf_step(f_tree, params, z, v, jnp.float32(0.0), h, eta)
    z0, v0 = alf_inverse(f_tree, params, z1, v1, h, h, eta)
    for k in ("a", "b"):
        np.testing.assert_allclose(z0[k], z[k], atol=1e-6)
        np.testing.assert_allclose(v0[k], v[k], atol=1e-6)


def test_trajectory_reconstruction_matches_forward():
    """Paper Fig. 3 / Eq. 5: whole trajectory recoverable from end state."""
    params = jnp.float32(0.5)
    z = jnp.float32(1.0)
    t0, n, h = jnp.float32(0.0), 16, jnp.float32(1.0 / 16)
    v = _decay(params, z, t0)
    fwd = [(z, v)]
    t = t0
    for _ in range(n):
        z, v = alf_step(_decay, params, z, v, t, h)
        t = t + h
        fwd.append((z, v))
    # reconstruct backward from the end state only
    for i in range(n, 0, -1):
        t_out = t0 + i * h
        z, v = alf_inverse(_decay, params, z, v, t_out, h)
        np.testing.assert_allclose(z, fwd[i - 1][0], rtol=2e-5)
        np.testing.assert_allclose(v, fwd[i - 1][1], rtol=2e-5)


def _one_step_z_error(h):
    """|z_1 - z(h)| for dz/dt = alpha z with exact v0 (float64 via numpy)."""
    alpha, z0 = -0.9, 1.7
    s1 = h / 2
    k1 = z0 + alpha * z0 * h / 2
    u1 = alpha * k1
    v1 = 2 * u1 - alpha * z0
    z1 = k1 + v1 * h / 2
    return abs(z1 - z0 * math.exp(alpha * h))


def test_local_truncation_order_thm31():
    """Thm 3.1: local z error O(h^3) => halving h cuts error ~8x."""
    e1 = _one_step_z_error(0.1)
    e2 = _one_step_z_error(0.05)
    ratio = e1 / e2
    assert 6.5 < ratio < 9.5, ratio


def test_global_order_two():
    """Global error O(h^2): doubling steps cuts end-state error ~4x."""
    alpha, z0, T = -0.9, 1.7, 1.0
    errs = []
    for n in (16, 32, 64):
        z, v = z0, alpha * z0
        h = T / n
        t = 0.0
        for _ in range(n):
            z, v = (float(x) for x in alf_step(
                _decay, jnp.float64(alpha) if False else jnp.float32(alpha),
                jnp.float32(z), jnp.float32(v), jnp.float32(t),
                jnp.float32(h)))
            t += h
        errs.append(abs(z - z0 * math.exp(alpha * T)))
    assert 3.0 < errs[0] / errs[1] < 5.0, errs
    assert 2.5 < errs[1] / errs[2] < 5.5, errs


def test_embedded_error_estimate_tracks_truncation():
    """alf_step_with_error: err ~ h*(u1 - v) shrinks ~4x when h halves
    (second-difference of a smooth trajectory)."""
    params = jnp.float32(-0.9)
    z = jnp.float32(1.7)
    # v deliberately offset from f(z) so (u1 - v) != 0
    v = _decay(params, z, 0.0) * 1.01

    def err_of(h):
        _, _, e = alf_step_with_error(_decay, params, z, v, jnp.float32(0.0),
                                      jnp.float32(h))
        return abs(float(e))

    assert err_of(0.2) > err_of(0.1) > 0.0


def test_check_eta():
    check_eta(1.0)
    check_eta(0.75)
    for bad in (0.0, -0.1, 1.5, 0.5):
        with pytest.raises(ValueError):
            check_eta(bad)


def test_plain_alf_not_a_stable_real_axis():
    """Thm A.2: for real negative h*sigma the undamped ALF amplifies —
    |lambda_-| = |hs - sqrt(h^2 s^2 + 1)| > 1 for hs < 0."""
    params = jnp.float32(-4.0)   # stiff-ish
    h = jnp.float32(0.5)         # hs = -2
    z, v = jnp.float32(1.0), _decay(jnp.float32(-4.0), jnp.float32(1.0), 0.0)
    t = jnp.float32(0.0)
    amps = []
    for _ in range(40):
        z, v = alf_step(_decay, params, z, v, t, h)
        t = t + h
        amps.append(float(jnp.sqrt(z * z + v * v)))
    assert amps[-1] > amps[0] * 10  # grows (true solution decays)


def test_damped_alf_stabilizes():
    """Thm 3.2: with eta<1 there is a non-empty stability region; the same
    stiff problem stays bounded under damping."""
    params = jnp.float32(-4.0)
    h = jnp.float32(0.25)        # hs = -1
    eta = 0.25
    # check the theorem's eigenvalue condition first (complex sqrt: the
    # discriminant is negative here — conjugate eigenvalue pair)
    import cmath
    hs = float(h) * -4.0
    disc = cmath.sqrt(eta * (2 * hs + eta * (hs - 1) ** 2))
    lam = [1 + eta * (hs - 1) + s * disc for s in (+1, -1)]
    assert all(abs(l) < 1 for l in lam), lam
    z, v = jnp.float32(1.0), _decay(params, jnp.float32(1.0), 0.0)
    t = jnp.float32(0.0)
    for _ in range(200):
        z, v = alf_step(_decay, params, z, v, t, h, eta)
        t = t + h
    assert abs(float(z)) < 1.0  # decays toward 0, no blow-up
