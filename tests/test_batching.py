"""Batching-axis semantics: solve(batching=Lockstep|PerSample|Sharded).

The contract under test:

(a) ``vmap(solve)`` (user-side), ``solve(batching=PerSample())`` and a
    Python-stacked loop of single-trajectory solves are THE SAME
    computation — values and gradients — for all four gradient methods and
    both controllers.
(b) ``Lockstep()`` is the old implicit semantics of an unbatched solve on
    a batch-shaped state, made explicit (only the layout changes to
    batch-first).
(c) ``Solution.stats.per_sample`` rows match what each sample's individual
    solve reports; the scalar counters are the per-row totals.
(d) A finished sample's padding iterations contribute exactly zero
    gradient (each row's gradient equals its single-solve gradient even
    when a batchmate runs 10x more steps).
(e) The boundary validation of the new axis is actionable.
"""
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ACA, ALF, AdaptiveController, Backsolve,
                        ConstantSteps, Dopri5, HeunEuler, Lockstep, MALI,
                        Naive, PerSample, SaveAt, Sharded, solve)

TOL = dict(rtol=2e-5, atol=2e-6)

METHOD_AXES = {
    "mali": (MALI(), ALF()),
    "naive": (Naive(), ALF()),
    "aca": (ACA(), HeunEuler()),
    "adjoint": (Backsolve(), Dopri5()),
}


def _f(params, z, t):
    # per-sample stiffness rides in the state (d rate/dt = 0), so the
    # batch is genuinely heterogeneous for the adaptive controller
    return {"y": -z["rate"] * z["y"] + params["c"] * jnp.sin(3.0 * t),
            "rate": jnp.zeros_like(z["rate"])}


def _setup(nb=3):
    params = {"c": jnp.float32(0.4)}
    z0 = {"y": jnp.linspace(0.6, 1.4, nb)[:, None],
          "rate": jnp.asarray([0.3, 2.0, 8.0])[:nb, None]}
    return params, z0


def _controller(fixed):
    return ConstantSteps(3) if fixed else AdaptiveController(1e-2, 1e-3, 32)


def _row(tree, i):
    return jax.tree_util.tree_map(lambda b: b[i], tree)


@pytest.mark.parametrize("method", sorted(METHOD_AXES))
@pytest.mark.parametrize("fixed", [True, False], ids=["fixed", "adaptive"])
def test_batched_matches_vmap_and_stacked_singles(method, fixed):
    """PerSample == vmap(solve) == stacked single solves, values AND grads."""
    gradient, solver = METHOD_AXES[method]
    controller = _controller(fixed)
    params, z0 = _setup()
    nb = z0["y"].shape[0]

    def single_ys(p, z):
        return solve(_f, p, z, 0.0, 1.0, solver=solver,
                     controller=controller, gradient=gradient).ys["y"]

    def batched_ys(p, z):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")  # PerSample+ConstantSteps warn
            return solve(_f, p, z, 0.0, 1.0, solver=solver,
                         controller=controller, gradient=gradient,
                         batching=PerSample()).ys["y"]

    stacked = jnp.stack([single_ys(params, _row(z0, i)) for i in range(nb)])
    vmapped = jax.vmap(lambda z: single_ys(params, z))(z0)
    batched = batched_ys(params, z0)
    np.testing.assert_allclose(np.asarray(vmapped), np.asarray(stacked),
                               **TOL)
    np.testing.assert_allclose(np.asarray(batched), np.asarray(stacked),
                               **TOL)

    # gradients w.r.t. params AND the initial state, all three routes
    def loss_stacked(p, z):
        return sum(jnp.sum(single_ys(p, _row(z, i)) ** 2)
                   for i in range(nb))

    def loss_vmap(p, z):
        return jnp.sum(jax.vmap(lambda zi: single_ys(p, zi))(z) ** 2)

    def loss_batched(p, z):
        return jnp.sum(batched_ys(p, z) ** 2)

    g_st = jax.grad(loss_stacked, argnums=(0, 1))(params, z0)
    g_vm = jax.grad(loss_vmap, argnums=(0, 1))(params, z0)
    g_ba = jax.grad(loss_batched, argnums=(0, 1))(params, z0)
    for got in (g_vm, g_ba):
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(g_st)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


def test_lockstep_is_explicit_implicit_semantics():
    """Lockstep() == the unbatched solve over the batched state, except for
    the batch-first layout and the per-row stats totals."""
    params, z0 = _setup()
    ctrl = AdaptiveController(1e-3, 1e-4, 64)
    implicit = solve(_f, params, z0, 0.0, 1.0, gradient=MALI(),
                     controller=ctrl)
    explicit = solve(_f, params, z0, 0.0, 1.0, gradient=MALI(),
                     controller=ctrl, batching=Lockstep())
    np.testing.assert_array_equal(np.asarray(explicit.ys["y"]),
                                  np.asarray(implicit.ys["y"]))
    nb = z0["y"].shape[0]
    # one shared decision per trial: every row reports the shared counters
    assert explicit.stats.per_sample.n_accepted.shape == (nb,)
    np.testing.assert_array_equal(
        np.asarray(explicit.stats.per_sample.n_accepted),
        np.full((nb,), int(implicit.stats.n_accepted)))
    assert int(explicit.stats.n_fevals) == nb * int(implicit.stats.n_fevals)

    # dense per-step output keeps the same stats contract: scalars are the
    # per-row totals, rows broadcast the shared schedule
    dense = solve(_f, params, z0, 0.0, 1.0, gradient=MALI(),
                  controller=ConstantSteps(5), batching=Lockstep(),
                  saveat=SaveAt(steps=True))
    assert dense.ys["y"].shape[0] == nb
    assert int(dense.stats.n_fevals) == int(
        jnp.sum(dense.stats.per_sample.n_fevals))
    np.testing.assert_array_equal(np.asarray(dense.stats.per_sample
                                             .n_accepted),
                                  np.full((nb,), 5))

    # trajectory saveat: batch-first (B, T, ...) == moveaxis of (T, B, ...)
    ts = jnp.linspace(0.0, 1.0, 4)
    implicit_t = solve(_f, params, z0, gradient=MALI(), controller=ctrl,
                       saveat=SaveAt(ts=ts))
    explicit_t = solve(_f, params, z0, gradient=MALI(), controller=ctrl,
                       saveat=SaveAt(ts=ts), batching=Lockstep())
    assert explicit_t.ys["y"].shape == (nb, 4, 1)
    np.testing.assert_array_equal(
        np.asarray(explicit_t.ys["y"]),
        np.asarray(jnp.moveaxis(implicit_t.ys["y"], 0, 1)))


def test_per_sample_stats_match_single_solves():
    """stats.per_sample rows == each sample's own solve stats; scalars are
    the row totals."""
    params, z0 = _setup()
    ctrl = AdaptiveController(1e-3, 1e-4, 64)
    sol = solve(_f, params, z0, 0.0, 1.0, gradient=MALI(), controller=ctrl,
                batching=PerSample())
    per = sol.stats.per_sample
    nb = z0["y"].shape[0]
    singles = [solve(_f, params, _row(z0, i), 0.0, 1.0, gradient=MALI(),
                     controller=ctrl).stats for i in range(nb)]
    for i, s in enumerate(singles):
        assert int(per.n_accepted[i]) == int(s.n_accepted)
        assert int(per.n_rejected[i]) == int(s.n_rejected)
        assert int(per.n_fevals[i]) == int(s.n_fevals)
    assert int(sol.stats.n_accepted) == sum(int(s.n_accepted)
                                            for s in singles)
    assert int(sol.stats.n_fevals) == sum(int(s.n_fevals) for s in singles)
    # the batch is heterogeneous: the stiff row must really work harder
    assert int(per.n_accepted[-1]) > int(per.n_accepted[0])


def test_per_sample_saves_fevals_vs_lockstep_on_heterogeneous_batch():
    """The acceptance criterion of the axis: fewer total f-evals when rows
    accept/reject independently (ALF damping per Appendix A.5 so the stiff
    rows' adaptive control is live, see benchmarks/batched_throughput)."""
    params, z0 = _setup()
    ctrl = AdaptiveController(1e-3, 1e-4, 128)
    kw = dict(solver=ALF(eta=0.9), controller=ctrl, gradient=MALI())
    lock = solve(_f, params, z0, 0.0, 1.0, batching=Lockstep(), **kw)
    per = solve(_f, params, z0, 0.0, 1.0, batching=PerSample(), **kw)
    assert int(per.stats.n_fevals) < int(lock.stats.n_fevals)


def test_done_sample_padding_steps_contribute_zero_gradient():
    """Regression: a sample that finishes in ~6 steps rides ~10x longer as
    a no-op next to a stiff batchmate; its gradient must equal its own
    single-solve gradient exactly (padding iterations inject nothing)."""
    params, z0 = _setup()
    ctrl = AdaptiveController(1e-3, 1e-4, 64)

    def loss_batched(p, z):
        sol = solve(_f, p, z, 0.0, 1.0, gradient=MALI(), controller=ctrl,
                    batching=PerSample())
        return jnp.sum(sol.ys["y"] ** 2)

    g_z = jax.grad(loss_batched, argnums=1)(params, z0)

    def loss_single(p, zi):
        return jnp.sum(solve(_f, p, zi, 0.0, 1.0, gradient=MALI(),
                             controller=ctrl).ys["y"] ** 2)

    for i in range(z0["y"].shape[0]):
        gi = jax.grad(loss_single, argnums=1)(params, _row(z0, i))
        np.testing.assert_allclose(np.asarray(_row(g_z, i)["y"]),
                                   np.asarray(gi["y"]), rtol=1e-6,
                                   atol=1e-7)


def test_sharded_on_host_mesh_matches_per_sample():
    from repro.launch.mesh import make_host_mesh
    params, z0 = _setup()
    ctrl = AdaptiveController(1e-3, 1e-4, 64)
    ref = solve(_f, params, z0, 0.0, 1.0, gradient=MALI(), controller=ctrl,
                batching=PerSample())
    with make_host_mesh():
        sol = solve(_f, params, z0, 0.0, 1.0, gradient=MALI(),
                    controller=ctrl,
                    batching=Sharded(axis="data", inner=PerSample()))
    np.testing.assert_allclose(np.asarray(sol.ys["y"]),
                               np.asarray(ref.ys["y"]), rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(sol.stats.per_sample.n_accepted),
        np.asarray(ref.stats.per_sample.n_accepted))


@pytest.mark.slow
def test_sharded_multidevice_subprocess(tmp_path):
    """4 fake CPU devices: Sharded(axis='data') must reproduce PerSample
    bit-for-bit and shard the output over the mesh (run in a subprocess so
    the XLA device-count flag never leaks into this process)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = """
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 4
from repro.core import solve, MALI, AdaptiveController, PerSample, Sharded
from repro.distributed.sharding import batch_sharding
from repro.launch.mesh import make_host_mesh

def f(p, z, t): return -z * p
zb = jnp.linspace(0.5, 2.0, 8)[:, None]
ctrl = AdaptiveController(1e-3, 1e-4, 32)
mesh = make_host_mesh()
assert mesh.shape["data"] == 4
with mesh:
    z_sh = jax.device_put(zb, batch_sharding(mesh, "data"))
    sol = solve(f, jnp.float32(1.0), z_sh, 0.0, 1.0, gradient=MALI(),
                controller=ctrl, batching=Sharded(axis="data",
                                                  inner=PerSample()))
    ref = solve(f, jnp.float32(1.0), zb, 0.0, 1.0, gradient=MALI(),
                controller=ctrl, batching=PerSample())
    np.testing.assert_array_equal(np.asarray(sol.ys), np.asarray(ref.ys))
    assert "data" in str(sol.ys.sharding.spec)
    try:
        solve(f, jnp.float32(1.0), zb[:6], gradient=MALI(), controller=ctrl,
              batching=Sharded())
        raise AssertionError("divisibility not checked")
    except ValueError as e:
        assert "divisible" in str(e)
print("MULTIDEVICE_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600, env=env, cwd=repo)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "MULTIDEVICE_OK" in r.stdout


def test_alf_pallas_backend_through_batched_solve():
    """ALF(backend='pallas') under MALI + PerSample: fused-kernel forward
    parity with the reference backend (the 'fused step for free' wiring)."""
    params, z0 = _setup()
    ctrl = AdaptiveController(1e-2, 1e-3, 32)
    ref = solve(_f, params, z0, 0.0, 1.0, solver=ALF(), controller=ctrl,
                gradient=MALI(), batching=PerSample())
    pal = solve(_f, params, z0, 0.0, 1.0, solver=ALF(backend="pallas"),
                controller=ctrl, gradient=MALI(), batching=PerSample())
    np.testing.assert_allclose(np.asarray(pal.ys["y"]),
                               np.asarray(ref.ys["y"]), rtol=1e-6,
                               atol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(pal.stats.per_sample.n_accepted),
        np.asarray(ref.stats.per_sample.n_accepted))


# --- boundary validation ---------------------------------------------------


def test_batching_validation_inconsistent_batch_axis():
    params, _ = _setup()
    bad = {"y": jnp.ones((3, 1)), "rate": jnp.ones((4, 1))}
    with pytest.raises(ValueError, match="inconsistent leading"):
        solve(_f, params, bad, gradient=MALI(), batching=PerSample())
    with pytest.raises(ValueError, match="scalar"):
        solve(lambda p, z, t: -z, params, jnp.float32(1.0),
              gradient=MALI(), batching=PerSample())


def test_batching_validation_per_sample_fixed_steps_warns():
    params, z0 = _setup()
    with pytest.warns(UserWarning, match="degenerates to"):
        solve(_f, params, z0, gradient=MALI(),
              controller=ConstantSteps(2), batching=PerSample())


def test_batching_validation_dense_saveat():
    params, z0 = _setup()
    with pytest.raises(ValueError, match="ragged"):
        solve(_f, params, z0, gradient=MALI(),
              saveat=SaveAt(steps=True), batching=PerSample())
    with pytest.raises(ValueError, match="ragged across shards"):
        solve(_f, params, z0, gradient=MALI(),
              saveat=SaveAt(steps=True), batching=Sharded())


def test_batching_validation_misc():
    params, z0 = _setup()
    with pytest.raises(TypeError, match="Batching"):
        solve(_f, params, z0, gradient=MALI(), batching="per_sample")
    with pytest.raises(ValueError, match="mesh context"):
        solve(_f, params, z0, gradient=MALI(), batching=Sharded())
    with pytest.raises(ValueError, match="does not nest"):
        Sharded(inner=Sharded())
    from repro.launch.mesh import make_host_mesh
    with make_host_mesh():
        with pytest.raises(ValueError, match="axes"):
            solve(_f, params, z0, gradient=MALI(),
                  batching=Sharded(axis="nonexistent"))
