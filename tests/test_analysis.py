"""Tests for repro.analysis: odelint rule fixture pairs (known-bad caught,
known-good passes), the registry/interface checks, the retrace-count
regression, and the repo's own lint-cleanliness."""
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import lint_source, run_lint
from repro.analysis.rules import r004_registry
from repro.analysis.trace_audit import count_traces, retrace_cases
from repro.core import (ACA, MALI, SOLVERS, Backsolve, Batching,
                        ConstantSteps, Event, GradientMethod, Naive,
                        SaveAt, Solver, solve)

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def rules_of(violations):
    return sorted({v.rule for v in violations})


# --------------------------------------------------------------------------
# R001 — traced branches
# --------------------------------------------------------------------------

R001_BAD = """
import jax.numpy as jnp
def f(x):
    y = jnp.sum(x)
    if y > 0:
        return y
    while y < 10:
        y = y * 2
    return y
"""

R001_GOOD = """
import jax
import jax.numpy as jnp
def f(x, grid):
    y = jnp.sum(x)
    if y.ndim > 0:                 # metadata: static
        y = y[0]
    if isinstance(grid, jax.core.Tracer):   # laundered
        grid = jnp.asarray(grid)
    if grid is None:               # structural
        grid = jnp.zeros(3)
    return jnp.where(y > 0, y, -y)
"""


def test_r001_bad_caught():
    vs = lint_source(R001_BAD, rules=["R001"])
    assert len(vs) == 2 and rules_of(vs) == ["R001"]


def test_r001_good_passes():
    assert lint_source(R001_GOOD, rules=["R001"]) == []


# --------------------------------------------------------------------------
# R002 — custom_vjp hygiene
# --------------------------------------------------------------------------

R002_BAD_RESIDUALS = """
import jax
def _f(params, z):
    return z
def _f_fwd(params, z):
    return z, [params, z]          # list, not an explicit tuple literal
def _f_bwd(res, ct):
    return ct, ct
_f = jax.custom_vjp(_f)
_f.defvjp(_f_fwd, _f_bwd)
"""

R002_BAD_CLOSURE = """
import jax
def make(scale):
    def _f(params, z):
        return z * scale           # closure-captured value
    f = jax.custom_vjp(_f)
    f.defvjp(lambda p, z: (z, (p,)), lambda res, ct: (ct, ct))
    return f
"""

R002_BAD_COUNTER = """
def total_evals(f, params, z0, ts, method, solver, controller):
    out, rstats = method.integrate(f, params, z0, ts, solver, controller)
    return rstats.n_fevals + 1     # float0 tangent crash under vmap-of-grad
"""

R002_GOOD = """
import jax
from jax import lax
def _detached(s):
    return lax.stop_gradient(s)
def _f(params, z):
    return z
def _f_fwd(params, z):
    res = _f(params, z)
    return res, (params, z)
def _f_bwd(res, ct):
    return ct, ct
_f = jax.custom_vjp(_f)
_f.defvjp(_f_fwd, _f_bwd)
def total_evals(f, params, z0, ts, method, solver, controller):
    out, rstats = method.integrate(f, params, z0, ts, solver, controller)
    rstats = _detached(rstats)
    return rstats.n_fevals + 1
"""


def test_r002_bad_residuals_caught():
    vs = lint_source(R002_BAD_RESIDUALS, rules=["R002"])
    assert any("tuple literal" in v.message for v in vs)


def test_r002_bad_closure_caught():
    vs = lint_source(R002_BAD_CLOSURE, rules=["R002"])
    assert any("module level" in v.message or "module-level" in v.message
               for v in vs)


def test_r002_bad_counter_arith_caught():
    vs = lint_source(R002_BAD_COUNTER, rules=["R002"])
    assert any("float0" in v.message for v in vs)


def test_r002_good_passes():
    assert lint_source(R002_GOOD, rules=["R002"]) == []


# --------------------------------------------------------------------------
# R003 — Pallas kernel contracts
# --------------------------------------------------------------------------

R003_BAD = """
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
def call(x):
    rows, d = x.shape
    bs = min(256, rows)
    return pl.pallas_call(          # no grid=
        _kernel,
        in_specs=[pl.BlockSpec((bs, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bs, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
def call2(x):
    rows, d = x.shape
    bs = min(256, rows)
    grid = (rows // bs,)            # unguarded floor division
    return pl.pallas_call(
        _kernel, grid=grid,
        in_specs=[pl.BlockSpec((bs, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bs, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0   # write without .astype(o_ref.dtype)
"""

R003_GOOD = """
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
def call(x):
    rows, d = x.shape
    bs = min(256, rows)
    assert rows % bs == 0
    return pl.pallas_call(
        _kernel, grid=(rows // bs,),
        in_specs=[pl.BlockSpec((bs, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bs, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
def call_padded(x, block_q):
    sq, d = x.shape
    pad_q = (-sq) % block_q
    sq_p = sq + pad_q
    x = jnp.pad(x, ((0, pad_q), (0, 0)))
    return pl.pallas_call(
        _kernel, grid=(sq_p // block_q,),
        in_specs=[pl.BlockSpec((block_q, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sq_p, d), x.dtype),
    )(x)[:sq]
def _kernel(x_ref, o_ref):
    o_ref[...] = (x_ref[...] * 2.0).astype(o_ref.dtype)
"""

OPS_SNIPPET = """
def my_op(x):
    return x
"""


def test_r003_bad_caught():
    vs = lint_source(R003_BAD, rules=["R003"])
    msgs = " | ".join(v.message for v in vs)
    assert "without an explicit grid=" in msgs
    assert "divisibility guard" in msgs
    assert ".astype" in msgs


def test_r003_good_passes():
    assert lint_source(R003_GOOD, rules=["R003"]) == []


def test_r003_allowlist_missing_caught():
    vs = lint_source(OPS_SNIPPET, path="kernels/demo/ops.py",
                     rules=["R003"],
                     ctx={"kernel_package": "demo", "no_reverse_rule": {}})
    assert any("NO_REVERSE_RULE" in v.message for v in vs)


def test_r003_allowlist_entry_passes():
    allow = {"demo.my_op": "forward-only serving kernel; training uses "
                           "the jnp oracle"}
    vs = lint_source(OPS_SNIPPET, path="kernels/demo/ops.py",
                     rules=["R003"],
                     ctx={"kernel_package": "demo",
                          "no_reverse_rule": allow})
    assert vs == []


def test_r003_placeholder_justification_caught():
    vs = lint_source(OPS_SNIPPET, path="kernels/demo/ops.py",
                     rules=["R003"],
                     ctx={"kernel_package": "demo",
                          "no_reverse_rule": {"demo.my_op": "todo"}})
    assert any("placeholder" in v.message for v in vs)


OPS_DELEGATED = """
import functools
import jax

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _impl(flag, x):
    return x

def _impl_fwd(flag, x):
    return _impl(flag, x), None

def _impl_bwd(flag, res, g):
    return (g,)

_impl.defvjp(_impl_fwd, _impl_bwd)

def my_op(x, *, flag=True):
    return _impl(flag, x)
"""

OPS_DELEGATED_BAD = """
import functools
import jax

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _impl(flag, x):
    return x

def _impl_fwd(flag, x):
    return _impl(flag, x), None

def _impl_bwd(flag, res, g):
    return (g,)

_impl.defvjp(_impl_fwd, _impl_bwd)

def _plain_helper(x):
    return x

def my_op(x, *, flag=True):
    return _plain_helper(x)
"""


def test_r003_defvjp_delegation_passes():
    """The keyword-facade pattern: a public op delegating to an internal
    custom_vjp owner (recognized by its X.defvjp registration) needs no
    allowlist entry — it inherits the owner's reverse rule."""
    vs = lint_source(OPS_DELEGATED, path="kernels/demo/ops.py",
                     rules=["R003"],
                     ctx={"kernel_package": "demo", "no_reverse_rule": {}})
    assert vs == []


def test_r003_delegation_to_plain_helper_still_caught():
    """Merely *containing* a defvjp owner somewhere in the module is not
    enough — the public op must actually call it."""
    vs = lint_source(OPS_DELEGATED_BAD, path="kernels/demo/ops.py",
                     rules=["R003"],
                     ctx={"kernel_package": "demo", "no_reverse_rule": {}})
    assert any("my_op" in v.message and "NO_REVERSE_RULE" in v.message
               for v in vs)


# --------------------------------------------------------------------------
# R004 — registry completeness
# --------------------------------------------------------------------------

def test_r004_missing_member_caught():
    class Incomplete(GradientMethod):
        name = "incomplete"

    missing = r004_registry.missing_interface(Incomplete, GradientMethod)
    assert "integrate" in missing and "default_solver" in missing


def test_r004_complete_subclasses_pass():
    for cls in (MALI, Naive, ACA, Backsolve):
        assert r004_registry.missing_interface(cls, GradientMethod) == []
    for inst in SOLVERS.values():
        assert r004_registry.missing_interface(type(inst), Solver) == []
    for sub in Batching.__subclasses__():
        assert r004_registry.missing_interface(sub, Batching) == []


# Every string-registered solver gets a real (tiny) solve here, which is
# also what keeps R004's appears-in-a-test sweep satisfied. The literal
# list is asserted against the live registry so it cannot drift.
REGISTERED_SOLVER_NAMES = ["alf", "bosh3", "dopri5", "euler", "heun2",
                           "heun_euler", "midpoint", "rk2", "rk23", "rk4"]


def test_solver_name_list_matches_registry():
    assert REGISTERED_SOLVER_NAMES == sorted(SOLVERS)


@pytest.mark.parametrize("name", REGISTERED_SOLVER_NAMES)
def test_every_registered_solver_solves(name):
    def f(params, z, t):
        return -params * z

    sol = solve(f, jnp.float32(0.7), jnp.ones((2,), jnp.float32), 0.0, 1.0,
                solver=name, controller=ConstantSteps(2), gradient=Naive())
    assert sol.ys.shape == (2,)
    np.testing.assert_allclose(np.asarray(sol.ys),
                               np.exp(-0.7) * np.ones(2), rtol=0.2)


# --------------------------------------------------------------------------
# R005 — signed-buffer discipline
# --------------------------------------------------------------------------

R005_BAD = """
import jax.numpy as jnp
def _replay_bwd(res, ct):
    ts, hs = res
    h = jnp.abs(hs[0])             # strips the recorded step's sign
    return h * ct
"""

R005_GOOD = """
import jax.numpy as jnp
def forward_driver(t, h, t1):
    # abs is fine on the FORWARD side (direction-agnostic span checks)
    return jnp.where(jnp.abs(h) >= jnp.abs(t1 - t), t1 - t, h)
def _replay_bwd(res, ct):
    ts, hs = res
    return -hs[0] * ct             # signed replay
"""


def test_r005_bad_caught():
    vs = lint_source(R005_BAD, rules=["R005"])
    assert len(vs) == 1 and vs[0].rule == "R005"


def test_r005_good_passes():
    assert lint_source(R005_GOOD, rules=["R005"]) == []


# --------------------------------------------------------------------------
# Suppressions
# --------------------------------------------------------------------------

def test_suppression_with_reason_suppresses():
    src = R001_BAD.replace("if y > 0:",
                           "if y > 0:  # odelint: disable=R001 -- demo")
    assert all("while" in v.message
               for v in lint_source(src, rules=["R001"]))


def test_suppression_without_reason_is_flagged():
    src = R001_BAD.replace("if y > 0:",
                           "if y > 0:  # odelint: disable=R001")
    vs = lint_source(src, rules=["R001"])
    assert any(v.rule == "R000" for v in vs)       # bare disable reported
    assert any(v.rule == "R001" and v.line == 5 for v in vs)  # not suppressed


# --------------------------------------------------------------------------
# The repo itself stays clean
# --------------------------------------------------------------------------

def test_repo_is_lint_clean():
    assert run_lint(REPO_ROOT) == []


# --------------------------------------------------------------------------
# Retrace regression: solve() twice with identical static config must not
# re-trace. Covers SaveAt (ts content hash), Event (field hash), and every
# frozen solver/controller/gradient/batching dataclass.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name,fresh",
                         retrace_cases(), ids=lambda c: c[0]
                         if isinstance(c, tuple) else None)
def test_solve_does_not_retrace(name, fresh):
    assert count_traces(fresh) == 1


def test_identity_hash_static_would_retrace():
    # negative control: the counter really detects retraces — a fresh
    # lambda per Event has a new identity and MUST trace twice.
    from repro.core import ALF, ConstantSteps

    def fresh_bad():
        return dict(solver=ALF(), controller=ConstantSteps(2),
                    gradient=MALI(), saveat=SaveAt(), batching=None,
                    event=Event(lambda z, t: jnp.sum(z) - 10.0))

    assert count_traces(fresh_bad) == 2


def test_saveat_value_semantics():
    a = SaveAt(ts=np.linspace(0.0, 1.0, 5))
    b = SaveAt(ts=np.linspace(0.0, 1.0, 5))
    c = SaveAt(ts=np.linspace(0.0, 2.0, 5))
    assert a == b and hash(a) == hash(b)
    assert a != c
    assert SaveAt() == SaveAt() and hash(SaveAt()) == hash(SaveAt())
    assert SaveAt(steps=True) != SaveAt()


def test_event_value_semantics():
    def cond(z, t):
        return z[0]

    assert Event(cond) == Event(cond)
    assert hash(Event(cond)) == hash(Event(cond))
    assert Event(cond, direction=+1) != Event(cond)
