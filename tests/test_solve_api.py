"""Composable solve() API tests.

(a) Compat-shim equivalence: the legacy string-keyed ``odeint(...)`` and the
object-based ``solve(...)`` must produce IDENTICAL outputs and gradients for
every method x fixed/adaptive x scalar/grid combination (the shim builds the
same objects, so this is a bit-for-bit check, not a tolerance check).
(b) ``Solution.stats`` consistency with the old ``mali_forward_stats``
side channel it replaces.
(c) SaveAt modes incl. dense per-step output, and the boundary validation
of solver/controller/gradient compatibility and malformed inputs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import mlp_dynamics, mlp_params
from repro.core import (ACA, ALF, AdaptiveController, Backsolve,
                        ConstantSteps, Dopri5, HeunEuler, MALI, METHODS,
                        Naive, SaveAt, Solution, mali_forward_stats, odeint,
                        solve)

ALPHA = 0.5
TS = jnp.linspace(0.0, 1.0, 6)


def _toy_f(params, z, t):
    return params["alpha"] * z


def _toy():
    return {"alpha": jnp.float32(ALPHA)}, jnp.float32(1.3)


def _objects(method, fixed):
    gradient = {"mali": MALI(), "naive": Naive(), "aca": ACA(),
                "adjoint": Backsolve()}[method]
    solver = {"mali": ALF(), "naive": ALF(), "aca": HeunEuler(),
              "adjoint": Dopri5()}[method]
    controller = (ConstantSteps(4) if fixed else
                  AdaptiveController(1e-4, 1e-5, 64))
    return gradient, solver, controller


def _legacy_kwargs(fixed):
    return (dict(n_steps=4) if fixed else
            dict(n_steps=0, rtol=1e-4, atol=1e-5, max_steps=64))


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("fixed", [True, False], ids=["fixed", "adaptive"])
@pytest.mark.parametrize("grid", [False, True], ids=["scalar", "grid"])
def test_shim_equivalence_outputs_and_gradients(method, fixed, grid):
    """odeint(strings) == solve(objects).ys bit-for-bit, values AND grads."""
    params, z0 = _toy()
    ts = TS if grid else None
    gradient, solver, controller = _objects(method, fixed)
    saveat = SaveAt(ts=ts) if grid else SaveAt()

    def loss_legacy(p, z):
        out = odeint(_toy_f, p, z, 0.0, 1.0, ts=ts, method=method,
                     **_legacy_kwargs(fixed))
        return jnp.sum(out ** 2)

    def loss_obj(p, z):
        sol = solve(_toy_f, p, z, 0.0, 1.0, solver=solver,
                    controller=controller, gradient=gradient, saveat=saveat)
        return jnp.sum(sol.ys ** 2)

    (L1, g1) = jax.value_and_grad(loss_legacy, argnums=(0, 1))(params, z0)
    (L2, g2) = jax.value_and_grad(loss_obj, argnums=(0, 1))(params, z0)
    np.testing.assert_array_equal(np.asarray(L1), np.asarray(L2))
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shim_equivalence_pytree_dynamics():
    """Equivalence also holds for MLP dynamics with pytree params."""
    d = 5
    params = mlp_params(jax.random.PRNGKey(0), d)
    f = mlp_dynamics()
    z0 = jax.random.normal(jax.random.PRNGKey(1), (d,))

    legacy = odeint(f, params, z0, 0.0, 1.0, method="mali", n_steps=6)
    sol = solve(f, params, z0, 0.0, 1.0, gradient=MALI(),
                controller=ConstantSteps(6))
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(sol.ys))


def test_stats_consistent_with_mali_forward_stats():
    """Solution.stats replaces the mali_forward_stats side channel:
    n_accepted matches, n_accepted + n_rejected == old n_evals, same zT."""
    params, z0 = _toy()
    sol = solve(_toy_f, params, z0, 0.0, 1.0, gradient=MALI(),
                controller=AdaptiveController(1e-3, 1e-4, 64))
    zT, n_acc, n_ev = mali_forward_stats(_toy_f, params, z0, 0.0, 1.0,
                                         rtol=1e-3, atol=1e-4, max_steps=64)
    assert int(sol.stats.n_accepted) == int(n_acc)
    assert int(sol.stats.n_accepted) + int(sol.stats.n_rejected) == int(n_ev)
    np.testing.assert_array_equal(np.asarray(sol.ys), np.asarray(zT))


@pytest.mark.parametrize("method", METHODS)
def test_stats_populated_all_methods(method):
    """Every gradient method returns a Solution with populated stats."""
    params, z0 = _toy()
    gradient, solver, controller = _objects(method, fixed=False)
    sol = solve(_toy_f, params, z0, 0.0, 1.0, solver=solver,
                controller=controller, gradient=gradient)
    assert int(sol.stats.n_accepted) >= 1
    assert int(sol.stats.n_rejected) >= 0
    # every trial costs at least one f-eval; ALF adds the v0 init
    assert int(sol.stats.n_fevals) >= int(sol.stats.n_accepted)
    assert sol.stats.n_segments == 1
    assert sol.stats.residual_bytes > 0


def test_stats_fixed_step_accounting():
    """ConstantSteps: rejected == 0, accepted == segments * n, ALF f-evals
    == steps + 1 (the v0 init)."""
    params, z0 = _toy()
    sol = solve(_toy_f, params, z0, solver=ALF(),
                controller=ConstantSteps(4), gradient=MALI(),
                saveat=SaveAt(ts=TS))
    n_seg = TS.shape[0] - 1
    assert int(sol.stats.n_rejected) == 0
    assert int(sol.stats.n_accepted) == 4 * n_seg
    assert int(sol.stats.n_fevals) == 4 * n_seg + 1
    assert sol.stats.n_segments == n_seg


def test_mali_residual_bytes_constant_in_steps():
    """The Stats residual estimate mirrors the Table 1 claim: constant in
    the step budget for MALI, growing for naive."""
    params, z0 = _toy()

    def res_bytes(gradient, n):
        return solve(_toy_f, params, z0, gradient=gradient,
                     solver=ALF(), controller=ConstantSteps(n)).stats \
            .residual_bytes

    assert res_bytes(MALI(), 4) == res_bytes(MALI(), 64)
    assert res_bytes(Naive(), 64) > res_bytes(Naive(), 4)


def test_saveat_trajectory_matches_legacy_ts():
    params, z0 = _toy()
    legacy = odeint(_toy_f, params, z0, ts=TS, method="mali", n_steps=3)
    sol = solve(_toy_f, params, z0, gradient=MALI(),
                controller=ConstantSteps(3), saveat=SaveAt(ts=TS))
    assert isinstance(sol, Solution)
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(sol.ys))
    np.testing.assert_array_equal(np.asarray(sol.ts), np.asarray(TS))


def test_saveat_steps_dense_output():
    """SaveAt(steps=True): the live rows (Solution.step_mask) are the
    step-start states then the final state, at the recorded step times."""
    params, z0 = _toy()
    sol = solve(_toy_f, params, z0, 0.0, 1.0, solver=ALF(),
                controller=ConstantSteps(8), saveat=SaveAt(steps=True))
    assert int(sol.num_steps) == 8
    mask = np.asarray(sol.step_mask)
    assert mask.sum() == 9  # 8 step starts + the endpoint row
    ts = np.asarray(sol.ts)[mask]
    np.testing.assert_allclose(ts, np.linspace(0.0, 1.0, 9), atol=1e-6)
    exact = float(z0) * np.exp(ALPHA * ts)
    np.testing.assert_allclose(np.asarray(sol.ys)[mask], exact, atol=5e-3)


def test_saveat_steps_adaptive_and_grad():
    params, z0 = _toy()
    sol = solve(_toy_f, params, z0, 0.0, 1.0, solver=ALF(),
                controller=AdaptiveController(1e-4, 1e-5, 64),
                saveat=SaveAt(steps=True))
    n = int(sol.num_steps)
    assert 2 <= n <= 64
    mask = np.asarray(sol.step_mask)
    assert mask.sum() == n + 1
    ts = np.asarray(sol.ts)[mask]
    assert ts[0] == 0.0 and ts[-1] == 1.0
    exact = float(z0) * np.exp(ALPHA * ts)
    np.testing.assert_allclose(np.asarray(sol.ys)[mask], exact, atol=5e-3)

    # dense output is differentiable (direct backprop through the record)
    def loss(p):
        s = solve(_toy_f, p, z0, 0.0, 1.0, solver=ALF(),
                  controller=ConstantSteps(4), saveat=SaveAt(steps=True))
        return jnp.sum(s.ys ** 2)

    g = jax.grad(loss)(params)
    assert np.isfinite(float(g["alpha"]))


def test_solve_composes_with_jit_vmap_grad():
    params, z0 = _toy()

    @jax.jit
    def batch_loss(p, zs):
        fn = jax.vmap(lambda z: solve(_toy_f, p, z, gradient=MALI(),
                                      controller=ConstantSteps(4)).ys)
        return jnp.sum(fn(zs) ** 2)

    g = jax.grad(batch_loss)(params, jnp.linspace(0.5, 2.0, 8))
    assert np.isfinite(float(g["alpha"]))


# --- boundary validation -----------------------------------------------


def test_validation_solver_method_compatibility():
    params, z0 = _toy()
    with pytest.raises(ValueError, match="ALF solver only"):
        solve(_toy_f, params, z0, solver=HeunEuler(), gradient=MALI(),
              controller=ConstantSteps(2))
    with pytest.raises(ValueError, match="Runge-Kutta"):
        solve(_toy_f, params, z0, solver=ALF(), gradient=ACA(),
              controller=ConstantSteps(2))
    with pytest.raises(ValueError, match="error estimate"):
        solve(_toy_f, params, z0, solver="euler", gradient=Naive())


def test_validation_controller_construction():
    with pytest.raises(ValueError):
        AdaptiveController(rtol=-1e-3)
    with pytest.raises(ValueError):
        AdaptiveController(rtol=0.0, atol=0.0)
    with pytest.raises(ValueError):
        AdaptiveController(max_steps=0)
    with pytest.raises(ValueError):
        ConstantSteps(0)
    with pytest.raises(ValueError):
        ConstantSteps(-3)


def test_validation_ts_grid():
    params, z0 = _toy()
    for bad in (jnp.asarray([0.5]), jnp.zeros((2, 2)),
                jnp.asarray([0.0, 0.5, 0.3]), jnp.asarray([0.0, 0.0, 1.0])):
        with pytest.raises(ValueError):
            solve(_toy_f, params, z0, gradient=Naive(),
                  controller=ConstantSteps(2), saveat=SaveAt(ts=bad))


def test_validation_legacy_kwarg_drop():
    """The historical silent-kwarg-drop now raises with actionable errors."""
    params, z0 = _toy()
    with pytest.raises(ValueError, match="eta"):
        odeint(_toy_f, params, z0, method="aca", eta=0.9, n_steps=4)
    with pytest.raises(ValueError, match="fused_bwd"):
        odeint(_toy_f, params, z0, method="naive", fused_bwd=False, n_steps=4)
    with pytest.raises(ValueError, match="n_steps"):
        odeint(_toy_f, params, z0, n_steps=-1)
    with pytest.warns(UserWarning, match="fixed-step"):
        odeint(_toy_f, params, z0, n_steps=4, rtol=1e-3)
    # eta *with* the ALF solver stays valid for every method that takes it
    out = odeint(_toy_f, params, z0, method="naive", solver="alf", eta=0.9,
                 n_steps=4)
    assert np.isfinite(float(out))


def test_validation_saveat():
    with pytest.raises(ValueError, match="only one of"):
        SaveAt(ts=jnp.asarray([0.0, 1.0]), steps=True)
    with pytest.raises(ValueError, match="only one of"):
        SaveAt(steps=True, dense=True)
    with pytest.raises(ValueError, match="only one of"):
        SaveAt(ts=jnp.asarray([0.0, 1.0]), dense=True)


def test_validation_empty_span():
    params, z0 = _toy()
    with pytest.raises(ValueError, match="empty integration span"):
        solve(_toy_f, params, z0, 0.5, 0.5, gradient=Naive(),
              controller=ConstantSteps(2))


def test_ode_settings_validate_extended():
    from repro.core import OdeSettings
    with pytest.raises(ValueError, match="n_steps"):
        OdeSettings(mode="per_block", n_steps=-1).validate()
    with pytest.raises(ValueError, match="max_steps"):
        OdeSettings(mode="per_block", max_steps=0).validate()
    with pytest.raises(ValueError, match="non-negative"):
        OdeSettings(mode="per_block", rtol=-0.5).validate()
    with pytest.raises(ValueError, match="bad ode.method"):
        OdeSettings(mode="per_block", method="nope").validate()
    # the happy path lowers to the object axes
    solver, controller, gradient, saveat = OdeSettings(
        mode="per_block", method="mali", n_steps=4, eta=0.9).as_objects()
    assert isinstance(solver, ALF) and solver.eta == 0.9
    assert isinstance(controller, ConstantSteps) and controller.n == 4
    assert isinstance(gradient, MALI)


def test_ode_settings_t0_and_reverse_block():
    from repro.core import OdeSettings, ode_block
    with pytest.raises(ValueError, match="empty integration span"):
        OdeSettings(mode="per_block", t0=1.0, t1=1.0).validate()
    with pytest.raises(ValueError, match="ode.t0"):
        OdeSettings(mode="per_block", t0=float("inf")).validate()

    params, z0 = _toy()
    # a reverse-time block (t0 > t1) straight from the config equals the
    # explicit reverse solve
    settings = OdeSettings(mode="per_block", method="mali", n_steps=8,
                           t0=1.0, t1=0.0)
    block = ode_block(_toy_f, settings)
    direct = solve(_toy_f, params, z0, 1.0, 0.0, solver=ALF(),
                   controller=ConstantSteps(8), gradient=MALI()).ys
    np.testing.assert_array_equal(np.asarray(block(params, z0)),
                                  np.asarray(direct))
    # default t0 stays 0.0 (behavior-preserving for existing configs)
    assert OdeSettings().t0 == 0.0


def test_odeint_facade_deprecation_warning():
    params, z0 = _toy()
    with pytest.warns(DeprecationWarning, match="legacy string-keyed"):
        odeint(_toy_f, params, z0, n_steps=4)


def test_get_solver_unknown_name_lists_registry():
    from repro.core import get_solver
    with pytest.raises(ValueError, match="registered solver names") as ei:
        get_solver("rk45")
    for name in ("alf", "dopri5", "heun_euler"):
        assert name in str(ei.value)
