"""The paper's central claim (Table 1): MALI's backward-pass residual memory
is O(1) in the number of solver steps; naive's grows linearly.

We verify structurally from the AOT-compiled artifact on CPU:
``temp_size_in_bytes`` of grad(loss) as n_steps grows. This is Fig. 4(c) as
an invariant rather than a plot (benchmarks/memory_cost.py does the plot)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.api import odeint

D = 4096  # big enough that per-step residuals dominate fixed overheads


def _f(params, z, t):
    return jnp.tanh(params["w"] * z) * params["a"]


def _make_params():
    return {"w": jnp.ones((D,), jnp.float32) * 0.5,
            "a": jnp.ones((D,), jnp.float32)}


def _grad_temp_bytes(method, n_steps, solver=None):
    params = _make_params()
    z0 = jnp.ones((D,), jnp.float32)

    def loss(p, z):
        zT = odeint(_f, p, z, 0.0, 1.0, method=method, solver=solver,
                    n_steps=n_steps)
        return jnp.sum(zT ** 2)

    compiled = jax.jit(jax.grad(loss, argnums=(0, 1))).lower(
        params, z0).compile()
    ma = compiled.memory_analysis()
    if ma is None:
        pytest.skip("memory_analysis unavailable on this backend")
    return int(ma.temp_size_in_bytes)


def test_mali_residual_memory_constant_in_steps():
    m8 = _grad_temp_bytes("mali", 8)
    m64 = _grad_temp_bytes("mali", 64)
    # 8x more steps must NOT grow live memory materially (allow slack for
    # scheduling noise)
    assert m64 < 1.5 * m8, (m8, m64)


def test_naive_residual_memory_grows_with_steps():
    n8 = _grad_temp_bytes("naive", 8, solver="alf")
    n64 = _grad_temp_bytes("naive", 64, solver="alf")
    assert n64 > 4 * n8, (n8, n64)


def test_mali_cheaper_than_naive_at_many_steps():
    m = _grad_temp_bytes("mali", 64)
    n = _grad_temp_bytes("naive", 64, solver="alf")
    assert m < n / 4, (m, n)


def test_aca_between_naive_and_mali():
    """ACA stores the accepted z-trajectory: O(N_t) but with a much smaller
    constant than naive (no intra-step activations)."""
    a8 = _grad_temp_bytes("aca", 8, solver="heun_euler")
    a64 = _grad_temp_bytes("aca", 64, solver="heun_euler")
    assert a64 > 2 * a8          # grows with N_t ...
    n64 = _grad_temp_bytes("naive", 64, solver="heun_euler")
    assert a64 < n64             # ... but below naive
