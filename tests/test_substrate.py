"""Substrate tests: optimizer math, checkpointing, fault-tolerance policy,
sharding rules (on a trivial 1-device mesh — full-mesh coverage is the
dry-run's job, exercised as a subprocess in test_distributed.py)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (AsyncCheckpointer, list_checkpoints,
                                         prune_checkpoints,
                                         restore_checkpoint, restore_latest,
                                         save_checkpoint)
from repro.distributed.fault_tolerance import (plan_elastic_mesh,
                                               reassign_shards,
                                               run_with_recovery)
from repro.optim.optimizer import (OptimizerConfig, apply_updates,
                                   init_opt_state, lr_schedule)

# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_lr_schedule_shape():
    cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(lr_schedule(cfg, jnp.int32(10))), 1e-3,
                               rtol=1e-5)
    end = float(lr_schedule(cfg, jnp.int32(100)))
    np.testing.assert_allclose(end, 1e-4, rtol=1e-4)  # min_lr_ratio * peak
    mid = float(lr_schedule(cfg, jnp.int32(55)))
    assert 1e-4 < mid < 1e-3


def test_adamw_first_step_matches_reference():
    """One AdamW step vs hand-computed update (f32 master path)."""
    cfg = OptimizerConfig(peak_lr=1e-2, warmup_steps=0, total_steps=10,
                          min_lr_ratio=1.0, b1=0.9, b2=0.95, eps=1e-8,
                          weight_decay=0.0, clip_norm=0.0,
                          momentum_dtype="float32")
    p = {"w": jnp.asarray([1.0, -2.0], jnp.float32)}
    g = {"w": jnp.asarray([0.5, 0.25], jnp.float32)}
    st = init_opt_state(cfg, p)
    p2, st2, metrics = apply_updates(cfg, p, g, st)
    m = 0.1 * np.asarray(g["w"])            # (1-b1)*g
    v = 0.05 * np.asarray(g["w"]) ** 2      # (1-b2)*g^2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    want = np.asarray(p["w"]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"], np.float32), want,
                               rtol=1e-5)
    assert int(st2.step) == 1


def test_sgd_momentum_and_weight_decay():
    cfg = OptimizerConfig(name="sgd", peak_lr=0.1, warmup_steps=0,
                          total_steps=10, min_lr_ratio=1.0, momentum=0.9,
                          weight_decay=0.0, clip_norm=0.0,
                          momentum_dtype="float32")
    p = {"w": jnp.asarray([1.0], jnp.float32)}
    g = {"w": jnp.asarray([1.0], jnp.float32)}
    st = init_opt_state(cfg, p)
    p1, st, _ = apply_updates(cfg, p, g, st)
    p2, st, _ = apply_updates(cfg, p1, g, st)
    # m1 = 1.0 ; m2 = 0.9*1 + 1 = 1.9
    np.testing.assert_allclose(float(p1["w"][0]), 1.0 - 0.1, rtol=1e-5)
    np.testing.assert_allclose(float(p2["w"][0]), 0.9 - 0.19, rtol=1e-4)


def test_grad_clip_inside_apply_updates():
    cfg = OptimizerConfig(clip_norm=1.0, warmup_steps=0)
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": 100.0 * jnp.ones((4,), jnp.float32)}
    st = init_opt_state(cfg, p)
    _, _, metrics = apply_updates(cfg, p, g, st)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-4)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _tree():
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "b": jnp.ones((3,), jnp.bfloat16)},
            "opt": (jnp.int32(7), jnp.zeros((2,), jnp.float32))}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    save_checkpoint(d, 100, tree, metadata={"loss": 1.25})
    got, meta = restore_checkpoint(os.path.join(d, "step_00000100"), tree)
    assert meta["loss"] == 1.25
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_restore_latest_and_prune(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    for s in (10, 20, 30, 40):
        save_checkpoint(d, s, tree)
    steps = [s for s, _ in list_checkpoints(d)]
    assert steps == [10, 20, 30, 40]
    step, got, _ = restore_latest(d, tree)
    assert step == 40
    prune_checkpoints(d, keep=2)
    assert [s for s, _ in list_checkpoints(d)] == [30, 40]


def test_restore_latest_empty(tmp_path):
    assert restore_latest(str(tmp_path), _tree()) is None


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    ck = AsyncCheckpointer(d, keep=2)
    tree = _tree()
    for s in (1, 2, 3):
        ck.save(s, tree, metadata={"step": s})
    ck.wait()
    steps = [s for s, _ in list_checkpoints(d)]
    assert steps == [2, 3]  # keep=2
    step, got, meta = restore_latest(d, tree)
    assert step == 3 and meta["step"] == 3


# ---------------------------------------------------------------------------
# fault tolerance / elasticity
# ---------------------------------------------------------------------------


def test_plan_elastic_mesh_shrinks_data_axis():
    plan = plan_elastic_mesh(n_available=512, model_size=16,
                             global_batch=256, pods=2)
    assert plan.model == 16 and plan.pod == 2
    assert plan.n_devices <= 512
    assert 256 % plan.data == 0
    # lose 3 nodes x 8 chips
    plan2 = plan_elastic_mesh(n_available=512 - 24, model_size=16,
                              global_batch=256, pods=2)
    assert plan2.data <= plan.data
    assert 256 % plan2.data == 0


def test_plan_elastic_mesh_raises_when_model_cannot_fit():
    with pytest.raises(RuntimeError):
        plan_elastic_mesh(n_available=8, model_size=16, global_batch=64)


def test_reassign_shards_covers_all():
    m = reassign_shards([0, 2, 5], n_shards=8)
    got = sorted(s for ss in m.values() for s in ss)
    assert got == list(range(8))
    # deterministic
    assert m == reassign_shards([5, 0, 2], n_shards=8)


def test_run_with_recovery_restores_and_finishes(tmp_path):
    """Simulated preemption: loop crashes twice, resumes from checkpointed
    step, completes."""
    state = {"ckpt": None, "crashes": 0}

    def restore_step():
        return state["ckpt"]

    def train_loop(resume):
        step = resume or 0
        for s in range(step, 10):
            state["ckpt"] = s
            if s == 4 and state["crashes"] < 2:
                state["crashes"] += 1
                raise RuntimeError("simulated node loss")
        return 10

    final, stats = run_with_recovery(train_loop, restore_step,
                                     max_failures=3)
    assert final == 10
    assert stats.failures == 2
    assert stats.restores >= 2


def test_run_with_recovery_gives_up():
    def train_loop(resume):
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        run_with_recovery(train_loop, lambda: None, max_failures=2)


# ---------------------------------------------------------------------------
# sharding rules (1-device mesh: spec logic only)
# ---------------------------------------------------------------------------


def test_param_shardings_cover_tree():
    from repro.configs import get_config
    from repro.distributed.sharding import param_shardings
    from repro.launch.specs import param_specs
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("qwen3-1.7b", "deepseek-moe-16b", "jamba-v0.1-52b"):
        cfg = get_config(arch)
        like = param_specs(cfg)
        sh = param_shardings(cfg, mesh, like)
        # same structure, every leaf a NamedSharding
        jax.tree_util.tree_map(
            lambda l, s: s.shard_shape(l.shape), like, sh)


def test_batch_shardings_batch_axis():
    from repro.configs import get_config
    from repro.distributed.sharding import batch_shardings
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("qwen3-1.7b")
    like = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    sh = batch_shardings(cfg, mesh, like)
    assert isinstance(sh["tokens"], jax.sharding.NamedSharding)
    # on a trivial 1-device mesh every axis has size 1 => fully replicated
    # is valid; the multi-device batch-axis placement is covered by the
    # dry-run subprocess test (spec logic exercised there at 512 devices).
    assert sh["tokens"].shard_shape((8, 16)) == (8, 16)
