"""Gradient-estimation tests: the paper's §4.1 analytic toy (strongest
available oracle), MALI == naive-through-ALF (reverse accuracy), adjoint
drift, damped MALI, pytree dynamics, adaptive mode."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import mlp_dynamics, mlp_params
from repro.core.api import METHODS, odeint, mali_forward_stats

ALPHA, Z0, T = 0.5, 1.3, 1.0


def _toy_f(params, z, t):
    return params["alpha"] * z


def _toy_loss(params, z0, method, **kw):
    zT = odeint(_toy_f, params, z0, 0.0, T, method=method, **kw)
    return zT ** 2


_EXACT = dict(
    L=(Z0 * math.exp(ALPHA * T)) ** 2,
    dz0=2 * Z0 * math.exp(2 * ALPHA * T),
    dalpha=2 * T * Z0 ** 2 * math.exp(2 * ALPHA * T),
)


@pytest.mark.parametrize("method", METHODS)
def test_toy_gradients_vs_analytic(method):
    """Paper Eq. 6/7: every method's fixed-step gradient converges to the
    analytic one."""
    params = {"alpha": jnp.float32(ALPHA)}
    z0 = jnp.float32(Z0)
    L, (gp, gz) = jax.value_and_grad(_toy_loss, argnums=(0, 1))(
        params, z0, method, n_steps=64)
    assert abs(float(L) - _EXACT["L"]) < 2e-3
    assert abs(float(gp["alpha"]) - _EXACT["dalpha"]) < 2e-2
    assert abs(float(gz) - _EXACT["dz0"]) < 1e-2


def test_mali_equals_naive_through_alf():
    """Reverse accuracy: MALI's reconstructed-trajectory gradient must match
    direct backprop through the same ALF forward (naive+alf) tightly."""
    params = {"alpha": jnp.float32(ALPHA)}
    z0 = jnp.float32(Z0)
    g_mali = jax.grad(_toy_loss, argnums=(0, 1))(params, z0, "mali", n_steps=8)
    g_naive = jax.grad(_toy_loss, argnums=(0, 1))(
        params, z0, "naive", solver="alf", n_steps=8)
    np.testing.assert_allclose(float(g_mali[0]["alpha"]),
                               float(g_naive[0]["alpha"]), rtol=1e-5)
    np.testing.assert_allclose(float(g_mali[1]), float(g_naive[1]), rtol=1e-5)


def test_mali_equals_naive_pytree_dynamics():
    """Same reverse-accuracy check for an MLP dynamics with pytree params."""
    d = 5
    params = mlp_params(jax.random.PRNGKey(0), d)
    f = mlp_dynamics()
    z0 = jax.random.normal(jax.random.PRNGKey(1), (d,))

    def loss(p, z, method):
        zT = odeint(f, p, z, 0.0, 1.0, method=method,
                    solver="alf" if method == "naive" else None, n_steps=8)
        return jnp.sum(zT ** 2)

    gm = jax.grad(loss, argnums=(0, 1))(params, z0, "mali")
    gn = jax.grad(loss, argnums=(0, 1))(params, z0, "naive")
    for a, b in zip(jax.tree_util.tree_leaves(gm),
                    jax.tree_util.tree_leaves(gn)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("eta", [0.9, 0.75])
def test_damped_mali_equals_damped_naive(eta):
    params = {"alpha": jnp.float32(ALPHA)}
    z0 = jnp.float32(Z0)
    gm = jax.grad(_toy_loss, argnums=(0, 1))(params, z0, "mali",
                                             n_steps=8, eta=eta)
    gn = jax.grad(_toy_loss, argnums=(0, 1))(params, z0, "naive",
                                             solver="alf", n_steps=8, eta=eta)
    np.testing.assert_allclose(float(gm[0]["alpha"]), float(gn[0]["alpha"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(gm[1]), float(gn[1]), rtol=1e-5)


def test_adaptive_mali_gradients():
    """Adaptive mode (paper Algo 1 + Algo 4): accepted-step bookkeeping must
    reconstruct correctly; gradient still near-analytic."""
    params = {"alpha": jnp.float32(ALPHA)}
    z0 = jnp.float32(Z0)
    gp, gz = jax.grad(_toy_loss, argnums=(0, 1))(
        params, z0, "mali", n_steps=0, rtol=1e-4, atol=1e-5, max_steps=128)
    assert abs(float(gp["alpha"]) - _EXACT["dalpha"]) < 5e-2
    assert abs(float(gz) - _EXACT["dz0"]) < 2e-2


def test_adaptive_forward_stats():
    params = {"alpha": jnp.float32(ALPHA)}
    zT, n_acc, n_evals = mali_forward_stats(
        _toy_f, params, jnp.float32(Z0), 0.0, T, rtol=1e-3, atol=1e-4)
    assert abs(float(zT) - Z0 * math.exp(ALPHA * T)) < 1e-3
    assert int(n_acc) >= 2
    assert int(n_evals) >= int(n_acc)  # rejected trials cost evals too


def test_adjoint_reverse_drift_vs_mali():
    """Paper Thm 2.1: with a coarse low-order solver, the adjoint's
    reverse-time reconstruction error shows up in the gradient, while MALI
    stays exact w.r.t. its own discretization. Compare both to backprop
    through the *same* forward discretization."""
    params = {"alpha": jnp.float32(1.5)}   # fast-growing => big reverse drift
    z0 = jnp.float32(Z0)

    g_naive_alf = jax.grad(_toy_loss, argnums=1)(params, z0, "naive",
                                                 solver="alf", n_steps=4)
    g_mali = jax.grad(_toy_loss, argnums=1)(params, z0, "mali", n_steps=4)
    g_adj = jax.grad(_toy_loss, argnums=1)(params, z0, "adjoint",
                                           solver="heun_euler", n_steps=4)
    err_mali = abs(float(g_mali) - float(g_naive_alf))
    # MALI == its own forward's true gradient to float precision
    assert err_mali < 1e-4 * abs(float(g_naive_alf))
    # the adjoint with a coarse solver is NOT (different discretization +
    # reverse drift) — sanity: it differs by far more than MALI's error
    err_adj = abs(float(g_adj) - float(g_naive_alf))
    assert err_adj > 10 * max(err_mali, 1e-12)


def test_methods_jit_and_vmap():
    """Integrators must compose with jit/vmap (SPMD requirement)."""
    params = {"alpha": jnp.float32(ALPHA)}
    z0s = jnp.linspace(0.5, 2.0, 8)

    @jax.jit
    def batch_loss(p, zs):
        f = jax.vmap(lambda z: odeint(_toy_f, p, z, 0.0, T, method="mali",
                                      n_steps=8))
        return jnp.sum(f(zs) ** 2)

    g = jax.grad(batch_loss)(params, z0s)
    assert np.isfinite(float(g["alpha"]))


def test_time_grid_endpoints():
    """Integration must hit t1 exactly (fixed grid)."""
    params = {"alpha": jnp.float32(0.0)}  # dz/dt = 0
    z0 = jnp.float32(2.5)
    for m in METHODS:
        zT = odeint(_toy_f, params, z0, 0.0, 1.0, method=m, n_steps=4)
        np.testing.assert_allclose(float(zT), 2.5, rtol=1e-6)


def test_fused_backward_matches_reference_path():
    """The fused inverse+VJP backward (beyond-paper §Perf optimization) must
    match the reference two-pass backward bit-for-bit in structure and to fp
    rounding in value, for damped and undamped ALF."""
    from repro.core.mali import odeint_mali
    d = 7
    params = mlp_params(jax.random.PRNGKey(3), d)
    f = mlp_dynamics()
    z0 = jax.random.normal(jax.random.PRNGKey(4), (d,))

    for eta in (1.0, 0.8):
        def loss(p, z, fused):
            zT = odeint_mali(f, p, z, 0.0, 1.0, n_steps=6, eta=eta,
                             fused_bwd=fused)
            return jnp.sum(zT ** 2)

        gf = jax.grad(loss, argnums=(0, 1))(params, z0, True)
        gr = jax.grad(loss, argnums=(0, 1))(params, z0, False)
        for a, b in zip(jax.tree_util.tree_leaves(gf),
                        jax.tree_util.tree_leaves(gr)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
