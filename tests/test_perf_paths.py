"""Tests pinning the §Perf optimizations to their reference semantics:
flash-bwd attention == AD-through-scan attention, sort-based MoE dispatch ==
cumsum dispatch, sharding hints are no-ops without a mesh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.distributed.sharding import hint
from repro.models import attention as A


@pytest.mark.parametrize("window", [0, 100])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_flash_bwd_matches_ad_reference(window, softcap):
    cfg = dataclasses.replace(smoke_config("gemma2-2b"),
                              attn_softcap=softcap)
    b, s, h, kv, dh = 2, 512, 4, 2, 16
    kq, kk, kvk = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (b, s, h, dh))
    k = jax.random.normal(kk, (b, s, kv, dh))
    v = jax.random.normal(kvk, (b, s, kv, dh))
    pos = jnp.arange(s, dtype=jnp.int32)

    def f_flash(q, k, v):
        return (A._sdpa_chunked_flash(cfg, q, k, v, pos, pos, window,
                                      block_q=128, block_kv=128) ** 2).sum()

    def f_ref(q, k, v):
        return (A._sdpa_chunked(cfg, q, k, v, pos, pos, window,
                                block_q=128, block_kv=128) ** 2).sum()

    np.testing.assert_allclose(float(f_flash(q, k, v)),
                               float(f_ref(q, k, v)), rtol=1e-4)
    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-3, atol=2e-3)


def test_flash_vs_direct_small():
    """Chunked (flash) path == direct softmax attention."""
    cfg = smoke_config("qwen3-1.7b")
    b, s, h, dh = 1, 256, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, h, dh))
    v = jax.random.normal(ks[2], (b, s, h, dh))
    pos = jnp.arange(s, dtype=jnp.int32)
    got = A._sdpa_chunked_flash(cfg, q, k, v, pos, pos, 0,
                                block_q=64, block_kv=64)
    bias = A._mask_bias(pos, pos, 0)
    want = A._sdpa_direct(cfg, q, k, v, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_sort_dispatch_matches_cumsum():
    """Rank-within-expert positions: sort-based == one-hot-cumsum oracle."""
    n, k, e = 128, 3, 16
    rng = np.random.default_rng(7)
    gate_idx = jnp.asarray(rng.integers(0, e, (n, k)), jnp.int32)
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32).reshape(n * k, e)
    pos_old = (((jnp.cumsum(onehot, 0) - onehot) * onehot).sum(-1)
               ).astype(jnp.int32)
    eidx = gate_idx.reshape(-1)
    order = jnp.argsort(eidx, stable=True)
    sorted_e = eidx[order]
    gs = jnp.searchsorted(sorted_e, jnp.arange(e, dtype=eidx.dtype),
                          side="left")
    pos_sorted = jnp.arange(n * k, dtype=jnp.int32) \
        - gs[sorted_e].astype(jnp.int32)
    pos_new = jnp.zeros((n * k,), jnp.int32).at[order].set(pos_sorted)
    np.testing.assert_array_equal(np.asarray(pos_old), np.asarray(pos_new))


def test_hint_is_noop_without_mesh():
    x = jnp.ones((8, 4))
    y = hint(x, "batch", "model")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_hint_under_trivial_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        x = jnp.ones((8, 4))
        y = hint(x, "batch", "model")  # size-1 axes -> no constraint applied
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
