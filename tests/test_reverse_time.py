"""Time as a first-class axis: reverse-time solves, dense output, events.

The paper's headline claim is *reverse accuracy* (Thm 2.1): ALF is
invertible, so MALI's backward pass reconstructs the exact forward
trajectory where Backsolve's reverse-time re-integration drifts. This file
asserts that claim in-library, plus the direction/dense/event contracts of
the time-axis redesign:

* a reverse-time solve (``t1 < t0``, or a descending ``SaveAt.ts`` grid)
  matches the time-reflected forward solve — values AND gradients — for
  all four gradient methods and both controllers;
* a forward solve followed by a reverse solve recovers ``z0`` to solver
  tolerance (and exercises ALF's inverse reconstruction in both
  directions through MALI's backward);
* the Thm 2.1 regression: on stiff decay with the identical ALF
  discretization, MALI's gradient matches the direct-backprop oracle to
  float precision while Backsolve's reverse-time drift is orders of
  magnitude larger;
* ``Solution.evaluate(t)`` (dense cubic-Hermite output) agrees with a
  direct ``SaveAt(ts=...)`` solve to interpolation order on a held-out
  grid, for every method;
* ``Event`` solves recover the analytic crossing time to bisection
  tolerance, freeze post-event grid rows at the terminal state, and their
  frozen-``t_event`` gradient path is finite for all four methods.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ACA, ALF, AdaptiveController, Backsolve,
                        ConstantSteps, Dopri5, Event, HeunEuler, MALI, Naive,
                        SaveAt, solve)

jax.config.update("jax_platform_name", "cpu")


CONFIGS = {
    "mali": (MALI(), ALF()),
    "naive": (Naive(), ALF()),
    "aca": (ACA(), HeunEuler()),
    "adjoint": (Backsolve(), Dopri5()),
}

CONTROLLERS = {
    "fixed": ConstantSteps(8),
    "adaptive": AdaptiveController(1e-4, 1e-5, 64),
}


def _f(params, z, t):
    # Non-autonomous linear decay — time-dependence makes the reflection
    # test meaningful (an autonomous f cannot tell t from T - t).
    return -params["a"] * z * (1.0 + 0.5 * jnp.cos(2.0 * jnp.pi * t))


def _f_reflected(params, z, t):
    # w(tau) = z(1 - tau) satisfies dw/dtau = -f(w, 1 - tau).
    return -_f(params, z, 1.0 - t)


PARAMS = {"a": jnp.float32(0.8)}
Z0 = jnp.asarray([1.0, 0.5, 2.0], jnp.float32)


# ---------------------------------------------------------------------------
# Reverse-time spans match time-reflected forward solves
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ctrl_name", sorted(CONTROLLERS))
@pytest.mark.parametrize("method", sorted(CONFIGS))
def test_reverse_span_matches_reflected_forward(method, ctrl_name):
    gradient, solver = CONFIGS[method]
    controller = CONTROLLERS[ctrl_name]
    tol = 1e-5 if ctrl_name == "fixed" else 2e-3

    def rev_loss(p):
        return jnp.sum(solve(_f, p, Z0, 1.0, 0.0, solver=solver,
                             controller=controller, gradient=gradient).ys ** 2)

    def refl_loss(p):
        return jnp.sum(solve(_f_reflected, p, Z0, 0.0, 1.0, solver=solver,
                             controller=controller, gradient=gradient).ys ** 2)

    rev = solve(_f, PARAMS, Z0, 1.0, 0.0, solver=solver,
                controller=controller, gradient=gradient)
    refl = solve(_f_reflected, PARAMS, Z0, 0.0, 1.0, solver=solver,
                 controller=controller, gradient=gradient)
    np.testing.assert_allclose(np.asarray(rev.ys), np.asarray(refl.ys),
                               rtol=tol, atol=tol)

    g_rev = jax.grad(rev_loss)(PARAMS)["a"]
    g_refl = jax.grad(refl_loss)(PARAMS)["a"]
    np.testing.assert_allclose(np.asarray(g_rev), np.asarray(g_refl),
                               rtol=20 * tol, atol=20 * tol)


@pytest.mark.parametrize("method", sorted(CONFIGS))
def test_descending_grid_matches_reflected_ascending(method):
    gradient, solver = CONFIGS[method]
    controller = CONTROLLERS["fixed"]
    ts_down = jnp.linspace(1.0, 0.0, 5)
    ts_up = jnp.linspace(0.0, 1.0, 5)

    down = solve(_f, PARAMS, Z0, solver=solver, controller=controller,
                 gradient=gradient, saveat=SaveAt(ts=ts_down))
    up = solve(_f_reflected, PARAMS, Z0, solver=solver, controller=controller,
               gradient=gradient, saveat=SaveAt(ts=ts_up))
    # Row k of the descending solve is z at 1 - k/4 — the reflected
    # ascending solve's row k.
    np.testing.assert_allclose(np.asarray(down.ys), np.asarray(up.ys),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(down.ts), np.asarray(ts_down))

    def loss(p, fn, grid):
        sol = solve(fn, p, Z0, solver=solver, controller=controller,
                    gradient=gradient, saveat=SaveAt(ts=grid))
        return jnp.sum(sol.ys[2] ** 2)  # an interior observation

    g_down = jax.grad(lambda p: loss(p, _f, ts_down))(PARAMS)["a"]
    g_up = jax.grad(lambda p: loss(p, _f_reflected, ts_up))(PARAMS)["a"]
    np.testing.assert_allclose(np.asarray(g_down), np.asarray(g_up),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("method", sorted(CONFIGS))
def test_forward_reverse_roundtrip_recovers_z0(method):
    """solve to t1, then solve back to t0 from the endpoint: the composed
    map is identity to solver tolerance (both directions of every driver
    and, through the gradient calls, of ALF's inverse reconstruction)."""
    gradient, solver = CONFIGS[method]
    # NB: max_steps must cover the whole span at this tolerance — an
    # exhausted trial budget truncates the solve silently (the controller's
    # documented bounded-budget contract), which would masquerade as
    # direction error here.
    controller = AdaptiveController(1e-5, 1e-6, 512)
    fwd = solve(_f, PARAMS, Z0, 0.0, 1.0, solver=solver,
                controller=controller, gradient=gradient)
    back = solve(_f, PARAMS, fwd.ys, 1.0, 0.0, solver=solver,
                 controller=controller, gradient=gradient)
    np.testing.assert_allclose(np.asarray(back.ys), np.asarray(Z0),
                               rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# Thm 2.1 regression: MALI reverse-accurate, Backsolve drifts
# ---------------------------------------------------------------------------


def test_thm21_mali_exact_backsolve_drifts():
    """Stiff decay, identical damped-ALF discretization for all three
    methods; Naive (direct backprop) is the exact discrete gradient.
    MALI's inverse reconstruction reproduces it to float precision;
    Backsolve re-derives the trajectory by a fresh reverse-time numerical
    solve of an (in reverse) exponentially unstable ODE and drifts by
    orders of magnitude more (paper Thm 2.1)."""
    def f(params, z, t):
        return -params["a"] * z

    params = {"a": jnp.float32(8.0)}
    z0 = jnp.ones((3,))
    solver = ALF(eta=0.9)       # damping suppresses the marginally-stable
    controller = ConstantSteps(128)  # velocity oscillation on stiff rows

    def loss(p, gradient):
        return jnp.sum(solve(f, p, z0, 0.0, 1.0, solver=solver,
                             controller=controller, gradient=gradient).ys)

    g_naive = float(jax.grad(lambda p: loss(p, Naive()))(params)["a"])
    g_mali = float(jax.grad(lambda p: loss(p, MALI()))(params)["a"])
    g_back = float(jax.grad(lambda p: loss(p, Backsolve()))(params)["a"])

    ref = abs(g_naive)
    assert ref > 0
    rel_mali = abs(g_mali - g_naive) / ref
    rel_back = abs(g_back - g_naive) / ref
    assert rel_mali < 1e-4, rel_mali           # reverse-accurate
    assert rel_back > 1e-3, rel_back           # measurable drift
    assert rel_back > 100 * rel_mali, (rel_mali, rel_back)


def test_thm21_regression_holds_on_pallas_backend():
    """The same stiff a=8 regression under ALF(backend='pallas'): the
    fused inverse+VJP backward kernels reproduce the reference MALI
    gradient to <= 1e-6 relative, and stay reverse-accurate against the
    direct-backprop oracle."""
    def f(params, z, t):
        return -params["a"] * z

    params = {"a": jnp.float32(8.0)}
    z0 = jnp.ones((3,))
    controller = ConstantSteps(128)

    def loss(p, solver, gradient):
        return jnp.sum(solve(f, p, z0, 0.0, 1.0, solver=solver,
                             controller=controller, gradient=gradient).ys)

    g_pallas = float(jax.grad(
        lambda p: loss(p, ALF(eta=0.9, backend="pallas"), MALI()))(
            params)["a"])
    g_ref = float(jax.grad(
        lambda p: loss(p, ALF(eta=0.9), MALI()))(params)["a"])
    g_naive = float(jax.grad(
        lambda p: loss(p, ALF(eta=0.9), Naive()))(params)["a"])

    assert abs(g_ref) > 0
    assert abs(g_pallas - g_ref) / abs(g_ref) <= 1e-6
    assert abs(g_pallas - g_naive) / abs(g_naive) < 1e-4


# ---------------------------------------------------------------------------
# Dense output: Solution.evaluate(t) vs direct grid solves
# ---------------------------------------------------------------------------


DENSE_CONTROLLERS = {
    "fixed": ConstantSteps(8),
    # Dense recording covers [t0, t1] as ONE segment: the budget must span
    # it (Stats.span_complete asserts it did).
    "adaptive": AdaptiveController(1e-4, 1e-5, 256),
}


@pytest.mark.parametrize("ctrl_name", sorted(DENSE_CONTROLLERS))
@pytest.mark.parametrize("method", sorted(CONFIGS))
def test_evaluate_agrees_with_grid_solve(method, ctrl_name):
    gradient, solver = CONFIGS[method]
    controller = DENSE_CONTROLLERS[ctrl_name]
    dense = solve(_f, PARAMS, Z0, 0.0, 1.0, solver=solver,
                  controller=controller, gradient=gradient,
                  saveat=SaveAt(dense=True))
    assert dense.interpolation is not None
    assert bool(dense.stats.span_complete)
    held_out = jnp.asarray([0.0, 0.13, 0.41, 0.77, 1.0])
    grid = solve(_f, PARAMS, Z0, solver=solver, controller=controller,
                 gradient=gradient, saveat=SaveAt(ts=held_out))
    np.testing.assert_allclose(np.asarray(dense.evaluate(held_out)),
                               np.asarray(grid.ys), rtol=5e-3, atol=2e-3)
    # endpoint consistency: evaluate(t1) is the recorded final state
    np.testing.assert_allclose(np.asarray(dense.evaluate(1.0)),
                               np.asarray(dense.ys), rtol=1e-6, atol=1e-6)


def test_lockstep_batched_step_record_accessors():
    """Lockstep-batched steps=True/dense=True rebuild Stats with per-row
    totals (B x the shared counters); the Solution accessors must still
    report the shared record's live rows and carry span_complete."""
    from repro.core import Lockstep
    zb = jnp.ones((4, 3))
    sol = solve(_f, PARAMS, zb, 0.0, 1.0, solver=ALF(),
                controller=ConstantSteps(8), saveat=SaveAt(steps=True),
                batching=Lockstep())
    assert int(sol.num_steps) == 8          # NOT 4 * 8 (the per-row total)
    assert int(sol.stats.n_accepted) == 32  # batched contract: row total
    assert int(np.asarray(sol.step_mask).sum()) == 9
    assert bool(sol.stats.span_complete)

    dense = solve(_f, PARAMS, zb, 0.0, 1.0, solver=ALF(),
                  controller=ConstantSteps(8), saveat=SaveAt(dense=True),
                  batching=Lockstep())
    assert dense.stats.span_complete is not None
    assert bool(dense.stats.span_complete)
    assert dense.evaluate(0.5).shape == (4, 3)


def test_span_complete_flags_truncated_dense_record():
    """An exhausted adaptive budget truncates the recorded span silently;
    Stats.span_complete is the documented way to detect it."""
    tight = AdaptiveController(1e-6, 1e-7, 8)  # cannot cover [0, 1]
    sol = solve(_f, PARAMS, Z0, 0.0, 1.0, solver=ALF(), controller=tight,
                saveat=SaveAt(dense=True))
    assert not bool(sol.stats.span_complete)
    ok = solve(_f, PARAMS, Z0, 0.0, 1.0, solver=ALF(),
               controller=DENSE_CONTROLLERS["adaptive"],
               saveat=SaveAt(dense=True))
    assert bool(ok.stats.span_complete)


def test_evaluate_reverse_time_dense():
    sol = solve(_f, PARAMS, Z0, 1.0, 0.0, solver=ALF(),
                controller=AdaptiveController(1e-4, 1e-5, 256),
                saveat=SaveAt(dense=True))
    fwd = solve(_f_reflected, PARAMS, Z0, solver=ALF(),
                controller=AdaptiveController(1e-4, 1e-5, 256),
                saveat=SaveAt(ts=jnp.asarray([0.0, 0.35, 0.8, 1.0])))
    # reflected query: z(t) of the reverse solve == w(1 - t)
    queries = 1.0 - jnp.asarray([0.0, 0.35, 0.8, 1.0])
    np.testing.assert_allclose(np.asarray(sol.evaluate(queries)),
                               np.asarray(fwd.ys), rtol=5e-3, atol=2e-3)


def test_evaluate_gradients_flow():
    def loss(p):
        sol = solve(_f, p, Z0, 0.0, 1.0, solver=ALF(),
                    controller=ConstantSteps(8), saveat=SaveAt(dense=True))
        return jnp.sum(sol.evaluate(jnp.asarray([0.25, 0.6])) ** 2)

    g = jax.grad(loss)(PARAMS)["a"]
    assert np.isfinite(float(g))
    # finite-difference check of the interpolated-loss gradient
    eps = 1e-3
    lp = loss({"a": PARAMS["a"] + eps})
    lm = loss({"a": PARAMS["a"] - eps})
    fd = (float(lp) - float(lm)) / (2 * eps)
    np.testing.assert_allclose(float(g), fd, rtol=5e-2)


def test_evaluate_requires_dense():
    sol = solve(_f, PARAMS, Z0, 0.0, 1.0, solver=ALF(),
                controller=ConstantSteps(4))
    with pytest.raises(ValueError, match="dense"):
        sol.evaluate(0.5)


def test_step_mask_disambiguates_padding():
    """A padded steps=True buffer whose padding rows hold t=0.0 must be
    distinguishable from a legitimate t=0.0 grid point (the _solve_dense
    ambiguity): step_mask marks exactly the live rows."""
    sol = solve(_f, PARAMS, Z0, 0.0, 1.0, solver=ALF(),
                controller=AdaptiveController(1e-2, 1e-3, 64),
                saveat=SaveAt(steps=True))
    mask = np.asarray(sol.step_mask)
    n = int(sol.num_steps)
    assert mask.sum() == n + 1
    assert mask[0] and not mask[-1]  # padded buffer: live prefix only
    # padding rows are exactly the masked-out ones even though their ts
    # value (0.0) collides with the legitimate first timepoint
    ts = np.asarray(sol.ts)
    assert ts[0] == 0.0 and np.all(ts[~mask] == 0.0)
    assert np.all(np.diff(ts[mask]) > 0)


# ---------------------------------------------------------------------------
# Events
# ---------------------------------------------------------------------------

EV_A = 0.7
T_CROSS = math.log(2.0) / EV_A  # z0=1 decaying through 0.5


def _decay(params, z, t):
    return -params["a"] * z


EV_PARAMS = {"a": jnp.float32(EV_A)}
EV_Z0 = jnp.ones((3,))
EV = Event(lambda z, t: z[0] - 0.5, direction=-1)


@pytest.mark.parametrize("method", sorted(CONFIGS))
def test_event_time_and_gradient(method):
    gradient, solver = CONFIGS[method]
    controller = ConstantSteps(96)
    sol = solve(_decay, EV_PARAMS, EV_Z0, 0.0, 3.0, solver=solver,
                controller=controller, gradient=gradient, event=EV)
    assert bool(sol.stats.event_fired)
    assert abs(float(sol.stats.event_time) - T_CROSS) < 1e-3
    assert abs(float(sol.ys[0]) - 0.5) < 1e-3
    assert abs(float(sol.ts) - float(sol.stats.event_time)) < 1e-6

    def loss(p):
        s = solve(_decay, p, EV_Z0, 0.0, 3.0, solver=solver,
                  controller=controller, gradient=gradient, event=EV)
        return jnp.sum(s.ys ** 2)

    g = float(jax.grad(loss)(EV_PARAMS)["a"])
    assert np.isfinite(g)
    # frozen-t_event analytic gradient: d/da sum(3 * e^{-2 a t*}) at t*
    g_exact = -2.0 * T_CROSS * 3.0 * math.exp(-2.0 * EV_A * T_CROSS)
    np.testing.assert_allclose(g, g_exact, rtol=2e-2)


def test_event_grid_rows_frozen_after_event():
    ts = jnp.linspace(0.0, 3.0, 7)
    sol = solve(_decay, EV_PARAMS, EV_Z0, solver=ALF(),
                controller=ConstantSteps(96), gradient=MALI(),
                saveat=SaveAt(ts=ts), event=EV)
    t_ev = float(sol.stats.event_time)
    ts_out = np.asarray(sol.ts)
    ys_out = np.asarray(sol.ys)
    assert bool(sol.stats.event_fired)
    # pre-event rows keep their grid time; post-event rows clamp to t_event
    pre = np.asarray(ts) <= t_ev
    np.testing.assert_allclose(ts_out[pre], np.asarray(ts)[pre], atol=1e-6)
    np.testing.assert_allclose(ts_out[~pre], t_ev, atol=1e-6)
    # ... and hold the frozen terminal state
    for row in ys_out[~pre]:
        np.testing.assert_allclose(row, ys_out[~pre][0], atol=1e-5)
    np.testing.assert_allclose(ys_out[~pre][:, 0], 0.5, atol=1e-3)


def test_event_does_not_fire_within_short_span():
    sol = solve(_decay, EV_PARAMS, EV_Z0, 0.0, 0.2, solver=ALF(),
                controller=ConstantSteps(16), gradient=MALI(), event=EV)
    assert not bool(sol.stats.event_fired)
    assert abs(float(sol.stats.event_time) - 0.2) < 1e-6
    # no event => the plain end state
    plain = solve(_decay, EV_PARAMS, EV_Z0, 0.0, 0.2, solver=ALF(),
                  controller=ConstantSteps(16), gradient=MALI())
    np.testing.assert_allclose(np.asarray(sol.ys), np.asarray(plain.ys),
                               atol=1e-6)


def test_event_direction_filter():
    # Harmonic oscillator, z[0](t) = cos t: zero crossings alternate
    # falling (pi/2) then rising (3*pi/2). The direction filter must skip
    # the first (falling) crossing for a rising-only event.
    def osc(params, z, t):
        return jnp.stack([z[1], -z[0]])

    z0 = jnp.asarray([1.0, 0.0])
    kw = dict(solver=ALF(), controller=ConstantSteps(160), gradient=MALI())
    s_fall = solve(osc, {}, z0, 0.0, 5.0,
                   event=Event(lambda z, t: z[0], direction=-1), **kw)
    s_rise = solve(osc, {}, z0, 0.0, 5.0,
                   event=Event(lambda z, t: z[0], direction=+1), **kw)
    assert bool(s_fall.stats.event_fired)
    assert bool(s_rise.stats.event_fired)
    assert abs(float(s_fall.stats.event_time) - math.pi / 2) < 5e-3
    assert abs(float(s_rise.stats.event_time) - 3 * math.pi / 2) < 5e-3


def test_event_reverse_time():
    z_end = EV_Z0 * math.exp(-EV_A * 3.0)
    ev_rise = Event(lambda z, t: z[0] - 0.5, direction=+1)
    sol = solve(_decay, EV_PARAMS, z_end, 3.0, 0.0, solver=ALF(),
                controller=ConstantSteps(96), gradient=MALI(), event=ev_rise)
    assert bool(sol.stats.event_fired)
    assert abs(float(sol.stats.event_time) - T_CROSS) < 2e-3


def test_event_validation():
    with pytest.raises(ValueError, match="direction"):
        Event(lambda z, t: z, direction=2)
    with pytest.raises(ValueError, match="max_bisections"):
        Event(lambda z, t: z, max_bisections=0)
    with pytest.raises(TypeError, match="callable"):
        Event(3.0)
    with pytest.raises(ValueError, match="not supported"):
        solve(_decay, EV_PARAMS, EV_Z0, 0.0, 1.0, solver=ALF(),
              controller=ConstantSteps(4), gradient=MALI(), event=EV,
              saveat=SaveAt(steps=True))
    from repro.core import Lockstep
    with pytest.raises(ValueError, match="batching"):
        solve(_decay, EV_PARAMS, jnp.ones((4, 3)), 0.0, 1.0, solver=ALF(),
              controller=ConstantSteps(4), gradient=MALI(), event=EV,
              batching=Lockstep())


@pytest.mark.parametrize("method", sorted(CONFIGS))
def test_event_time_gradient_matches_ift(method):
    # Stats.event_time is differentiable via the implicit function
    # theorem: c(z(t*; theta), t*) = 0 with z = z0 e^{-a t} and
    # c = z[0] - 0.5 gives t* = ln(2 z0[0]) / a, so
    # dt*/da = -t*/a and dt*/dz0 = (1/(a z0[0]), 0, 0).
    gradient, solver = CONFIGS[method]
    controller = ConstantSteps(96)

    def t_star(p, z):
        s = solve(_decay, p, z, 0.0, 3.0, solver=solver,
                  controller=controller, gradient=gradient, event=EV)
        return s.stats.event_time

    g_p, g_z = jax.grad(t_star, argnums=(0, 1))(EV_PARAMS, EV_Z0)
    np.testing.assert_allclose(float(g_p["a"]), -T_CROSS / EV_A, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(g_z),
                               [1.0 / EV_A, 0.0, 0.0], atol=2e-2)


def test_event_time_gradient_zero_when_unfired():
    # the IFT correction is gated on event_fired: an event-free span keeps
    # the plain span endpoint with no parameter gradient
    def t_end(p):
        s = solve(_decay, p, EV_Z0, 0.0, 0.2, solver=ALF(),
                  controller=ConstantSteps(16), gradient=MALI(), event=EV)
        return s.stats.event_time

    assert float(jax.grad(t_end)(EV_PARAMS)["a"]) == 0.0
