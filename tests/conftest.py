"""Shared fixtures/helpers. NOTE: no XLA_FLAGS here — tests must see the
real single CPU device (the 512-device override is dryrun.py-only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def linear_dynamics(A):
    """dz/dt = A @ z (matrix params)."""
    def f(params, z, t):
        return params @ z
    return f


def mlp_dynamics():
    """Small time-dependent MLP dynamics over a vector state, pytree params."""
    def f(params, z, t):
        h = jnp.tanh(z @ params["w1"] + params["b1"] + t * params["bt"])
        return h @ params["w2"] + params["b2"]
    return f


def mlp_params(key, d, width=8):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": 0.5 * jax.random.normal(k1, (d, width)),
        "b1": jnp.zeros((width,)),
        "bt": 0.3 * jnp.ones((width,)),
        "w2": 0.5 * jax.random.normal(k2, (width, d)),
        "b2": 0.1 * jax.random.normal(k3, (d,)),
    }
