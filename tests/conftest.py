"""Shared fixtures/helpers. NOTE: no XLA_FLAGS here — tests must see the
real single CPU device (the 512-device override is dryrun.py-only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


def pytest_collection_modifyitems(config, items):
    """Skip ``requires_pallas_device`` tests on CPU-only hosts.

    Some Pallas kernels (flash_attention) exceed what interpret mode can
    emulate with current jax on CPU; they need a real TPU/GPU lowering.
    The marker replaces the old ``-k "not flash_attention"`` CI deselect so
    a bare ``pytest`` collects cleanly everywhere.
    """
    if jax.default_backend() != "cpu":
        return
    skip = pytest.mark.skip(
        reason="needs a Pallas-compiled accelerator (TPU/GPU); CPU "
               "interpret mode cannot run this kernel")
    for item in items:
        if "requires_pallas_device" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def linear_dynamics(A):
    """dz/dt = A @ z (matrix params)."""
    def f(params, z, t):
        return params @ z
    return f


def mlp_dynamics():
    """Small time-dependent MLP dynamics over a vector state, pytree params."""
    def f(params, z, t):
        h = jnp.tanh(z @ params["w1"] + params["b1"] + t * params["bt"])
        return h @ params["w2"] + params["b2"]
    return f


def mlp_params(key, d, width=8):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": 0.5 * jax.random.normal(k1, (d, width)),
        "b1": jnp.zeros((width,)),
        "bt": 0.3 * jnp.ones((width,)),
        "w2": 0.5 * jax.random.normal(k2, (width, d)),
        "b2": 0.1 * jax.random.normal(k3, (d,)),
    }
