"""The closed Pallas loop: fused MALI backward kernels + direct backprop
through the forward launches.

Covers the full gradient story of ALF(backend='pallas'):

* MALI gradient parity (pallas vs reference) across controller x direction
  x fused_bwd, at <= 1e-6 combined relative error;
* Naive(), SaveAt(steps=True) and SaveAt(dense=True) now ACCEPT the pallas
  backend (the forward ops carry closed-form custom_vjp rules) and their
  gradients match the reference backend;
* the NO_REVERSE_RULE registry reflects the new contract (forward ops
  absent, backward-sweep ops present) and a future VJP-less step op is
  still rejected with its recorded justification;
* launch accounting: one fused MALI backward step is exactly TWO
  pallas_call launches (alf_bwd_pre / alf_bwd_post, one on each side of
  the f-eval linearization), the forward step is two, the reference
  backend zero.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ALF, MALI, AdaptiveController, ConstantSteps, Naive,
                        SaveAt, solve)

_tl = jax.tree_util.tree_leaves


def _f(params, z, t):
    return -params["a"] * z + jnp.sin(t) * params["b"]


def _params():
    return {"a": jnp.float32(8.0), "b": jnp.float32(0.5)}


def _z0():
    return jnp.linspace(0.3, 1.0, 5).astype(jnp.float32)


def _rel(got, want):
    fa = jnp.concatenate([x.reshape(-1) for t in got for x in _tl(t)])
    fb = jnp.concatenate([x.reshape(-1) for t in want for x in _tl(t)])
    return float(jnp.linalg.norm(fa - fb) / (jnp.linalg.norm(fb) + 1e-30))


def _assert_grads_match(got, want, rtol=1e-6, atol=2e-8):
    """Per-leaf <= rtol relative parity, with a tiny absolute floor for
    entries that are themselves ~0 (stiff decay makes some dL/dz0 entries
    cross zero, where pure relative error is meaningless)."""
    for g, w in zip(_tl(got), _tl(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=rtol, atol=atol)


def _grad(solver, gradient, controller, t0, t1, saveat=None):
    def loss(p, z):
        sol = solve(_f, p, z, t0, t1, solver=solver, controller=controller,
                    gradient=gradient, saveat=saveat)
        return jnp.sum(sol.ys ** 2)
    return jax.grad(loss, argnums=(0, 1))(_params(), _z0())


@pytest.mark.parametrize("controller", [ConstantSteps(16),
                                        AdaptiveController(1e-3, 1e-4, 32)],
                         ids=["const16", "adaptive"])
@pytest.mark.parametrize("span", [(0.0, 1.0), (1.0, 0.0)],
                         ids=["fwd", "rev"])
@pytest.mark.parametrize("fused", [True, False])
def test_mali_pallas_gradient_parity(controller, span, fused):
    """MALI with the fused Pallas backward vs reference MALI: same recorded
    step sequence, same closed-form algebra, <= 1e-6 relative."""
    t0, t1 = span
    gp = _grad(ALF(eta=0.9, backend="pallas"), MALI(fused_bwd=fused),
               controller, t0, t1)
    gr = _grad(ALF(eta=0.9), MALI(fused_bwd=fused), controller, t0, t1)
    _assert_grads_match(gp, gr)


def test_naive_accepts_pallas_and_matches_reference():
    """Direct backprop through the fused forward launches (custom_vjp
    rules) == direct backprop through the jnp reference step."""
    gp = _grad(ALF(eta=0.9, backend="pallas"), Naive(), ConstantSteps(16),
               0.0, 1.0)
    gr = _grad(ALF(eta=0.9), Naive(), ConstantSteps(16), 0.0, 1.0)
    _assert_grads_match(gp, gr)


def test_naive_pallas_is_mali_gradient_oracle():
    """The paper's core identity, now on the pallas backend end-to-end:
    MALI and Naive run the identical forward, so gradients agree."""
    gm = _grad(ALF(eta=0.9, backend="pallas"), MALI(), ConstantSteps(32),
               0.0, 1.0)
    gn = _grad(ALF(eta=0.9, backend="pallas"), Naive(), ConstantSteps(32),
               0.0, 1.0)
    assert _rel(gm, gn) <= 1e-4


def test_saveat_steps_accepts_pallas():
    """SaveAt(steps=True) used to reject backend='pallas' outright; the
    per-step record is now differentiable through the launches."""
    def run(backend):
        def loss(p, z):
            sol = solve(_f, p, z, 0.0, 1.0, solver=ALF(backend=backend),
                        controller=ConstantSteps(8), gradient=Naive(),
                        saveat=SaveAt(steps=True))
            return jnp.sum(sol.ys ** 2)
        sol = solve(_f, _params(), _z0(), 0.0, 1.0,
                    solver=ALF(backend=backend), controller=ConstantSteps(8),
                    gradient=Naive(), saveat=SaveAt(steps=True))
        return sol, jax.grad(loss, argnums=(0, 1))(_params(), _z0())

    sol_p, g_p = run("pallas")
    sol_r, g_r = run("reference")
    assert int(sol_p.n_live) == int(sol_r.n_live) == 9
    np.testing.assert_allclose(np.asarray(sol_p.ys), np.asarray(sol_r.ys),
                               rtol=1e-6, atol=1e-7)
    _assert_grads_match(g_p, g_r)


def test_saveat_dense_accepts_pallas():
    """SaveAt(dense=True): evaluate(t) works on the pallas backend and its
    interpolated values are differentiable through the launches."""
    def loss(p, z, backend):
        sol = solve(_f, p, z, 0.0, 1.0, solver=ALF(backend=backend),
                    controller=ConstantSteps(8), gradient=Naive(),
                    saveat=SaveAt(dense=True))
        return jnp.sum(sol.evaluate(0.37) ** 2)

    vals, grads = {}, {}
    for backend in ("pallas", "reference"):
        vals[backend] = loss(_params(), _z0(), backend)
        grads[backend] = jax.grad(loss, argnums=(0, 1))(
            _params(), _z0(), backend)
    np.testing.assert_allclose(float(vals["pallas"]),
                               float(vals["reference"]), rtol=1e-6)
    _assert_grads_match(grads["pallas"], grads["reference"])


# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------

def test_registry_reflects_new_reverse_contract():
    from repro.kernels.registry import no_reverse_reason
    # forward ops carry custom_vjp rules -> NOT allowlisted forward-only
    assert no_reverse_reason("alf_step.alf_midpoint") is None
    assert no_reverse_reason("alf_step.alf_update") is None
    # backward-sweep ops are forward-only by design, with justifications
    for op in ("alf_step.alf_inverse", "alf_step.alf_inverse_update",
               "alf_step.alf_bwd_pre", "alf_step.alf_bwd_post"):
        reason = no_reverse_reason(op)
        assert reason is not None and len(reason) >= 20, op


def test_future_forward_only_step_op_still_rejected():
    """The rejection machinery is registry-driven now: a solver whose step
    dispatches ANY allowlisted op is refused by every direct-backprop
    consumer, with the recorded justification in the error."""
    from repro.core.naive import check_direct_backprop

    class FrankenALF(ALF):
        def pallas_step_ops(self):
            return ("alf_step.alf_bwd_pre",)

    solver = FrankenALF(backend="pallas")
    with pytest.raises(ValueError, match="NO_REVERSE_RULE"):
        check_direct_backprop(solver, "Naive()")
    with pytest.raises(ValueError, match="fused head"):
        Naive().validate(solver, ConstantSteps(4))
    # the per-step record path runs its own consumer check (gradient=MALI
    # passes MALI.validate, so the rejection must come from SaveAt itself)
    with pytest.raises(ValueError, match="SaveAt\\(steps=True\\)"):
        solve(_f, _params(), _z0(), 0.0, 1.0, solver=solver,
              controller=ConstantSteps(4), gradient=MALI(),
              saveat=SaveAt(steps=True))


def test_plain_pallas_alf_passes_direct_backprop_check():
    from repro.core.naive import check_direct_backprop
    check_direct_backprop(ALF(backend="pallas"), "Naive()")  # no raise
    Naive().validate(ALF(backend="pallas"), ConstantSteps(4))
    assert ALF().pallas_step_ops() == ()


# ---------------------------------------------------------------------------
# Launch accounting: the backward elementwise algebra is ONE launch on each
# side of the f-eval linearization
# ---------------------------------------------------------------------------

def test_fused_backward_step_is_two_launches():
    from repro.core.mali import _pallas_fused_inverse_and_vjp
    from repro.launch.hlo_cost import count_pallas_launches

    z = jnp.ones((5,), jnp.float32)
    args = (_params(), z, z, jnp.float32(1.0), jnp.float32(0.1), z, z)

    def bwd_step(params, z_i, v_i, t_i, h, a_z, a_v):
        return _pallas_fused_inverse_and_vjp(_f, 0.9, params, z_i, v_i,
                                             t_i, h, a_z, a_v)

    assert count_pallas_launches(bwd_step, *args) == 2


def test_forward_step_launch_counts():
    from repro.core.alf import alf_step_with_error
    from repro.launch.hlo_cost import count_pallas_launches

    z = jnp.ones((5,), jnp.float32)
    args = (_params(), z, z, jnp.float32(0.0), jnp.float32(0.1))

    def step(backend):
        def fn(params, z_, v_, t, h):
            return alf_step_with_error(_f, params, z_, v_, t, h, 0.9,
                                       backend)
        return fn

    assert count_pallas_launches(step("pallas"), *args) == 2
    assert count_pallas_launches(step("reference"), *args) == 0


def test_mali_pallas_grad_total_launches():
    """End-to-end check that the WHOLE backward elementwise algebra stays
    fused: one MALI train-step jaxpr on the pallas backend contains exactly
    4 launches — 2 in the forward scan body (midpoint + update) and 2 in
    the backward scan body (bwd_pre + bwd_post); the reference backend
    contains none."""
    from repro.launch.hlo_cost import count_pallas_launches

    def loss_fn(backend):
        def loss(p, z):
            sol = solve(_f, p, z, 0.0, 1.0, solver=ALF(backend=backend),
                        controller=ConstantSteps(4), gradient=MALI())
            return jnp.sum(sol.ys)
        return loss

    n_pallas = count_pallas_launches(jax.grad(loss_fn("pallas"),
                                              argnums=(0, 1)),
                                     _params(), _z0())
    n_ref = count_pallas_launches(jax.grad(loss_fn("reference"),
                                           argnums=(0, 1)),
                                  _params(), _z0())
    assert n_pallas == 4, n_pallas
    assert n_ref == 0, n_ref
