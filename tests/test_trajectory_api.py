"""Observation-grid trajectory API: odeint(f, params, z0, ts) across all
four gradient methods.

Oracles: (a) naive backprop through the identical segmented ALF forward —
MALI's trajectory AND its gradients (including through *intermediate*
observations) must match tightly; (b) the analytic solution of the paper's
§4.1 toy; (c) the AOT memory artifact — MALI's residual set is the
per-observation (z_k, v_k) pairs, independent of the per-segment step count.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import mlp_dynamics, mlp_params
from repro.core.api import METHODS, odeint

ALPHA = 0.5


def _toy_f(params, z, t):
    return params["alpha"] * z


def _toy():
    return {"alpha": jnp.float32(ALPHA)}, jnp.float32(1.3)


TS = jnp.linspace(0.0, 1.0, 8)


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("n_steps", [4, 0])
def test_trajectory_matches_analytic(method, n_steps):
    """Every method, fixed and adaptive: traj[k] ~= z0 * exp(alpha * ts[k])."""
    params, z0 = _toy()
    kw = {} if n_steps else dict(rtol=1e-4, atol=1e-5, max_steps=64)
    traj = odeint(_toy_f, params, z0, ts=TS, method=method,
                  n_steps=n_steps, **kw)
    assert traj.shape == (8,)
    exact = float(z0) * np.exp(ALPHA * np.asarray(TS))
    np.testing.assert_allclose(np.asarray(traj), exact, atol=5e-3)
    np.testing.assert_allclose(float(traj[0]), float(z0), rtol=1e-6)


def test_mali_trajectory_equals_naive_fixed_grid():
    """MALI multi-timepoint trajectory == naive on the same fixed ALF grid."""
    params, z0 = _toy()
    tm = odeint(_toy_f, params, z0, ts=TS, method="mali", n_steps=4)
    tn = odeint(_toy_f, params, z0, ts=TS, method="naive", solver="alf",
                n_steps=4)
    np.testing.assert_allclose(np.asarray(tm), np.asarray(tn), rtol=1e-5)


def test_mali_grad_through_intermediate_observation():
    """Gradients of a loss over intermediate observations: MALI's
    reconstructed backward must match jax.grad through the naive method."""
    params, z0 = _toy()

    def loss(p, z, method):
        traj = odeint(_toy_f, p, z, ts=TS, method=method,
                      solver="alf" if method == "naive" else None, n_steps=4)
        # weights every observation, not just the endpoint
        return jnp.sum(jnp.arange(1.0, 9.0) * traj ** 2)

    gm = jax.grad(loss, argnums=(0, 1))(params, z0, "mali")
    gn = jax.grad(loss, argnums=(0, 1))(params, z0, "naive")
    np.testing.assert_allclose(float(gm[0]["alpha"]), float(gn[0]["alpha"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(gm[1]), float(gn[1]), rtol=1e-5)


def test_mali_grad_pytree_dynamics_trajectory():
    """Same oracle for MLP dynamics with pytree params + batched state."""
    d = 5
    params = mlp_params(jax.random.PRNGKey(0), d)
    f = mlp_dynamics()
    z0 = jax.random.normal(jax.random.PRNGKey(1), (d,))
    ts = jnp.linspace(0.0, 1.0, 4)

    def loss(p, z, method):
        traj = odeint(f, p, z, ts=ts, method=method,
                      solver="alf" if method == "naive" else None, n_steps=4)
        return jnp.sum(traj[1] ** 2) + 0.5 * jnp.sum(traj[-1] ** 2)

    gm = jax.grad(loss, argnums=(0, 1))(params, z0, "mali")
    gn = jax.grad(loss, argnums=(0, 1))(params, z0, "naive")
    for a, b in zip(jax.tree_util.tree_leaves(gm),
                    jax.tree_util.tree_leaves(gn)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_adaptive_mali_trajectory_gradients_finite_and_close():
    params, z0 = _toy()

    def loss(p, z, method):
        traj = odeint(_toy_f, p, z, ts=TS, method=method,
                      solver="alf" if method == "naive" else None,
                      n_steps=0, rtol=1e-4, atol=1e-5, max_steps=64)
        return jnp.sum(traj ** 2)

    gm = jax.grad(loss, argnums=(0, 1))(params, z0, "mali")
    gn = jax.grad(loss, argnums=(0, 1))(params, z0, "naive")
    np.testing.assert_allclose(float(gm[0]["alpha"]), float(gn[0]["alpha"]),
                               rtol=1e-4)
    np.testing.assert_allclose(float(gm[1]), float(gn[1]), rtol=1e-4)


def test_scalar_path_equals_grid_endpoint():
    """The scalar t0->t1 path is the length-1 grid: same value bit-for-bit."""
    params, z0 = _toy()
    for method in METHODS:
        zT = odeint(_toy_f, params, z0, 0.0, 1.0, method=method, n_steps=4)
        traj = odeint(_toy_f, params, z0, ts=jnp.asarray([0.0, 1.0]),
                      method=method, n_steps=4)
        np.testing.assert_array_equal(np.asarray(zT), np.asarray(traj[-1]))


def test_reverse_time_grid():
    """Decreasing observation grids (CNF sampling direction) integrate too."""
    params, z0 = _toy()
    ts_rev = jnp.linspace(1.0, 0.0, 5)
    traj = odeint(_toy_f, params, z0, ts=ts_rev, method="mali", n_steps=4)
    exact = float(z0) * np.exp(ALPHA * (np.asarray(ts_rev) - 1.0))
    np.testing.assert_allclose(np.asarray(traj), exact, atol=5e-3)


def test_ts_validation():
    params, z0 = _toy()
    with pytest.raises(ValueError):
        odeint(_toy_f, params, z0, ts=jnp.asarray([0.5]), method="mali",
               n_steps=2)
    with pytest.raises(ValueError):
        odeint(_toy_f, params, z0, ts=jnp.zeros((2, 2)), method="naive",
               n_steps=2)


D = 4096


def _big_f(params, z, t):
    return jnp.tanh(params["w"] * z) * params["a"]


def _grid_grad_temp_bytes(method, n_steps):
    params = {"w": jnp.ones((D,), jnp.float32) * 0.5,
              "a": jnp.ones((D,), jnp.float32)}
    z0 = jnp.ones((D,), jnp.float32)
    ts = jnp.linspace(0.0, 1.0, 4)

    def loss(p, z):
        traj = odeint(_big_f, p, z, ts=ts, method=method,
                      solver="alf" if method == "naive" else None,
                      n_steps=n_steps)
        return jnp.sum(traj ** 2)

    compiled = jax.jit(jax.grad(loss, argnums=(0, 1))).lower(
        params, z0).compile()
    ma = compiled.memory_analysis()
    if ma is None:
        pytest.skip("memory_analysis unavailable on this backend")
    return int(ma.temp_size_in_bytes)


def test_mali_trajectory_residuals_constant_in_steps():
    """Residual pytree is the per-observation (z_k, v_k) pairs: growing the
    per-segment step count 8x must not grow live backward memory."""
    m8 = _grid_grad_temp_bytes("mali", 8)
    m64 = _grid_grad_temp_bytes("mali", 64)
    assert m64 < 1.5 * m8, (m8, m64)


def test_naive_trajectory_residuals_grow_in_steps():
    n8 = _grid_grad_temp_bytes("naive", 8)
    n64 = _grid_grad_temp_bytes("naive", 64)
    assert n64 > 4 * n8, (n8, n64)


def test_latent_ode_style_batched_rollout():
    """Batched latent-ODE shape: one call returns [T, B, L] and is the same
    as chaining per-interval calls in Python (same grid, same method)."""
    d = 3
    params = mlp_params(jax.random.PRNGKey(2), d)
    f = mlp_dynamics()
    z0 = jax.random.normal(jax.random.PRNGKey(3), (6, d))
    ts = jnp.linspace(0.0, 2.0, 5)

    traj = odeint(f, params, z0, ts=ts, method="mali", n_steps=2)
    assert traj.shape == (5, 6, d)

    # oracle: naive on the same native grid runs the identical segmented
    # forward (a chained per-interval rollout would re-init v each segment
    # and is deliberately NOT equivalent)
    tn = odeint(f, params, z0, ts=ts, method="naive", solver="alf", n_steps=2)
    np.testing.assert_allclose(np.asarray(traj), np.asarray(tn), rtol=2e-5,
                               atol=1e-6)
