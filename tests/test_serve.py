"""Serving-layer tests: scheduler, cache, engine parity, CLI smoke.

Everything time-dependent runs on an injected deterministic clock (a fake
timer advancing a fixed step per sample), so no assertion here depends on
wall time. The engine parity tests are the load-bearing ones: the chunked
continuous-batching engine must reproduce ``solve()``'s end states
exactly — chunk boundaries are scan boundaries with identical carry, so
backfilled serving is numerically invisible.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import ALF, AdaptiveController, SaveAt, solve
from repro.serve import (ADMISSION_POLICIES, CACHE_POLICIES,
                         SCHEDULING_POLICIES, AdmissionPolicy, AdmitAll,
                         BoundedQueue, CachePolicy, ContinuousBatchingEngine,
                         EngineConfig, FIFO, InterpolantCache, LRU, NoCache,
                         Request, RequestConfig, Scheduler, SchedulingPolicy,
                         ShortestSpanFirst, StaticFleetEngine,
                         decay_dynamics, hot_trajectory_requests,
                         mixed_stiffness_requests, percentile,
                         poisson_arrivals)


def make_timer(step: float = 1e-3):
    """Deterministic clock: advances `step` per sample."""
    state = {"t": 0.0}

    def timer() -> float:
        state["t"] += step
        return state["t"]

    return timer


def _z0(rng, d=4, lam=3.0):
    return {"y": rng.standard_normal(d).astype(np.float32),
            "lam": np.full((d,), lam, dtype=np.float32)}


def _solve_reference(req):
    cfg = req.config
    return solve(decay_dynamics, None,
                 {k: jnp.asarray(v) for k, v in req.z0.items()},
                 cfg.t0, cfg.t1, solver=ALF(eta=0.9),
                 controller=AdaptiveController(cfg.rtol, cfg.atol,
                                               cfg.max_steps))


def small_config():
    return EngineConfig(slots=3, chunk_steps=8, solver=ALF(eta=0.9))


# ---------------------------------------------------------------------------
# RequestConfig
# ---------------------------------------------------------------------------

class TestRequestConfig:
    def test_validation(self):
        with pytest.raises(ValueError, match="empty span"):
            RequestConfig(t0=1.0, t1=1.0)
        with pytest.raises(ValueError, match="non-negative"):
            RequestConfig(rtol=-1e-3)
        with pytest.raises(ValueError, match="cannot both be 0"):
            RequestConfig(rtol=0.0, atol=0.0)
        with pytest.raises(ValueError, match="max_steps"):
            RequestConfig(max_steps=0)

    def test_value_hashing(self):
        # The PR 6 contract: fresh equal-valued configs are interchangeable
        # as jit statics and cache-key components.
        a = RequestConfig(t1=np.float32(2.0), rtol=1e-4)
        b = RequestConfig(t1=2.0, rtol=1e-4)
        assert a == b and hash(a) == hash(b)
        assert a != RequestConfig(t1=2.0, rtol=1e-3)
        assert RequestConfig(t0=1.0, t1=0.0).span == -1.0


# ---------------------------------------------------------------------------
# Scheduler (deterministic clock — no wall time anywhere)
# ---------------------------------------------------------------------------

class TestScheduler:
    def _requests(self, arrivals):
        rng = np.random.default_rng(0)
        return [Request(z0=_z0(rng), arrival=t) for t in arrivals]

    def test_release_by_stamp(self):
        s = Scheduler()
        s.schedule(self._requests([0.0, 0.5, 1.0, 2.0]))
        assert s.next_arrival() == 0.0
        assert s.release(now=0.6) == 2
        assert s.depth == 2 and not s.drained
        assert s.next_arrival() == 1.0
        assert s.release(now=0.7) == 0          # nothing new has arrived
        assert s.release(now=5.0) == 2
        taken = s.take(10)
        assert [r.arrival for r in taken] == [0.0, 0.5, 1.0, 2.0]  # FIFO
        assert s.drained

    def test_bounded_queue_rejects(self):
        s = Scheduler(admission=BoundedQueue(max_depth=2))
        s.schedule(self._requests([0.0, 0.1, 0.2, 0.3]))
        s.release(now=1.0)
        assert s.depth == 2
        assert s.n_rejected == 2
        assert [r.arrival for r in s.rejected] == [0.2, 0.3]
        # draining the queue re-opens admission for later arrivals
        s.take(2)
        s.schedule(self._requests([1.5]))
        s.release(now=2.0)
        assert s.depth == 1 and s.n_rejected == 2
        assert AdmitAll().admit(10_000, None)

    def test_shortest_span_first(self):
        rng = np.random.default_rng(0)
        spans = [3.0, 1.0, 2.0]
        reqs = [Request(z0=_z0(rng), config=RequestConfig(t1=t1))
                for t1 in spans]
        s = Scheduler(policy=ShortestSpanFirst())
        s.schedule(reqs)
        s.release(now=0.0)
        out = s.take(2)
        assert [r.config.t1 for r in out] == [1.0, 2.0]
        assert [r.config.t1 for r in s.take(5)] == [3.0]
        # FIFO control on the same spans
        assert isinstance(FIFO().select([], 4), list)

    def test_take_pred_splits_lanes(self):
        rng = np.random.default_rng(0)
        dense = Request(z0=_z0(rng), config=RequestConfig(dense=True))
        plain = Request(z0=_z0(rng))
        s = Scheduler()
        s.schedule([dense, plain])
        s.release(now=0.0)
        out = s.take(5, pred=lambda r: r.wants_dense)
        assert out == [dense]
        assert s.take(5) == [plain]

    def test_registries(self):
        assert set(ADMISSION_POLICIES) == {"admit_all", "bounded"}
        assert set(SCHEDULING_POLICIES) == {"fifo", "shortest_span"}
        assert set(CACHE_POLICIES) == {"lru", "none"}


# ---------------------------------------------------------------------------
# Interpolant cache
# ---------------------------------------------------------------------------

class TestInterpolantCache:
    def test_key_is_content_hash(self):
        rng = np.random.default_rng(1)
        z0 = _z0(rng)
        cfg = RequestConfig(dense=True)
        k = InterpolantCache.key("vf", cfg, z0)
        assert k == InterpolantCache.key(
            "vf", RequestConfig(dense=True),
            {kk: vv.copy() for kk, vv in z0.items()})
        assert k != InterpolantCache.key("vf2", cfg, z0)
        assert k != InterpolantCache.key(
            "vf", RequestConfig(dense=True, rtol=1e-5), z0)
        other = {kk: vv.copy() for kk, vv in z0.items()}
        other["y"][0] += 1.0
        assert k != InterpolantCache.key("vf", cfg, other)

    def test_hit_miss_counters(self):
        c = InterpolantCache(LRU(max_entries=4))
        assert c.get("a") is None
        c.put("a", "va")
        assert c.get("a") == "va"
        assert (c.hits, c.misses, c.hit_rate) == (1, 1, 0.5)
        assert "a" in c and len(c) == 1

    def test_lru_eviction(self):
        c = InterpolantCache(LRU(max_entries=2))
        c.put("a", 1)
        c.put("b", 2)
        assert c.get("a") == 1          # refresh "a": now "b" is oldest
        c.put("c", 3)
        assert c.evictions == 1
        assert c.get("b") is None       # "b" was evicted, not "a"
        assert c.get("a") == 1 and c.get("c") == 3

    def test_no_cache_policy(self):
        c = InterpolantCache(NoCache())
        c.put("a", 1)
        assert len(c) == 0 and c.get("a") is None
        with pytest.raises(ValueError, match="max_entries"):
            LRU(max_entries=0)


# ---------------------------------------------------------------------------
# Engine: backfilled chunked serving == stacked individual solves
# ---------------------------------------------------------------------------

class TestEngineParity:
    def _mixed_requests(self):
        rng = np.random.default_rng(7)
        reqs = mixed_stiffness_requests(rng, 7, rate=1_000.0, d_state=4,
                                        lam_decades=(0.0, 1.3),
                                        max_steps=256)
        # one reverse-time request rides the same fleet
        reqs.append(Request(z0=_z0(rng, lam=2.0),
                            config=RequestConfig(t0=1.0, t1=0.0,
                                                 max_steps=256),
                            arrival=0.002))
        return reqs

    def test_backfill_equals_stacked_solves(self):
        reqs = self._mixed_requests()
        eng = ContinuousBatchingEngine(decay_dynamics, None,
                                       config=small_config(),
                                       timer=make_timer())
        eng.submit(reqs)
        report = eng.run()
        assert report.n_requests == len(reqs)
        assert report.n_completed == len(reqs)
        for req in reqs:
            ref = _solve_reference(req)
            got = eng.results[req.rid]["y"]
            np.testing.assert_allclose(got, np.asarray(ref.ys["y"]),
                                       atol=1e-6, rtol=1e-6)
        # f-eval accounting matches solve()'s Stats exactly
        ref0 = _solve_reference(reqs[0])
        rec0 = next(r for r in eng.records if r.rid == reqs[0].rid)
        assert rec0.n_fevals == int(ref0.stats.n_fevals)
        assert rec0.n_accepted == int(ref0.stats.n_accepted)

    def test_deterministic_under_fake_clock(self):
        def trace(seed_step):
            eng = ContinuousBatchingEngine(decay_dynamics, None,
                                           config=small_config(),
                                           timer=make_timer(seed_step))
            eng.submit(self._mixed_requests())
            eng.run()
            return [(r.arrival, r.completion, r.n_fevals, r.completed)
                    for r in sorted(eng.records, key=lambda r: r.arrival)]

        assert trace(1e-3) == trace(1e-3)

    def test_budget_exhaustion_marks_incomplete(self):
        rng = np.random.default_rng(3)
        req = Request(z0=_z0(rng, lam=50.0),
                      config=RequestConfig(max_steps=3))
        eng = ContinuousBatchingEngine(decay_dynamics, None,
                                       config=small_config(),
                                       timer=make_timer())
        eng.submit([req])
        report = eng.run()
        rec = eng.records[0]
        assert not rec.completed and rec.n_fevals == 3 + 1  # trials + v0
        assert report.n_completed == 0
        assert req.rid in eng.results   # truncated end state still returned

    def test_static_fleet_completes_together(self):
        reqs = self._mixed_requests()
        eng = StaticFleetEngine(decay_dynamics, None, config=small_config(),
                                timer=make_timer())
        eng.submit(reqs)
        report = eng.run()
        assert report.n_completed == len(reqs)
        for req in reqs:
            ref = _solve_reference(req)
            np.testing.assert_allclose(eng.results[req.rid]["y"],
                                       np.asarray(ref.ys["y"]),
                                       atol=1e-6, rtol=1e-6)
        # one-shot fleet semantics: batch members share a completion stamp
        stamps = {r.completion for r in eng.records}
        assert len(stamps) <= int(np.ceil(len(reqs)
                                          / eng.config.slots)) + 1

    def test_engine_config_validation(self):
        with pytest.raises(ValueError, match="slots"):
            EngineConfig(slots=0)
        with pytest.raises(ValueError, match="error estimate"):
            from repro.core import Rk4
            EngineConfig(solver=Rk4())

    def test_mismatched_state_shape_rejected(self):
        rng = np.random.default_rng(0)
        eng = ContinuousBatchingEngine(decay_dynamics, None,
                                       config=small_config(),
                                       timer=make_timer())
        eng.submit([Request(z0=_z0(rng, d=4))])
        eng.scheduler.release(0.0)
        eng._backfill()
        with pytest.raises(ValueError, match="structure/shapes"):
            eng._insert(1, Request(z0=_z0(rng, d=8)))


# ---------------------------------------------------------------------------
# Dense lane + interpolant cache through the engine
# ---------------------------------------------------------------------------

class TestDenseLane:
    def test_hot_trajectory_hits_cost_zero_fevals(self):
        rng = np.random.default_rng(5)
        reqs = hot_trajectory_requests(rng, n_repeats=3, d_state=4,
                                       lam=4.0)
        cache = InterpolantCache(LRU(max_entries=8))
        eng = ContinuousBatchingEngine(decay_dynamics, None,
                                       config=small_config(), cache=cache,
                                       vf_id="decay", timer=make_timer())
        eng.submit(reqs)
        report = eng.run()
        assert (cache.hits, cache.misses) == (3, 1)
        assert report.cache_hit_rate == pytest.approx(0.75)
        hit_recs = [r for r in eng.records if r.cache_hit]
        assert len(hit_recs) == 3
        assert all(r.n_fevals == 0 for r in hit_recs)   # the acceptance bar
        miss = next(r for r in eng.records if not r.cache_hit)
        assert miss.n_fevals > 0

    def test_eval_matches_direct_dense_solve(self):
        rng = np.random.default_rng(6)
        req = hot_trajectory_requests(rng, n_repeats=0, d_state=4,
                                      lam=4.0)[0]
        eng = ContinuousBatchingEngine(decay_dynamics, None,
                                       config=small_config(),
                                       timer=make_timer())
        eng.submit([req])
        eng.run()
        cfg = req.config
        ref = solve(decay_dynamics, None,
                    {k: jnp.asarray(v) for k, v in req.z0.items()},
                    cfg.t0, cfg.t1, solver=ALF(eta=0.9),
                    controller=AdaptiveController(cfg.rtol, cfg.atol,
                                                  cfg.max_steps),
                    saveat=SaveAt(dense=True))
        want = ref.evaluate(jnp.asarray(req.eval_ts))
        np.testing.assert_allclose(eng.results[req.rid]["y"],
                                   np.asarray(want["y"]),
                                   atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# Load generation + metrics
# ---------------------------------------------------------------------------

class TestLoadgenMetrics:
    def test_poisson_arrivals(self):
        rng = np.random.default_rng(0)
        ts = poisson_arrivals(rng, rate=100.0, n=500)
        assert len(ts) == 500 and np.all(np.diff(ts) > 0)
        assert np.mean(np.diff(ts)) == pytest.approx(0.01, rel=0.2)
        with pytest.raises(ValueError, match="rate"):
            poisson_arrivals(rng, rate=0.0, n=1)

    def test_percentile(self):
        xs = [1.0, 2.0, 3.0, 4.0]
        assert percentile(xs, 50.0) == pytest.approx(2.5)
        assert percentile(xs, 100.0) == 4.0
        assert np.isnan(percentile([], 50.0))
        with pytest.raises(ValueError):
            percentile(xs, 101.0)


# ---------------------------------------------------------------------------
# Static-analysis contracts on the serve layer
# ---------------------------------------------------------------------------

class TestServeAnalysisContracts:
    def test_policies_implement_full_interface(self):
        from repro.analysis.rules.r004_registry import missing_interface
        for cls, base in [(AdmitAll, AdmissionPolicy),
                          (BoundedQueue, AdmissionPolicy),
                          (FIFO, SchedulingPolicy),
                          (ShortestSpanFirst, SchedulingPolicy),
                          (LRU, CachePolicy), (NoCache, CachePolicy)]:
            assert missing_interface(cls, base) == []

        class Incomplete(AdmissionPolicy):
            name = "incomplete"

        assert missing_interface(Incomplete, AdmissionPolicy) == ["admit"]

    def test_serve_trace_audit_clean(self):
        # Device-free: chunk_transition is spec-preserving and one trace
        # serves every round across fresh equal-valued configs.
        from repro.analysis.trace_audit import run_serve_audit
        combos, failures, retrace = run_serve_audit()
        assert combos >= 5
        assert failures == []
        assert all(n == 1 for n in retrace.values()), retrace


# ---------------------------------------------------------------------------
# CLI smoke: launch/serve.py --mode ode through the new engine
# ---------------------------------------------------------------------------

class TestServeCLI:
    def test_mode_default_batch_single_source(self):
        from repro.launch.serve import MODE_DEFAULT_BATCH
        assert MODE_DEFAULT_BATCH == {"lm": 4, "ode": 64}

    def test_ode_mode_smoke(self, monkeypatch, capsys):
        from repro.launch import serve as serve_mod
        monkeypatch.setattr("sys.argv", [
            "serve", "--mode", "ode", "--batch", "2", "--requests", "5",
            "--d-state", "4", "--chunk-steps", "8", "--rate", "500",
            "--seed", "3", "--t1", "0.5", "--rtol", "1e-3", "--atol",
            "1e-4", "--max-steps", "128"])
        serve_mod.main()
        out = capsys.readouterr().out
        # run header prints the resolved batch + forwarded CLI knobs
        assert "batch(slots)=2" in out
        assert "t1=0.5" in out and "seed=3" in out
        assert "engine=continuous" in out
        assert "serve[continuous]" in out      # the ServeReport
        assert "5 completed" in out

    def test_ode_mode_static_engine(self, monkeypatch, capsys):
        from repro.launch import serve as serve_mod
        monkeypatch.setattr("sys.argv", [
            "serve", "--mode", "ode", "--ode-engine", "static", "--batch",
            "2", "--requests", "4", "--d-state", "4", "--chunk-steps",
            "8", "--max-steps", "128"])
        serve_mod.main()
        out = capsys.readouterr().out
        assert "engine=static" in out and "serve[static]" in out
