"""Per-architecture smoke tests (assignment requirement) + train/serve
consistency: every assigned arch at reduced config runs one forward/train
step on CPU with finite loss and correct shapes, with the paper's technique
(continuous depth + MALI) both on and off."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, DEFAULT_ODE, smoke_config
from repro.core.ode_block import OdeSettings
from repro.launch.steps import make_decode_step, make_prefill_step, \
    make_train_step
from repro.models import init_lm
from repro.models.lm import backbone_train, _head_matrix, init_serve_state
from repro.optim.optimizer import OptimizerConfig, init_opt_state

ALL_ARCHS = sorted(ARCHS)
B, S = 2, 16


def _batch(cfg, key=0):
    rng = np.random.default_rng(key)
    if cfg.input_mode == "embeds":
        x = {"embeds": jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)).astype(np.float32))}
    else:
        x = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))}
    x["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))
    return x


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step_ode(arch):
    """Reduced config, continuous-depth (paper technique) train step."""
    cfg = smoke_config(arch, DEFAULT_ODE)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptimizerConfig(warmup_steps=2, total_steps=10)
    opt = init_opt_state(opt_cfg, params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    p2, o2, metrics = step(params, opt, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step_discrete(arch):
    """Same reduced config with ode.mode=off (the ResNet-analogue baseline)."""
    cfg = smoke_config(arch, OdeSettings(mode="off"))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptimizerConfig()
    opt = init_opt_state(opt_cfg, params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    _, _, metrics = step(params, opt, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma2-2b",
                                  "deepseek-moe-16b", "jamba-v0.1-52b",
                                  "xlstm-125m", "granite-20b"])
def test_prefill_decode_matches_train_forward(arch):
    """Teacher-forced decode after prefill must reproduce the training
    forward's next-token logits (KV-cache correctness, incl. the ODE
    virtual-layer cache)."""
    cfg = smoke_config(arch, DEFAULT_ODE)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    s_pre, n_dec = S - 4, 4

    # full training-mode forward logits at each position
    h, _ = backbone_train(params, cfg, batch)
    from repro.models.common import rmsnorm as _rn  # noqa
    full_logits = np.asarray(
        (jnp.einsum("bsd,dv->bsv",
                    _final_h(params, cfg, batch), _head_matrix(params, cfg))
         ).astype(jnp.float32))

    # prefill on the first s_pre tokens, then decode the rest one-by-one
    state = init_serve_state(cfg, B, S)
    pre_batch = {k: v[:, :s_pre] for k, v in batch.items() if k != "labels"}
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    logits, state = prefill(params, pre_batch, state)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               full_logits[:, s_pre - 1], rtol=2e-3,
                               atol=2e-3)
    for i in range(n_dec):
        pos = s_pre + i
        if cfg.input_mode == "embeds":
            tok = batch["embeds"][:, pos:pos + 1]
        else:
            tok = batch["tokens"][:, pos:pos + 1]
        logits, state = decode(params, tok, state)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   full_logits[:, pos], rtol=2e-3, atol=2e-3)


def _final_h(params, cfg, batch):
    from repro.models.common import rmsnorm
    from repro.models.transformer import blocks_train
    from repro.models.lm import _embed
    x = _embed(params, cfg, batch)
    x, _ = blocks_train(params["blocks"], cfg, x, None)
    return rmsnorm(params["final_norm"], x)


def test_gemma2_softcap_active():
    cfg = smoke_config("gemma2-2b")
    assert cfg.attn_softcap > 0 and cfg.final_softcap > 0
    assert cfg.sliding_window > 0
    kinds = [l.attn_kind for l in cfg.layers()]
    assert "local" in kinds and "global" in kinds


def test_full_configs_match_assignment():
    """Exact spec table from the assignment."""
    from repro.configs import get_config
    spec = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    }
    for name, (L, d, H, kv, dff, vocab) in spec.items():
        cfg = get_config(name)
        assert cfg.n_layers == L, name
        assert cfg.d_model == d, name
        assert cfg.n_heads == H, name
        assert cfg.n_kv_heads == kv, name
        assert cfg.vocab_size == vocab, name
        if name == "deepseek-moe-16b":
            assert cfg.moe_d_ff == dff and cfg.moe_experts == 64 \
                and cfg.moe_top_k == 6 and cfg.moe_shared_experts == 2
        elif name == "grok-1-314b":
            assert cfg.d_ff == dff and cfg.moe_experts == 8 \
                and cfg.moe_top_k == 2
        elif name == "jamba-v0.1-52b":
            assert cfg.d_ff == dff and cfg.moe_experts == 16 \
                and cfg.moe_top_k == 2
        elif name == "xlstm-125m":
            assert cfg.d_ff == 0
        else:
            assert cfg.d_ff == dff, name


def test_jamba_interleave_pattern():
    cfg = smoke_config("jamba-v0.1-52b")
    mixers = [l.mixer for l in cfg.layers()]
    assert "mamba" in mixers and "attn" in mixers
    assert cfg.subquadratic


def test_xlstm_blocks():
    cfg = smoke_config("xlstm-125m")
    mixers = {l.mixer for l in cfg.layers()}
    assert mixers <= {"mlstm", "slstm"}
    assert cfg.subquadratic


def test_stub_frontends_use_embeds():
    for name in ("musicgen-large", "internvl2-76b"):
        from repro.configs import get_config
        cfg = get_config(name)
        assert cfg.input_mode == ("embeds" if name == "internvl2-76b"
                                  else "tokens") or cfg.input_mode in (
            "tokens", "embeds")


def test_ode_settings_change_compute_not_params():
    """Continuous depth must not change parameter count (paper §4.2: same
    f shared between residual and ODE forms)."""
    cfg_d = smoke_config("qwen3-1.7b", OdeSettings(mode="off"))
    cfg_o = smoke_config("qwen3-1.7b", DEFAULT_ODE)
    p_d = init_lm(jax.random.PRNGKey(0), cfg_d)
    p_o = init_lm(jax.random.PRNGKey(0), cfg_o)
    n_d = sum(l.size for l in jax.tree_util.tree_leaves(p_d))
    n_o = sum(l.size for l in jax.tree_util.tree_leaves(p_o))
    assert n_d == n_o
