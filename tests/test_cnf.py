"""repro.cnf: trace estimators, flow densities, losses.

Contracts under test:

* estimator algebra — :class:`Exact` recovers the true Jacobian trace;
  :class:`Hutchinson` is exact for linear fields with Rademacher probes
  (``eps^T A eps = tr(A) + sum_{i!=j} A_ij eps_i eps_j`` and sign probes
  square to one), unbiased in expectation for the ``hutchinson_gaussian``
  registry entry, and refuses to run without a probe key;
* fixed-noise-per-solve — the probe rides in the solve carry, so the same
  key gives a BIT-EQUAL logdet under adaptive stepping (accept/reject
  re-evaluations see the same noise) and different keys differ;
* analytic density — for the linear field ``f = a*z`` the flow is
  ``z(t1) = x e^{a t1}`` with ``logdet = d*a*t1``, so ``log_prob`` is
  checkable in closed form, for every gradient method;
* sampling is the reverse-time solve of the same augmented dynamics
  (round-trips through ``log_prob``), and the losses implement the
  standard bits/dim bookkeeping.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cnf import (CNF, Exact, Hutchinson, TRACE_ESTIMATORS,
                       bits_per_dim, cnf_loss, get_estimator, nll_nats)
from repro.core import (ACA, ALF, AdaptiveController, Backsolve,
                        ConstantSteps, HeunEuler, Dopri5, MALI, Naive,
                        PerSample, SaveAt)
from repro.models import init_mlp_vfield, mlp_vfield

jax.config.update("jax_platform_name", "cpu")

D = 4
KEY = jax.random.PRNGKey(0)

CONFIGS = {
    "mali": (MALI(), ALF()),
    "naive": (Naive(), ALF()),
    "aca": (ACA(), HeunEuler()),
    "adjoint": (Backsolve(), Dopri5()),
}


def _linear_field(params, z, t):
    return params["a"] * z


def _mlp_params(scale=0.3):
    # init_mlp_vfield zero-inits the output layer (identity flow), so
    # perturb every leaf to get a nontrivial Jacobian trace
    fp = init_mlp_vfield(jax.random.PRNGKey(3), D, hidden=16)
    return jax.tree_util.tree_map(
        lambda a: a + scale * jax.random.normal(jax.random.PRNGKey(9),
                                                a.shape), fp)


# ---------------------------------------------------------------------------
# Estimators
# ---------------------------------------------------------------------------

def test_exact_trace_recovers_jacobian_trace():
    a = jax.random.normal(jax.random.PRNGKey(1), (D, D))

    def f(z):
        return z @ a.T

    z = jax.random.normal(jax.random.PRNGKey(2), (D,))
    fz, tr = Exact().value_and_trace(f, z, None)
    np.testing.assert_allclose(np.asarray(fz), np.asarray(f(z)), rtol=1e-6)
    np.testing.assert_allclose(float(tr), float(jnp.trace(a)), rtol=1e-5)


def test_hutchinson_rademacher_exact_on_diagonal_field():
    # sign probes square to one: eps^T diag(d) eps == tr for ANY eps
    diag = jnp.array([0.5, -1.0, 2.0, 0.25])

    def f(z):
        return diag * z

    z = jnp.ones((D,))
    eps = Hutchinson().init_noise(KEY, z)
    _, tr = Hutchinson().value_and_trace(f, z, eps)
    np.testing.assert_allclose(float(tr), float(jnp.sum(diag)), rtol=1e-6)


def test_hutchinson_gaussian_unbiased():
    a = jax.random.normal(jax.random.PRNGKey(4), (D, D))

    def f(z):
        return z @ a.T

    est = get_estimator("hutchinson_gaussian")
    z = jnp.zeros((D,))
    keys = jax.random.split(KEY, 4096)
    trs = jax.vmap(
        lambda k: est.value_and_trace(f, z, est.init_noise(k, z))[1])(keys)
    np.testing.assert_allclose(float(trs.mean()), float(jnp.trace(a)),
                               atol=0.25)


def test_hutchinson_requires_key():
    with pytest.raises(ValueError, match="probe per solve"):
        Hutchinson().init_noise(None, jnp.zeros((D,)))
    with pytest.raises(ValueError, match="rademacher"):
        Hutchinson(dist="sobol")


def test_estimator_registry():
    assert set(TRACE_ESTIMATORS) == {"exact", "hutchinson",
                                     "hutchinson_gaussian"}
    assert isinstance(get_estimator("exact"), Exact)
    assert get_estimator("hutchinson_gaussian").dist == "gaussian"
    est = Hutchinson()
    assert get_estimator(est) is est
    with pytest.raises(ValueError, match="unknown trace estimator"):
        get_estimator("cholesky")
    # cost accounting: exact pays d f-eval-equivalents, hutchinson one
    assert Exact().trace_fevals(D) == D
    assert Hutchinson().trace_fevals(D) == 1


# ---------------------------------------------------------------------------
# Flow densities
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", sorted(CONFIGS))
def test_log_prob_matches_analytic_linear_flow(method):
    gradient, solver = CONFIGS[method]
    a = 0.4
    flow = CNF(_linear_field, D, estimator=Exact())
    x = jax.random.normal(jax.random.PRNGKey(5), (6, D))
    r = flow.log_prob({"a": jnp.float32(a)}, x, solver=solver,
                      controller=ConstantSteps(64), gradient=gradient)
    z_t1 = x * math.exp(a)
    want_logdet = D * a
    want_logp = (-0.5 * np.sum(np.asarray(z_t1) ** 2, -1)
                 - 0.5 * D * math.log(2 * math.pi) + want_logdet)
    np.testing.assert_allclose(np.asarray(r.logdet),
                               np.full((6,), want_logdet), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(r.logp), want_logp, rtol=1e-3)


def test_identity_init_logdet_zero():
    # zero-initialized output layer => f == 0 => the flow is the identity
    # and log_prob is exactly the base density
    fp = init_mlp_vfield(jax.random.PRNGKey(3), D, hidden=16)
    flow = CNF(mlp_vfield, D, estimator=Exact())
    x = jax.random.normal(jax.random.PRNGKey(6), (5, D))
    r = flow.log_prob(fp, x, controller=ConstantSteps(4))
    np.testing.assert_allclose(np.asarray(r.logdet), 0.0, atol=1e-6)


def test_fixed_noise_same_key_bit_equal_under_adaptive():
    fp = _mlp_params()
    flow = CNF(mlp_vfield, D, estimator=Hutchinson())
    x = jax.random.normal(jax.random.PRNGKey(7), (8, D))
    r1 = flow.log_prob(fp, x, KEY, controller=AdaptiveController())
    r2 = flow.log_prob(fp, x, KEY, controller=AdaptiveController())
    # bit-equal, not allclose: the probe lives in the solve carry, so the
    # estimate is a pure function of (params, x, key) under ANY schedule
    assert jnp.array_equal(r1.logdet, r2.logdet)
    assert jnp.array_equal(r1.logp, r2.logp)
    r3 = flow.log_prob(fp, x, jax.random.PRNGKey(77),
                       controller=AdaptiveController())
    assert bool(jnp.any(r1.logdet != r3.logdet))


def test_hutchinson_mean_approaches_exact():
    fp = _mlp_params()
    x = jax.random.normal(jax.random.PRNGKey(8), (4, D))
    exact = CNF(mlp_vfield, D, estimator=Exact()).log_prob(
        fp, x, controller=ConstantSteps(8)).logdet
    hflow = CNF(mlp_vfield, D, estimator=Hutchinson())
    keys = jax.random.split(KEY, 64)
    hs = jnp.stack([
        hflow.log_prob(fp, x, k, controller=ConstantSteps(8)).logdet
        for k in keys])
    bias = float(jnp.abs(hs.mean(0) - exact).mean())
    spread = float(hs.std(0).mean())
    assert bias < 3.0 * spread / math.sqrt(64) + 5e-2, (bias, spread)


def test_per_sample_batching_and_string_estimator():
    fp = _mlp_params()
    flow = CNF(mlp_vfield, D, estimator="hutchinson")
    x = jax.random.normal(jax.random.PRNGKey(10), (6, D))
    r = flow.log_prob(fp, x, KEY, batching=PerSample(),
                      controller=AdaptiveController())
    assert r.logp.shape == (6,)
    assert np.all(np.isfinite(np.asarray(r.logp)))


def test_diff_bounds_through_log_prob():
    fp = _mlp_params()
    flow = CNF(mlp_vfield, D, estimator=Hutchinson())
    x = jax.random.normal(jax.random.PRNGKey(11), (4, D))

    def loss(t1):
        r = flow.log_prob(fp, x, KEY, controller=ConstantSteps(8), t1=t1,
                          diff_bounds=True)
        return nll_nats(r)

    g = jax.grad(loss)(jnp.float32(1.0))
    assert np.isfinite(float(g)) and float(g) != 0.0


# ---------------------------------------------------------------------------
# Sampling & losses
# ---------------------------------------------------------------------------

def test_sample_shapes_and_flow_path():
    fp = _mlp_params()
    flow = CNF(mlp_vfield, D, estimator=Hutchinson())
    sol = flow.sample(fp, KEY, 5, controller=ConstantSteps(4))
    assert sol.ys[0].shape == (5, D)
    path = flow.sample(fp, KEY, 5, controller=ConstantSteps(2),
                       saveat=SaveAt(ts=jnp.linspace(1.0, 0.0, 3)))
    assert path.ys[0].shape == (3, 5, D)


def test_sample_log_prob_round_trip():
    fp = _mlp_params(scale=0.1)
    flow = CNF(mlp_vfield, D, estimator=Exact())
    xs = flow.sample(fp, KEY, 16, controller=ConstantSteps(16)).ys[0]
    r = flow.log_prob(fp, xs, controller=ConstantSteps(16))
    assert np.all(np.isfinite(np.asarray(r.logp)))
    # samples from the model should not be wildly improbable under it
    assert float(r.logp.mean()) > -10.0 * D


def test_losses_bookkeeping():
    fp = _mlp_params()
    flow = CNF(mlp_vfield, D, estimator=Exact())
    x = jax.random.normal(jax.random.PRNGKey(12), (8, D))
    r = flow.log_prob(fp, x, controller=ConstantSteps(4))
    nll = float(nll_nats(r))
    np.testing.assert_allclose(nll, -float(r.logp.mean()), rtol=1e-6)
    np.testing.assert_allclose(
        float(bits_per_dim(r, D, n_bins=256)),
        nll / (D * math.log(2.0)) + math.log2(256.0), rtol=1e-6)
    assert float(cnf_loss(r, kinetic_reg=0.0)) == pytest.approx(nll)
    assert float(cnf_loss(r, kinetic_reg=0.5)) > float(
        cnf_loss(r, kinetic_reg=0.0))
    assert float(r.kinetic.min()) >= 0.0
