"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dep: property tests are skipped without hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.alf import alf_inverse, alf_step  # noqa: E402
from repro.core.integrate import fixed_grid_times  # noqa: E402
from repro.models.lm import chunked_ce_loss  # noqa: E402
from repro.optim.compression import (compress_grads, dequantize_int8,  # noqa: E402
                                     EFState, quantize_int8)
from repro.optim.optimizer import clip_by_global_norm, global_norm  # noqa: E402

_SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def _state_and_step(draw):
    n = draw(st.integers(2, 32))
    seed = draw(st.integers(0, 2 ** 16))
    h = draw(st.floats(0.01, 0.8))
    eta = draw(st.sampled_from([1.0, 0.9, 0.75, 0.3]))
    return n, seed, h, eta


@given(_state_and_step())
@settings(**_SETTINGS)
def test_alf_step_bijective(args):
    """psi_h is a bijection: inverse(step(x)) == x for any state, any h,
    any valid eta, any (deterministic) dynamics."""
    n, seed, h, eta = args
    rng = np.random.default_rng(seed)
    A = jnp.asarray(0.5 * rng.standard_normal((n, n)), jnp.float32)

    def f(params, z, t):
        return jnp.tanh(params @ z) + 0.1 * t * z

    z = jnp.asarray(rng.standard_normal(n), jnp.float32)
    v = f(A, z, jnp.float32(0.0))
    h = jnp.float32(h)
    z1, v1 = alf_step(f, A, z, v, jnp.float32(0.0), h, eta)
    z0, v0 = alf_inverse(f, A, z1, v1, h, h, eta)
    np.testing.assert_allclose(np.asarray(z0), np.asarray(z),
                               rtol=5e-4, atol=5e-5)
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v),
                               rtol=5e-4, atol=5e-5)


@given(st.floats(-10, 10), st.floats(0.05, 2.0), st.integers(1, 64))
@settings(**_SETTINGS)
def test_fixed_grid_covers_interval(t0, span, n):
    ts, h = fixed_grid_times(jnp.float32(t0), jnp.float32(t0 + span), n)
    assert ts.shape == (n,)
    np.testing.assert_allclose(float(ts[0]), t0, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(ts[-1] + h), t0 + span,
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2 ** 16), st.integers(1, 4096))
@settings(**_SETTINGS)
def test_int8_quantization_error_bound(seed, n):
    """|x - deq(q(x))| <= scale/2 elementwise (round-to-nearest)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n) * 10 ** rng.uniform(-3, 3),
                    jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


@given(st.integers(0, 2 ** 16))
@settings(**_SETTINGS)
def test_error_feedback_identity(seed):
    """EF invariant: deq + new_error == grads + old_error exactly."""
    rng = np.random.default_rng(seed)
    g = {"a": jnp.asarray(rng.standard_normal(64), jnp.float32),
         "b": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)}
    ef = EFState({"a": jnp.asarray(rng.standard_normal(64) * 0.1,
                                   jnp.float32),
                  "b": jnp.zeros((4, 8), jnp.float32)})
    deq, ef2 = compress_grads(g, ef)
    for k in g:
        lhs = np.asarray(deq[k]) + np.asarray(ef2.error[k])
        rhs = np.asarray(g[k]) + np.asarray(ef.error[k])
        np.testing.assert_allclose(lhs, rhs, rtol=1e-6, atol=1e-6)


@given(st.integers(0, 2 ** 16), st.floats(0.1, 10.0))
@settings(**_SETTINGS)
def test_clip_by_global_norm_properties(seed, max_norm):
    rng = np.random.default_rng(seed)
    g = {"a": jnp.asarray(rng.standard_normal(32) * 5, jnp.float32)}
    clipped, norm = clip_by_global_norm(g, max_norm)
    out_norm = float(global_norm(clipped))
    assert out_norm <= max_norm * (1 + 1e-4)
    if float(norm) <= max_norm:  # no-op case: unchanged
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(g["a"]), rtol=1e-6)
    else:  # direction preserved
        cos = np.dot(np.asarray(clipped["a"]), np.asarray(g["a"])) / (
            out_norm * float(norm))
        np.testing.assert_allclose(cos, 1.0, rtol=1e-4)


@given(st.integers(1, 4), st.integers(2, 40), st.integers(3, 50),
       st.integers(0, 2 ** 16))
@settings(**_SETTINGS)
def test_chunked_ce_matches_dense_ce(b, s, vocab, seed):
    """The chunked-scan CE (never materializes [B,S,V]) must equal the dense
    softmax cross-entropy for any shape, including non-divisible chunks."""
    from repro.configs import smoke_config
    cfg = smoke_config("qwen3-1.7b")
    rng = np.random.default_rng(seed)
    d = 16
    h = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    head = jnp.asarray(rng.standard_normal((d, vocab)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, vocab, (b, s)), jnp.int32)
    got = chunked_ce_loss(h, head, labels, cfg, chunk=7)
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    logp = jax.nn.log_softmax(logits, axis=-1)
    want = -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4)


@given(st.integers(0, 2 ** 16), st.integers(1, 8))
@settings(**_SETTINGS)
def test_data_pipeline_determinism_and_disjointness(seed, n_shards):
    """Any host can regenerate any shard of any step (elasticity invariant);
    shards of the same step are pairwise different."""
    from repro.configs import smoke_config
    from repro.data.synthetic import DataConfig, make_batch
    cfg = smoke_config("qwen3-1.7b")
    dcfg = DataConfig(seed=seed, global_batch=8 * n_shards, seq_len=16)
    a = make_batch(cfg, dcfg, step=3, shard=0, n_shards=n_shards)
    b = make_batch(cfg, dcfg, step=3, shard=0, n_shards=n_shards)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    if n_shards > 1:
        c = make_batch(cfg, dcfg, step=3, shard=1, n_shards=n_shards)
        assert not np.array_equal(a["tokens"], c["tokens"])
    d = make_batch(cfg, dcfg, step=4, shard=0, n_shards=n_shards)
    assert not np.array_equal(a["tokens"], d["tokens"])
    # labels are the next-token shift of the same stream
    full = make_batch(cfg, dcfg, step=3, shard=0, n_shards=n_shards)
    np.testing.assert_array_equal(full["tokens"][:, 1:],
                                  full["labels"][:, :-1])
