"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp ref.py oracle for every kernel in src/repro/kernels/."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.alf_step import ops as alf_ops
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rmsnorm import ops as rn_ops
from repro.kernels.rmsnorm import ref as rn_ref

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# alf_step: fused elementwise ALF state updates (pytree-generic)
# ---------------------------------------------------------------------------

ALF_STATES = [
    {"z": (128,)},
    {"z": (3, 200)},                      # non-lane-aligned => pad path
    {"z": (2, 64, 64), "w": (257,)},      # multi-leaf pytree
]


@pytest.mark.parametrize("shapes", ALF_STATES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("eta", [1.0, 0.8])
def test_alf_kernels_vs_ref(shapes, dtype, eta):
    keys = jax.random.split(jax.random.PRNGKey(0), 3 * len(shapes))
    mk = lambda i: {k: _rand(keys[i * len(shapes) + j], s, dtype)
                    for j, (k, s) in enumerate(shapes.items())}
    z, v, u = mk(0), mk(1), mk(2)
    h = jnp.float32(0.23)

    for sign in (1.0, -1.0):
        got = alf_ops.alf_midpoint(z, v, h, sign=sign, use_pallas=True)
        want = alf_ops.alf_midpoint(z, v, h, sign=sign, use_pallas=False)
        for g, w in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(w, np.float32),
                                       **_tol(dtype))

    zo_p, vo_p = alf_ops.alf_update(z, v, u, h, eta=eta, use_pallas=True)
    zo_r, vo_r = alf_ops.alf_update(z, v, u, h, eta=eta, use_pallas=False)
    for g, w in zip(jax.tree_util.tree_leaves((zo_p, vo_p)),
                    jax.tree_util.tree_leaves((zo_r, vo_r))):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), **_tol(dtype))

    zi_p, vi_p = alf_ops.alf_inverse_update(z, vo_p, u, h, eta=eta,
                                            use_pallas=True)
    zi_r, vi_r = alf_ops.alf_inverse_update(z, vo_r, u, h, eta=eta,
                                            use_pallas=False)
    for g, w in zip(jax.tree_util.tree_leaves((zi_p, vi_p)),
                    jax.tree_util.tree_leaves((zi_r, vi_r))):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), **_tol(dtype))


def test_alf_kernel_update_inverse_roundtrip():
    """Pallas update followed by Pallas inverse recovers v exactly."""
    z = {"s": jnp.linspace(-1, 1, 384, dtype=jnp.float32)}
    v = {"s": jnp.cos(jnp.linspace(0, 3, 384, dtype=jnp.float32))}
    u = {"s": jnp.sin(jnp.linspace(0, 5, 384, dtype=jnp.float32))}
    h = jnp.float32(0.11)
    zo, vo = alf_ops.alf_update(z, v, u, h, use_pallas=True)
    # inverse tail consumes (k1=z, v_out, u1) and must return v_in = v
    _, vi = alf_ops.alf_inverse_update(z, vo, u, h, use_pallas=True)
    np.testing.assert_allclose(np.asarray(vi["s"]), np.asarray(v["s"]),
                               rtol=1e-6, atol=1e-6)


def test_alf_solver_pallas_backend_parity():
    """ALF(backend='pallas') dispatches the fused midpoint/update kernels
    from inside the solver hierarchy; one trial step must match the
    reference alf_step bit-for-bit math (same f32 algebra, fused launch)."""
    from repro.core.alf import alf_step, alf_step_with_error
    from repro.core.solvers import ALF
    from repro.core.stepsize import AdaptiveController

    def f(params, z, t):
        return {"s": jnp.tanh(params * z["s"]) - 0.2 * z["s"] * t}

    params = jnp.float32(0.7)
    z = {"s": jnp.linspace(-1.0, 1.0, 300, dtype=jnp.float32)}
    v = f(params, z, jnp.float32(0.0))
    t, h = jnp.float32(0.1), jnp.float32(0.23)

    for eta in (1.0, 0.8):
        z_ref, v_ref = alf_step(f, params, z, v, t, h, eta)
        zr, vr, er = alf_step_with_error(f, params, z, v, t, h, eta)
        zp, vp, ep = alf_step_with_error(f, params, z, v, t, h, eta,
                                         backend="pallas")
        # with-error vs plain reference step: identical update
        np.testing.assert_array_equal(np.asarray(zr["s"]),
                                      np.asarray(z_ref["s"]))
        np.testing.assert_array_equal(np.asarray(vr["s"]),
                                      np.asarray(v_ref["s"]))
        for a, b in ((zr, zp), (vr, vp), (er, ep)):
            np.testing.assert_allclose(np.asarray(a["s"]), np.asarray(b["s"]),
                                       rtol=1e-6, atol=1e-6)

    # and through the full solver interface under a controller
    ctrl = AdaptiveController(1e-3, 1e-4, 16)
    for backend in ("reference", "pallas"):
        trial = ALF(eta=0.8, backend=backend).trial_fn(f, params, ctrl)
        out, ratio = trial((z, v), t, h)
        if backend == "reference":
            ref_out, ref_ratio = out, ratio
    np.testing.assert_allclose(np.asarray(out[0]["s"]),
                               np.asarray(ref_out[0]["s"]), rtol=1e-6)
    np.testing.assert_allclose(float(ratio), float(ref_ratio), rtol=1e-6)


@pytest.mark.parametrize("shapes", ALF_STATES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("eta", [1.0, 0.8])
def test_alf_backward_kernels_vs_ref(shapes, dtype, eta):
    """The MALI-backward ops (alf_inverse, alf_bwd_pre, alf_bwd_post):
    Pallas vs jnp-oracle parity over the same state sweep as the forward."""
    keys = jax.random.split(jax.random.PRNGKey(21), 6 * len(shapes))
    mk = lambda i: {k: _rand(keys[i * len(shapes) + j], s, dtype)
                    for j, (k, s) in enumerate(shapes.items())}
    z, v, u, a_z, a_v, dk1 = (mk(i) for i in range(6))
    h = jnp.float32(0.23)

    def check(got, want):
        for g, w in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(want)):
            assert g.dtype == w.dtype
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(w, np.float32),
                                       **_tol(dtype))

    check(alf_ops.alf_inverse(z, v, u, h, eta=eta, use_pallas=True),
          alf_ops.alf_inverse(z, v, u, h, eta=eta, use_pallas=False))
    check(alf_ops.alf_bwd_pre(z, v, a_z, a_v, h, eta=eta, use_pallas=True),
          alf_ops.alf_bwd_pre(z, v, a_z, a_v, h, eta=eta, use_pallas=False))
    check(alf_ops.alf_bwd_post(z, v, u, a_z, a_v, dk1, h, eta=eta,
                               use_pallas=True),
          alf_ops.alf_bwd_post(z, v, u, a_z, a_v, dk1, h, eta=eta,
                               use_pallas=False))


def test_alf_kernel_step_inverse_roundtrip():
    """Pallas step followed by the ONE-PASS Pallas psi^-1 (alf_inverse,
    which re-derives k1 internally) recovers (z, v) to float rounding."""
    z = {"s": jnp.linspace(-1, 1, 384, dtype=jnp.float32)}
    v = {"s": jnp.cos(jnp.linspace(0, 3, 384, dtype=jnp.float32))}
    u = {"s": jnp.sin(jnp.linspace(0, 5, 384, dtype=jnp.float32))}
    h = jnp.float32(0.11)
    for eta in (1.0, 0.8):
        k1 = alf_ops.alf_midpoint(z, v, h, use_pallas=True)
        zo, vo = alf_ops.alf_update(k1, v, u, h, eta=eta, use_pallas=True)
        # the true inverse re-evaluates f at k1; feeding the forward's u1
        # makes the algebraic roundtrip exact
        zi, vi = alf_ops.alf_inverse(zo, vo, u, h, eta=eta, use_pallas=True)
        np.testing.assert_allclose(np.asarray(zi["s"]), np.asarray(z["s"]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(vi["s"]), np.asarray(v["s"]),
                                   rtol=1e-6, atol=1e-6)


def test_alf_forward_ops_custom_vjp_vs_jnp():
    """jax.grad through the Pallas alf_midpoint + alf_update launches (the
    closed-form custom_vjp rules, themselves fused kernels) vs the plain
    jnp formula — including the h cotangent, which adaptive controllers
    feed back into states/params."""
    eta = 0.9
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    z = {"a": _rand(keys[0], (3, 200), jnp.float32)}
    v = {"a": _rand(keys[1], (3, 200), jnp.float32)}
    u = {"a": _rand(keys[2], (3, 200), jnp.float32)}
    h = jnp.float32(0.17)

    def loss_pallas(z, v, u, h):
        k1 = alf_ops.alf_midpoint(z, v, h, use_pallas=True)
        zo, vo = alf_ops.alf_update(k1, v, u, h, eta=eta, use_pallas=True)
        return jnp.sum(zo["a"] ** 2) + jnp.sum(jnp.sin(vo["a"]))

    def loss_jnp(z, v, u, h):
        k1 = {"a": z["a"] + v["a"] * (h / 2)}
        vo = {"a": v["a"] + 2.0 * eta * (u["a"] - v["a"])}
        zo = {"a": k1["a"] + vo["a"] * (h / 2)}
        return jnp.sum(zo["a"] ** 2) + jnp.sum(jnp.sin(vo["a"]))

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2, 3))(z, v, u, h)
    gj = jax.grad(loss_jnp, argnums=(0, 1, 2, 3))(z, v, u, h)
    for g, w in zip(jax.tree_util.tree_leaves(gp),
                    jax.tree_util.tree_leaves(gj)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


def test_alf_ops_mixed_dtype_tree():
    """A {f32, bf16} mixed tree: one fused launch at the promoted common
    dtype, every output leaf restored to its own input dtype (the old
    _flatten force-cast to f32 silently upcast bf16 leaves)."""
    z = {"big": jnp.ones((2, 128), jnp.float32),
         "small": jnp.full((63,), 0.5, jnp.bfloat16)}
    v = {"big": jnp.full((2, 128), 0.25, jnp.float32),
         "small": jnp.full((63,), -0.5, jnp.bfloat16)}
    h = jnp.float32(0.2)
    for use_pallas in (True, False):
        k1 = alf_ops.alf_midpoint(z, v, h, use_pallas=use_pallas)
        assert k1["big"].dtype == jnp.float32
        assert k1["small"].dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(k1["big"]), 1.025, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(k1["small"], np.float32),
                                   0.45, rtol=2e-2)
        # gradients keep per-leaf dtypes too (cotangent avals == primal)
        g = jax.grad(lambda zz, vv: jnp.sum(
            alf_ops.alf_midpoint(zz, vv, h,
                                 use_pallas=use_pallas)["big"]) +
            jnp.sum(alf_ops.alf_midpoint(
                zz, vv, h, use_pallas=use_pallas)["small"]
                .astype(jnp.float32)), argnums=(0, 1))(z, v)
        assert g[0]["small"].dtype == jnp.bfloat16
        assert g[1]["big"].dtype == jnp.float32


def test_alf_ops_preserve_float64():
    """Under x64, f64 state trees stay f64 through the fused launch (the
    old _flatten force-cast every leaf to f32 and lost the precision)."""
    jax.config.update("jax_enable_x64", True)
    try:
        z = {"s": jnp.linspace(-1, 1, 200, dtype=jnp.float64)}
        v = {"s": jnp.cos(jnp.linspace(0, 3, 200, dtype=jnp.float64))}
        u = {"s": jnp.sin(jnp.linspace(0, 5, 200, dtype=jnp.float64))}
        h = jnp.float64(0.1)
        k1 = alf_ops.alf_midpoint(z, v, h, use_pallas=True)
        zo, vo = alf_ops.alf_update(k1, v, u, h, eta=0.8, use_pallas=True)
        assert zo["s"].dtype == jnp.float64
        assert vo["s"].dtype == jnp.float64
        want = np.asarray(z["s"], np.float64) \
            + np.asarray(v["s"], np.float64) * 0.05
        # f64 parity to ~1e-15: would fail at ~1e-7 under an f32 round-trip
        np.testing.assert_allclose(np.asarray(k1["s"]), want, rtol=1e-14)
    finally:
        jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# flash_attention (Pallas-device only: interpret mode cannot emulate these
# kernels on CPU with current jax — see the requires_pallas_device marker)
# ---------------------------------------------------------------------------

FA_CASES = [
    # (B, Sq, Sk, H, KV, d, causal, window, softcap)
    (1, 128, 128, 4, 4, 64, True, 0, 0.0),      # MHA causal
    (2, 128, 128, 4, 2, 64, True, 0, 0.0),      # GQA 2:1
    (1, 256, 256, 8, 1, 64, True, 0, 0.0),      # MQA (granite kv=1)
    (1, 128, 128, 4, 4, 64, False, 0, 0.0),     # bidirectional
    (1, 256, 256, 4, 2, 64, True, 128, 0.0),    # sliding window (gemma2)
    (1, 128, 128, 4, 2, 64, True, 0, 50.0),     # softcap (gemma2)
    (2, 384, 384, 4, 2, 128, True, 256, 30.0),  # window+softcap, d=128
]


@pytest.mark.requires_pallas_device
@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_attention_vs_ref(case, dtype):
    b, sq, sk, h, kv, d, causal, window, softcap = case
    kq, kk, kvk = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(kq, (b, sq, h, d), dtype)
    k = _rand(kk, (b, sk, kv, d), dtype)
    v = _rand(kvk, (b, sk, kv, d), dtype)
    got = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                 softcap=softcap, use_pallas=True,
                                 interpret=True)
    want = fa_ref.attention_ref(q, k, v, causal=causal, window=window,
                                softcap=softcap)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.requires_pallas_device
def test_flash_attention_rows_sum_to_one_property():
    """Causal row 0 attends only to itself => output == v[0]."""
    b, s, h, d = 1, 64, 2, 32
    q = _rand(jax.random.PRNGKey(0), (b, s, h, d), jnp.float32)
    k = _rand(jax.random.PRNGKey(1), (b, s, h, d), jnp.float32)
    v = _rand(jax.random.PRNGKey(2), (b, s, h, d), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=True, use_pallas=True,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(v[:, 0]),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

RN_SHAPES = [(4, 128), (2, 7, 256), (1, 384), (3, 5, 64)]


@pytest.mark.parametrize("shape", RN_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_rmsnorm_vs_ref(shape, dtype):
    x = _rand(jax.random.PRNGKey(3), shape, dtype)
    scale = 1.0 + 0.1 * _rand(jax.random.PRNGKey(4), shape[-1:], jnp.float32)
    got = rn_ops.rmsnorm(x, scale, use_pallas=True)
    want = rn_ref.rmsnorm_ref(x, scale)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_rmsnorm_unit_output_norm():
    """RMS of output/scale must be ~1 per row."""
    x = 5.0 * _rand(jax.random.PRNGKey(5), (16, 128), jnp.float32)
    out = rn_ops.rmsnorm(x, jnp.ones((128,)), use_pallas=True)
    rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


# ---------------------------------------------------------------------------
# mamba_scan: fused selective scan
# ---------------------------------------------------------------------------

from repro.kernels.mamba_scan import ops as ms_ops  # noqa: E402
from repro.kernels.mamba_scan import ref as ms_ref  # noqa: E402

MS_CASES = [
    # (Bt, S, DI, ST)
    (1, 16, 128, 16),
    (2, 33, 256, 16),     # odd seq
    (1, 8, 200, 8),       # DI padding path
    (2, 64, 512, 4),
]


@pytest.mark.parametrize("case", MS_CASES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_mamba_scan_vs_ref(case, dtype):
    bt, s, di, st = case
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    delta = jax.nn.softplus(_rand(ks[0], (bt, s, di), dtype))
    u = _rand(ks[1], (bt, s, di), dtype)
    A = -jnp.exp(0.3 * jax.random.normal(ks[2], (di, st)))
    B = _rand(ks[3], (bt, s, st), dtype)
    C = _rand(ks[4], (bt, s, st), dtype)
    y_p, h_p = ms_ops.selective_scan(delta, u, A, B, C, use_pallas=True,
                                     interpret=True)
    y_r, h_r = ms_ref.selective_scan_ref(delta, u, A, B, C)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r), **tol)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_r), **tol)


def test_mamba_scan_carries_initial_state():
    bt, s, di, st = 1, 12, 128, 8
    ks = jax.random.split(jax.random.PRNGKey(12), 6)
    delta = jax.nn.softplus(jax.random.normal(ks[0], (bt, s, di)))
    u = jax.random.normal(ks[1], (bt, s, di))
    A = -jnp.exp(0.3 * jax.random.normal(ks[2], (di, st)))
    B = jax.random.normal(ks[3], (bt, s, st))
    C = jax.random.normal(ks[4], (bt, s, st))
    h0 = jax.random.normal(ks[5], (bt, di, st))
    # split scan == full scan (chunked-prefill invariant)
    y_full, h_full = ms_ops.selective_scan(delta, u, A, B, C, h0,
                                           use_pallas=True)
    y1, h1 = ms_ops.selective_scan(delta[:, :6], u[:, :6], A, B[:, :6],
                                   C[:, :6], h0, use_pallas=True)
    y2, h2 = ms_ops.selective_scan(delta[:, 6:], u[:, 6:], A, B[:, 6:],
                                   C[:, 6:], h1, use_pallas=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-5)
