"""Distribution integration: the multi-pod dry-run as a subprocess (so the
512-fake-device XLA flag never leaks into this process), plus HLO-derived
roofline sanity."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO)


@pytest.mark.slow
def test_dryrun_single_and_multi_pod(tmp_path):
    """One representative cell must lower+compile on the 16x16 pod AND the
    2x16x16 multi-pod mesh (proves the 'pod' axis shards)."""
    out = str(tmp_path)
    r = _run_dryrun(["--arch", "qwen3-1.7b", "--shape", "train_4k",
                     "--mesh", "both", "--out", out])
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    recs = [json.loads(l) for l in
            open(os.path.join(out, "summary.jsonl"))]
    assert len(recs) == 2
    for rec in recs:
        assert rec["status"] == "ok", rec
        roof = rec["roofline"]
        assert roof["flops"] > 0
        assert roof["hbm_bytes"] > 0
        assert rec["chips"] in (256, 512)
    multi = [r for r in recs if r["mesh"] == "pod2x16x16"]
    assert len(multi) == 1


@pytest.mark.slow
def test_dryrun_skip_rule(tmp_path):
    """long_500k must be skipped for full-attention archs, run for SSM."""
    out = str(tmp_path)
    r = _run_dryrun(["--arch", "qwen3-1.7b", "--shape", "long_500k",
                     "--mesh", "single", "--out", out])
    assert r.returncode == 0
    rec = json.loads(open(os.path.join(out, "summary.jsonl")).readline())
    assert rec["status"] == "skipped"
    assert "sub-quadratic" in rec["reason"]


def test_roofline_math():
    """Unit check of the three-term model with synthetic inputs: per-chip
    197 TFLOPs of compute / 819 GB of HBM traffic / 50 GB on the wire each
    take exactly 1 second at v5e peaks."""
    from repro.launch.roofline import CollectiveStats, Roofline
    rep = Roofline(flops=197e12, hbm_bytes=819e9,
                   coll=CollectiveStats(wire_bytes_per_chip=50e9),
                   chips=256, model_flops=197e12 * 256)
    d = rep.to_dict()
    assert abs(d["t_compute_s"] - 1.0) < 1e-6
    assert abs(d["t_memory_s"] - 1.0) < 1e-6
    assert abs(d["t_collective_s"] - 1.0) < 1e-6
    assert d["useful_flops_ratio"] == pytest.approx(1.0)


def test_hlo_collective_parser():
    """collective wire-byte parsing from HLO text, incl. the ring-algorithm
    multipliers (AR 2(n-1)/n; AG (n-1)/n)."""
    from repro.launch.roofline import collective_stats
    hlo = """
HloModule m

ENTRY %e (p: f32[1024,256]) -> (f32[1024,512]) {
  %p = f32[1024,256]{1,0} parameter(0)
  %ag = f32[1024,512]{1,0} all-gather(%p), dimensions={1}, replica_groups={{0,1}}
  %ar = f32[1024,512]{1,0} all-reduce(%ag), to_apply=%add, replica_groups={{0,1}}
  ROOT %t = (f32[1024,512]{1,0}) tuple(%ar)
}
"""
    stats = collective_stats(hlo, default_group=2)
    assert stats.op_counts.get("all-gather") == 1
    assert stats.op_counts.get("all-reduce") == 1
    ag_bytes = 1024 * 512 * 4
    assert stats.op_bytes["all-gather"] == pytest.approx(ag_bytes * 0.5)
    assert stats.op_bytes["all-reduce"] == pytest.approx(ag_bytes * 1.0)


def test_loop_trip_multiplication():
    """Collectives inside a while body (scan-over-layers) must be counted
    trip-count times."""
    from repro.launch.roofline import collective_stats
    hlo = """
HloModule m

%body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,64] get-tuple-element(%p), index=1
  %ar = f32[64,64] all-reduce(%x), to_apply=%add, replica_groups={{0,1}}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %r = (s32[], f32[64,64]) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[64,64])) -> pred[] {
  %p = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %e (p0: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p0 = (s32[], f32[64,64]) parameter(0)
  ROOT %w = (s32[], f32[64,64]) while(%p0), condition=%cond, body=%body
}
"""
    stats = collective_stats(hlo, default_group=2)
    assert stats.op_counts.get("all-reduce") == 12
