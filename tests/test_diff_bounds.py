"""Differentiable integration bounds (``solve(..., diff_bounds=True)``).

The contract under test (torchdiffeq/diffrax boundary-term convention):

* ``dL/dt1 = <g_T, f(z_T, t1)>`` — the end-time gradient is the loss
  cotangent at the terminal state contracted with the dynamics there;
* ``dL/dt0 = -<a(t0), f(z0, t0)>`` where ``a(t0)`` is the swept adjoint
  at the start — the TOTAL ``dL/dz0`` minus the identity-row cotangent of
  the observed ``traj[0] == z0`` row (moving ``t0`` does not move the
  observed initial row itself, only everything downstream of it);
* interior observation times get ``dL/dt_k = <g_k, f(z_k, t_k)>``.

All four gradient methods must agree on these *continuous* semantics —
including Naive, whose direct AD through the step loop would otherwise
produce the *discrete* derivative of the step-size arithmetic (that is
why naive.py carries the ``_naive_grid_db`` custom_vjp). The analytic
checks are exact self-consistency (<= 1e-6 rel); the finite-difference
checks pin the convention to the true derivative at truncation-error
tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ACA, ALF, AdaptiveController, Backsolve,
                        ConstantSteps, Dopri5, HeunEuler, MALI, Naive,
                        SaveAt, solve)
from repro.core.interface import Lockstep, Sharded

jax.config.update("jax_platform_name", "cpu")

CONFIGS = {
    "mali": (MALI(), ALF()),
    "naive": (Naive(), ALF()),
    "aca": (ACA(), HeunEuler()),
    "adjoint": (Backsolve(), Dopri5()),
}

CONTROLLERS = {
    "fixed": ConstantSteps(16),
    "adaptive": AdaptiveController(),
}

# both integration directions: reverse spans flip the grid ordering the
# boundary terms must survive sign-agnostically
SPANS = {"forward": (0.0, 1.0), "reverse": (1.0, 0.2)}


def _f(params, z, t):
    # non-autonomous: makes f(z, t0) != f(z, t1), so a sign error in
    # either boundary term cannot cancel
    return params["a"] * z * jnp.cos(t)


PARAMS = {"a": jnp.asarray(0.8)}
Z0 = jnp.array([1.0, -0.5, 0.3])


@pytest.mark.parametrize("direction", sorted(SPANS))
@pytest.mark.parametrize("ctrl_name", sorted(CONTROLLERS))
@pytest.mark.parametrize("method", sorted(CONFIGS))
def test_bound_gradients_match_analytic(method, ctrl_name, direction):
    gradient, solver = CONFIGS[method]
    controller = CONTROLLERS[ctrl_name]
    t0, t1 = SPANS[direction]

    def loss(a, b):
        s = solve(_f, PARAMS, Z0, a, b, solver=solver,
                  controller=controller, gradient=gradient,
                  diff_bounds=True)
        return jnp.sum(s.ys ** 2), s.ys

    (_, z_end), (g_t0, g_t1) = jax.value_and_grad(
        loss, argnums=(0, 1), has_aux=True)(t0, t1)

    # end-state loss => the swept adjoint at t0 IS the total dL/dz0
    # (the observed traj[0] row carries zero cotangent)
    def loss_z0(z):
        s = solve(_f, PARAMS, z, t0, t1, solver=solver,
                  controller=controller, gradient=gradient)
        return jnp.sum(s.ys ** 2)

    g_z0 = jax.grad(loss_z0)(Z0)
    want_t1 = jnp.vdot(2.0 * z_end, _f(PARAMS, z_end, t1))
    want_t0 = -jnp.vdot(g_z0, _f(PARAMS, Z0, t0))
    np.testing.assert_allclose(float(g_t1), float(want_t1), rtol=1e-6)
    np.testing.assert_allclose(float(g_t0), float(want_t0), rtol=1e-6)


@pytest.mark.parametrize("method", sorted(CONFIGS))
def test_bound_gradients_fd_parity(method):
    # the analytic test above is self-consistency; this one pins the
    # convention to the true derivative (central differences over a fine
    # fixed grid — agreement is up to truncation error, hence 1e-2)
    gradient, solver = CONFIGS[method]
    controller = ConstantSteps(64)

    def loss(t0, t1):
        s = solve(_f, PARAMS, Z0, t0, t1, solver=solver,
                  controller=controller, gradient=gradient,
                  diff_bounds=True)
        return float(jnp.sum(s.ys ** 2))

    g_t0, g_t1 = jax.grad(
        lambda a, b: jnp.sum(solve(
            _f, PARAMS, Z0, a, b, solver=solver, controller=controller,
            gradient=gradient, diff_bounds=True).ys ** 2),
        argnums=(0, 1))(0.0, 1.0)
    eps = 1e-3
    fd_t1 = (loss(0.0, 1.0 + eps) - loss(0.0, 1.0 - eps)) / (2 * eps)
    fd_t0 = (loss(eps, 1.0) - loss(-eps, 1.0)) / (2 * eps)
    np.testing.assert_allclose(float(g_t1), fd_t1, rtol=1e-2)
    np.testing.assert_allclose(float(g_t0), fd_t0, rtol=1e-2)


@pytest.mark.parametrize("method", sorted(CONFIGS))
def test_grid_interior_cotangents(method):
    # weighted multi-observation loss: every interior grid row k >= 1 must
    # receive <g_k, f(z_k, t_k)>, and row 0 the swept-adjoint boundary
    # term with the identity row subtracted
    gradient, solver = CONFIGS[method]
    controller = ConstantSteps(8)
    ts = jnp.linspace(0.0, 1.0, 5)
    w = jnp.array([0.3, 1.0, -0.5, 2.0, 0.7])

    def loss_ts(ts_):
        traj, _ = gradient.integrate(_f, PARAMS, Z0, ts_, solver,
                                     controller, True)
        return jnp.sum(w[:, None] * traj ** 2)

    def loss_z0(z):
        traj, _ = gradient.integrate(_f, PARAMS, z, ts, solver,
                                     controller)
        return jnp.sum(w[:, None] * traj ** 2)

    g_ts = jax.grad(loss_ts)(ts)
    traj, _ = gradient.integrate(_f, PARAMS, Z0, ts, solver, controller)
    for k in range(1, 5):
        want = jnp.vdot(2.0 * w[k] * traj[k], _f(PARAMS, traj[k], ts[k]))
        np.testing.assert_allclose(float(g_ts[k]), float(want), rtol=1e-6,
                                   err_msg=f"row {k}")
    a_t0 = jax.grad(loss_z0)(Z0) - 2.0 * w[0] * Z0
    want_0 = -jnp.vdot(a_t0, _f(PARAMS, Z0, ts[0]))
    np.testing.assert_allclose(float(g_ts[0]), float(want_0), rtol=1e-6)


def test_methods_agree_on_bound_gradients():
    # cross-method agreement on the same fixed grid: the four custom_vjps
    # implement one convention, not four
    controller = ConstantSteps(32)
    grads = {}
    for name, (gradient, solver) in CONFIGS.items():
        g = jax.grad(
            lambda a, b, gr=gradient, sv=solver: jnp.sum(solve(
                _f, PARAMS, Z0, a, b, solver=sv, controller=controller,
                gradient=gr, diff_bounds=True).ys ** 2),
            argnums=(0, 1))(0.0, 1.0)
        grads[name] = (float(g[0]), float(g[1]))
    ref = grads["naive"]
    for name, g in grads.items():
        np.testing.assert_allclose(g, ref, rtol=5e-3, err_msg=name)


def test_diff_bounds_off_keeps_zero_cotangents():
    # the default path is unchanged: without the flag, bound gradients
    # stay identically zero (the pre-PR behavior callers may rely on)
    g_t0, g_t1 = jax.grad(
        lambda a, b: jnp.sum(solve(
            _f, PARAMS, Z0, a, b, solver=ALF(),
            controller=ConstantSteps(8), gradient=MALI()).ys ** 2),
        argnums=(0, 1))(0.0, 1.0)
    assert float(g_t0) == 0.0 and float(g_t1) == 0.0


def test_diff_bounds_validation():
    with pytest.raises(ValueError, match="fixed observation grid"):
        solve(_f, PARAMS, Z0, 0.0, 1.0, solver=ALF(),
              controller=ConstantSteps(4), gradient=MALI(),
              saveat=SaveAt(steps=True), diff_bounds=True)
    with pytest.raises(ValueError, match="Sharded"):
        solve(_f, PARAMS, jnp.tile(Z0, (4, 1)), 0.0, 1.0, solver=ALF(),
              controller=ConstantSteps(4), gradient=MALI(),
              batching=Sharded(axis="data", inner=Lockstep()),
              diff_bounds=True)


def test_diff_bounds_observation_grid_through_solve():
    # the public solve() front door with a SaveAt grid still solves with
    # diff_bounds=True (the grid rows' cotangent path is exercised in
    # test_grid_interior_cotangents via integrate directly)
    ts = np.linspace(0.0, 1.0, 4)
    s = solve(_f, PARAMS, Z0, solver=ALF(), controller=ConstantSteps(8),
              gradient=MALI(), saveat=SaveAt(ts=ts), diff_bounds=True)
    assert np.all(np.isfinite(np.asarray(s.ys)))
